"""Flash attention (fwd + custom VJP) vs naive reference."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.common import decode_attention, flash_attention


def naive_attention(q, k, v, causal=True, window=0):
    b, sq, h, d = q.shape
    skv, kv = k.shape[1], k.shape[2]
    rep = h // kv
    k = jnp.repeat(k, rep, axis=2)
    v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / np.sqrt(d)
    qpos = (skv - sq) + jnp.arange(sq)
    kpos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= qpos[:, None] >= kpos[None, :]
    if window:
        mask &= qpos[:, None] - kpos[None, :] < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestFlashForward:
    @pytest.mark.parametrize("sq,skv,h,kv,qc,kc", [
        (16, 16, 4, 4, 8, 8),
        (33, 33, 4, 2, 8, 16),
        (16, 48, 2, 1, 8, 16),   # cross: q aligned to end of kv
        (64, 64, 3, 3, 64, 64),  # single block
    ])
    def test_matches_naive(self, sq, skv, h, kv, qc, kc):
        q = rand((2, sq, h, 16), 1)
        k = rand((2, skv, kv, 16), 2)
        v = rand((2, skv, kv, 16), 3)
        got = flash_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
        want = naive_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_sliding_window(self):
        q = rand((1, 32, 2, 8), 4)
        got = flash_attention(q, q, q, q_chunk=8, kv_chunk=8, window=6)
        want = naive_attention(q, q, q, window=6)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


class TestFlashBackward:
    @pytest.mark.parametrize("sq,h,kv,qc,kc", [
        (16, 4, 4, 8, 8),
        (24, 4, 2, 8, 16),
        (17, 2, 1, 8, 8),  # ragged blocks
    ])
    def test_grads_match_naive(self, sq, h, kv, qc, kc):
        q = rand((2, sq, h, 8), 5)
        k = rand((2, sq, kv, 8), 6)
        v = rand((2, sq, kv, 8), 7)
        co = rand((2, sq, h, 8), 8)

        def loss_flash(q, k, v):
            return jnp.sum(flash_attention(q, k, v, q_chunk=qc, kv_chunk=kc) * co)

        def loss_naive(q, k, v):
            return jnp.sum(naive_attention(q, k, v) * co)

        g1 = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
        g2 = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
        for a, b, name in zip(g1, g2, "qkv"):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=5e-4, atol=5e-4,
                err_msg=f"grad d{name} mismatch",
            )

    def test_grad_with_window(self):
        q = rand((1, 24, 2, 8), 9)
        co = rand((1, 24, 2, 8), 10)
        g1 = jax.grad(lambda x: jnp.sum(
            flash_attention(x, x, x, q_chunk=8, kv_chunk=8, window=5) * co))(q)
        g2 = jax.grad(lambda x: jnp.sum(naive_attention(x, x, x, window=5) * co))(q)
        np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=5e-4, atol=5e-4)


class TestDecodeAttention:
    def test_matches_naive_last_position(self):
        skv = 20
        q = rand((2, 1, 4, 8), 11)
        k = rand((2, 32, 2, 8), 12)  # cache bigger than fill
        v = rand((2, 32, 2, 8), 13)
        got = decode_attention(q, k, v, jnp.asarray(skv))
        want = naive_attention(q, k[:, :skv], v[:, :skv], causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)
