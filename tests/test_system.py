"""End-to-end behaviour tests: the paper's pipeline on a trained model.

The heavier statistical claims live in benchmarks/ (Table 1/2); these
tests pin the *mechanisms* end-to-end: train -> rotate -> quantize ->
eval/serve stays consistent, rotation beats identity at W2 on a trained
model, and the quantized serving path agrees with the training forward.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import SyntheticLM
from repro.models.common import NOQUANT, QuantizeSpec
from repro.models.registry import get_arch
from repro.quant.pipeline import PTQConfig, quantize_model
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_eval_step, make_train_step


@pytest.fixture(scope="module")
def trained():
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    opt = OptConfig(lr=1e-2, warmup_steps=10, total_steps=120)
    step = jax.jit(make_train_step(arch, opt))
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    state = init_opt_state(params, opt)
    data = SyntheticLM(cfg.vocab, 48, seed=3)
    for i in range(120):
        params, state, _, _ = step(params, state, {},
                                   {"tokens": jnp.asarray(data.batch(i, 0, 16))})
    held = {"tokens": jnp.asarray(data.batch(9999, 0, 16))}
    return arch, params, held


def test_training_learned_something(trained):
    arch, params, held = trained
    ev = jax.jit(make_eval_step(arch, NOQUANT))
    nll = float(ev(params, held)["nll"])
    chance = np.log(arch.config.vocab)
    assert nll < chance - 0.5, (nll, chance)


def test_w2_rotation_beats_identity(trained):
    """The reason rotations exist: at W2, an orthogonal rotation should
    beat no rotation on a trained model.

    Deflaked for the reduced scale (ROADMAP open item): quantize with RTN
    — GPTQ's error compensation washes the rotation margin into noise on
    a 64-dim model (the full-setting comparison lives in
    benchmarks/table1) — and average the NLL margin over a small fixed
    seed set of held-out batches instead of asserting one draw.  All
    seeds are pinned, so the averaged margin is deterministic; the
    widened threshold (> 0.005 nats mean vs. strict per-draw dominance)
    keeps the test about the mechanism, not the noise floor.
    """
    arch, params, _ = trained
    data = SyntheticLM(arch.config.vocab, 48, seed=3)
    evs = {}
    for kind in ("I", "GSR"):
        ptq = PTQConfig(r1_kind=kind, wakv="W2A16", method="rtn", group=32)
        qp, spec = quantize_model(arch, params, ptq)
        evs[kind] = (jax.jit(make_eval_step(arch, spec)), qp)
    margins = []
    for k in range(4):  # the fixed held-out seed set
        held_k = {"tokens": jnp.asarray(data.batch(9_999 + 10_000 * k, 0, 16))}
        nll = {kind: float(ev(qp, held_k)["nll"]) for kind, (ev, qp) in evs.items()}
        margins.append(nll["I"] - nll["GSR"])
    assert np.mean(margins) > 0.005, margins


def test_w4_quantization_near_lossless(trained):
    arch, params, held = trained
    ev = jax.jit(make_eval_step(arch, NOQUANT))
    base = float(ev(params, held)["nll"])
    ptq = PTQConfig(r1_kind="GSR", wakv="W4A16", method="gptq", group=16,
                    n_calib=4, calib_seq=48)
    qp, spec = quantize_model(arch, params, ptq)
    evq = jax.jit(make_eval_step(arch, spec))
    nll = float(evq(qp, held)["nll"])
    assert nll < base + 0.15, (base, nll)


def test_quantized_serving_matches_quantized_forward(trained):
    """Serve path (prefill+decode) of the PTQ'd model is consistent with
    its training forward - greedy decode continuation agrees."""
    arch, params, held = trained
    ptq = PTQConfig(r1_kind="GSR", wakv="W4A16", method="rtn", group=16)
    qp, spec = quantize_model(arch, params, ptq)
    toks = held["tokens"][:2, :17]
    full = arch.forward(qp, {"tokens": toks}, spec)
    cache = arch.init_cache(2, 32, spec, jnp.float32)
    logits, cache = arch.prefill(qp, {"tokens": toks[:, :16]}, cache, spec)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32).squeeze(),
        np.asarray(full[:, 15], np.float32), rtol=2e-3, atol=2e-3)
    dec, cache = arch.decode(qp, toks[:, 16], cache, spec)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full[:, 16], np.float32),
        rtol=2e-3, atol=2e-3)


def test_gsr_init_helps_learned_rotation(trained):
    """Paper Sec 4: GSR as initialization for learned methods - the
    optimized result from GSR init should be no worse than from GH init."""
    arch, params, held = trained
    nlls = {}
    for kind in ("GH", "GSR"):
        ptq = PTQConfig(r1_kind=kind, wakv="W2A16", method="gptq", group=16,
                        learned="rotation", learn_steps=40, n_calib=4, calib_seq=48)
        qp, spec = quantize_model(arch, params, ptq)
        ev = jax.jit(make_eval_step(arch, spec))
        nlls[kind] = float(ev(qp, held)["nll"])
    # soft claim at this scale: GSR-init within noise of or better than GH-init
    assert nlls["GSR"] < nlls["GH"] + 0.5, nlls
