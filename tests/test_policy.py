"""Per-site quantization policy tests (the declarative PTQ front door).

Covers the QuantPolicy redesign contract:

  * pattern precedence (first match wins), layer-range overlap, and
    construction-time validation with actionable errors;
  * a mixed-precision policy (>= 2 distinct (bits, group, rotation)
    rules) quantizes, saves, loads, and serves bit-exactly on dense and
    MoE;
  * ``PTQConfig`` lowered to its single-rule policy produces a
    byte-identical artifact to the flat-config front door;
  * layer-range heterogeneity inside one stacked leaf quantizes each
    layer on its own grid, exactly matching per-layer quantization;
  * per-site online R4 choices cancel their fused weight pre-rotation
    (fp forward invariance);
  * heterogeneous packed leaves co-shard (param_pspecs mirrors the
    logical spec regardless of bits/group);
  * the padded-prefill variant returns logits at the *true* last token
    under right-padding, token-identical to exact-length prefill;
  * the explicit shard_map EP schedule for ``moe_apply`` matches the
    GSPMD einsum path on a mesh and falls back off-mesh.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.models.common import NOQUANT
from repro.models.registry import get_arch
from repro.quant.packed import PackedWeight, is_packed
from repro.quant.pipeline import PTQConfig, build_plan_rotations
from repro.quant.policy import (
    PRESETS, QuantPolicy, RotationPlan, RotationSpec, SiteRule, get_policy,
)

MIXED = QuantPolicy(
    rules=(
        SiteRule(pattern="*down*", bits=4, group=32, method="rtn",
                 rotation="GSR"),
        SiteRule(pattern="*", bits=2, group=16, method="rtn"),
    ),
    rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=32)),
)


@pytest.fixture(scope="module")
def dense_setup():
    arch = get_arch("smollm-135m", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    toks = np.random.default_rng(0).integers(
        0, arch.config.vocab, (2, 12)).astype(np.int32)
    return arch, params, toks


@pytest.fixture(scope="module")
def moe_setup():
    arch = get_arch("deepseek-moe-16b", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    toks = np.random.default_rng(0).integers(
        0, arch.config.vocab, (2, 12)).astype(np.int32)
    return arch, params, toks


# ---------------------------------------------------------------------------
# Rule matching / precedence / validation
# ---------------------------------------------------------------------------


def test_first_match_wins_over_overlapping_patterns():
    pol = QuantPolicy(rules=(
        SiteRule(pattern="w_down", bits=4),
        SiteRule(pattern="*down*", bits=3),
        SiteRule(pattern="*", bits=2),
    ))
    assert pol.rule_for("w_down", 0).bits == 4
    assert pol.rule_for("shared_down", 0).bits == 3
    assert pol.rule_for("moe_mlp/w_down", 0).bits == 4  # bare-name match
    assert pol.rule_for("wq", 5).bits == 2


def test_layer_range_matching():
    pol = QuantPolicy(rules=(
        SiteRule(pattern="*", layers=(0, 1), bits=4),
        SiteRule(pattern="*", layers=(2, None), bits=2),
    ))
    assert pol.rule_for("wq", 0).bits == 4
    assert pol.rule_for("wq", 1).bits == 4
    assert pol.rule_for("wq", 2).bits == 2
    assert pol.rule_for("wq", 99).bits == 2


def test_unmatched_site_stays_float(dense_setup):
    arch, params, _ = dense_setup
    pol = QuantPolicy(rules=(SiteRule(pattern="w_down", bits=4, group=16),))
    qm = api.quantize(arch, params, pol)
    assert is_packed(qm.params["layers"]["w_down"])
    assert not is_packed(qm.params["layers"]["wq"])


@pytest.mark.parametrize("bad", [
    lambda: SiteRule(bits=5),
    lambda: SiteRule(pattern=""),
    lambda: SiteRule(group=0),
    lambda: SiteRule(method="awq"),
    lambda: SiteRule(layers=(3, 1)),
    lambda: SiteRule(layers=(0, 1), rotation="GSR"),  # ranged + online rot
    lambda: SiteRule(rotation="XX"),
    lambda: SiteRule(act_bits=7),
    lambda: SiteRule(act_group=0),
    lambda: SiteRule(act_clip=1.5),
    lambda: SiteRule(layers=(0, 1), act_bits=8),  # ranged + act override
    lambda: RotationSpec(source="download"),
    lambda: RotationSpec(source="load"),  # load without a path
    lambda: RotationSpec(kind="ZZ"),
    lambda: RotationPlan(r4_kind="ZZ"),
    lambda: QuantPolicy(rules=()),
    lambda: QuantPolicy(act_bits=7),
    lambda: PTQConfig(wakv="WXAY"),
    lambda: PTQConfig(wakv="W4A8KVx"),
    lambda: PTQConfig(group=0),
    lambda: PTQConfig(method="awq"),
    lambda: PTQConfig(r1_kind="nope"),
    lambda: PTQConfig(learned="maybe"),
])
def test_construction_time_validation(bad):
    with pytest.raises(ValueError):
        bad()


def test_resolve_rejects_partially_quantized_leaf(dense_setup):
    arch, params, _ = dense_setup
    pol = QuantPolicy(rules=(SiteRule(pattern="*", layers=(0, 0), bits=4),))
    with pytest.raises(ValueError, match="quantized at layers"):
        pol.resolve(arch.config)


def test_resolve_rejects_policy_matching_nothing(dense_setup):
    arch, _, _ = dense_setup
    pol = QuantPolicy(rules=(SiteRule(pattern="no_such_site", bits=4),))
    with pytest.raises(ValueError, match="matched any site"):
        pol.resolve(arch.config)


def test_get_policy_lookup_errors():
    with pytest.raises(ValueError, match="preset"):
        get_policy("not-a-preset")


@pytest.mark.parametrize("name", sorted(PRESETS))
def test_presets_parse_resolve_roundtrip(name):
    pol = get_policy(name)
    cfg = get_arch("deepseek-moe-16b", reduced=True).config
    res = pol.resolve(cfg)
    assert any(s.quantized for s in res.sites)
    # JSON round trip is exact (the artifact manifest depends on it)
    assert QuantPolicy.from_json_dict(pol.to_json_dict()) == pol
    assert pol.describe()


# ---------------------------------------------------------------------------
# PTQConfig lowering: byte-identical artifacts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family_arch", ["smollm-135m", "deepseek-moe-16b"])
def test_ptqconfig_lowered_policy_byte_identical(family_arch):
    arch = get_arch(family_arch, reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    ptq = PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn", group=32)
    qm1 = api.quantize(arch, params, ptq)
    qm2 = api.quantize(arch, params, ptq.to_policy())
    assert qm1.spec == qm2.spec
    l1 = jax.tree.leaves(qm1.params, is_leaf=is_packed)
    l2 = jax.tree.leaves(qm2.params, is_leaf=is_packed)
    for a, b in zip(l1, l2):
        if is_packed(a):
            assert (a.bits, a.group, a.c, a.packed) == (
                b.bits, b.group, b.c, b.packed)
            np.testing.assert_array_equal(np.asarray(a.codes),
                                          np.asarray(b.codes))
            np.testing.assert_array_equal(np.asarray(a.scale),
                                          np.asarray(b.scale))
            np.testing.assert_array_equal(np.asarray(a.zero),
                                          np.asarray(b.zero))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Mixed precision: quantize -> save -> load -> serve, bit-exact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("setup", ["dense_setup", "moe_setup"])
def test_mixed_precision_roundtrip_bit_exact(setup, request, tmp_path):
    arch, params, toks = request.getfixturevalue(setup)
    qm = api.quantize(arch, params, MIXED)
    # the policy really is mixed: down projections at W4, the rest W2
    bits = {p[-1]: l.bits for p, l in _packed_items(qm.params)}
    assert bits["w_down"] == 4 and bits["wq"] == 2

    d = str(tmp_path / "mixed")
    qm.save(d)
    qm2 = api.load_quantized(d)
    assert qm2.policy == qm.policy and qm2.spec == qm.spec
    for (p1, l1), (p2, l2) in zip(_packed_items(qm.params),
                                  _packed_items(qm2.params)):
        assert p1 == p2
        assert (l1.bits, l1.group, l1.c, l1.packed) == (
            l2.bits, l2.group, l2.c, l2.packed)
        np.testing.assert_array_equal(np.asarray(l1.codes),
                                      np.asarray(l2.codes))
        np.testing.assert_array_equal(np.asarray(l1.scale),
                                      np.asarray(l2.scale))
        np.testing.assert_array_equal(np.asarray(l1.zero),
                                      np.asarray(l2.zero))

    lf = arch.forward(qm.params, {"tokens": jnp.asarray(toks)}, qm.spec)
    ll = qm2.arch.forward(qm2.params, {"tokens": jnp.asarray(toks)}, qm2.spec)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ll))

    scfg = api.ServeConfig(max_seq=32, batch_slots=2)
    out1 = qm.serve(scfg).generate(toks[:, :8], 3)
    out2 = qm2.serve(scfg).generate(toks[:, :8], 3)
    np.testing.assert_array_equal(out1["tokens"], out2["tokens"])


def _packed_items(tree, prefix=()):
    out = []
    for k in sorted(tree):
        v = tree[k]
        if isinstance(v, dict):
            out.extend(_packed_items(v, prefix + (k,)))
        elif is_packed(v):
            out.append((prefix + (k,), v))
    return out


# ---------------------------------------------------------------------------
# Layer-range heterogeneity inside one stacked leaf
# ---------------------------------------------------------------------------


def test_layer_heterogeneous_leaf_matches_per_layer_quantization(dense_setup):
    from repro.core.fuse import fuse_rotations

    arch, params, toks = dense_setup
    cfg = arch.config
    assert cfg.n_layers >= 2
    pol = QuantPolicy(
        rules=(SiteRule(pattern="*", layers=(0, 0), bits=4, group=32),
               SiteRule(pattern="*", bits=2, group=32)),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=32)),
    )
    qm = api.quantize(arch, params, pol)
    w = qm.params["layers"]["w_down"]
    assert w.bits == 4  # merged storage at the widest rule

    r1, r2, _ = build_plan_rotations(cfg, params, pol)
    fused = fuse_rotations(cfg, params, r1, r2=r2, spec=pol.spec())
    for layer, rule in ((0, pol.rules[0]), (cfg.n_layers - 1, pol.rules[1])):
        ref = PackedWeight.from_float(fused["layers"]["w_down"][layer],
                                      rule.weight_cfg(w.c))
        np.testing.assert_array_equal(np.asarray(w.dequantize()[layer]),
                                      np.asarray(ref.dequantize()))

    # the merged leaf still rides the scanned forward + a save/load cycle
    lg = arch.forward(qm.params, {"tokens": jnp.asarray(toks)}, qm.spec)
    assert np.isfinite(np.asarray(lg)).all()


def test_heterogeneous_groups_share_finest_refinement(dense_setup):
    arch, params, _ = dense_setup
    pol = QuantPolicy(
        rules=(SiteRule(pattern="*", layers=(0, 0), bits=4, group=32),
               SiteRule(pattern="*", bits=4, group=16)),
    )
    qm = api.quantize(arch, params, pol)
    w = qm.params["layers"]["w_down"]
    assert w.group == 16  # scales stored at the finest group


# ---------------------------------------------------------------------------
# Per-site online rotations (R4) + R2 slot
# ---------------------------------------------------------------------------


def test_per_site_r4_fp_invariance(moe_setup):
    """W16 policy with different online rotations per down-proj site:
    fusion pre-rotations must cancel the online apply_r4 exactly."""
    arch, params, toks = moe_setup
    pol = QuantPolicy(
        rules=(
            SiteRule(pattern="shared_down", bits=16, rotation="GH"),
            SiteRule(pattern="w_down", bits=16, rotation="GSR", group=16),
            SiteRule(pattern="*", bits=16),
        ),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=32),
                              r4_kind="I"),
    )
    spec = pol.spec()
    assert spec.r4_for("shared_down")[0] == "GH"
    assert spec.r4_for("w_down")[0] == "GSR"
    assert spec.r4_for("anything_else")[0] == "I"

    from repro.core.fuse import fuse_rotations
    from repro.quant.pipeline import build_plan_rotations

    r1, r2, _ = build_plan_rotations(arch.config, params, pol)
    fused = fuse_rotations(arch.config, params, r1, r2=r2, spec=spec)
    ref = arch.forward(params, {"tokens": jnp.asarray(toks)}, NOQUANT)
    got = arch.forward(fused, {"tokens": jnp.asarray(toks)}, spec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_qualified_pattern_rotation_override_applies():
    """A slash-qualified rule pattern must still drive the online R4 at
    its bare site name (apply_r4 call sites cannot know the tree path)."""
    pol = QuantPolicy(
        rules=(SiteRule(pattern="moe_mlp/w_down", bits=4, rotation="GSR",
                        group=16),
               SiteRule(pattern="*", bits=2, group=16)),
        rotation=RotationPlan(r4_kind="GH"),
    )
    spec = pol.spec()
    assert spec.r4_for("w_down")[0] == "GSR"
    assert spec.r4_for("shared_down")[0] == "GH"  # plan default


def test_r2_slot_fp_invariance(dense_setup):
    arch, params, toks = dense_setup
    pol = QuantPolicy(
        rules=(SiteRule(pattern="*", bits=16),),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=32), r2="GH",
                              r4_kind="I"),
    )
    from repro.core.fuse import fuse_rotations

    r1, r2, _ = build_plan_rotations(arch.config, params, pol)
    assert r2 is not None
    fused = fuse_rotations(arch.config, params, r1, r2=r2, spec=pol.spec())
    ref = arch.forward(params, {"tokens": jnp.asarray(toks)}, NOQUANT)
    got = arch.forward(fused, {"tokens": jnp.asarray(toks)}, pol.spec())
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_r2_rejected_for_mla():
    arch = get_arch("minicpm3-4b", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    pol = QuantPolicy(
        rules=(SiteRule(pattern="*", bits=4, group=16),),
        rotation=RotationPlan(r2="GH"),
    )
    with pytest.raises(ValueError, match="per-head"):
        api.quantize(arch, params, pol)


# ---------------------------------------------------------------------------
# Heterogeneous packed co-sharding
# ---------------------------------------------------------------------------


def test_heterogeneous_packed_leaves_co_shard(dense_setup):
    from repro.dist.sharding import param_pspecs

    arch, params, _ = dense_setup
    qm = api.quantize(arch, params, MIXED)
    sds = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                       qm.params)
    specs = param_pspecs(arch.config, sds)
    layers = specs["layers"]
    # every packed leaf mirrors its logical weight's spec onto all three
    # children regardless of bits/group heterogeneity across leaves
    for name in ("w_down", "wq"):
        leaf = layers[name]
        assert is_packed(leaf)
        assert leaf.codes == leaf.scale == leaf.zero
        assert leaf.codes is not None


# ---------------------------------------------------------------------------
# Padded prefill (prompt-length bucketing satellite)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["smollm-135m", "deepseek-moe-16b",
                                  "minicpm3-4b"])
def test_padded_prefill_true_last_token(name):
    arch = get_arch(name, reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    s, pad_to = 11, 16
    toks = rng.integers(0, cfg.vocab, (2, s)).astype(np.int32)

    cache = arch.init_cache(2, 32, NOQUANT, jnp.float32)
    lg_e, c_e = arch.prefill(params, {"tokens": jnp.asarray(toks)}, cache,
                             NOQUANT)
    padded = np.pad(toks, ((0, 0), (0, pad_to - s)))
    cache = arch.init_cache(2, 32, NOQUANT, jnp.float32)
    lg_p, c_p = arch.padded_prefill(params, {"tokens": jnp.asarray(padded)},
                                    cache, jnp.asarray(s, jnp.int32), NOQUANT)
    np.testing.assert_array_equal(np.asarray(lg_e), np.asarray(lg_p))
    assert int(c_p["length"]) == s

    nxt = np.argmax(np.asarray(lg_p)[:, 0], -1).astype(np.int32)
    d_e, _ = arch.decode(params, jnp.asarray(nxt), c_e, NOQUANT)
    d_p, _ = arch.decode(params, jnp.asarray(nxt), c_p, NOQUANT)
    np.testing.assert_array_equal(np.asarray(d_e), np.asarray(d_p))


def test_recurrent_families_have_no_padded_prefill():
    assert get_arch("xlstm-1.3b", reduced=True).padded_prefill is None
    assert get_arch("zamba2-1.2b", reduced=True).padded_prefill is None


def test_engine_bucketed_prompts_token_identical(dense_setup):
    arch, params, _ = dense_setup
    qm = api.quantize(arch, params,
                      PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn",
                                group=32))
    prompts = np.random.default_rng(1).integers(
        0, arch.config.vocab, (3, 13)).astype(np.int32)
    o1 = qm.serve(api.ServeConfig(max_seq=48, batch_slots=3)
                  ).generate(prompts, 6)
    o2 = qm.serve(api.ServeConfig(max_seq=48, batch_slots=3,
                                  bucket_prompts=True)).generate(prompts, 6)
    np.testing.assert_array_equal(o1["tokens"], o2["tokens"])


# ---------------------------------------------------------------------------
# Explicit shard_map EP schedule for moe_apply
# ---------------------------------------------------------------------------


def test_moe_explicit_ep_matches_gspmd_on_mesh(moe_setup):
    from jax.sharding import Mesh

    from repro.models import moe as moe_mod

    arch, params, toks = moe_setup
    # 4 fake devices when available (standalone run: a real (2,2) mesh
    # with a live all-to-all); a (1,1) mesh otherwise (full-suite run in
    # the single-device container) — the shard_map schedule still runs,
    # its collectives short-circuiting at ep == 1.
    devs = jax.devices()
    shape = (2, 2) if len(devs) >= 4 else (1, 1)
    n = shape[0] * shape[1]
    mesh = Mesh(np.array(devs[:n]).reshape(shape), ("data", "model"))
    batch = {"tokens": jnp.asarray(np.tile(toks, (2, 1)))}  # B=4 divisible
    with mesh:
        ref = jax.jit(lambda p, b: arch.forward(p, b, NOQUANT))(params, batch)
        with moe_mod.moe_ep_impl("explicit"):
            got = jax.jit(lambda p, b: arch.forward(p, b, NOQUANT))(
                params, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_moe_explicit_ep_falls_back_off_mesh(moe_setup):
    from repro.models import moe as moe_mod

    arch, params, toks = moe_setup
    ref = arch.forward(params, {"tokens": jnp.asarray(toks)}, NOQUANT)
    with moe_mod.moe_ep_impl("explicit"):
        got = arch.forward(params, {"tokens": jnp.asarray(toks)}, NOQUANT)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_pick_moe_ep_default_is_data_driven():
    from repro.launch.dryrun import pick_moe_ep_default

    win = {"explicit_ep": {"wire_bytes_per_layer": 100},
           "gspmd_einsum": {"wire_bytes_per_layer": 200}}
    lose = {"explicit_ep": {"wire_bytes_per_layer": 300},
            "gspmd_einsum": {"wire_bytes_per_layer": 200}}
    infeasible = {"explicit_ep": {"error": "ValueError(...)"},
                  "gspmd_einsum": {"wire_bytes_per_layer": 200}}
    assert pick_moe_ep_default(win) == "explicit"
    assert pick_moe_ep_default(lose) == "gspmd"
    assert pick_moe_ep_default(infeasible) == "gspmd"
    assert pick_moe_ep_default({"error": "boom"}) == "gspmd"
