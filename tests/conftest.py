"""Repo-level pytest config.

``hypothesis`` is declared in the ``test`` extra (pyproject.toml), but the
hermetic CI/eval containers do not always ship it.  Rather than letting
three test modules die at collection, fall back to the vendored minimal
shim in ``tests/_vendor`` — same decorator API, deterministic example
generation — whenever the real package is absent.  A real ``hypothesis``
install always wins (the vendor dir is appended only on ImportError).
"""
import pathlib
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent / "_vendor"))
