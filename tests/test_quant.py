"""Tests for RTN quantizers, packing, GPTQ, and learned-rotation baselines."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.quant import gptq, pack, qlinear, rtn, spinquant
from repro.quant.qtypes import QuantConfig, WAKVConfig, paper_act_cfg, paper_weight_cfg


def rand(shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale).astype(np.float32)


class TestRTN:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("symmetric", [True, False])
    def test_roundtrip_error_bounded(self, bits, symmetric):
        cfg = QuantConfig(bits=bits, group=16, symmetric=symmetric)
        w = rand((64, 32), seed=bits)
        dq = rtn.fake_quant_weight(jnp.asarray(w), cfg)
        # max error is half an LSB of the per-group scale
        wg = w.reshape(4, 16, 32)
        if symmetric:
            lsb = np.abs(wg).max(1) / (2 ** (bits - 1) - 1)
        else:
            lsb = (wg.max(1) - wg.min(1)) / (2**bits - 1)
        err = np.abs(np.asarray(dq).reshape(4, 16, 32) - wg)
        assert np.all(err <= lsb[:, None, :] * 0.5 + 1e-6)

    def test_8bit_near_lossless(self):
        cfg = QuantConfig(bits=8, group=32, symmetric=False)
        w = rand((128, 16))
        dq = np.asarray(rtn.fake_quant_weight(jnp.asarray(w), cfg))
        assert np.abs(dq - w).max() < 0.02

    def test_mse_clip_never_worse(self):
        cfg_plain = QuantConfig(bits=2, group=32, symmetric=False)
        cfg_mse = cfg_plain.replace(mse_clip=True)
        # heavy-tailed weights where clipping helps
        w = rand((64, 32), seed=7)
        w[5, :] *= 20.0
        e_plain = np.mean((np.asarray(rtn.fake_quant_weight(jnp.asarray(w), cfg_plain)) - w) ** 2)
        e_mse = np.mean((np.asarray(rtn.fake_quant_weight(jnp.asarray(w), cfg_mse)) - w) ** 2)
        assert e_mse <= e_plain + 1e-9

    def test_act_quant_shapes_and_sym(self):
        cfg = paper_act_cfg(4, group=32)
        x = rand((2, 5, 64))
        dq = np.asarray(rtn.fake_quant_act_grouped(jnp.asarray(x), cfg))
        assert dq.shape == x.shape
        # symmetric: zero maps to zero
        x0 = np.zeros((1, 64), np.float32)
        assert np.all(np.asarray(rtn.fake_quant_act_grouped(jnp.asarray(x0), cfg)) == 0)

    def test_wakv_parse(self):
        c = WAKVConfig.parse("W2A4KV4")
        assert (c.weight.bits, c.act.bits, c.kv.bits) == (2, 4, 4)
        assert not c.weight.symmetric and c.weight.mse_clip  # paper A.1
        assert c.act.symmetric and c.act.clip_ratio == 0.9
        assert WAKVConfig.parse("W16A16").tag() == "W16A16KV16"


class TestPack:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("symmetric", [True, False])
    def test_pack_roundtrip(self, bits, symmetric):
        cfg = QuantConfig(bits=bits, group=16, symmetric=symmetric)
        w = rand((64, 24), seed=bits + 10)
        qt = rtn.quantize_weight_grouped(jnp.asarray(w), cfg)
        if symmetric:
            qt = type(qt)(codes=qt.codes, scale=qt.scale, zero=None, bits=bits, group=16)
        packed = pack.pack(qt)
        assert packed.codes.shape[0] == 64 // pack.codes_per_byte(bits)
        unpacked = pack.unpack(packed)
        np.testing.assert_array_equal(np.asarray(unpacked.codes), np.asarray(qt.codes))

    def test_packed_bytes(self):
        cfg = QuantConfig(bits=2, group=16, symmetric=False)
        qt = pack.pack(rtn.quantize_weight_grouped(jnp.asarray(rand((64, 32))), cfg))
        assert qt.codes.dtype == jnp.uint8 and qt.codes.shape == (16, 32)


class TestQLinear:
    def test_dequant_matmul_matches_fp(self):
        cfg = QuantConfig(bits=8, group=32, symmetric=False)
        w = rand((64, 48))
        x = rand((5, 64), seed=3)
        qt = qlinear.quantize_for_serving(jnp.asarray(w), cfg)
        y = np.asarray(qlinear.dequant_matmul(jnp.asarray(x), qt))
        np.testing.assert_allclose(y, x @ w, rtol=0.05, atol=0.05)


class TestGPTQ:
    def _setup(self, c=64, h=32, n=512, seed=0):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(n, c)).astype(np.float32)
        # correlated activations (realistic: GPTQ's advantage needs them)
        mix = rng.normal(size=(c, c)).astype(np.float32) * 0.3 + np.eye(c, dtype=np.float32)
        x = x @ mix
        w = rng.normal(size=(c, h)).astype(np.float32)
        hmat = gptq.collect_hessian(jnp.asarray(x))
        return jnp.asarray(x), jnp.asarray(w), hmat

    @pytest.mark.parametrize("bits", [2, 4])
    def test_gptq_beats_rtn_on_proxy(self, bits):
        x, w, hmat = self._setup()
        cfg = QuantConfig(bits=bits, group=16, symmetric=False)
        _, wq_gptq = gptq.gptq_quantize(w, hmat, cfg)
        wq_rtn = rtn.fake_quant_weight(w, cfg)
        l_gptq = float(gptq.gptq_proxy_loss(w, wq_gptq, hmat))
        l_rtn = float(gptq.gptq_proxy_loss(w, wq_rtn, hmat))
        assert l_gptq < l_rtn

    def test_gptq_output_mse(self):
        x, w, hmat = self._setup(seed=4)
        cfg = QuantConfig(bits=4, group=16, symmetric=False)
        _, wq = gptq.gptq_quantize(w, hmat, cfg)
        y, yq = np.asarray(x @ w), np.asarray(x @ wq)
        rel = np.linalg.norm(y - yq) / np.linalg.norm(y)
        assert rel < 0.15

    def test_gptq_identity_hessian_reduces_to_rtn(self):
        _, w, _ = self._setup(seed=5)
        cfg = QuantConfig(bits=4, group=16, symmetric=False)
        eye = jnp.eye(w.shape[0], dtype=jnp.float32)
        _, wq = gptq.gptq_quantize(w, eye, cfg, percdamp=1e-8)
        np.testing.assert_allclose(
            np.asarray(wq), np.asarray(rtn.fake_quant_weight(w, cfg)), atol=1e-4
        )


class TestSpinQuantLite:
    def test_cayley_orthogonal(self):
        a = jnp.asarray(rand((32, 32), seed=9))
        r = np.asarray(spinquant.cayley(a))
        np.testing.assert_allclose(r @ r.T, np.eye(32), atol=1e-4)

    def test_learning_improves_proxy(self):
        from repro.core.rotation import make_rotation

        rng = np.random.default_rng(0)
        w = [jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32) * (1 + 3 * (rng.random((32, 1)) > 0.9)))]
        cfg = QuantConfig(bits=2, group=8, symmetric=False)
        r0 = make_rotation("GH", 32, seed=0).dense()
        res = spinquant.optimize_rotation(r0, w, [], cfg, steps=40, lr=3e-3)
        assert res.losses[-1] < res.losses[0]
        r = res.rotation
        np.testing.assert_allclose(r @ r.T, np.eye(32), atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    bits=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 1000),
    sym=st.booleans(),
)
def test_property_quant_codes_in_range(bits, seed, sym):
    cfg = QuantConfig(bits=bits, group=8, symmetric=sym)
    w = jnp.asarray(rand((32, 8), seed=seed, scale=5.0))
    qt = rtn.quantize_weight_grouped(w, cfg)
    codes = np.asarray(qt.codes)
    assert codes.min() >= cfg.qmin and codes.max() <= cfg.qmax


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_rotation_invariance_of_fp_matmul(seed):
    """Rotating W front+rear and counter-rotating inputs is exact in fp:
    the whole PTQ scheme rests on this equivalence."""
    from repro.core.rotation import make_rotation

    rng = np.random.default_rng(seed)
    c, h = 32, 16
    w = rng.normal(size=(c, h))
    x = rng.normal(size=(4, c))
    r = make_rotation("GSR", c, group=8).dense()
    y = x @ w
    y_rot = (x @ r) @ (r.T @ w)
    np.testing.assert_allclose(y, y_rot, atol=1e-10)
