"""Static-analysis guard: every ``act_q`` call must carry a site tag.

Per-site activation rules (``SiteRule.act_bits`` ->
``QuantizeSpec.act_sites`` -> ``act_q(x, spec, site)``) only work if
every activation-quant call site in the model code is tagged, and tagged
with a name a policy rule can actually match.  This AST walk fails the
suite if anyone adds an anonymous ``act_q(x, spec)`` call back to
``src/repro/models/`` or ``dist/collectives.py``, and checks every
string-literal tag against the site vocabulary ``resolve_policy``
accepts (``quant.policy.act_site_names``).  Computed tags (e.g.
``swiglu`` deriving its gate site from the down site) pass the presence
check only.
"""
import ast
import os
from typing import List, Tuple

import repro.models as models_pkg
from repro.quant.policy import act_site_names

MODELS_DIR = os.path.dirname(models_pkg.__file__)
COLLECTIVES = os.path.join(MODELS_DIR, os.pardir, "dist", "collectives.py")


def _is_act_q(func: ast.expr) -> bool:
    return (isinstance(func, ast.Name) and func.id == "act_q") or (
        isinstance(func, ast.Attribute) and func.attr == "act_q")


def lint_act_q_calls(source: str, filename: str = "<str>"
                     ) -> Tuple[List[str], List[Tuple[str, str]]]:
    """Return (untagged call descriptions, (literal tag, location) pairs).

    A call is tagged when it passes a third positional argument or a
    ``site=`` keyword.  Definitions of ``act_q`` itself are ignored.
    """
    untagged, tags = [], []
    for node in ast.walk(ast.parse(source, filename=filename)):
        if not isinstance(node, ast.Call) or not _is_act_q(node.func):
            continue
        where = f"{os.path.basename(filename)}:{node.lineno}"
        site = None
        if len(node.args) >= 3:
            site = node.args[2]
        for kw in node.keywords:
            if kw.arg == "site":
                site = kw.value
        if site is None:
            untagged.append(where)
        elif isinstance(site, ast.Constant) and isinstance(site.value, str):
            tags.append((site.value, where))
    return untagged, tags


def _source_files():
    files = [os.path.join(MODELS_DIR, f) for f in sorted(os.listdir(MODELS_DIR))
             if f.endswith(".py")]
    files.append(os.path.normpath(COLLECTIVES))
    return files


def test_every_act_q_call_is_site_tagged():
    problems = []
    n_calls = 0
    for path in _source_files():
        with open(path) as f:
            untagged, tags = lint_act_q_calls(f.read(), path)
        problems.extend(untagged)
        n_calls += len(untagged) + len(tags)
    assert not problems, (
        f"act_q calls without a site tag: {problems} — pass "
        f"site=\"<name>\" so per-site activation rules can resolve")
    # the walk really covers the model code (all five families + the EP
    # collective): a refactor that moves act_q out from under this lint
    # should fail loudly, not silently pass on zero calls
    assert n_calls >= 39, f"expected >= 39 act_q call sites, found {n_calls}"


def test_literal_tags_match_policy_site_vocabulary():
    vocab = act_site_names()
    bad = []
    for path in _source_files():
        with open(path) as f:
            _, tags = lint_act_q_calls(f.read(), path)
        bad.extend((t, w) for t, w in tags if t not in vocab)
    assert not bad, (
        f"act_q site tags outside the resolve_policy vocabulary: {bad} "
        f"(known sites: {sorted(vocab)})")


def test_vocabulary_covers_all_families():
    vocab = act_site_names()
    # spot-check one tag per family plus the act-only lm_head site
    for name in ("wq", "w_down", "shared_down", "wq_a", "wkv_a", "wx",
                 "out_proj", "in_proj", "lm_head"):
        assert name in vocab, name


def test_lint_fails_on_untagged_call():
    """The guard demonstrably catches the regression it exists for."""
    snippet = (
        "def forward(x, spec):\n"
        "    x = act_q(x, spec)\n"          # untagged: must be flagged
        "    y = act_q(x, spec, site=\"wq\")\n"   # tagged keyword: fine
        "    z = common.act_q(y, spec, \"wo\")\n"  # tagged positional: fine
        "    return z\n")
    untagged, tags = lint_act_q_calls(snippet, "snippet.py")
    assert untagged == ["snippet.py:2"]
    assert sorted(t for t, _ in tags) == ["wo", "wq"]
