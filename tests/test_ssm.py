"""Chunked linear-attention engine vs sequential oracle (mLSTM / Mamba2)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.models.ssm_common import (
    causal_conv1d,
    chunked_linear_attention,
    linear_attention_sequential,
)


def make_inputs(b, s, h, dk, dv, seed=0, gated=True):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, s, h, dk)).astype(np.float32)) / np.sqrt(dk)
    v = jnp.asarray(rng.normal(size=(b, s, h, dv)).astype(np.float32))
    log_f = jnp.asarray(
        np.log(rng.uniform(0.7, 0.999, size=(b, s, h))).astype(np.float32)
    )
    if gated:
        log_i = jnp.asarray(
            np.log(rng.uniform(0.1, 1.0, size=(b, s, h))).astype(np.float32)
        )
    else:
        log_i = jnp.zeros((b, s, h), jnp.float32)
    return q, k, v, log_f, log_i


@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
@pytest.mark.parametrize("normalize", [False, True])
def test_chunked_matches_sequential(chunk, normalize):
    q, k, v, lf, li = make_inputs(2, 33, 3, 8, 16, seed=chunk)
    y_c, (s_c, n_c) = chunked_linear_attention(
        q, k, v, lf, li, chunk=chunk, normalize=normalize
    )
    y_s, (s_s, n_s) = linear_attention_sequential(q, k, v, lf, li, normalize=normalize)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(n_c), np.asarray(n_s), rtol=2e-4, atol=2e-4)


def test_state_carry_across_calls():
    """prefill(x[:s1]) then prefill(x[s1:], state) == prefill(x) - the
    property that makes chunked serving correct."""
    q, k, v, lf, li = make_inputs(1, 24, 2, 4, 4, seed=9)
    y_full, st_full = chunked_linear_attention(q, k, v, lf, li, chunk=8)
    cut = 11
    sl = lambda x: x[:, :cut]
    sr = lambda x: x[:, cut:]
    y1, st1 = chunked_linear_attention(sl(q), sl(k), sl(v), sl(lf), sl(li), chunk=8)
    y2, st2 = chunked_linear_attention(
        sr(q), sr(k), sr(v), sr(lf), sr(li), chunk=8, state=st1
    )
    np.testing.assert_allclose(
        np.concatenate([np.asarray(y1), np.asarray(y2)], 1), np.asarray(y_full),
        rtol=2e-4, atol=2e-4,
    )
    np.testing.assert_allclose(np.asarray(st2[0]), np.asarray(st_full[0]), rtol=2e-4, atol=2e-4)


def test_causal_conv_matches_shift_sum():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 10, 5)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32))
    y, state = causal_conv1d(x, w)
    xp = np.concatenate([np.zeros((2, 3, 5), np.float32), np.asarray(x)], 1)
    want = sum(xp[:, i : i + 10] * np.asarray(w)[i] for i in range(4))
    want = np.asarray(jax.nn.silu(jnp.asarray(want)))
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(state), xp[:, -3:], rtol=1e-6, atol=1e-6)


def test_conv_state_decode_consistency():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(1, 8, 3)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    y_full, _ = causal_conv1d(x, w)
    y1, st = causal_conv1d(x[:, :7], w)
    y2, _ = causal_conv1d(x[:, 7:8], w, state=st)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_full)[:, 7:8], rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(s=st.integers(1, 40), chunk=st.sampled_from([4, 16, 128]), seed=st.integers(0, 99))
def test_property_chunk_invariance(s, chunk, seed):
    """Output must not depend on the chunk size (incl. ragged tails)."""
    q, k, v, lf, li = make_inputs(1, s, 2, 4, 4, seed=seed)
    y_a, _ = chunked_linear_attention(q, k, v, lf, li, chunk=chunk, normalize=True)
    y_b, _ = chunked_linear_attention(q, k, v, lf, li, chunk=7, normalize=True)
    np.testing.assert_allclose(np.asarray(y_a), np.asarray(y_b), rtol=3e-4, atol=3e-4)
