"""QuantizedModel artifact tests: quantize -> save -> load -> serve.

The front-door contract (repro.api) over all five model families at
reduced scale:

  * the packed integer representation round-trips a save/load bit-exactly;
  * executing the packed params through the "reference" backend is
    logit-identical to the legacy fake-quant float pipeline;
  * the "pallas" backend (fused dequant_matmul, interpret mode on CPU)
    matches within dtype tolerance on dense + MoE;
  * a ServeEngine built from a *loaded* artifact generates the same
    tokens as one built from the in-memory quantization - i.e. serving
    never needs to re-quantize.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.models.registry import get_arch
from repro.quant import pack
from repro.quant.packed import PackedWeight, is_packed, set_backend
from repro.quant.pipeline import PTQConfig, quantize_model

FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mla": "minicpm3-4b",
    "ssm": "xlstm-1.3b",
    "hybrid": "zamba2-1.2b",
}
FAMILIES = sorted(FAMILY_ARCHS)

_PTQ = PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn", group=32)


@pytest.fixture(scope="module")
def quantized():
    """{family: (arch, float params, QuantizedModel, tokens)} cache."""
    out = {}
    for family, name in FAMILY_ARCHS.items():
        arch = get_arch(name, reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        toks = jax.random.randint(
            jax.random.PRNGKey(1), (2, 16), 0, arch.config.vocab
        )
        out[family] = (arch, params, api.quantize(arch, params, _PTQ), toks)
    return out


def _packed_leaves(tree):
    return [l for l in jax.tree.leaves(tree, is_leaf=is_packed) if is_packed(l)]


# ---------------------------------------------------------------------------
# Packing layer: stacked layouts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("lead", [(), (3,), (2, 5)])
def test_pack_roundtrip_stacked(bits, lead):
    rng = np.random.default_rng(bits)
    codes = rng.integers(0, 2**bits, size=(*lead, 16, 8))
    packed = pack.pack_codes(jnp.asarray(codes), bits)
    assert packed.shape == (*lead, 16 // pack.codes_per_byte(bits), 8)
    assert packed.dtype == jnp.uint8
    unpacked = pack.unpack_codes(packed, bits, 16)
    np.testing.assert_array_equal(np.asarray(unpacked), codes)


def test_packed_weight_from_float_stacked_matches_2d():
    """A (L, C, H) stack quantizes layer-for-layer like its 2-D slices."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(3, 32, 8)).astype(np.float32))
    from repro.quant.qtypes import paper_weight_cfg

    cfg = paper_weight_cfg(4, group=16)
    stacked = PackedWeight.from_float(w, cfg)
    for i in range(3):
        single = PackedWeight.from_float(w[i], cfg)
        np.testing.assert_array_equal(
            np.asarray(stacked.codes[i]), np.asarray(single.codes))
        np.testing.assert_array_equal(
            np.asarray(stacked.dequantize()[i]), np.asarray(single.dequantize()))


# ---------------------------------------------------------------------------
# Reference backend == legacy fake-quant pipeline (all five families)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_dequantize_bit_identical_to_legacy_pipeline(quantized, family):
    arch, params, qm, _ = quantized[family]
    legacy, spec = quantize_model(arch, params, _PTQ)
    assert spec == qm.spec
    for a, b in zip(jax.tree.leaves(qm.dequantize()), jax.tree.leaves(legacy)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("family", FAMILIES)
def test_reference_backend_logit_identical(quantized, family):
    """Packed execution (dequant-on-use dispatch) == fake-quant floats."""
    arch, params, qm, toks = quantized[family]
    legacy, spec = quantize_model(arch, params, _PTQ)
    lf = arch.forward(legacy, {"tokens": toks}, spec)
    lp = arch.forward(qm.params, {"tokens": toks}, qm.spec)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(lp))


# ---------------------------------------------------------------------------
# Save / load round trip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_roundtrip_bit_exact(quantized, family, tmp_path):
    arch, _, qm, toks = quantized[family]
    d = str(tmp_path / family)
    qm.save(d)
    qm2 = api.load_quantized(d)
    assert qm2.config == qm.config
    assert qm2.ptq == qm.ptq and qm2.spec == qm.spec

    leaves1 = jax.tree.leaves(qm.params, is_leaf=is_packed)
    leaves2 = jax.tree.leaves(qm2.params, is_leaf=is_packed)
    assert len(leaves1) == len(leaves2)
    n_packed = 0
    for l1, l2 in zip(leaves1, leaves2):
        assert is_packed(l1) == is_packed(l2)
        if is_packed(l1):
            n_packed += 1
            np.testing.assert_array_equal(np.asarray(l1.codes), np.asarray(l2.codes))
            np.testing.assert_array_equal(np.asarray(l1.scale), np.asarray(l2.scale))
            np.testing.assert_array_equal(np.asarray(l1.zero), np.asarray(l2.zero))
            assert (l1.bits, l1.group, l1.c, l1.dtype, l1.packed) == (
                l2.bits, l2.group, l2.c, l2.dtype, l2.packed)
        else:
            assert l1.dtype == l2.dtype
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    assert n_packed > 0, "artifact contained no packed weights"

    # loaded artifact evaluates identically
    lf = arch.forward(qm.params, {"tokens": toks}, qm.spec)
    ll = qm2.arch.forward(qm2.params, {"tokens": toks}, qm2.spec)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ll))


@pytest.mark.parametrize("shards", [2, 3])
def test_multi_shard_roundtrip_bit_exact(quantized, shards, tmp_path):
    """Multi-host artifact layout: one byte-balanced shard_<i>.npz per
    host, manifest written after the last shard, restore merges all shards
    bit-exactly (single-process stand-in for the cluster write)."""
    import os

    arch, _, qm, toks = quantized["dense"]
    d = str(tmp_path / f"sharded{shards}")
    stepdir = qm.save(d, shards=shards)
    files = sorted(f for f in os.listdir(stepdir) if f.endswith(".npz"))
    assert files == [f"shard_{i}.npz" for i in range(shards)]

    qm2 = api.load_quantized(d)
    assert qm2.config == qm.config and qm2.ptq == qm.ptq
    leaves1 = jax.tree.leaves(qm.params, is_leaf=is_packed)
    leaves2 = jax.tree.leaves(qm2.params, is_leaf=is_packed)
    for l1, l2 in zip(leaves1, leaves2):
        if is_packed(l1):
            np.testing.assert_array_equal(np.asarray(l1.codes), np.asarray(l2.codes))
            np.testing.assert_array_equal(np.asarray(l1.scale), np.asarray(l2.scale))
        else:
            np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
    lf = arch.forward(qm.params, {"tokens": toks}, qm.spec)
    ll = qm2.arch.forward(qm2.params, {"tokens": toks}, qm2.spec)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ll))


def test_save_is_atomic_and_self_describing(quantized, tmp_path):
    import json
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "artifact")
    stepdir = qm.save(d)
    with open(os.path.join(stepdir, "manifest.json")) as f:
        man = json.load(f)
    assert man["kind"] == "quantized-model"
    assert man["config"]["name"] == qm.config.name
    assert man["ptq"]["r1_kind"] == "GSR"
    assert man["packed"], "manifest must enumerate packed leaves"
    for meta in man["packed"].values():
        assert set(meta) >= {"bits", "group", "c", "dtype", "packed"}


def test_load_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        api.load_quantized(str(tmp_path / "nope"))


# ---------------------------------------------------------------------------
# Corrupted artifacts fail with the path and a hint, never a raw
# KeyError/BadZipFile
# ---------------------------------------------------------------------------


def test_load_truncated_shard_names_path(quantized, tmp_path):
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "trunc")
    stepdir = qm.save(d)
    shard = os.path.join(stepdir, "shard_0.npz")
    with open(shard, "r+b") as f:  # chop the zip central directory off
        f.truncate(os.path.getsize(shard) // 2)
    with pytest.raises(ValueError, match=r"shard_0\.npz.*truncated"):
        api.load_quantized(d)


def test_load_missing_manifest_explains_interrupted_save(quantized, tmp_path):
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "noman")
    stepdir = qm.save(d)
    os.unlink(os.path.join(stepdir, "manifest.json"))
    with pytest.raises(ValueError, match="no manifest.json"):
        api.load_quantized(d)


def test_load_unknown_format_version_raises(quantized, tmp_path):
    import json
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "future")
    stepdir = qm.save(d)
    man_path = os.path.join(stepdir, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["format"] = 99
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="format 99 is newer"):
        api.load_quantized(d)


def test_load_manifest_missing_key_raises(quantized, tmp_path):
    import json
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "nokey")
    stepdir = qm.save(d)
    man_path = os.path.join(stepdir, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    del man["packed"]
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="missing the 'packed' entry"):
        api.load_quantized(d)


def test_load_wrong_kind_raises(quantized, tmp_path):
    import json
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "kind")
    stepdir = qm.save(d)
    man_path = os.path.join(stepdir, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    man["kind"] = "trainer-checkpoint"
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError, match="expected 'quantized-model'"):
        api.load_quantized(d)


def test_load_missing_shard_raises(quantized, tmp_path):
    import os

    _, _, qm, _ = quantized["dense"]
    d = str(tmp_path / "noshard")
    qm.save(d, shards=2)
    stepdir = os.path.join(d, "step_00000000")
    os.unlink(os.path.join(stepdir, "shard_1.npz"))
    with pytest.raises(ValueError, match=r"missing shard 1 of 2"):
        api.load_quantized(d)


def test_restore_checkpoint_truncated_shard_names_path(tmp_path):
    import os

    from repro.checkpoint import ckpt

    tree = {"w": np.arange(6, dtype=np.float32)}
    d = str(tmp_path / "ck")
    stepdir = ckpt.save_checkpoint(d, 0, tree)
    shard = os.path.join(stepdir, "shard_0.npz")
    with open(shard, "r+b") as f:
        f.truncate(os.path.getsize(shard) // 2)
    with pytest.raises(ValueError, match=r"shard_0\.npz.*truncated"):
        ckpt.restore_checkpoint(d, tree)


def test_restore_checkpoint_template_mismatch_names_key(tmp_path):
    from repro.checkpoint import ckpt

    d = str(tmp_path / "ck2")
    ckpt.save_checkpoint(d, 0, {"w": np.arange(6, dtype=np.float32)})
    with pytest.raises(ValueError, match="no entry 'other'"):
        ckpt.restore_checkpoint(
            d, {"other": np.zeros((6,), np.float32)})


# ---------------------------------------------------------------------------
# Serving off the artifact
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_serve_off_loaded_artifact_matches_in_memory(quantized, family, tmp_path):
    """quantize -> save -> load -> serve produces the same greedy tokens
    as serving the in-memory quantization: no re-quantization anywhere."""
    arch, _, qm, toks = quantized[family]
    d = str(tmp_path / family)
    qm.save(d)
    qm2 = api.load_quantized(d)

    scfg = api.ServeConfig(max_seq=32, batch_slots=2)
    prompts = np.asarray(toks[:, :8])
    out1 = qm.serve(scfg).generate(prompts, 3)
    out2 = qm2.serve(scfg).generate(prompts, 3)
    np.testing.assert_array_equal(out1["tokens"], out2["tokens"])


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_pallas_backend_matches_reference(quantized, family):
    """backend="pallas" (fused dequant_matmul, interpret mode on CPU)
    agrees with the reference dequant-on-use path within f32 tolerance."""
    arch, _, qm, toks = quantized[family]
    batch = {"tokens": toks[:, :8]}
    ref = arch.forward(set_backend(qm.params, "reference"), batch, qm.spec)
    pal = arch.forward(set_backend(qm.params, "pallas"), batch, qm.spec)
    np.testing.assert_allclose(
        np.asarray(pal), np.asarray(ref), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("family", ["dense", "moe"])
def test_pallas_backend_serve_tokens_match(quantized, family):
    _, _, qm, toks = quantized[family]
    scfg = api.ServeConfig(max_seq=24, batch_slots=2)
    prompts = np.asarray(toks[:, :8])
    out_ref = qm.serve(scfg, backend="reference").generate(prompts, 3)
    out_pal = qm.serve(scfg, backend="pallas").generate(prompts, 3)
    np.testing.assert_array_equal(out_ref["tokens"], out_pal["tokens"])


def test_packed_bytes_smaller_than_float(quantized):
    arch, params, qm, _ = quantized["dense"]
    float_bytes = sum(
        np.asarray(l).nbytes
        for l in jax.tree.leaves(params)
    )
    assert 0 < qm.packed_bytes() < float_bytes
