"""End-to-end elastic re-mesh test (ROADMAP open item).

Train N steps on mesh A, checkpoint, resume on mesh B via
``dist.elastic.plan_remesh`` + ``reshard`` (the Trainer's restore path),
and assert the post-resize loss trajectory matches the unresized run.

Multiple devices only exist if ``--xla_force_host_platform_device_count``
is set *before* jax initialises, and the pytest process must keep seeing
1 CPU device (see test_dist.py) — so the whole scenario runs in a
subprocess with its own XLA_FLAGS.
"""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import json
import sys

import jax
import jax.numpy as jnp

from repro.data import SyntheticLM
from repro.dist.elastic import make_mesh, plan_remesh
from repro.models.registry import get_arch
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig

out_path, ckpt_root = sys.argv[1], sys.argv[2]
TOTAL, RESIZE_AT, BATCH = 6, 3, 8

arch = get_arch("smollm-135m", reduced=True)
cfg = arch.config
data = SyntheticLM(cfg.vocab, 32, seed=5)


def batches_from(trainer):
    step = trainer.step
    while True:
        yield {"tokens": data.batch(step, 0, BATCH)}
        step += 1


def run(tag, phases):
    # phases: [(n_devices, total_steps), ...] sharing one ckpt dir
    losses = {}
    for n_dev, total in phases:
        plan = plan_remesh(n_dev, BATCH, model_parallel=1)
        assert plan.mesh_shape[0] == n_dev and plan.effective_batch == BATCH
        mesh = make_mesh(plan)
        opt = OptConfig(lr=1e-2, warmup_steps=2, total_steps=TOTAL)
        tcfg = TrainerConfig(total_steps=total, ckpt_interval=RESIZE_AT,
                             ckpt_dir=os.path.join(ckpt_root, tag),
                             log_interval=1, seed=0)
        trainer = Trainer(arch, opt, tcfg, mesh=mesh)
        trainer.run(batches_from(trainer))
        for rec in trainer.metrics_log:
            losses[rec["step"]] = rec["loss"]
    return losses


# Control: mesh A (2 devices) end to end, no resize.
control = run("control", [(2, TOTAL)])
# Elastic: mesh A to step 3, checkpoint, resume on mesh B (4 devices).
elastic = run("elastic", [(2, RESIZE_AT), (4, TOTAL)])

with open(out_path, "w") as f:
    json.dump({"control": control, "elastic": elastic}, f)
"""


def test_elastic_resize_preserves_loss_trajectory(tmp_path):
    out = tmp_path / "losses.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", _SCRIPT, str(out), str(tmp_path / "ckpt")],
        env=env, capture_output=True, text=True, timeout=540,
    )
    assert proc.returncode == 0, proc.stderr[-4000:]
    res = json.loads(out.read_text())
    control, elastic = res["control"], res["elastic"]
    assert set(control) == set(elastic) and len(control) == 6
    # Pre-resize steps ran on the same mesh: identical.
    for s in ("1", "2", "3"):
        np.testing.assert_allclose(elastic[s], control[s], rtol=1e-5)
    # Post-resize (2 -> 4 data shards): same trajectory up to the changed
    # reduction order of the data-parallel mean/sum.
    for s in ("4", "5", "6"):
        np.testing.assert_allclose(elastic[s], control[s], rtol=2e-3, atol=2e-3)
