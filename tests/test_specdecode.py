"""Self-drafted speculative decoding tests: derive_draft + draft-k/verify-1.

The acceptance contract of the spec-decode subsystem
(:mod:`repro.serve.specdecode`):

  * greedy spec decode is *token-identical* to greedy non-spec decode
    (and hence to ``generate_static``) across the paged attention-cache
    families (dense / MoE / MLA), float and quantized KV, for every
    draft depth k — the draft quality moves the acceptance rate, never
    the text;
  * a stop token landing mid-window ends the request there: later
    accepted tokens are discarded, the rollback rewinds the pool, and no
    block leaks;
  * the pool passes its invariant + leak checks after every scheduler
    step of a trace with real rejections (rewind is exercised, not just
    full acceptance);
  * ``api.derive_draft`` validates the overlay at construction time —
    weight-only, layer-uniform, calibration-free, strictly cheaper —
    with actionable errors, and the derived draft saves/loads as a
    normal artifact with the *identical* serving spec;
  * ``qm.serve(draft=...)`` rejects drafts whose config or cache codec
    differ from the target's (one pool, one codec).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.models.registry import get_arch
from repro.quant.policy import (QuantPolicy, RotationPlan, RotationSpec,
                                SiteRule)
from repro.serve import specdecode
from repro.serve.scheduler import synthetic_trace

PAGED_FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mla": "minicpm3-4b",
}
PAGED_FAMILIES = sorted(PAGED_FAMILY_ARCHS)

DRAFT = "draft-w3-rtn"  # decent acceptance on reduced random models


def _w4_policy(kv_bits=16):
    """W4 RTN GSR target — roomy enough for a w2/w3 draft underneath.

    (The paper-table1 preset is already W2, which a draft cannot
    undercut — derive_draft rejects it by design.)"""
    return QuantPolicy(
        name=f"w4-rtn-kv{kv_bits}",
        rules=(SiteRule(pattern="*", bits=4, group=32, method="rtn"),),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=32)),
        act_bits=16, kv_bits=kv_bits,
    )


@pytest.fixture(scope="module")
def quantized():
    """{(family, kv_bits): QuantizedModel} at reduced scale, W4 target."""
    out = {}
    for family, name in PAGED_FAMILY_ARCHS.items():
        arch = get_arch(name, reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        for kv in (16, 4):
            out[family, kv] = api.quantize(arch, params, _w4_policy(kv))
    return out


def _prompts(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)


def _spec_engine(qm, k, *, slots=2, max_seq=48):
    draft = api.derive_draft(qm, DRAFT)
    return qm.serve(api.ServeConfig(max_seq=max_seq, batch_slots=slots,
                                    block_tokens=8, spec_decode=True,
                                    draft_k=k),
                    draft=draft)


# ---------------------------------------------------------------------------
# Token identity: spec decode == static greedy, families x KV x k
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", PAGED_FAMILIES)
@pytest.mark.parametrize("kv_bits", [16, 4])
@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_token_identical_to_static(quantized, family, kv_bits, k):
    """3 requests through 2 slots with draft-k/verify-1 produce exactly
    the static fixed-batch greedy tokens; the drained pool is pristine."""
    qm = quantized[family, kv_bits]
    prompts = _prompts(qm.config, 3, 8)
    out_s = qm.serve(api.ServeConfig(max_seq=48, batch_slots=3)
                     ).generate_static(prompts, 6)
    eng = _spec_engine(qm, k)
    out_c = eng.generate(prompts, 6)
    np.testing.assert_array_equal(out_s["tokens"], out_c["tokens"])
    agg = eng.scheduler.metrics()["aggregate"]
    assert agg["spec_windows"] == agg["decode_steps"] > 0
    assert agg["spec_draft_tokens"] == agg["busy_slot_steps"] * k
    assert 0 <= agg["spec_accepted_tokens"] <= agg["spec_draft_tokens"]
    eng.pool.check_invariants()
    assert not any(eng.pool.slot_blocks[s] for s in range(2))


def test_spec_fewer_verify_steps_than_baseline(quantized):
    """The point of the exercise: the same trace finishes in fewer
    target-model invocations than one-token-per-step decode."""
    qm = quantized["dense", 16]
    prompts = _prompts(qm.config, 4, 8, seed=1)
    base = qm.serve(api.ServeConfig(max_seq=48, batch_slots=2,
                                    block_tokens=8))
    out_b = base.generate(prompts, 12)
    eng = _spec_engine(qm, 4)
    out_c = eng.generate(prompts, 12)
    np.testing.assert_array_equal(out_b["tokens"], out_c["tokens"])
    steps_b = base.scheduler.metrics()["aggregate"]["decode_steps"]
    steps_c = eng.scheduler.metrics()["aggregate"]["decode_steps"]
    assert steps_c < steps_b, (steps_c, steps_b)


# ---------------------------------------------------------------------------
# Rollback: stop tokens mid-window, rejection rewind invariants
# ---------------------------------------------------------------------------


def test_stop_token_mid_window_ends_request(quantized):
    """A stop token accepted mid-window terminates the request at that
    token — the rest of the accepted run is dropped, matching what the
    sequential scheduler would have emitted."""
    qm = quantized["dense", 16]
    prompt = _prompts(qm.config, 1, 8, seed=2)[0]
    ref_eng = qm.serve(api.ServeConfig(max_seq=48, batch_slots=1,
                                       block_tokens=8))
    ref = ref_eng.submit(prompt, 8)
    ref_eng.drain()
    for pos in (1, 2):  # stop on the 2nd / 3rd greedy token
        stop = int(ref.token_array()[pos])
        eng = _spec_engine(qm, 4, slots=1)
        r = eng.submit(prompt, 8, stop_token=stop)
        eng.drain()
        assert len(r.tokens) == pos + 1
        assert int(r.token_array()[-1]) == stop
        np.testing.assert_array_equal(r.token_array(),
                                      ref.token_array()[:pos + 1])
        eng.pool.check_invariants()
        assert not any(eng.pool.slot_blocks)


@pytest.mark.parametrize("kv_bits", [16, 4])
def test_pool_invariants_after_rejection_rewind(quantized, kv_bits):
    """Mixed-length trace with real draft rejections: after every spec
    window no block is leaked or double-assigned, and the drained pool's
    free list is whole."""
    qm = quantized["dense", kv_bits]
    eng = _spec_engine(qm, 4, slots=2, max_seq=48)
    trace = synthetic_trace(qm.config, 6, seed=3, prompt_len=6,
                            prompt_jitter=4, max_new_low=2, max_new_high=10)
    for r in trace:
        eng.scheduler.submit(r)
        eng.pool.check_invariants()
    while eng.scheduler.queue or eng.scheduler.n_active:
        eng.step()
        eng.pool.check_invariants()
    assert all(len(r.tokens) == r.max_new_tokens for r in trace)
    agg = eng.scheduler.metrics()["aggregate"]
    assert agg["spec_accepted_tokens"] < agg["spec_draft_tokens"], \
        "trace never exercised a rejection rewind"
    assert len(eng.pool.free) == eng.pool.capacity_blocks
    assert not any(eng.pool.slot_blocks)


# ---------------------------------------------------------------------------
# Artifact side: derive_draft validation + save/load round trip
# ---------------------------------------------------------------------------


def test_derive_draft_shares_spec_and_float_leaves(quantized):
    qm = quantized["dense", 4]
    draft = api.derive_draft(qm, DRAFT)
    assert draft.spec == qm.spec  # one cache codec, one pool
    assert draft.config == qm.config
    # w2 codes pack below the W4 target's (w3 rides an int8 lane at this
    # scale, so bits-in-tree is the invariant, bytes only for w2)
    assert (api.derive_draft(qm, "draft-w2-rtn").packed_bytes()
            < qm.packed_bytes())
    # every packed leaf got strictly cheaper; float leaves are the same
    # objects (shared by reference, no copy)
    tgt = dict(specdecode.packed_sites(qm.params))
    for site, leaf in specdecode.packed_sites(draft.params):
        assert leaf.bits == 3 and tgt[site].bits == 4, site
    assert draft.params["embed"] is qm.params["embed"]


def test_draft_artifact_save_load_round_trip(quantized, tmp_path):
    """A derived draft is a normal artifact: it saves, reloads with the
    identical serving spec, and serves the same spec-decoded tokens."""
    qm = quantized["dense", 16]
    draft = api.derive_draft(qm, DRAFT)
    draft.save(str(tmp_path / "draft"))
    draft2 = api.load_quantized(str(tmp_path / "draft"))
    assert draft2.spec == draft.spec == qm.spec
    assert draft2.policy.describe() == draft.policy.describe()
    prompts = _prompts(qm.config, 2, 8, seed=4)
    scfg = api.ServeConfig(max_seq=48, batch_slots=2, block_tokens=8,
                           spec_decode=True, draft_k=2)
    out1 = qm.serve(scfg, draft=draft).generate(prompts, 5)
    out2 = qm.serve(scfg, draft=draft2).generate(prompts, 5)
    np.testing.assert_array_equal(out1["tokens"], out2["tokens"])


def test_derive_draft_validation_errors(quantized):
    qm = quantized["dense", 16]
    with pytest.raises(ValueError, match="at least one SiteRule"):
        api.derive_draft(qm, QuantPolicy(name="empty", rules=()))

    def overlay(**kw):
        return QuantPolicy(name="bad", rules=(
            SiteRule(pattern="*", bits=2, group=32, method="rtn", **kw),))

    with pytest.raises(ValueError, match="layer-restricted"):
        api.derive_draft(qm, overlay(layers=(0, 1)))
    with pytest.raises(ValueError, match="online rotation"):
        api.derive_draft(qm, overlay(rotation="GSR"))
    with pytest.raises(ValueError, match="activation quantization"):
        api.derive_draft(qm, overlay(act_bits=8))
    with pytest.raises(ValueError, match="method 'gptq'"):
        api.derive_draft(qm, QuantPolicy(name="bad", rules=(
            SiteRule(pattern="*", bits=2, group=32, method="gptq"),)))
    with pytest.raises(ValueError, match="in float"):
        api.derive_draft(qm, QuantPolicy(name="bad", rules=(
            SiteRule(pattern="*", bits=16, group=32, method="rtn"),)))
    # covers only part of the tree -> uncovered packed site
    with pytest.raises(ValueError, match="uncovered"):
        api.derive_draft(qm, QuantPolicy(name="bad", rules=(
            SiteRule(pattern="*down*", bits=2, group=32, method="rtn"),)))
    # not strictly cheaper: same width as the W4 target everywhere
    with pytest.raises(ValueError, match="not strictly cheaper"):
        api.derive_draft(qm, QuantPolicy(name="bad", rules=(
            SiteRule(pattern="*", bits=4, group=32, method="rtn"),)))
    # above the target's width at some site
    with pytest.raises(ValueError, match="above the target"):
        api.derive_draft(qm, QuantPolicy(name="bad", rules=(
            SiteRule(pattern="*", bits=8, group=32, method="rtn"),)))


def test_serve_rejects_mismatched_draft(quantized):
    """One pool needs one cache codec: a draft derived from the KV4
    artifact cannot serve the float-KV target (and vice versa)."""
    qm16, qm4 = quantized["dense", 16], quantized["dense", 4]
    draft4 = api.derive_draft(qm4, DRAFT)
    scfg = api.ServeConfig(max_seq=48, batch_slots=1, block_tokens=8,
                           spec_decode=True, draft_k=2)
    with pytest.raises(ValueError, match="spec differs"):
        qm16.serve(scfg, draft=draft4)
    moe = quantized["moe", 16]
    with pytest.raises(ValueError, match="config differs"):
        moe.serve(scfg, draft=api.derive_draft(qm16, DRAFT))


def test_spec_decode_requires_supported_engine(quantized):
    """Gating: recurrent-state families and missing drafts fail fast at
    engine build, not with wrong tokens later."""
    qm = quantized["dense", 16]
    scfg = api.ServeConfig(max_seq=48, batch_slots=1, block_tokens=8,
                           spec_decode=True, draft_k=2)
    with pytest.raises(ValueError, match="no draft weights"):
        qm.serve(scfg).scheduler  # spec_decode without a draft
    arch = get_arch("xlstm-1.3b", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    qs = api.quantize(arch, params, _w4_policy())
    with pytest.raises(ValueError, match="rewind"):
        qs.serve(scfg, draft=api.derive_draft(qs, DRAFT)).scheduler
