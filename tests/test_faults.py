"""Fault-injection chaos tests: the serving robustness contract.

The invariant everything here locks down: **under any injected fault
plan, every surviving request's token stream is bit-identical to the
fault-free run, and the pool reconciles after drain** — a poisoned
request, a throwing callback, a failing draft window, a corrupted prefix
index, or an expiring deadline takes down exactly one request (or one
subsystem's fast path), never the engine and never a survivor's tokens.

Why survivors can be bit-identical at all: prefill and decode are
per-sequence computations and sampling keys are per-request
(fold_in(seed, rid)), so failures changing *scheduling* (a freed slot
refills earlier) cannot change any surviving sequence's logits or draws.

Also covered: ``faults=None`` is bit-identical to pre-robustness
behaviour (tokens and ``scheduler.metrics()``), deadlines/backpressure,
spec-decode degradation, the health cycle's leak recovery, and
``engine.health()``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.common import QuantizeSpec
from repro.models.registry import get_arch
from repro.serve import FaultPlan, QueueFull
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.faults import FaultInjector, InjectedFault, StallClock

FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mla": "minicpm3-4b",
}
FAMILIES = sorted(FAMILY_ARCHS)


@pytest.fixture(scope="module")
def models():
    out = {}
    for family, name in FAMILY_ARCHS.items():
        arch = get_arch(name, reduced=True)
        out[family] = (arch, arch.init(jax.random.PRNGKey(0), jnp.float32))
    return out


def _prompts(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)


def _run(arch, params, scfg, prompts, max_new=6, deadlines=None, spec=None,
         draft_params=None):
    eng = ServeEngine(arch, params, scfg, spec or QuantizeSpec(),
                      draft_params=draft_params)
    reqs = []
    for i, p in enumerate(prompts):
        dl = None if deadlines is None else deadlines.get(i)
        reqs.append(eng.submit(p, max_new, deadline_s=dl))
    eng.drain()
    return eng, reqs


def _tokens(reqs):
    return {r.rid: r.token_array().tolist() for r in reqs}


# ---------------------------------------------------------------------------
# The chaos invariant: combined fault plan, survivors bit-identical
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
@pytest.mark.parametrize("kv_bits", [16, 4])
def test_chaos_survivors_bit_identical(models, family, kv_bits):
    """One run under a combined plan — NaN logits, a throwing callback,
    a leaked pool block, a corrupted prefix index, and a zero-TTL
    request — against the clean run: every surviving request's tokens
    match bit-for-bit, every failed request carries status/error, and
    the pool passes check_invariants after drain."""
    arch, params = models[family]
    spec = QuantizeSpec(kv_bits=kv_bits)
    prompts = _prompts(arch.config, 6, 8)
    base = dict(max_seq=48, batch_slots=2, block_tokens=4, prefix_cache=True)

    _, clean = _run(arch, params, ServeConfig(**base), prompts, spec=spec)
    want = _tokens(clean)

    plan = FaultPlan(
        nan_logits=[(1, 2)],        # r1 poisoned at its 3rd token
        callback_raise=[(3, 1)],    # r3's callback throws on its 2nd token
        leak_block=[0],             # first release leaks a block
        corrupt_prefix=[1],         # second insert plants a bogus node
    )
    eng, reqs = _run(
        arch, params,
        ServeConfig(**base, faults=plan, health_every_syncs=3),
        prompts, spec=spec, deadlines={5: 0.0})  # r5 expires in queue

    failed = {r.rid: r for r in reqs if r.status != "done"}
    assert set(failed) == {1, 3, 5}
    assert failed[1].status == "failed" and "non-finite" in failed[1].error
    assert failed[3].status == "failed" and "callback" in failed[3].error
    assert failed[5].status == "timeout" and failed[5].error
    # partial progress is preserved up to the failure point
    assert _tokens([failed[1]])[1] == want[1][:2]
    for r in reqs:
        if r.status == "done":
            assert r.token_array().tolist() == want[r.rid], f"r{r.rid}"
            assert r.error is None
    # resources reconciled: no leaked or double-owned blocks survive the
    # plan (the health cycle reclaimed the injected leak as a counted
    # recoverable event)
    eng.pool.check_invariants()
    assert eng.faults.leaked_blocks, "the leak must actually have fired"
    assert len(eng.faults.fired) >= 4
    # failures surfaced through the registry, not metrics() aggregates
    reg = eng.scheduler.reg
    by_reason = reg.counter("serve_requests_failed_total")
    assert by_reason.value(reason="nan_logits") == 1
    assert by_reason.value(reason="callback") == 1
    assert by_reason.value(reason="timeout") == 1
    assert reg.counter("kvpool_blocks_recovered_total").value() >= 1


@pytest.mark.parametrize("steps_per_sync", [1, 4])
def test_nan_quarantine_tick_and_window(models, steps_per_sync):
    """NaN isolation on both decode paths: the poisoned request fails at
    exactly the planned token index; survivors and the pool are clean."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 3, 8)
    base = dict(max_seq=32, batch_slots=2, block_tokens=8,
                steps_per_sync=steps_per_sync)
    _, clean = _run(arch, params, ServeConfig(**base), prompts)
    want = _tokens(clean)
    eng, reqs = _run(arch, params,
                     ServeConfig(**base, faults=FaultPlan(nan_logits=[(0, 3)])),
                     prompts)
    assert reqs[0].status == "failed"
    assert len(reqs[0].tokens) == 3  # tokens before the poisoned index
    assert reqs[0].token_array().tolist() == want[0][:3]
    assert reqs[1].token_array().tolist() == want[1]
    assert reqs[2].token_array().tolist() == want[2]
    eng.pool.check_invariants()
    eng.pool.check_leaks()


def test_nan_at_prefill_sample(models):
    """Poison index 0 fires on the admission sample: the request fails
    with zero tokens, the slot refills, survivors unaffected."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 3, 8)
    base = dict(max_seq=32, batch_slots=2, block_tokens=8)
    _, clean = _run(arch, params, ServeConfig(**base), prompts)
    eng, reqs = _run(arch, params,
                     ServeConfig(**base, faults=FaultPlan(nan_logits=[(1, 0)])),
                     prompts)
    assert reqs[1].status == "failed" and len(reqs[1].tokens) == 0
    assert reqs[1].token_array().shape == (0,)
    assert reqs[0].token_array().tolist() == _tokens(clean)[0]
    assert reqs[2].token_array().tolist() == _tokens(clean)[2]
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Satellite: guarded on_token callbacks (the scheduler.py:307 regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("steps_per_sync", [1, 4])
def test_callback_exception_mid_replay_is_isolated(models, steps_per_sync):
    """A user callback that throws mid-window-replay (the previously
    unguarded call) fails only its own request; the replay loop keeps
    emitting for every other slot and slot/emission state stays
    consistent (pool reconciles, survivors bit-identical)."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 3, 8)
    base = dict(max_seq=32, batch_slots=2, block_tokens=8,
                steps_per_sync=steps_per_sync)
    _, clean = _run(arch, params, ServeConfig(**base), prompts)
    want = _tokens(clean)

    seen = []

    def boom(req, tok, done):
        seen.append((req.rid, int(tok)))
        if req.rid == 0 and len(req.tokens) == 3:
            raise RuntimeError("user callback bug")

    eng = ServeEngine(arch, params, ServeConfig(**base))
    reqs = [eng.submit(p, 6, on_token=boom) for p in prompts]
    eng.drain()
    assert reqs[0].status == "failed"
    assert "user callback bug" in reqs[0].error
    assert len(reqs[0].tokens) == 3  # kept the tokens emitted so far
    assert reqs[1].token_array().tolist() == want[1]
    assert reqs[2].token_array().tolist() == want[2]
    # survivors' callbacks all fired, in token order
    for rid in (1, 2):
        assert [t for r, t in seen if r == rid] == want[rid]
    eng.pool.check_invariants()
    eng.pool.check_leaks()


def test_injected_callback_fault_without_user_callback(models):
    """The callback_raise injection point fires even when the request
    installed no on_token (the guard wraps the whole emission hook)."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 2, 8)
    eng, reqs = _run(arch, params,
                     ServeConfig(max_seq=32, batch_slots=2, block_tokens=8,
                                 faults=FaultPlan(callback_raise=[(0, 1)])),
                     prompts)
    assert reqs[0].status == "failed" and "InjectedFault" in reqs[0].error
    assert reqs[1].status == "done"
    eng.pool.check_invariants()
    eng.pool.check_leaks()


# ---------------------------------------------------------------------------
# Spec decode: draft failure fallback + degradation, NaN in verify
# ---------------------------------------------------------------------------


def _spec_cfg(**kw):
    return ServeConfig(max_seq=48, batch_slots=2, block_tokens=8,
                       spec_decode=True, draft_k=2, **kw)


def test_draft_failure_falls_back_token_identically(models):
    """Every spec window raising: output still bit-identical to the
    plain run; after spec_fail_threshold consecutive failures spec decode
    is disabled globally (counted degradation, engine degraded)."""
    arch, params = models["dense"]
    draft = arch.init(jax.random.PRNGKey(1), jnp.float32)
    prompts = _prompts(arch.config, 3, 8)
    _, clean = _run(arch, params,
                    ServeConfig(max_seq=32, batch_slots=2, block_tokens=8),
                    prompts)
    eng, reqs = _run(
        arch, params,
        _spec_cfg(faults=FaultPlan(draft_fail=list(range(50))),
                  spec_fail_threshold=2),
        prompts, draft_params=draft)
    assert _tokens(reqs) == _tokens(clean)
    assert eng.scheduler.spec_degraded
    assert eng.health()["status"] == "degraded"
    assert eng.health()["spec_decode"]["degraded"]
    reg = eng.scheduler.reg
    assert reg.counter("serve_draft_failures_total").value() == 2
    assert reg.counter("serve_degraded_events_total").value(
        subsystem="specdecode") == 1
    eng.pool.check_invariants()
    eng.pool.check_leaks()


def test_single_draft_failure_recovers_without_degrading(models):
    """One failing window below the threshold: that step decodes plainly,
    spec decode stays on, tokens still bit-identical."""
    arch, params = models["dense"]
    draft = arch.init(jax.random.PRNGKey(1), jnp.float32)
    prompts = _prompts(arch.config, 3, 8)
    _, clean = _run(arch, params,
                    ServeConfig(max_seq=32, batch_slots=2, block_tokens=8),
                    prompts)
    eng, reqs = _run(arch, params,
                     _spec_cfg(faults=FaultPlan(draft_fail=[1]),
                               spec_fail_threshold=2),
                     prompts, draft_params=draft)
    assert _tokens(reqs) == _tokens(clean)
    assert not eng.scheduler.spec_degraded
    assert eng.scheduler.spec_windows > 0
    eng.pool.check_invariants()


def test_spec_nan_verify_quarantines_request(models):
    """NaN injected at a spec-decoded position: the poisoned request
    fails mid-stream with its pre-fault tokens intact; survivors match
    the clean run bit-for-bit."""
    arch, params = models["dense"]
    draft = arch.init(jax.random.PRNGKey(1), jnp.float32)
    prompts = _prompts(arch.config, 3, 8)
    _, clean = _run(arch, params,
                    ServeConfig(max_seq=32, batch_slots=2, block_tokens=8),
                    prompts)
    want = _tokens(clean)
    eng, reqs = _run(arch, params,
                     _spec_cfg(faults=FaultPlan(nan_logits=[(2, 1)])),
                     prompts, draft_params=draft)
    assert reqs[2].status == "failed" and len(reqs[2].tokens) == 1
    assert reqs[2].token_array().tolist() == want[2][:1]
    assert reqs[0].token_array().tolist() == want[0]
    assert reqs[1].token_array().tolist() == want[1]
    eng.pool.check_invariants()
    eng.pool.check_leaks()


def test_acceptance_floor_degrades_token_identically(models):
    """A floor above the mismatched draft's real acceptance rate trips
    per-slot bypass then global disable — tokens never change."""
    arch, params = models["dense"]
    draft = arch.init(jax.random.PRNGKey(1), jnp.float32)  # random draft
    prompts = _prompts(arch.config, 4, 8)
    _, clean = _run(arch, params,
                    ServeConfig(max_seq=32, batch_slots=2, block_tokens=8),
                    prompts, max_new=8)
    eng, reqs = _run(arch, params,
                     _spec_cfg(spec_min_acceptance=0.99,
                               spec_accept_window=2),
                     prompts, max_new=8, draft_params=draft)
    assert _tokens(reqs) == _tokens(clean)
    assert (eng.scheduler.spec_degraded
            or eng.scheduler._spec_bypass), "floor must have tripped"
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Pool corruption + health cycle self-healing
# ---------------------------------------------------------------------------


def test_leaked_block_recovered_by_health_cycle(models):
    """An injected free-list leak is found and reclaimed by the periodic
    audit as a counted recoverable event — check_leaks passes at drain
    instead of raising at teardown."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 4, 8)
    base = dict(max_seq=32, batch_slots=2, block_tokens=8)
    _, clean = _run(arch, params, ServeConfig(**base), prompts)
    eng, reqs = _run(arch, params,
                     ServeConfig(**base, faults=FaultPlan(leak_block=[0, 1]),
                                 health_every_syncs=2),
                     prompts)
    assert _tokens(reqs) == _tokens(clean)
    assert len(eng.faults.leaked_blocks) == 2
    eng.pool.check_invariants()
    eng.pool.check_leaks()
    assert eng.scheduler.reg.counter(
        "kvpool_blocks_recovered_total").value() == 2
    assert eng.health()["pool"]["invariants_ok"]


def test_prefix_corruption_self_bypasses(models):
    """A corrupted prefix index flips the cache to bypass (serving
    unshared, counted) instead of crashing; tokens are unchanged and the
    cache stays off until flushed."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 4, 8)
    base = dict(max_seq=32, batch_slots=2, block_tokens=4, prefix_cache=True)
    _, clean = _run(arch, params, ServeConfig(**base), prompts)
    eng, reqs = _run(arch, params,
                     ServeConfig(**base,
                                 faults=FaultPlan(corrupt_prefix=[0]),
                                 health_every_syncs=2),
                     prompts)
    assert _tokens(reqs) == _tokens(clean)
    pc = eng.prefix_cache
    assert pc.bypassed
    assert pc.stats()["bypassed"]
    assert eng.health()["prefix_cache"]["bypassed"]
    assert eng.scheduler.reg.counter("serve_degraded_events_total").value(
        subsystem="prefixcache") == 1
    # bypassed lookups serve unshared and are counted
    before = pc.stats()["bypass_lookups"]
    nxt = eng.submit(prompts[0], 3)
    eng.drain()
    assert nxt.status == "done"
    assert pc.stats()["bypass_lookups"] > before
    eng.pool.check_invariants()
    # flush re-arms the cache
    pc.flush()
    assert not pc.bypassed
    eng.pool.check_leaks()


# ---------------------------------------------------------------------------
# Deadlines, backpressure, clock stalls
# ---------------------------------------------------------------------------


def test_deadline_expires_in_queue_and_mid_decode(models):
    arch, params = models["dense"]
    cfg = arch.config
    prompts = _prompts(cfg, 3, 8)
    base = dict(max_seq=32, batch_slots=1, block_tokens=8)
    _, clean = _run(arch, params, ServeConfig(**base), prompts)
    # r2 has TTL 0: admitted work never starts, it expires in queue
    eng, reqs = _run(arch, params, ServeConfig(**base), prompts,
                     deadlines={2: 0.0})
    assert reqs[2].status == "timeout" and "in queue" in reqs[2].error
    assert len(reqs[2].tokens) == 0
    assert reqs[0].token_array().tolist() == _tokens(clean)[0]
    assert reqs[1].token_array().tolist() == _tokens(clean)[1]
    eng.pool.check_invariants()
    eng.pool.check_leaks()

    # a clock stall mid-decode expires an *active* request
    eng = ServeEngine(arch, params, ServeConfig(
        **base, faults=FaultPlan(clock_stall=[(10, 600.0)])))
    r = eng.submit(prompts[0], 6, deadline_s=60.0)
    eng.drain()
    assert r.status == "timeout" and "tokens emitted" in r.error
    eng.pool.check_invariants()
    eng.pool.check_leaks()


def test_max_queue_reject_and_raise(models):
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 4, 8)
    base = dict(max_seq=32, batch_slots=1, block_tokens=8, max_queue=2)
    eng = ServeEngine(arch, params, ServeConfig(**base))
    rs = [eng.submit(p, 4) for p in prompts]
    # max_queue bounds *waiting* submissions: the 3rd and 4th arrive with
    # two already queued and are shed
    assert [r.status for r in rs] == ["queued", "queued",
                                      "rejected", "rejected"]
    assert rs[2].error and "queue full" in rs[2].error
    assert rs[2].rid >= 0  # identifiable in logs/metrics
    eng.drain()
    assert all(r.status == "done" for r in rs[:2])
    assert eng.scheduler.reg.counter("serve_requests_failed_total").value(
        reason="queue_full") == 2
    eng.pool.check_invariants()
    eng.pool.check_leaks()

    eng = ServeEngine(arch, params, ServeConfig(**base,
                                                queue_policy="raise"))
    for p in prompts[:2]:
        eng.submit(p, 4)
    with pytest.raises(QueueFull, match="admission queue full"):
        eng.submit(prompts[2], 4)
    eng.drain()


# ---------------------------------------------------------------------------
# Zero-overhead / bit-identity when faults are off
# ---------------------------------------------------------------------------


def test_faults_none_bit_identical_to_empty_plan(models):
    """faults=None (injection compiled out) and FaultPlan() (machinery
    armed, nothing fires) agree on every token and every deterministic
    metrics() aggregate — the zero-overhead discipline."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 4, 8)
    base = dict(max_seq=48, batch_slots=2, block_tokens=4, prefix_cache=True)
    eng_a, ra = _run(arch, params, ServeConfig(**base), prompts)
    eng_b, rb = _run(arch, params, ServeConfig(**base, faults=FaultPlan()),
                     prompts)
    assert _tokens(ra) == _tokens(rb)
    ma = eng_a.scheduler.metrics()["aggregate"]
    mb = eng_b.scheduler.metrics()["aggregate"]
    volatile = {"wall_s", "tokens_per_s", "mean_ttft_s",
                "mean_queue_wait_s"}
    for k in ma:
        if k not in volatile:
            assert ma[k] == mb[k], k
    assert eng_b.faults.fired == []


def test_metrics_keys_unchanged_by_robustness_layer(models):
    """metrics() must not grow aggregate keys (the pre-PR contract);
    failures live in engine.health() and the registry instead."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 2, 8)
    eng, _ = _run(arch, params,
                  ServeConfig(max_seq=32, batch_slots=2, block_tokens=8),
                  prompts)
    agg = eng.scheduler.metrics()["aggregate"]
    assert set(agg) == {
        "n_requests", "decode_steps", "busy_slot_steps", "slot_utilisation",
        "tokens_generated", "host_syncs", "tokens_per_s",
        "mean_queue_wait_s", "mean_ttft_s", "prefill_tokens_computed",
        "prefill_tokens_saved", "prefix_hit_rate", "blocks_shared",
        "cow_copies", "spec_windows", "spec_draft_tokens",
        "spec_accepted_tokens", "spec_acceptance_rate", "prefix_cache",
    }


def test_health_snapshot_shape(models):
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 2, 8)
    eng, _ = _run(arch, params,
                  ServeConfig(max_seq=32, batch_slots=2, block_tokens=4,
                              prefix_cache=True),
                  prompts)
    h = eng.health()
    assert h["status"] == "ok"
    assert h["requests_done"] == 2 and h["requests_failed"] == 0
    assert h["pool"]["invariants_ok"]
    assert h["pool"]["free_blocks"] <= h["pool"]["capacity_blocks"]
    assert h["prefix_cache"] is not None
    assert h["spec_decode"] == {"enabled": False, "degraded": False}


# ---------------------------------------------------------------------------
# FaultPlan / injector unit behaviour
# ---------------------------------------------------------------------------


def test_fault_plan_json_roundtrip(tmp_path):
    plan = FaultPlan(nan_logits=[(1, 2)], callback_raise=[(3, 0)],
                     draft_fail=[5], leak_block=[0], corrupt_prefix=[2],
                     clock_stall=[(7, 1.5)])
    again = FaultPlan.from_json(plan.to_json())
    assert again == plan
    path = tmp_path / "plan.json"
    path.write_text(plan.to_json())
    assert FaultPlan.from_json(f"@{path}") == plan
    assert FaultPlan().empty and not plan.empty
    with pytest.raises(ValueError, match="unknown fault plan keys"):
        FaultPlan.from_json('{"bogus": []}')
    with pytest.raises(ValueError, match="JSON object"):
        FaultPlan.from_json("[1, 2]")


def test_injector_fires_each_entry_once():
    inj = FaultInjector(FaultPlan(nan_logits=[(0, 1), (0, 4)]))
    assert not inj.poison_token(0, 0)
    assert inj.poison_token(0, 1)
    assert not inj.poison_token(0, 1)  # consumed
    # windowed lookup respects the reach limit and keeps later entries
    assert inj.poison_from(0, 2, 4) == -1  # idx 4 beyond [2, 4)
    assert inj.poison_from(0, 2, 5) == 4
    assert inj.poison_from(0, 2, 5) == -1
    assert inj.fired == ["nan_logits r0 t1", "nan_logits r0 t4"]


def test_stall_clock_jumps_at_ordinals():
    base_t = [0.0]

    def base():
        base_t[0] += 1.0
        return base_t[0]

    clock = StallClock(base, ((2, 10.0),))
    assert clock() == 1.0
    assert clock() == 2.0
    assert clock() == 13.0  # 3.0 + 10.0, offset is cumulative
    assert clock() == 14.0
