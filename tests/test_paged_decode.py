"""Fused paged decode path: kernel parity, token identity, in-graph loop.

The acceptance contract of the fused serving hot path:

  * the Pallas paged-attention kernel (interpret mode here) matches the
    reference contiguous-cache attention on a tiny pool, float and
    quantized, GQA and MLA-shaped;
  * the fused pool step (``ServeConfig(paged_kernel=True)``) is
    *token-identical* to both the vmapped gather/scatter baseline and
    ``generate_static()`` across the attention-cache families, float and
    KV4, including mixed per-slot lengths;
  * the in-graph multi-step decode loop (``steps_per_sync > 1``) emits
    exactly the single-sync tokens, honors mid-window stop tokens, keeps
    streaming callbacks in token order, and syncs the host at most once
    per window;
  * the autotune table round-trips through its JSON cache and its
    entries actually steer the kernels.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.common import QuantizeSpec
from repro.models.registry import get_arch
from repro.serve.engine import ServeConfig, ServeEngine

FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mla": "minicpm3-4b",
    "hybrid": "zamba2-1.2b",
}
FAMILIES = sorted(FAMILY_ARCHS)


@pytest.fixture(scope="module")
def models():
    out = {}
    for family, name in FAMILY_ARCHS.items():
        arch = get_arch(name, reduced=True)
        out[family] = (arch, arch.init(jax.random.PRNGKey(0), jnp.float32))
    return out


def _prompts(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.modality == "audio":
        return rng.integers(0, cfg.vocab, size=(b, s, cfg.n_codebooks)
                            ).astype(np.int32)
    return rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)


# ---------------------------------------------------------------------------
# Kernel parity on a tiny pool (interpret mode; also the CI fast cell)
# ---------------------------------------------------------------------------


def _ref_paged_attention(q, kview, vview, lengths, knew, vnew, scale):
    """Oracle: contiguous view + new token, exact softmax, per slot."""
    s, kv, rep, d = q.shape
    outs = []
    for i in range(s):
        ln = int(lengths[i])
        ks = np.concatenate([kview[i, :ln], knew[i][None]], 0)  # (ln+1,KV,d)
        vs = np.concatenate([vview[i, :ln], vnew[i][None]], 0)
        sc = np.einsum("grd,tgd->grt", q[i] * scale, ks)
        p = np.exp(sc - sc.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        outs.append(np.einsum("grt,tgd->grd", p, vs[..., : vs.shape[-1]]))
    return np.stack(outs)


@pytest.mark.parametrize("kvq", [False, True])
@pytest.mark.parametrize("kv,rep", [(2, 3), (1, 4)])
def test_kernel_matches_reference_attention(kvq, kv, rep):
    """Block-table walk + in-kernel dequant + running softmax == exact
    attention over the gathered view, and the new token lands in its
    block (aliased write)."""
    from repro.kernels import ops

    s, mb, t, d = 3, 3, 4, 8
    nb = s * mb + 1
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(s, kv, rep, d)).astype(np.float32))
    tables = jnp.asarray(1 + np.arange(s * mb).reshape(s, mb), jnp.int32)
    lengths = jnp.asarray([5, 11, 2], jnp.int32)
    scale = 1.0 / np.sqrt(d)
    if kvq:
        pages = lambda: jnp.asarray(
            rng.integers(0, 16, size=(2, nb, t, kv, d)), jnp.uint8)
        scales = lambda: jnp.asarray(
            0.1 + np.abs(rng.normal(size=(2, nb, t, kv))), jnp.float32)
        kp = (pages(), scales(), scales())
        vp = (pages(), scales(), scales())
        k_new = (jnp.asarray(rng.integers(0, 16, size=(s, kv, d)), jnp.uint8),
                 jnp.full((s, kv), 0.5, jnp.float32),
                 jnp.full((s, kv), 3.0, jnp.float32))
        v_new = (jnp.asarray(rng.integers(0, 16, size=(s, kv, d)), jnp.uint8),
                 jnp.full((s, kv), 0.25, jnp.float32),
                 jnp.full((s, kv), 1.0, jnp.float32))
        dq = lambda tup: ((np.asarray(tup[0], np.float32)
                           - np.asarray(tup[2])[..., None])
                          * np.asarray(tup[1])[..., None])
    else:
        kp = (jnp.asarray(rng.normal(size=(2, nb, t, kv, d)), jnp.float32),)
        vp = (jnp.asarray(rng.normal(size=(2, nb, t, kv, d)), jnp.float32),)
        k_new = (jnp.asarray(rng.normal(size=(s, kv, d)), jnp.float32),)
        v_new = (jnp.asarray(rng.normal(size=(s, kv, d)), jnp.float32),)
        dq = lambda tup: np.asarray(tup[0], np.float32)

    for layer in (0, 1):
        out, new_pages = ops.paged_attention(
            q, tables, lengths, layer, kp, vp, None, k_new, v_new, None)
        view = lambda tup: dq(tup)[layer][np.asarray(tables)].reshape(
            s, mb * t, kv, d)
        want = _ref_paged_attention(
            np.asarray(q), view(kp), view(vp), np.asarray(lengths),
            dq(k_new), dq(v_new), scale)
        np.testing.assert_allclose(np.asarray(out), want, rtol=2e-5,
                                   atol=2e-5)
        # the new token was appended to block tables[s, len // t] in place
        for i in range(s):
            ln = int(lengths[i])
            blk = int(np.asarray(tables)[i, ln // t])
            np.testing.assert_array_equal(
                np.asarray(new_pages[0])[layer, blk, ln % t],
                np.asarray(k_new[0][i]))
        # untouched layer is bit-identical
        np.testing.assert_array_equal(
            np.asarray(new_pages[0])[1 - layer],
            np.asarray(kp[0])[1 - layer])


def test_kernel_mla_mapping_second_k_source():
    """The MLA mapping: KV=1, K = concat(latent, rope source 2), V is the
    first K source (``v_is_k1``)."""
    from repro.kernels import ops

    s, mb, t, h, rank, rope = 2, 2, 4, 3, 6, 4
    nb = s * mb + 1
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(s, 1, h, rank + rope)), jnp.float32)
    tables = jnp.asarray(1 + np.arange(s * mb).reshape(s, mb), jnp.int32)
    lengths = jnp.asarray([6, 3], jnp.int32)
    k1 = jnp.asarray(rng.normal(size=(1, nb, t, 1, rank)), jnp.float32)
    k2 = jnp.asarray(rng.normal(size=(1, nb, t, 1, rope)), jnp.float32)
    k1n = jnp.asarray(rng.normal(size=(s, 1, rank)), jnp.float32)
    k2n = jnp.asarray(rng.normal(size=(s, 1, rope)), jnp.float32)
    scale = 0.123
    out, new_pages = ops.paged_attention(
        q, tables, lengths, 0, (k1,), None, k2, (k1n,), None, k2n,
        scale=scale, v_is_k1=True)
    kcat = np.concatenate([np.asarray(k1), np.asarray(k2)], -1)
    view = kcat[0][np.asarray(tables)].reshape(s, mb * t, 1, rank + rope)
    vview = view[..., :rank]
    want = _ref_paged_attention(
        np.asarray(q), view, vview, np.asarray(lengths),
        np.concatenate([np.asarray(k1n), np.asarray(k2n)], -1),
        np.asarray(k1n), scale)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-5, atol=2e-5)
    assert len(new_pages) == 2  # k1 and k2 both got the token appended
    for i in range(s):
        ln = int(lengths[i])
        blk = int(np.asarray(tables)[i, ln // t])
        np.testing.assert_array_equal(
            np.asarray(new_pages[1])[0, blk, ln % t], np.asarray(k2n[i]))


@pytest.mark.parametrize("block_pages", [2, 3])
def test_kernel_block_pages_identical(block_pages):
    """The autotune knob changes scheduling, never results."""
    from repro.kernels.paged_attention import paged_attention_pallas

    s, mb, t, kv, rep, d = 2, 5, 4, 2, 2, 8
    nb = s * mb + 1
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.normal(size=(s, kv, rep, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(1, nb, t, kv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(1, nb, t, kv, d)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(s, kv, d)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(s, kv, d)), jnp.float32)
    tables = jnp.asarray(1 + np.arange(s * mb).reshape(s, mb), jnp.int32)
    lengths = jnp.asarray([17, 9], jnp.int32)
    args = (q, tables, lengths, 0, (kp,), (vp,), None, (kn,), (vn,), None)
    base, _ = paged_attention_pallas(*args, block_pages=1)
    got, _ = paged_attention_pallas(*args, block_pages=block_pages)
    np.testing.assert_allclose(np.asarray(got), np.asarray(base),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Fused pool step == vmapped baseline == static loop (token identity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_fused_token_identical_float(models, family):
    arch, params = models[family]
    prompts = _prompts(arch.config, 3, 8)
    out_s = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=3)
                        ).generate_static(prompts, 5)
    fused = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8, paged_kernel=True))
    out_f = fused.generate(prompts, 5)
    assert fused.fused_decode
    np.testing.assert_array_equal(out_s["tokens"], out_f["tokens"])
    baseline = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8, paged_kernel=False))
    out_b = baseline.generate(prompts, 5)
    assert not baseline.fused_decode
    np.testing.assert_array_equal(out_b["tokens"], out_f["tokens"])
    fused.pool.check_invariants()


@pytest.mark.parametrize("family", ["dense", "mla", "moe", "hybrid"])
def test_fused_token_identical_kv4(models, family):
    arch, params = models[family]
    spec = QuantizeSpec(kv_bits=4)
    prompts = _prompts(arch.config, 3, 8)
    out_s = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=3),
                        spec).generate_static(prompts, 4)
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8), spec)
    out_f = eng.generate(prompts, 4)
    assert eng.fused_decode
    np.testing.assert_array_equal(out_s["tokens"], out_f["tokens"])


def test_fused_token_identical_bf16_pool(models):
    """bf16 cache storage: the kernel must score the appended token at
    the *stored* (rounded) precision, exactly like the baseline which
    writes then attends."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 2, 8)
    outs = []
    for pk in (True, False):
        eng = ServeEngine(arch, params, ServeConfig(
            max_seq=32, batch_slots=2, block_tokens=8, paged_kernel=pk),
            dtype=jnp.bfloat16)
        outs.append(eng.generate(prompts, 5)["tokens"])
        assert eng.fused_decode == pk
    np.testing.assert_array_equal(outs[0], outs[1])


def test_autotune_cross_backend_table_applies(tmp_path, monkeypatch):
    """An entry measured on TPU is honored by a CPU process (the ride-
    along contract the ROADMAP documents)."""
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "t.json"))
    autotune.reset_cache()
    try:
        key = autotune.key_for((64, 256), jnp.float32)
        assert key.endswith("|cpu")
        autotune.record("fwht", key.replace("|cpu", "|tpu"), {"block_m": 64})
        got = autotune.best("fwht", (64, 256), jnp.float32, {"block_m": 128})
        assert got == {"block_m": 64}
    finally:
        autotune.reset_cache()


def test_fused_mixed_prompt_lengths(models):
    """Per-slot lengths diverge (different prompts + refills): each
    request still matches its dedicated static run."""
    arch, params = models["dense"]
    cfg = arch.config
    lens = [5, 9, 12, 7]
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=(s,)).astype(np.int32)
               for s in lens]
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                block_tokens=8))
    reqs = [eng.submit(p, 4) for p in prompts]
    eng.drain()
    assert eng.fused_decode
    oracle = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=1,
                                                   paged_kernel=False))
    for p, r in zip(prompts, reqs):
        out = oracle.generate_static(p[None], 4)
        np.testing.assert_array_equal(out["tokens"][0], r.token_array())


# ---------------------------------------------------------------------------
# In-graph multi-step decode loop (steps_per_sync > 1)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["dense", "hybrid"])
@pytest.mark.parametrize("w", [2, 4])
def test_window_token_identical(models, family, w):
    arch, params = models[family]
    prompts = _prompts(arch.config, 4, 8)
    base = ServeEngine(arch, params, ServeConfig(max_seq=48, batch_slots=2,
                                                 block_tokens=8))
    out_b = base.generate(prompts, 7)
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=48, batch_slots=2, block_tokens=8, steps_per_sync=w))
    out_w = eng.generate(prompts, 7)
    np.testing.assert_array_equal(out_b["tokens"], out_w["tokens"])
    mb, mw = (base.scheduler.metrics()["aggregate"],
              eng.scheduler.metrics()["aggregate"])
    # identical tokens; the host syncs at most once per w-step window
    # (slack: a refill boundary can cut a window short)
    assert mw["tokens_generated"] == mb["tokens_generated"]
    assert mw["host_syncs"] <= -(-mw["decode_steps"] // w) + 2
    assert mw["host_syncs"] < mb["host_syncs"]
    eng.pool.check_invariants()


def test_window_kv4_and_pool_pristine(models):
    arch, params = models["dense"]
    spec = QuantizeSpec(kv_bits=4)
    prompts = _prompts(arch.config, 3, 8)
    out_b = ServeEngine(arch, params, ServeConfig(
        max_seq=48, batch_slots=2, block_tokens=8), spec).generate(prompts, 6)
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=48, batch_slots=2, block_tokens=8, steps_per_sync=4), spec)
    out_w = eng.generate(prompts, 6)
    np.testing.assert_array_equal(out_b["tokens"], out_w["tokens"])
    eng.pool.check_invariants()
    assert not any(eng.pool.slot_blocks)


def test_window_stop_token_mid_window(models):
    """A stop token hit inside the window ends the request at exactly the
    single-sync position; its slot's later window steps emit nothing."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 1, 8)
    ref = ServeEngine(arch, params, ServeConfig(max_seq=48, batch_slots=1,
                                                block_tokens=8))
    r0 = ref.submit(prompts[0], 8)
    ref.drain()
    toks = [int(x) for x in r0.token_array()]
    # first token that does not appear earlier in the sequence: stopping
    # on it is unambiguous
    idx = next(i for i in range(1, len(toks)) if toks[i] not in toks[:i])
    for w in (1, 4):
        eng = ServeEngine(arch, params, ServeConfig(
            max_seq=48, batch_slots=1, block_tokens=8, steps_per_sync=w))
        r = eng.submit(prompts[0], 8, stop_token=toks[idx])
        eng.drain()
        assert [int(x) for x in r.token_array()] == toks[: idx + 1]
        eng.pool.check_invariants()


def test_window_streaming_callback_order(models):
    """Callbacks flush once per window but still fire in token order per
    request, with done flags on the last token."""
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=48, batch_slots=2, block_tokens=8, steps_per_sync=4))
    seen = []

    def cb(req, tok, done):
        seen.append((req.rid, int(np.asarray(tok)), done))

    prompts = _prompts(cfg, 5, 8)
    reqs = [eng.submit(prompts[i], 5, on_token=cb) for i in range(5)]
    eng.drain()
    for r in reqs:
        mine = [(t, d) for rid, t, d in seen if rid == r.rid]
        assert [t for t, _ in mine] == [int(x) for x in r.token_array()]
        assert [d for _, d in mine] == [False] * 4 + [True]


def test_window_refills_between_windows(models):
    """More requests than slots under steps_per_sync > 1: releases and
    refills happen at window boundaries, tokens unchanged."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 6, 8)
    base = ServeEngine(arch, params, ServeConfig(max_seq=48, batch_slots=2,
                                                 block_tokens=8))
    out_b = base.generate(prompts, 6)
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=48, batch_slots=2, block_tokens=8, steps_per_sync=3))
    out_w = eng.generate(prompts, 6)
    np.testing.assert_array_equal(out_b["tokens"], out_w["tokens"])
    assert len(eng.pool.free) == eng.pool.capacity_blocks


def test_window_temperature_sampling_identical(models):
    """On-device categorical uses the host sampler's fold_in(rid, count)
    key chain: draws are identical across sync intervals."""
    arch, params = models["dense"]
    prompts = _prompts(arch.config, 3, 8)
    outs = []
    for w in (1, 3):
        eng = ServeEngine(arch, params, ServeConfig(
            max_seq=48, batch_slots=2, block_tokens=8, temperature=0.7,
            seed=11, steps_per_sync=w))
        outs.append(eng.generate(prompts, 5)["tokens"])
    np.testing.assert_array_equal(outs[0], outs[1])


# ---------------------------------------------------------------------------
# Autotune cache round-trip
# ---------------------------------------------------------------------------


def test_autotune_roundtrip_and_injection(tmp_path, monkeypatch):
    from repro.kernels import autotune

    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    autotune.reset_cache()
    try:
        # defaults on an interpret backend with an empty table
        got = autotune.best("fwht", (64, 256), jnp.float32, {"block_m": 128})
        assert got == {"block_m": 128}
        # record + save + reload (fresh in-memory state) round-trips
        key = autotune.key_for((64, 256), jnp.float32)
        autotune.record("fwht", key, {"block_m": 32, "us": 1.0})
        autotune.save_table()
        autotune.reset_cache()
        assert json.loads(path.read_text())["fwht"][key]["block_m"] == 32
        got = autotune.best("fwht", (64, 256), jnp.float32, {"block_m": 128})
        assert got == {"block_m": 32}  # table hit wins; extras filtered
    finally:
        autotune.reset_cache()  # do not leak tmp entries into other tests


def test_autotune_entry_steers_kernel(tmp_path, monkeypatch):
    """An injected table entry changes the block size the kernel actually
    runs with — and the result stays correct."""
    from repro.kernels import autotune
    from repro.kernels import ref
    from repro.kernels.fwht import fwht_pallas

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "t.json"))
    autotune.reset_cache()
    try:
        x = jnp.asarray(np.random.default_rng(0).normal(size=(16, 64)),
                        jnp.float32)
        autotune.record("fwht", autotune.key_for((16, 64), jnp.float32),
                        {"block_m": 2})
        got = fwht_pallas(x)  # block_m=None -> table -> 2-row stripes
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.fwht_ref(x)),
                                   rtol=2e-5, atol=2e-5)
    finally:
        autotune.reset_cache()
