"""Property tests for the distribution layer.

Contracts pinned here (hypothesis; deterministic shim in hermetic CI):
  * ``plan_remesh`` never plans more devices than exist, always keeps the
    model axis a divisor of the device count, and never *shrinks* the
    global batch (exact preservation whenever the data degree divides it).
  * ``sanitize_pspecs`` output always divides the mesh: every surviving
    placement's axis-size product divides its dimension, unknown axis
    names never survive, and the pass is idempotent.
  * the packed-quantization specs co-shard codes/scales with their source
    weight's output axis (the invariant the fused dequant kernel needs).
  * the explicit-EP expert FFN equals the plain einsum path on a 1-device
    mesh (the multi-device equivalence runs in the dry-run harness).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.elastic import plan_remesh
from repro.dist.sharding import param_pspecs, sanitize_pspecs


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)
        self.shape = dict(zip(names, shape))


MESHES = [((1, 1), ("data", "model")), ((4, 2), ("data", "model")),
          ((8, 4), ("data", "model")), ((2, 16, 16), ("pod", "data", "model"))]

_ENTRIES = [None, "data", "model", ("data", "model"), "pod", "bogus"]


@settings(max_examples=80, deadline=None)
@given(n=st.integers(1, 4096), gb=st.integers(1, 2048))
def test_plan_remesh_contract(n, gb):
    plan = plan_remesh(n, gb)
    data, model = plan.mesh_shape
    assert 1 <= data * model <= n
    assert n % model == 0, (n, model)
    assert plan.effective_batch >= gb
    if gb % data == 0:
        assert plan.effective_batch == gb  # exact preservation
    assert plan.per_device_batch >= 1 and plan.grad_accum >= 1
    assert plan.per_device_batch <= 16  # live-microbatch cap always holds


@settings(max_examples=60, deadline=None)
@given(
    mesh_i=st.integers(0, len(MESHES) - 1),
    dims=st.lists(st.integers(1, 48), min_size=1, max_size=4),
    picks=st.lists(st.integers(0, len(_ENTRIES) - 1), min_size=1, max_size=4),
    seed=st.integers(0, 10_000),
)
def test_sanitizer_output_always_divides_mesh(mesh_i, dims, picks, seed):
    shape, names = MESHES[mesh_i]
    mesh = FakeMesh(shape, names)
    sds = jax.ShapeDtypeStruct(tuple(dims), jnp.float32)
    spec = P(*[_ENTRIES[p] for p in picks[: len(dims)]])
    out = sanitize_pspecs(mesh, spec, sds)
    assert len(out) <= sds.ndim
    for i, entry in enumerate(out):
        if entry is None:
            continue
        axis_names = entry if isinstance(entry, tuple) else (entry,)
        assert all(a in mesh.shape for a in axis_names), entry
        total = int(np.prod([mesh.shape[a] for a in axis_names]))
        assert sds.shape[i] % total == 0, (sds.shape, i, entry)
    # idempotent: a sanitized spec sanitizes to itself
    assert sanitize_pspecs(mesh, out, sds) == out


@pytest.mark.parametrize("arch_name", ["smollm-135m", "deepseek-moe-16b",
                                       "minicpm3-4b"])
def test_quant_specs_coshard_output_axis(arch_name):
    from repro.launch.quant_serve import quant_param_pspecs, quant_param_specs
    from repro.models.registry import get_arch
    from repro.quant.packed import is_packed

    arch = get_arch(arch_name)
    sds = arch.param_specs()
    qsds = quant_param_specs(arch.config, sds, wbits=4)
    qspecs = quant_param_pspecs(arch.config, sds, qsds)
    base = param_pspecs(arch.config, sds)

    packed = {
        "/".join(str(getattr(p, "key", p)) for p in path): node
        for path, node in jax.tree_util.tree_flatten_with_path(
            qspecs, is_leaf=lambda x: is_packed(x) or isinstance(x, P)
        )[0]
        if is_packed(node)
    }
    assert packed, "no leaves were packed"
    flat_base = {
        "/".join(str(getattr(p, "key", p)) for p in path): s
        for path, s in jax.tree_util.tree_flatten_with_path(
            base, is_leaf=lambda x: isinstance(x, P)
        )[0]
    }
    for key, node in packed.items():
        src = flat_base[key]
        out_axis = src[len(src) - 1] if len(src) else None
        for part, got_spec in (("codes", node.codes), ("scale", node.scale),
                               ("zero", node.zero)):
            got = got_spec[len(got_spec) - 1] if len(got_spec) else None
            assert got == out_axis, (key, part, got, out_axis)


def test_expert_ffn_ep_matches_reference_single_device():
    from repro.dist.collectives import expert_ffn_ep

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    key = jax.random.PRNGKey(0)
    e, cap, d, de = 4, 3, 8, 16
    xe = jax.random.normal(key, (2, e, cap, d))
    wg = jax.random.normal(jax.random.fold_in(key, 1), (e, d, de))
    wu = jax.random.normal(jax.random.fold_in(key, 2), (e, d, de))
    wd = jax.random.normal(jax.random.fold_in(key, 3), (e, de, d))
    ref = jnp.einsum(
        "becf,efd->becd",
        jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg))
        * jnp.einsum("becd,edf->becf", xe, wu),
        wd,
    )
    got = expert_ffn_ep(xe, wg, wu, wd, mesh)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_psum_partial_combine_sums_distinct_partials():
    """Slice i of the stacked input is rank i's partial — the sum must be
    the sum of *distinct* slices, not ep copies of one array."""
    from repro.dist.collectives import psum_partial_combine

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    partials = jnp.stack([jnp.full((2, 3), 5.0)])  # ep == 1
    out = psum_partial_combine(partials, mesh)
    np.testing.assert_allclose(np.asarray(out), 5.0)
    with pytest.raises(ValueError):
        psum_partial_combine(jnp.zeros((2, 2, 3)), mesh)  # 2 partials, ep=1


def test_param_pspecs_fsdp_axes_survive_sanitize():
    """FSDP placements that survive must divide; dropped ones replicate."""
    from repro.models.registry import get_arch

    arch = get_arch("smollm-135m")
    sds = arch.param_specs()
    mesh = FakeMesh((2, 16, 16), ("pod", "data", "model"))
    specs = sanitize_pspecs(
        mesh,
        param_pspecs(arch.config, sds, fsdp_axes=("pod", "data"), fsdp_size=32),
        sds,
    )
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    sds_leaves = jax.tree.leaves(sds)
    assert len(leaves) == len(sds_leaves)
    assert any(
        any(entry == ("pod", "data") for entry in spec) for spec in leaves
    ), "no leaf kept an FSDP placement"
