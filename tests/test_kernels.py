"""Per-kernel shape/dtype sweeps: pallas_call (interpret) vs pure-jnp oracle."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.quant import pack, rtn
from repro.quant.qtypes import QuantConfig


def rand(shape, seed=0, dtype=np.float32):
    return np.random.default_rng(seed).normal(size=shape).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestFWHTKernel:
    @pytest.mark.parametrize("m,d", [(1, 8), (7, 64), (16, 256), (33, 512), (4, 1024)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, d, dtype):
        x = jnp.asarray(rand((m, d), seed=m + d), dtype)
        got = ops.fwht(x)
        want = ref.fwht_ref(x)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32), **tol(dtype)
        )

    def test_batched_dims(self):
        x = jnp.asarray(rand((2, 3, 128), seed=1))
        got = ops.fwht(x)
        want = ref.fwht_ref(x.reshape(-1, 128)).reshape(2, 3, 128)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


class TestGroupedRotateKernel:
    @pytest.mark.parametrize("m,g,n", [(5, 8, 4), (16, 32, 2), (9, 64, 3), (128, 128, 2)])
    @pytest.mark.parametrize("shared", [True, False])
    @pytest.mark.parametrize("inverse", [True, False])
    def test_matches_ref(self, m, g, n, shared, inverse):
        from repro.core.hadamard import walsh

        c = g * n
        x = jnp.asarray(rand((m, c), seed=g))
        if shared:
            blocks = jnp.asarray(walsh(g), jnp.float32)[None]
        else:
            blocks = jnp.stack(
                [jnp.asarray(walsh(g), jnp.float32) * ((-1.0) ** i) for i in range(n)]
            )
        got = ops.grouped_rotate(x, blocks, inverse=inverse)
        want = ref.grouped_rotate_ref(x, blocks, inverse=inverse)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)

    def test_equals_core_apply_rotation(self):
        from repro.core.rotation import apply_rotation, make_rotation

        rot = make_rotation("GSR", 256, group=64)
        x = jnp.asarray(rand((4, 256), seed=2))
        got = ops.grouped_rotate(x, jnp.asarray(rot.matrix, jnp.float32)[None])
        want = apply_rotation(x, rot)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


class TestDequantMatmulKernel:
    @pytest.mark.parametrize("bits", [2, 4, 8])
    @pytest.mark.parametrize("m,c,h,g", [(4, 64, 32, 16), (17, 128, 48, 32), (3, 256, 128, 128)])
    @pytest.mark.parametrize("symmetric", [False, True])
    def test_matches_ref(self, bits, m, c, h, g, symmetric):
        cfg = QuantConfig(bits=bits, group=g, symmetric=symmetric)
        w = rand((c, h), seed=bits * 7 + g)
        x = jnp.asarray(rand((m, c), seed=m))
        qt = rtn.quantize_weight_grouped(jnp.asarray(w), cfg)
        if symmetric:
            qt = type(qt)(codes=qt.codes, scale=qt.scale, zero=None, bits=bits, group=g)
        packed = pack.pack(qt)
        got = ops.dequant_matmul(x, packed)
        want = ref.dequant_matmul_ref(x, packed)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_block_tiling_edges(self):
        # force multi-tile grid in every dimension incl. padding remainder
        cfg = QuantConfig(bits=4, group=32, symmetric=False)
        w, x = rand((128, 96), 1), jnp.asarray(rand((70, 128), 2))
        packed = pack.pack(rtn.quantize_weight_grouped(jnp.asarray(w), cfg))
        got = np.asarray(
            __import__("repro.kernels.dequant_matmul", fromlist=["d"]).dequant_matmul_pallas(
                x, packed, block_m=32, block_n=32, interpret=True
            )
        )
        want = np.asarray(ref.dequant_matmul_ref(x, packed))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestRTNQuantKernel:
    @pytest.mark.parametrize("m,c,g", [(4, 64, 16), (33, 128, 128), (16, 512, 64)])
    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, c, g, bits, dtype):
        x = jnp.asarray(rand((m, c), seed=c + bits), dtype)
        got = np.asarray(ops.rtn_fake_quant(x, bits=bits, group=g), np.float32)
        want = np.asarray(ref.rtn_fake_quant_ref(x, bits=bits, group=g), np.float32)
        if dtype == jnp.bfloat16:
            # bf16-grid inputs can land x/scale on exact .5 boundaries where
            # a 1-ulp quotient difference legitimately flips round(): allow
            # <=1 LSB on a small fraction of elements.
            xf = np.asarray(x, np.float32).reshape(m, c // g, g)
            lsb = np.abs(xf).max(-1, keepdims=True) * 0.9 / (2 ** (bits - 1) - 1)
            diff = np.abs(got - want).reshape(m, c // g, g)
            # 1 LSB flip + bf16 output-cast rounding (2^-8 relative)
            bound = lsb * 1.02 + np.abs(want).reshape(m, c // g, g) * 2**-7
            assert np.all(diff <= bound)
            assert (diff > 1e-6).mean() < 0.05
        else:
            np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_idempotent(self):
        x = jnp.asarray(rand((8, 128), seed=5))
        once = ops.rtn_fake_quant(x, bits=4, group=32)
        twice = ops.rtn_fake_quant(once, bits=4, group=32)
        # quantizing an already-quantized tensor with clip<1 can re-clip;
        # check with clip 1.0 for strict idempotence
        once1 = ops.rtn_fake_quant(x, bits=4, group=32, clip_ratio=1.0)
        twice1 = ops.rtn_fake_quant(once1, bits=4, group=32, clip_ratio=1.0)
        np.testing.assert_allclose(np.asarray(once1), np.asarray(twice1), rtol=1e-5, atol=1e-6)


class TestGSRQuantFusedKernel:
    @pytest.mark.parametrize("m,g,n", [(5, 16, 4), (33, 32, 2), (64, 64, 2)])
    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("shared", [True, False])
    def test_matches_two_step_ref(self, m, g, n, bits, shared):
        from repro.core.hadamard import walsh

        c = g * n
        x = jnp.asarray(rand((m, c), seed=g + bits))
        if shared:
            blocks = jnp.asarray(walsh(g), jnp.float32)[None]
        else:
            blocks = jnp.stack(
                [jnp.asarray(walsh(g), jnp.float32) * ((-1.0) ** i) for i in range(n)]
            )
        got = np.asarray(ops.gsr_rotate_quant(x, blocks, bits=bits))
        want = np.asarray(ref.gsr_rotate_quant_ref(x, blocks, bits=bits))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_fused_equals_unfused_pipeline(self):
        from repro.core.hadamard import walsh

        x = jnp.asarray(rand((16, 128), seed=3))
        blocks = jnp.asarray(walsh(32), jnp.float32)[None]
        fused = np.asarray(ops.gsr_rotate_quant(x, blocks, bits=4))
        twostep = np.asarray(
            ops.rtn_fake_quant(ops.grouped_rotate(x, blocks), bits=4, group=32)
        )
        np.testing.assert_allclose(fused, twostep, rtol=2e-5, atol=2e-5)
