"""Per-site activation rule exactness (the act side of SiteRule).

The contract mirrored from the weight/rotation sides of the policy
redesign, made bit-exact:

  * ``QuantizeSpec.act_for`` resolves first-match-wins with the same
    bare-name fallback as ``r4_for``;
  * a wildcard per-site A8 rule is *bit-identical* to the policy-global
    ``act_bits=8`` path (the refactor changed plumbing, not numerics);
  * act rules at 16 bits are exact no-ops against the no-rule policy;
  * act rules never touch packed weight bytes (activation quant is
    online-only);
  * mixed act precision (A8 only on ``*down*``) saves, loads, and serves
    bit-exactly on dense + MoE via a format-3 manifest, and behaves
    strictly differently from global A8;
  * a format-2 manifest (no ``act_sites`` provenance) still loads.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.models.common import QuantizeSpec
from repro.models.registry import get_arch
from repro.quant.packed import is_packed
from repro.quant.policy import QuantPolicy, RotationPlan, RotationSpec, SiteRule

ROT = RotationPlan(r1=RotationSpec(kind="GSR", group=32), r4_kind="GH",
                   r4_group=32)


def _rules(**act):
    return (SiteRule(pattern="*down*", bits=4, group=32, method="rtn", **act),
            SiteRule(pattern="*", bits=4, group=32, method="rtn"))


MIXED_ACT = QuantPolicy(rules=_rules(act_bits=8), rotation=ROT,
                        act_bits=16, act_group=32)


@pytest.fixture(scope="module")
def dense_setup():
    arch = get_arch("smollm-135m", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    toks = np.random.default_rng(0).integers(
        0, arch.config.vocab, (2, 12)).astype(np.int32)
    return arch, params, toks


@pytest.fixture(scope="module")
def moe_setup():
    arch = get_arch("deepseek-moe-16b", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    toks = np.random.default_rng(0).integers(
        0, arch.config.vocab, (2, 12)).astype(np.int32)
    return arch, params, toks


# ---------------------------------------------------------------------------
# Resolution semantics
# ---------------------------------------------------------------------------


def test_act_for_first_match_wins_with_bare_name_fallback():
    spec = QuantizeSpec(act_bits=16, act_group=128, act_clip=0.9,
                        act_sites=(("moe_mlp/w_down", 4, 32, 1.0),
                                   ("*down*", 8, 64, 0.8)))
    # act_q call sites pass bare names: a slash-qualified pattern falls
    # back to matching by its last path component (like r4_for)
    assert spec.act_for("w_down") == (4, 32, 1.0)
    assert spec.act_for("shared_down") == (8, 64, 0.8)
    assert spec.act_for("wq") == (16, 128, 0.9)  # global default
    assert spec.act_enabled  # site table alone can enable act quant


def test_policy_lowers_only_act_carrying_rules():
    spec = MIXED_ACT.spec()
    assert spec.act_sites == (("*down*", 8, 32, 0.9),)
    assert spec.act_for("w_down")[0] == 8
    assert spec.act_for("wq")[0] == 16


# ---------------------------------------------------------------------------
# Exactness: the refactor changed plumbing, not numerics
# ---------------------------------------------------------------------------


def test_wildcard_act_rule_bit_identical_to_global_a8(dense_setup):
    """SiteRule("*", act_bits=8) == policy-global act_bits=8, bit-exact."""
    arch, params, toks = dense_setup
    per_site = QuantPolicy(
        rules=(SiteRule(pattern="*", bits=4, group=32, method="rtn",
                        act_bits=8, act_group=32),),
        rotation=ROT, act_bits=16, act_group=32)
    global_a8 = QuantPolicy(
        rules=(SiteRule(pattern="*", bits=4, group=32, method="rtn"),),
        rotation=ROT, act_bits=8, act_group=32)
    q1 = api.quantize(arch, params, per_site)
    q2 = api.quantize(arch, params, global_a8)
    l1 = arch.forward(q1.params, {"tokens": jnp.asarray(toks)}, q1.spec)
    l2 = arch.forward(q2.params, {"tokens": jnp.asarray(toks)}, q2.spec)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_act16_rules_are_exact_noops(dense_setup):
    """act_bits=16 rules resolve to the fp passthrough: logits identical
    to the same policy with no act fields at all."""
    arch, params, toks = dense_setup
    with_rule = QuantPolicy(rules=_rules(act_bits=16), rotation=ROT,
                            act_bits=16, act_group=32)
    without = QuantPolicy(rules=_rules(), rotation=ROT,
                          act_bits=16, act_group=32)
    q1 = api.quantize(arch, params, with_rule)
    q2 = api.quantize(arch, params, without)
    assert q1.spec.act_sites == (("*down*", 16, 32, 0.9),)
    assert not q1.spec.act_enabled
    l1 = arch.forward(q1.params, {"tokens": jnp.asarray(toks)}, q1.spec)
    l2 = arch.forward(q2.params, {"tokens": jnp.asarray(toks)}, q2.spec)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_act_rules_do_not_touch_packed_bytes(dense_setup):
    """Activation quant is online-only: identical weight rules produce
    byte-identical packed leaves with or without act overrides."""
    arch, params, _ = dense_setup
    q1 = api.quantize(arch, params, MIXED_ACT)
    q2 = api.quantize(arch, params,
                      QuantPolicy(rules=_rules(), rotation=ROT,
                                  act_bits=16, act_group=32))
    l1 = jax.tree.leaves(q1.params, is_leaf=is_packed)
    l2 = jax.tree.leaves(q2.params, is_leaf=is_packed)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        if is_packed(a):
            np.testing.assert_array_equal(np.asarray(a.codes),
                                          np.asarray(b.codes))
            np.testing.assert_array_equal(np.asarray(a.scale),
                                          np.asarray(b.scale))
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_per_site_a8_strictly_differs_from_global_a8(dense_setup):
    """A8-on-down-only is a genuinely different quantizer than global A8
    (if these were logit-equal the site table would be dead plumbing)."""
    arch, params, toks = dense_setup
    global_a8 = QuantPolicy(rules=_rules(), rotation=ROT,
                            act_bits=8, act_group=32)
    q1 = api.quantize(arch, params, MIXED_ACT)
    q2 = api.quantize(arch, params, global_a8)
    l1 = arch.forward(q1.params, {"tokens": jnp.asarray(toks)}, q1.spec)
    l2 = arch.forward(q2.params, {"tokens": jnp.asarray(toks)}, q2.spec)
    assert not np.array_equal(np.asarray(l1), np.asarray(l2))


# ---------------------------------------------------------------------------
# Artifact round trip (format-3 manifest)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("setup", ["dense_setup", "moe_setup"])
def test_mixed_act_precision_roundtrip_bit_exact(setup, request, tmp_path):
    arch, params, toks = request.getfixturevalue(setup)
    qm = api.quantize(arch, params, MIXED_ACT)
    d = str(tmp_path / "mixed-act")
    stepdir = qm.save(d)
    with open(os.path.join(stepdir, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] >= 3
    assert man["act_sites"] == [["*down*", 8, 32, 0.9]]

    qm2 = api.load_quantized(d)
    assert qm2.policy == qm.policy and qm2.spec == qm.spec
    assert qm2.spec.act_for("w_down")[0] == 8

    lf = arch.forward(qm.params, {"tokens": jnp.asarray(toks)}, qm.spec)
    ll = qm2.arch.forward(qm2.params, {"tokens": jnp.asarray(toks)}, qm2.spec)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ll))

    scfg = api.ServeConfig(max_seq=32, batch_slots=2)
    out1 = qm.serve(scfg).generate(toks[:, :8], 3)
    out2 = qm2.serve(scfg).generate(toks[:, :8], 3)
    np.testing.assert_array_equal(out1["tokens"], out2["tokens"])


def test_format2_manifest_still_loads(dense_setup, tmp_path):
    """Artifacts written before the act-site table (format 2, no
    ``act_sites`` key) must keep loading: the policy is canonical and
    pre-format-3 policies carry no act overrides by construction."""
    arch, params, toks = dense_setup
    qm = api.quantize(arch, params,
                      QuantPolicy(rules=_rules(), rotation=ROT,
                                  act_bits=8, act_group=32))
    d = str(tmp_path / "fmt2")
    stepdir = qm.save(d)
    path = os.path.join(stepdir, "manifest.json")
    with open(path) as f:
        man = json.load(f)
    man["format"] = 2
    del man["act_sites"]
    with open(path, "w") as f:
        json.dump(man, f)

    qm2 = api.load_quantized(d)
    assert qm2.spec == qm.spec
    lf = arch.forward(qm.params, {"tokens": jnp.asarray(toks)}, qm.spec)
    ll = qm2.arch.forward(qm2.params, {"tokens": jnp.asarray(toks)}, qm2.spec)
    np.testing.assert_array_equal(np.asarray(lf), np.asarray(ll))
