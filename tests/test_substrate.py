"""Training loop, checkpoint/restart, grad compression, PTQ pipeline, serving."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, restore_checkpoint, save_checkpoint
from repro.data import SyntheticLM
from repro.data.synthetic import make_batch_for
from repro.models.common import QuantizeSpec
from repro.models.registry import get_arch
from repro.quant.pipeline import PTQConfig, quantize_model
from repro.train import grad_compress
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_eval_step, make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _batches(cfg, batch_size=4, seq=32, start=0):
    data = SyntheticLM(cfg.vocab, seq, seed=1)
    step = start
    while True:
        yield make_batch_for(cfg, data, step, shard=0, batch_size=batch_size)
        step += 1


class TestTraining:
    def test_loss_decreases(self):
        arch = get_arch("smollm-135m", reduced=True)
        opt = OptConfig(lr=1e-2, warmup_steps=5, total_steps=100)
        step = jax.jit(make_train_step(arch, opt))
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        state = init_opt_state(params, opt)
        gen = _batches(arch.config)
        losses = []
        for i in range(100):
            params, state, _, m = step(params, state, {}, {k: jnp.asarray(v) for k, v in next(gen).items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[::20]

    def test_microbatch_equivalence(self):
        """Grad accumulation over microbatches ~= full-batch step."""
        arch = get_arch("smollm-135m", reduced=True)
        opt = OptConfig(lr=1e-3, warmup_steps=0, grad_clip=0.0, weight_decay=0.0)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        batch = {k: jnp.asarray(v) for k, v in next(_batches(arch.config, batch_size=4)).items()}
        s1 = jax.jit(make_train_step(arch, opt, microbatches=1))
        s2 = jax.jit(make_train_step(arch, opt, microbatches=2))
        p1, *_ , m1 = s1(params, init_opt_state(params, opt), {}, batch)
        p2, *_ , m2 = s2(params, init_opt_state(params, opt), {}, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
        d = max(
            float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
        )
        assert d < 5e-5, d

    def test_nan_step_skipped(self):
        arch = get_arch("smollm-135m", reduced=True)
        opt = OptConfig(lr=1e-3)
        step = jax.jit(make_train_step(arch, opt))
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        state = init_opt_state(params, opt)
        batch = {k: jnp.asarray(v) for k, v in next(_batches(arch.config)).items()}
        bad = dict(params)
        bad["final_norm"] = params["final_norm"].at[0].set(jnp.nan)  # always used
        p2, s2, _, m = step(bad, state, {}, batch)
        assert int(m["skipped"]) == 1
        np.testing.assert_array_equal(
            np.asarray(p2["final_norm"]), np.asarray(bad["final_norm"])
        )
        assert int(s2.step) == 0  # optimizer untouched


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}, "s": jnp.asarray(3, jnp.int32)}
        save_checkpoint(str(tmp_path), 7, tree)
        out, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            assert x.dtype == y.dtype
            np.testing.assert_array_equal(np.asarray(x, np.float32), np.asarray(y, np.float32))

    def test_retention_and_latest(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_last=2)
        tree = {"x": jnp.zeros((2,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        names = sorted(os.listdir(tmp_path))
        assert names == ["step_00000003", "step_00000004"]

    def test_crash_restart_resumes(self, tmp_path):
        arch = get_arch("smollm-135m", reduced=True)
        opt = OptConfig(lr=1e-3, total_steps=30)
        tcfg = TrainerConfig(total_steps=30, ckpt_interval=10, log_interval=100,
                             ckpt_dir=str(tmp_path), fail_at_step=25)
        tr = Trainer(arch, opt, tcfg)
        with pytest.raises(RuntimeError, match="injected failure"):
            tr.run(_batches(arch.config))
        # restart: resumes from step 20, finishes
        tcfg2 = TrainerConfig(total_steps=30, ckpt_interval=10, log_interval=100,
                              ckpt_dir=str(tmp_path))
        tr2 = Trainer(arch, opt, tcfg2)
        assert tr2.step == 20
        out = tr2.run(_batches(arch.config, start=tr2.step))
        assert out["step"] == 30


class TestGradCompression:
    def test_error_feedback_reduces_bias(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(2048,)).astype(np.float32) * 1e-3)
        err = jnp.zeros_like(g)
        total = jnp.zeros_like(g)
        for _ in range(50):
            dq, err = grad_compress._quant_ef(g, err)[0:1][0], None  # placeholder
            break
        # use public API: accumulated compressed grads converge to the truth
        err_state = {"g": jnp.zeros_like(g)}
        acc = jnp.zeros_like(g)
        for _ in range(50):
            out, err_state = grad_compress.compress_for_allreduce({"g": g}, err_state)
            acc = acc + out["g"]
        rel = float(jnp.linalg.norm(acc / 50 - g) / jnp.linalg.norm(g))
        assert rel < 0.02, rel

    def test_int8_psum_shard_map(self):
        import os
        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("single device container: exercised via dryrun configs")

    def test_training_with_compression_converges(self):
        arch = get_arch("smollm-135m", reduced=True)
        opt = OptConfig(lr=1e-2, warmup_steps=5)
        step = jax.jit(make_train_step(arch, opt, compress_grads=True))
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        state = init_opt_state(params, opt)
        err = grad_compress.init_error_state(params)
        gen = _batches(arch.config)
        losses = []
        for i in range(60):
            params, state, err, m = step(params, state, err,
                                         {k: jnp.asarray(v) for k, v in next(gen).items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 0.3, losses[::15]


class TestPTQPipeline:
    @pytest.mark.parametrize("kind", ["GH", "GW", "LH", "GSR"])
    def test_rtn_pipeline_runs_all_kinds(self, kind):
        arch = get_arch("smollm-135m", reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        ptq = PTQConfig(r1_kind=kind, wakv="W4A16", method="rtn", group=32)
        qp, spec = quantize_model(arch, params, ptq)
        batch = next(_batches(arch.config))
        logits = arch.forward(qp, {k: jnp.asarray(v) for k, v in batch.items()}, spec)
        assert np.isfinite(np.asarray(logits)).all()

    def test_gptq_pipeline_better_than_rtn_w2(self):
        """The central paper mechanic: on a *trained* model, rotated GPTQ-W2
        degrades PPL less than rotated RTN-W2."""
        arch = get_arch("smollm-135m", reduced=True)
        opt = OptConfig(lr=3e-3, warmup_steps=5)
        step = jax.jit(make_train_step(arch, opt))
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        state = init_opt_state(params, opt)
        gen = _batches(arch.config)
        for _ in range(80):
            params, state, _, _ = step(params, state, {},
                                       {k: jnp.asarray(v) for k, v in next(gen).items()})
        eval_batch = {k: jnp.asarray(v) for k, v in next(gen).items()}
        ev = jax.jit(make_eval_step(arch))
        base = float(ev(params, eval_batch)["nll"])

        nlls = {}
        for method in ("rtn", "gptq"):
            ptq = PTQConfig(r1_kind="GSR", wakv="W2A16", method=method, group=16,
                            n_calib=4, calib_seq=32)
            qp, spec = quantize_model(arch, params, ptq)
            evq = jax.jit(make_eval_step(arch, spec))
            nlls[method] = float(evq(qp, eval_batch)["nll"])
        assert nlls["gptq"] >= base - 0.05  # quantization can't beat fp
        assert nlls["gptq"] < nlls["rtn"], nlls

    def test_learned_pipeline_runs(self):
        arch = get_arch("smollm-135m", reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        ptq = PTQConfig(r1_kind="GSR", wakv="W4A16", method="rtn", group=32,
                        learned="rotation+scale", learn_steps=10)
        qp, spec = quantize_model(arch, params, ptq)
        batch = next(_batches(arch.config))
        logits = arch.forward(qp, {k: jnp.asarray(v) for k, v in batch.items()}, spec)
        assert np.isfinite(np.asarray(logits)).all()


class TestServing:
    def test_generate_greedy(self):
        from repro.serve.engine import ServeConfig, ServeEngine

        arch = get_arch("smollm-135m", reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        eng = ServeEngine(arch, params, ServeConfig(max_seq=64, batch_slots=4))
        prompts = np.random.default_rng(0).integers(0, arch.config.vocab, size=(3, 8)).astype(np.int32)
        out = eng.generate(prompts, max_new_tokens=5)
        assert out["tokens"].shape == (3, 5)
        assert out["final_length"] == 13

    def test_generate_with_quantized_kv(self):
        from repro.serve.engine import ServeConfig, ServeEngine

        arch = get_arch("smollm-135m", reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        spec = QuantizeSpec(kv_bits=4)
        eng = ServeEngine(arch, params, ServeConfig(max_seq=64, batch_slots=2), spec)
        prompts = np.random.default_rng(1).integers(0, arch.config.vocab, size=(2, 8)).astype(np.int32)
        out = eng.generate(prompts, max_new_tokens=4)
        assert out["tokens"].shape == (2, 4)
