"""Distribution-layer tests: sharding rules, sanitizer, elastic planning,
HLO collective parsing.  (The full-mesh lower/compile itself is exercised
by launch/dryrun.py with 512 placeholder devices - not under pytest, which
must keep seeing 1 CPU device.)"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.elastic import plan_remesh
from repro.dist.sharding import batch_pspecs, cache_pspecs, param_pspecs, sanitize_pspecs
from repro.launch.hlo_stats import collective_stats, total_wire_bytes
from repro.models.common import QuantizeSpec
from repro.models.registry import ARCH_IDS, get_arch


class FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        self.devices = np.zeros(shape)
        self.shape = dict(zip(names, shape))


@pytest.mark.parametrize("name", ARCH_IDS)
def test_param_pspecs_cover_every_leaf(name):
    arch = get_arch(name)
    sds = arch.param_specs()
    specs = param_pspecs(arch.config, sds, fsdp_axes=("data",))
    n_leaves = len(jax.tree.leaves(sds))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_specs == n_leaves
    # every spec rank <= leaf rank
    for spec, leaf in zip(
        jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree.leaves(sds),
    ):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)


@pytest.mark.parametrize("name", ["smollm-135m", "deepseek-moe-16b", "minicpm3-4b",
                                  "xlstm-1.3b", "zamba2-1.2b"])
def test_cache_pspecs_cover_every_leaf(name):
    arch = get_arch(name)
    sds = arch.cache_specs(8, 64, QuantizeSpec(kv_bits=4))
    specs = cache_pspecs(arch.config, sds, ("data",), model_size=16)
    assert len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))) == len(
        jax.tree.leaves(sds)
    )


def test_sanitizer_drops_nondivisible():
    mesh = FakeMesh((4, 2), ("data", "model"))
    sds = {"a": jax.ShapeDtypeStruct((3, 8), jnp.float32),
           "b": jax.ShapeDtypeStruct((8, 6), jnp.float32)}
    specs = {"a": P("data", "model"), "b": P(("data", "model"), None)}
    out = sanitize_pspecs(mesh, specs, sds)
    assert out["a"] == P(None, "model")  # 3 % 4 != 0 dropped, 8 % 2 kept
    assert out["b"] == P(("data", "model"), None)  # 8 % 8 ok


def test_batch_pspecs_shard_seq():
    arch = get_arch("smollm-135m")
    sds = arch.input_specs(__import__("repro.configs.base", fromlist=["SHAPES"]).SHAPES["train_4k"])
    sp = batch_pspecs(arch.config, sds, ("pod", "data"), shard_seq=True)
    assert jax.tree.leaves(sp, is_leaf=lambda x: isinstance(x, P))[0][1] == ("pod", "data")


class TestElastic:
    def test_plan_remesh_preserves_global_batch(self):
        for n in (512, 480, 384, 256, 96):
            plan = plan_remesh(n, global_batch=256)
            data, model = plan.mesh_shape
            assert data * model == n or data * model <= n
            assert plan.per_device_batch * data * plan.grad_accum >= 256

    def test_plan_remesh_keeps_model_axis_when_divisible(self):
        plan = plan_remesh(480, global_batch=256)
        assert plan.mesh_shape[1] == 16  # 480 = 30 x 16

    def test_plan_remesh_shrinks_model_axis_when_needed(self):
        plan = plan_remesh(24, global_batch=256)
        assert plan.mesh_shape[1] in (8, 4, 2, 1)
        assert 24 % plan.mesh_shape[1] == 0


class TestHLOStats:
    HLO = """
HloModule test

%region_body (x: f32[128,256]) -> f32[128,256] {
  %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  ROOT %r = f32[128,256]{1,0} add(%ar, %ar)
}

ENTRY %main (a: bf16[512,512]) -> bf16[512,512] {
  %ag = bf16[512,512]{1,0} all-gather(%a), dimensions={0}
  %rs = bf16[256,512]{1,0} reduce-scatter(%ag), dimensions={0}
  %cp = bf16[256,512]{1,0} collective-permute(%rs), source_target_pairs={{0,1}}
  ROOT %out = bf16[512,512]{1,0} all-gather(%cp), dimensions={0}
}
"""

    def test_counts_and_bytes(self):
        st = collective_stats(self.HLO, body_multiplier=10)
        assert st["all-gather"]["count"] == 2
        assert st["all-gather"]["bytes"] == 2 * 512 * 512 * 2
        assert st["reduce-scatter"]["count"] == 1
        # body all-reduce multiplied by 10
        assert st["all-reduce"]["count"] == 10
        assert st["all-reduce"]["bytes"] == 10 * 128 * 256 * 4
        # wire factor: AR 2x
        assert st["all-reduce"]["wire_bytes"] == 2 * st["all-reduce"]["bytes"]
        assert total_wire_bytes(st) > 0

    def test_done_ops_not_double_counted(self):
        hlo = """ENTRY %e (a: f32[4]) -> f32[4] {
  %s = f32[4]{0} all-gather-start(%a), dimensions={0}
  ROOT %d = f32[4]{0} all-gather-done(%s)
}"""
        st = collective_stats(hlo)
        assert st["all-gather"]["count"] == 1
