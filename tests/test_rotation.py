"""Unit + property tests for the paper's rotation construction."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import hadamard as hd
from repro.core.rotation import Rotation, RotationKind, apply_rotation, fwht, make_rotation

POW2 = [2, 4, 8, 16, 32, 64, 128, 256]


class TestHadamard:
    @pytest.mark.parametrize("n", POW2)
    def test_orthogonal(self, n):
        h = hd.hadamard(n)
        np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-10)

    def test_sylvester_recursion(self):
        h2 = hd.hadamard(2, normalize=False)
        h4 = hd.hadamard(4, normalize=False)
        np.testing.assert_array_equal(h4, np.kron(h2, h2))

    def test_paper_sequency_example(self):
        # Paper Sec 2.1: rows of H_8 have sequency 0, 7, 3, 4, 1, 6, 2, 5.
        h8 = hd.hadamard(8)
        np.testing.assert_array_equal(hd.sequency_of_rows(h8), [0, 7, 3, 4, 1, 6, 2, 5])

    @pytest.mark.parametrize("n", POW2)
    def test_natural_sequency_closed_form(self, n):
        np.testing.assert_array_equal(
            hd.natural_sequency(n), hd.sequency_of_rows(hd.hadamard(n))
        )


class TestWalsh:
    @pytest.mark.parametrize("n", POW2)
    def test_sequency_ascending(self, n):
        w = hd.walsh(n)
        np.testing.assert_array_equal(hd.sequency_of_rows(w), np.arange(n))

    @pytest.mark.parametrize("n", POW2)
    def test_orthogonal(self, n):
        w = hd.walsh(n)
        np.testing.assert_allclose(w @ w.T, np.eye(n), atol=1e-10)

    @pytest.mark.parametrize("n", POW2)
    def test_row_permutation_of_hadamard(self, n):
        # Walsh must be a pure row permutation of the Sylvester matrix.
        w = hd.walsh(n, normalize=False)
        h = hd.hadamard(n, normalize=False)
        perm = hd.walsh_permutation(n)
        assert sorted(perm) == list(range(n))
        np.testing.assert_array_equal(w, h[perm])

    def test_rht_preserves_sequency(self):
        # Paper Sec 3.2: RHT sign flips act per-column -> row sequency can
        # change locally but the *set/ordering structure* is that of the
        # natural ordering, not sequency ordering. We verify the weaker,
        # testable claim used by the paper's argument: RHT != sequency
        # ordered, while Walsh is.
        r = hd.randomized_hadamard(64, seed=3)
        seq = hd.sequency_of_rows(r)
        assert not np.all(np.diff(seq) >= 0)

    def test_intragroup_sequency_variance(self):
        # The paper's core justification: Walsh has smaller sequency
        # variance within each column group of R_f than Hadamard.
        n, g = 256, 32
        for mat in ["h", "w"]:
            pass
        seq_h = hd.natural_sequency(n).reshape(n // g, g)
        seq_w = np.arange(n).reshape(n // g, g)
        var_h = seq_h.var(axis=1).mean()
        var_w = seq_w.var(axis=1).mean()
        assert var_w < var_h / 10  # drastically smaller by construction


class TestGSR:
    def test_gsr_structure(self):
        m = hd.gsr_matrix(16, 4)
        w4 = hd.walsh(4)
        for b in range(4):
            np.testing.assert_allclose(m[4 * b : 4 * b + 4, 4 * b : 4 * b + 4], w4)
        # off-diagonal blocks zero
        assert np.count_nonzero(m) == 16 * 4

    @pytest.mark.parametrize("kind", ["GH", "GW", "LH", "GSR"])
    def test_make_rotation_orthogonal(self, kind):
        rot = make_rotation(kind, 64, group=16, seed=0)
        d = rot.dense()
        np.testing.assert_allclose(d @ d.T, np.eye(64), atol=1e-10)

    @pytest.mark.parametrize("kind", ["I", "GH", "GW", "LH", "GSR"])
    def test_apply_matches_dense(self, kind):
        rot = make_rotation(kind, 64, group=16, seed=1)
        x = np.random.default_rng(0).normal(size=(5, 64)).astype(np.float32)
        got = np.asarray(apply_rotation(jnp.asarray(x), rot))
        want = x @ rot.dense().astype(np.float32)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        got_inv = np.asarray(apply_rotation(jnp.asarray(got), rot, inverse=True))
        np.testing.assert_allclose(got_inv, x, rtol=2e-4, atol=2e-4)


class TestFWHT:
    @pytest.mark.parametrize("d", [2, 8, 64, 512])
    def test_matches_matmul(self, d):
        x = np.random.default_rng(1).normal(size=(3, d)).astype(np.float32)
        got = np.asarray(fwht(jnp.asarray(x)))
        want = x @ hd.hadamard(d).astype(np.float32)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_involution(self):
        x = np.random.default_rng(2).normal(size=(4, 128)).astype(np.float32)
        twice = np.asarray(fwht(fwht(jnp.asarray(x))))
        np.testing.assert_allclose(twice, x, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    logn=st.integers(min_value=1, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_rotation_preserves_norm(logn, seed):
    """Any constructed rotation is an isometry (quantization-error analysis
    relies on this: rotating cannot change the energy being quantized)."""
    n = 2**logn
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(2, n))
    for kind in ["GH", "GW"]:
        rot = make_rotation(kind, n, seed=seed)
        y = x @ rot.dense()
        np.testing.assert_allclose(
            np.linalg.norm(y, axis=-1), np.linalg.norm(x, axis=-1), rtol=1e-9
        )


@settings(max_examples=25, deadline=None)
@given(
    logg=st.integers(min_value=1, max_value=5),
    blocks=st.integers(min_value=1, max_value=8),
)
def test_property_gsr_block_locality(logg, blocks):
    """GSR confines mixing within groups: a vector supported on group b
    stays supported on group b after rotation (paper Fig. 2b)."""
    g = 2**logg
    dim = g * blocks
    rot = make_rotation("GSR", dim, group=g)
    x = np.zeros((1, dim))
    b = blocks // 2
    x[0, b * g : (b + 1) * g] = np.random.default_rng(0).normal(size=g)
    y = np.asarray(apply_rotation(jnp.asarray(x.astype(np.float32)), rot))
    mask = np.ones(dim, bool)
    mask[b * g : (b + 1) * g] = False
    if mask.any():
        assert np.abs(y[0, mask]).max() == 0.0
