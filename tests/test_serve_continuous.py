"""Continuous-batching serving tests: scheduler + paged KV pool.

The acceptance contract of the serving subsystem:

  * the continuous scheduler is *token-identical* to the static
    fixed-batch ``generate_static()`` on the same prompts, across all
    five model families (reference backend), including when requests
    outnumber slots (queue + per-slot refill) and with quantized KV;
  * the KV pool never leaks or double-assigns a block across
    admit/stop/refill cycles (float and quantized KV), and returns to
    pristine state once drained;
  * streaming callbacks fire token-by-token and the metrics surface
    queue wait / TTFT / decode-slot utilisation.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.common import QuantizeSpec
from repro.models.registry import get_arch
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import synthetic_trace

FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mla": "minicpm3-4b",
    "ssm": "xlstm-1.3b",
    "hybrid": "zamba2-1.2b",
}
FAMILIES = sorted(FAMILY_ARCHS)


@pytest.fixture(scope="module")
def models():
    """{family: (arch, float params)} at reduced scale."""
    out = {}
    for family, name in FAMILY_ARCHS.items():
        arch = get_arch(name, reduced=True)
        out[family] = (arch, arch.init(jax.random.PRNGKey(0), jnp.float32))
    return out


def _prompts(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.modality == "audio":
        return rng.integers(0, cfg.vocab, size=(b, s, cfg.n_codebooks)
                            ).astype(np.int32)
    return rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)


# ---------------------------------------------------------------------------
# Token identity: continuous scheduler == static fixed-batch loop
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", FAMILIES)
def test_continuous_token_identical_to_static(models, family):
    """3 requests through 2 slots (queue + refill) produce exactly the
    tokens the static loop produces with all 3 resident."""
    arch, params = models[family]
    prompts = _prompts(arch.config, 3, 8)
    static = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=3))
    out_s = static.generate_static(prompts, 5)
    cont = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                 block_tokens=8))
    out_c = cont.generate(prompts, 5)
    np.testing.assert_array_equal(out_s["tokens"], out_c["tokens"])
    # the pool is pristine after drain: every block back on the free list
    cont.pool.check_invariants()
    assert not any(cont.pool.slot_blocks[s] for s in range(2))


@pytest.mark.parametrize("family", ["dense", "mla", "hybrid"])
def test_continuous_token_identical_quantized_kv(models, family):
    """Same contract through the quantized-KV path: packed int8 KV blocks
    in the pool, dequantized at attention time."""
    arch, params = models[family]
    spec = QuantizeSpec(kv_bits=4)
    prompts = _prompts(arch.config, 3, 8)
    out_s = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=3),
                        spec).generate_static(prompts, 4)
    out_c = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                  block_tokens=8),
                        spec).generate(prompts, 4)
    np.testing.assert_array_equal(out_s["tokens"], out_c["tokens"])


def test_mixed_prompt_lengths_match_per_request_static(models):
    """Continuous admission prefilled at exact per-request prompt lengths:
    each request's tokens equal a dedicated static run of that prompt."""
    arch, params = models["dense"]
    cfg = arch.config
    lens = [5, 9, 12]
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=(s,)).astype(np.int32)
               for s in lens]
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                block_tokens=8))
    reqs = [eng.submit(p, 4) for p in prompts]
    eng.drain()
    oracle = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=1))
    for p, r in zip(prompts, reqs):
        out = oracle.generate_static(p[None], 4)
        np.testing.assert_array_equal(out["tokens"][0], r.token_array())


@pytest.mark.parametrize("name", ["musicgen-medium", "internvl2-2b"])
def test_modalities_generate_matches_static(name):
    """The generate() wrapper keeps the audio / vlm contracts."""
    arch = get_arch(name, reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    prompts = _prompts(cfg, 2, 6)
    pe = None
    if cfg.modality == "vlm":
        pe = (np.random.default_rng(0)
              .normal(size=(2, cfg.n_patches, cfg.d_model))
              .astype(np.float32) * 0.02)
    out_s = ServeEngine(arch, params, ServeConfig(max_seq=48, batch_slots=2)
                        ).generate_static(prompts, 3, patch_embeds=pe)
    out_c = ServeEngine(arch, params, ServeConfig(max_seq=48, batch_slots=2,
                                                  block_tokens=8)
                        ).generate(prompts, 3, patch_embeds=pe)
    np.testing.assert_array_equal(out_s["tokens"], out_c["tokens"])


# ---------------------------------------------------------------------------
# Pool invariants across admit / stop / refill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_bits", [16, 4])
def test_pool_invariants_over_oversubscribed_trace(models, kv_bits):
    """Mixed-length trace through an *undersized* pool (admission must
    defer): after every tick no block is leaked, double-assigned, or both
    free and owned; the drained pool is pristine."""
    arch, params = models["dense"]
    spec = QuantizeSpec(kv_bits=kv_bits)
    eng = ServeEngine(arch, params,
                      ServeConfig(max_seq=32, batch_slots=2, block_tokens=8,
                                  pool_blocks=7),  # < full provisioning (9)
                      spec)
    trace = synthetic_trace(arch.config, 6, seed=2, prompt_len=6,
                            prompt_jitter=4, max_new_low=2, max_new_high=8)
    for r in trace:
        eng.scheduler.submit(r)
        eng.pool.check_invariants()
    waited = False
    while eng.scheduler.queue or eng.scheduler.n_active:
        free_before = len(eng.pool.free)
        eng.step()
        eng.pool.check_invariants()
        waited |= bool(eng.scheduler.queue) and free_before > 0
    assert all(len(r.tokens) == r.max_new_tokens for r in trace)
    assert len(eng.pool.free) == eng.pool.capacity_blocks  # all returned
    assert not any(eng.pool.slot_blocks)
    assert waited, "trace never exercised deferred admission"


def test_pool_release_and_reuse_is_exact(models):
    """A refilled slot reuses blocks a finished request returned; its
    tokens are unaffected by the stale content (masked by length)."""
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=1,
                                                block_tokens=8))
    p1, p2 = _prompts(cfg, 2, 8, seed=3)
    r1 = eng.submit(p1, 6)
    eng.drain()
    r2 = eng.submit(p2, 6)  # refills slot 0 with r1's returned blocks
    eng.drain()
    oracle = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=1))
    np.testing.assert_array_equal(
        oracle.generate_static(p2[None], 6)["tokens"][0], r2.token_array())
    assert r1.rid != r2.rid


# ---------------------------------------------------------------------------
# Streaming + metrics + validation
# ---------------------------------------------------------------------------


def test_streaming_callbacks_and_metrics(models):
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                block_tokens=8))
    seen = []

    def cb(req, tok, done):
        seen.append((req.rid, int(np.asarray(tok)), done))

    prompts = _prompts(cfg, 3, 8)
    reqs = [eng.submit(prompts[i], 3, on_token=cb) for i in range(3)]
    eng.drain()
    for r in reqs:
        mine = [(rid, t, d) for rid, t, d in seen if rid == r.rid]
        assert [m[1] for m in mine] == [int(x) for x in r.token_array()]
        assert [m[2] for m in mine] == [False, False, True]

    m = eng.scheduler.metrics()
    agg = m["aggregate"]
    assert agg["n_requests"] == 3
    assert agg["tokens_generated"] == 9
    assert 0 < agg["slot_utilisation"] <= 1
    assert agg["busy_slot_steps"] <= agg["decode_steps"] * 2
    for r in m["requests"]:
        assert r["queue_wait_s"] >= 0
        assert r["ttft_s"] >= r["queue_wait_s"]
        assert r["new_tokens"] == 3


def test_stop_token_ends_request_early(models):
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=1,
                                                block_tokens=8))
    prompt = _prompts(cfg, 1, 8)[0]
    ref = eng.submit(prompt, 6)
    eng.drain()
    stop = int(ref.token_array()[1])  # stop on the 2nd greedy token
    eng2 = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=1,
                                                 block_tokens=8))
    r = eng2.submit(prompt, 6, stop_token=stop)
    eng2.drain()
    assert len(r.tokens) == 2
    assert int(r.token_array()[-1]) == stop
    eng2.pool.check_invariants()


def test_continuous_under_mesh_matches_unmeshed(models):
    """The pool's block storage is placed by ``dist.sharding.pool_pspecs``
    under a mesh; a 1-device mesh must be a behavioural no-op."""
    arch, params = models["dense"]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    prompts = _prompts(arch.config, 2, 8)
    scfg = ServeConfig(max_seq=32, batch_slots=2, block_tokens=8)
    out_m = ServeEngine(arch, params, scfg, mesh=mesh).generate(prompts, 4)
    out_0 = ServeEngine(arch, params, scfg).generate(prompts, 4)
    np.testing.assert_array_equal(out_m["tokens"], out_0["tokens"])


def test_submit_validation(models):
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(max_seq=16, batch_slots=1,
                                                block_tokens=8))
    with pytest.raises(ValueError, match="cache positions"):
        eng.submit(_prompts(cfg, 1, 14)[0], 8)  # 14 + 7 > 16-token view
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit(_prompts(cfg, 1, 4)[0], 0)


# ---------------------------------------------------------------------------
# Mid-stream abort: dropping a scheduler with live work reconciles the pool
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("prefix_cache", [False, True])
def test_abort_mid_stream_reconciles_pool(models, prefix_cache):
    """abort() with active slots AND queued requests: every request comes
    back status="aborted" with blocks and prefix-cache refs released — the
    pool passes check_invariants/check_leaks immediately, no teardown
    RuntimeError."""
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=4,
        prefix_cache=prefix_cache))
    prompts = _prompts(cfg, 4, 8)
    reqs = [eng.submit(p, 8) for p in prompts]
    for _ in range(3):  # 2 active mid-decode, 2 still queued
        eng.step()
    assert eng.scheduler.n_active == 2 and len(eng.scheduler.queue) == 2
    aborted = eng.scheduler.abort()
    assert len(aborted) == 4
    assert all(r.status == "aborted" and r.error for r in reqs)
    assert eng.scheduler.n_active == 0 and not eng.scheduler.queue
    eng.pool.check_invariants()
    if not prefix_cache:
        eng.pool.check_leaks()  # cached-idle blocks are intentional
    else:
        # cached blocks are refcount-0 by design; everything else is free
        pc = eng.prefix_cache
        cached = set(pc._blocks)
        free = set(eng.pool.free)
        assert not (cached & free)
        assert cached | free == set(range(1, eng.pool.n_blocks))
    # the scheduler still serves after the abort
    r = eng.submit(prompts[0], 3)
    eng.drain()
    assert r.status == "done" and len(r.tokens) == 3


def test_abort_releases_shared_prefix_refs(models):
    """Abort while two slots share cached prefix blocks: shared refcounts
    drop back to cache-only and the free list reconciles."""
    arch, params = models["dense"]
    cfg = arch.config
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=4, prefix_cache=True))
    common = _prompts(cfg, 1, 8)[0]
    rng = np.random.default_rng(3)
    p1 = np.concatenate([common, rng.integers(0, cfg.vocab, 4).astype(np.int32)])
    p2 = np.concatenate([common, rng.integers(0, cfg.vocab, 4).astype(np.int32)])
    warm = eng.submit(common, 2)
    eng.drain()
    assert warm.status == "done"
    eng.submit(p1, 8)
    eng.submit(p2, 8)
    eng.step()
    assert eng.scheduler.blocks_shared > 0, "prefix must actually be shared"
    eng.scheduler.abort()
    eng.pool.check_invariants()
    pc = eng.prefix_cache
    for blk in pc._blocks:
        assert eng.pool.refcount[blk] == 0  # cache-only residency again
