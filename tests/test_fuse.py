"""Rotation-fusion invariance: the foundation of the whole PTQ scheme.

For every architecture family and every rotation kind, fusing R1 (and R2 /
the R4 pre-rotation) into the weights must leave fp32 outputs unchanged.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import hadamard as hd
from repro.core.fuse import fuse_rotations
from repro.core.rotation import make_rotation
from repro.models.common import QuantizeSpec
from repro.models.registry import ARCH_IDS, get_arch

B, S = 2, 12

FUSE_ARCHS = [
    "smollm-135m",        # dense GQA
    "qwen1.5-4b",         # dense + qkv bias
    "internvl2-2b",       # vlm prefix
    "musicgen-medium",    # audio K codebooks
    "deepseek-moe-16b",   # uniform MoE + shared experts
    "llama4-maverick-400b-a17b",  # interleaved MoE
    "minicpm3-4b",        # MLA
    "xlstm-1.3b",         # ssm
    "zamba2-1.2b",        # hybrid
]


def make_batch(cfg, key, s=S):
    ks = jax.random.split(key, 2)
    if cfg.modality == "audio":
        batch = {"tokens": jax.random.randint(ks[0], (B, s, cfg.n_codebooks), 0, cfg.vocab)}
    else:
        batch = {"tokens": jax.random.randint(ks[0], (B, s), 0, cfg.vocab)}
    if cfg.modality == "vlm":
        batch["patch_embeds"] = jax.random.normal(ks[1], (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.mark.parametrize("name", FUSE_ARCHS)
@pytest.mark.parametrize("kind", ["GH", "GW", "LH", "GSR"])
def test_r1_fusion_invariance(name, kind):
    arch = get_arch(name, reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    # make the norm scales non-trivial so folding is actually exercised
    params = jax.tree.map(
        lambda a: a * 1.3 if a.ndim >= 1 and np.all(np.asarray(a) == 1.0) else a, params
    )
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    base = np.asarray(arch.forward(params, batch), np.float32)

    r1 = make_rotation(kind, cfg.d_model, group=32, seed=3)
    fused = fuse_rotations(cfg, params, r1)
    got = np.asarray(arch.forward(fused, batch), np.float32)
    np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3)


def test_r2_fusion_invariance_dense():
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    base = np.asarray(arch.forward(params, batch), np.float32)
    r1 = make_rotation("GSR", cfg.d_model, group=32, seed=0)
    r2 = make_rotation("GH", cfg.hd, seed=5)
    fused = fuse_rotations(cfg, params, r1, r2=r2)
    got = np.asarray(arch.forward(fused, batch), np.float32)
    np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("r4", ["GH", "GW", "LH", "GSR"])
def test_r4_online_cancels_fused_prerotation(r4):
    """Online apply_r4(x) @ (R4^T W_down) == x @ W_down in fp."""
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    base = np.asarray(arch.forward(params, batch), np.float32)
    spec = QuantizeSpec(r4_kind=r4, r4_group=32)
    r1 = make_rotation("I", cfg.d_model)
    fused = fuse_rotations(cfg, params, r1, spec=spec)
    got = np.asarray(arch.forward(fused, batch, spec), np.float32)
    np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3)


def test_act_rules_compose_with_per_site_r4_fp_invariant():
    """A populated act-site table at 16 bits must not perturb the R4
    cancellation: site-tagged act_q resolves to the fp passthrough at
    every site while per-site online rotations still cancel their fused
    pre-rotation."""
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    base = np.asarray(arch.forward(params, batch), np.float32)
    spec = QuantizeSpec(
        r4_kind="I", r4_group=32,
        r4_sites=(("w_down", "GSR", 32, 7),),
        act_sites=(("*down*", 16, 32, 1.0), ("wq", 16, 64, 0.9)),
    )
    assert spec.r4_for("w_down")[0] == "GSR"
    assert not spec.act_enabled
    r1 = make_rotation("I", cfg.d_model)
    fused = fuse_rotations(cfg, params, r1, spec=spec)
    got = np.asarray(arch.forward(fused, batch, spec), np.float32)
    np.testing.assert_allclose(got, base, rtol=2e-3, atol=2e-3)


def test_prefill_decode_invariance_after_fusion():
    """Fused serving path stays consistent with fused training forward."""
    arch = get_arch("smollm-135m", reduced=True)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    r1 = make_rotation("GSR", cfg.d_model, group=32, seed=0)
    fused = fuse_rotations(cfg, params, r1)
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    full = np.asarray(arch.forward(fused, batch), np.float32)
    cache = arch.init_cache(B, S + 4, QuantizeSpec(), jnp.float32)
    pre = {"tokens": batch["tokens"][:, : S - 1]}
    logits, cache = arch.prefill(fused, pre, cache, QuantizeSpec())
    np.testing.assert_allclose(
        np.asarray(logits, np.float32).squeeze(), full[:, S - 2].squeeze(),
        rtol=2e-3, atol=2e-3,
    )


class TestNonPow2Hadamard:
    @pytest.mark.parametrize("n", [12, 20, 28, 36, 576, 1536, 2560, 5120])
    def test_orthogonal(self, n):
        h = hd.hadamard_auto(n)
        np.testing.assert_allclose(h @ h.T, np.eye(n), atol=1e-8)
        assert set(np.unique(np.round(h * np.sqrt(n)))) <= {-1.0, 1.0}

    @pytest.mark.parametrize("n", [12, 576, 1536])
    def test_walsh_auto_sequency_sorted(self, n):
        w = hd.walsh_auto(n)
        seq = hd.sequency_of_rows(w)
        assert np.all(np.diff(seq) >= 0)
        np.testing.assert_allclose(w @ w.T, np.eye(n), atol=1e-8)

    def test_pow2_walsh_auto_matches_walsh(self):
        np.testing.assert_allclose(hd.walsh_auto(64), hd.walsh(64), atol=0)
