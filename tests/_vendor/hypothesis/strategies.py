"""Strategies for the vendored hypothesis shim (see package docstring).

Each strategy implements ``do_draw(rnd, i)``: deterministic example ``i``
drawn with the per-example ``random.Random``.  The first few examples are
the strategy's boundary values (min, max, zero, ...), the rest uniform.
"""
from __future__ import annotations

import math
import random
from typing import Any, List, Optional, Sequence


class SearchStrategy:
    def do_draw(self, rnd: random.Random, i: int) -> Any:
        raise NotImplementedError

    def map(self, f) -> "SearchStrategy":
        return _Mapped(self, f)

    def filter(self, pred) -> "SearchStrategy":
        return _Filtered(self, pred)


class _Mapped(SearchStrategy):
    def __init__(self, base, f):
        self.base, self.f = base, f

    def do_draw(self, rnd, i):
        return self.f(self.base.do_draw(rnd, i))


class _Filtered(SearchStrategy):
    def __init__(self, base, pred):
        self.base, self.pred = base, pred

    def do_draw(self, rnd, i):
        for k in range(1000):
            v = self.base.do_draw(rnd, i + 1000 * k if k else i)
            if self.pred(v):
                return v
        raise ValueError("filter predicate satisfied by no drawn example")


class _Integers(SearchStrategy):
    def __init__(self, lo: Optional[int], hi: Optional[int]):
        self.lo = -(2**32) if lo is None else int(lo)
        self.hi = 2**32 if hi is None else int(hi)
        if self.lo > self.hi:
            raise ValueError(f"integers({lo}, {hi}): empty range")
        edges = [self.lo, self.hi]
        if self.lo < 0 < self.hi:
            edges.append(0)
        if self.lo < 1 <= self.hi:
            edges.append(1)
        self.edges: List[int] = list(dict.fromkeys(edges))

    def do_draw(self, rnd, i):
        if i < len(self.edges):
            return self.edges[i]
        return rnd.randint(self.lo, self.hi)


def integers(min_value: Optional[int] = None, max_value: Optional[int] = None
             ) -> SearchStrategy:
    return _Integers(min_value, max_value)


class _Booleans(SearchStrategy):
    def do_draw(self, rnd, i):
        if i < 2:
            return bool(i)
        return rnd.random() < 0.5


def booleans() -> SearchStrategy:
    return _Booleans()


class _SampledFrom(SearchStrategy):
    def __init__(self, elements: Sequence[Any]):
        self.elements = list(elements)
        if not self.elements:
            raise ValueError("sampled_from of empty sequence")

    def do_draw(self, rnd, i):
        if i < len(self.elements):
            return self.elements[i]
        return rnd.choice(self.elements)


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    return _SampledFrom(elements)


class _Floats(SearchStrategy):
    def __init__(self, lo, hi, allow_nan, allow_infinity):
        self.lo = -1e9 if lo is None else float(lo)
        self.hi = 1e9 if hi is None else float(hi)
        self.allow_nan = allow_nan
        self.allow_infinity = allow_infinity
        self.edges = [self.lo, self.hi]
        if self.lo < 0.0 < self.hi:
            self.edges.append(0.0)

    def do_draw(self, rnd, i):
        if i < len(self.edges):
            return self.edges[i]
        v = rnd.uniform(self.lo, self.hi)
        return v if math.isfinite(v) else self.lo


def floats(min_value=None, max_value=None, *, allow_nan: bool = False,
           allow_infinity: bool = False, width: int = 64) -> SearchStrategy:
    return _Floats(min_value, max_value, allow_nan, allow_infinity)


class _Lists(SearchStrategy):
    def __init__(self, elem: SearchStrategy, min_size: int, max_size: Optional[int],
                 unique: bool):
        self.elem = elem
        self.min_size = min_size
        self.max_size = max_size if max_size is not None else min_size + 10
        self.unique = unique

    def do_draw(self, rnd, i):
        size = self.min_size if i == 0 else rnd.randint(self.min_size, self.max_size)
        out: List[Any] = []
        tries = 0
        while len(out) < size and tries < 100 * (size + 1):
            v = self.elem.do_draw(rnd, i + len(out) + 1)
            tries += 1
            if self.unique and v in out:
                continue
            out.append(v)
        return out


def lists(elements: SearchStrategy, *, min_size: int = 0,
          max_size: Optional[int] = None, unique: bool = False) -> SearchStrategy:
    return _Lists(elements, min_size, max_size, unique)


class _Tuples(SearchStrategy):
    def __init__(self, strategies):
        self.strategies = strategies

    def do_draw(self, rnd, i):
        return tuple(s.do_draw(rnd, i) for s in self.strategies)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return _Tuples(strategies)


class _Just(SearchStrategy):
    def __init__(self, value):
        self.value = value

    def do_draw(self, rnd, i):
        return self.value


def just(value) -> SearchStrategy:
    return _Just(value)


class _OneOf(SearchStrategy):
    def __init__(self, strategies):
        self.strategies = list(strategies)

    def do_draw(self, rnd, i):
        if i < len(self.strategies):
            return self.strategies[i].do_draw(rnd, i)
        return rnd.choice(self.strategies).do_draw(rnd, i)


def one_of(*strategies: SearchStrategy) -> SearchStrategy:
    return _OneOf(strategies)
