"""Minimal vendored stand-in for the ``hypothesis`` property-testing API.

Loaded by ``tests/conftest.py`` ONLY when the real package is not
installed.  Supports the subset this repo's tests use — ``@given`` with
keyword strategies, ``@settings(max_examples=..., deadline=...)``,
``assume`` — with *deterministic* example generation: example ``i`` of a
test is drawn from ``random.Random`` seeded by ``i``, so failures
reproduce run-to-run.  Unlike real hypothesis there is no shrinking and
no coverage-guided search; the first examples of every strategy are its
boundary values, which recovers most of the edge-case value.
"""
from __future__ import annotations

import functools
import inspect
import random

from hypothesis import strategies  # noqa: F401  (submodule, vendored)
from hypothesis.strategies import SearchStrategy  # noqa: F401

__version__ = "0.0.0+vendored-shim"

_DEFAULT_MAX_EXAMPLES = 20
_SEED = 0x5EED


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition) -> bool:
    """Skip the current example when ``condition`` is falsy."""
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class HealthCheck:
    """Attribute sink: ``suppress_health_check=[HealthCheck.x]`` parses."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"
    function_scoped_fixture = "function_scoped_fixture"


def settings(max_examples=None, deadline=None, **_ignored):
    """Decorator: only ``max_examples`` is honoured (no deadlines here)."""

    def deco(fn):
        if max_examples is not None:
            fn._shim_max_examples = int(max_examples)
        return fn

    return deco


def note(_msg) -> None:
    pass


def given(*arg_strategies, **kw_strategies):
    if arg_strategies:
        raise TypeError(
            "vendored hypothesis shim supports only keyword-form @given(...)"
        )

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            ran = 0
            for i in range(max(4 * n, n + 16)):
                if ran >= n:
                    break
                rnd = random.Random((_SEED << 20) ^ (7919 * i))
                drawn = {k: s.do_draw(rnd, i) for k, s in kw_strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except UnsatisfiedAssumption:
                    continue
                ran += 1

        # Hide strategy params from pytest's fixture resolution.
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[
                p for name, p in sig.parameters.items() if name not in kw_strategies
            ]
        )
        return wrapper

    return deco
