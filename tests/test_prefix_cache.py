"""Prefix-sharing KV cache tests: radix index + refcounts + copy-on-write.

The acceptance contract of the prefix-sharing subsystem
(:mod:`repro.serve.prefixcache`):

  * with ``ServeConfig(prefix_cache=True)`` the emitted tokens are
    *bit-identical* to ``prefix_cache=False`` on shared-prefix traffic,
    across the paged attention-cache families (dense / MoE / MLA), float
    and quantized KV, single-tick and in-graph-window decode;
  * a fully cached prompt is served through copy-on-write — the shared
    blocks are mapped, exactly one fresh block is written — and still
    matches the unshared run token-for-token;
  * refcounts never leak or double-free under oversubscription: released
    shared blocks stay resident while the index holds them, eviction
    reclaims only refcount-0 unpinned blocks, and the pool passes its
    invariant + leak checks after drain/flush;
  * the scheduler metrics account every admitted prompt position as
    either computed or saved, and ``prefix_hit_rate`` reflects sharing;
  * recurrent-state families (xLSTM / Zamba) silently serve unshared.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.common import NOQUANT, QuantizeSpec
from repro.models.registry import get_arch
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import synthetic_trace

PAGED_FAMILY_ARCHS = {
    "dense": "smollm-135m",
    "moe": "deepseek-moe-16b",
    "mla": "minicpm3-4b",
}


@pytest.fixture(scope="module")
def models():
    """{family: (arch, float params)} at reduced scale (paged families)."""
    out = {}
    for family, name in PAGED_FAMILY_ARCHS.items():
        arch = get_arch(name, reduced=True)
        out[family] = (arch, arch.init(jax.random.PRNGKey(0), jnp.float32))
    return out


def _run_trace(arch, params, spec, trace, *, prefix_cache, block_tokens=8,
               max_seq=96, batch_slots=2, pool_blocks=None,
               steps_per_sync=1):
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=max_seq, batch_slots=batch_slots, block_tokens=block_tokens,
        pool_blocks=pool_blocks, prefix_cache=prefix_cache,
        steps_per_sync=steps_per_sync), spec, dtype=jnp.float32)
    reqs = [eng.scheduler.submit(r) for r in trace]
    eng.drain()
    return eng, [r.token_array() for r in reqs]


# ---------------------------------------------------------------------------
# Token identity: prefix_cache=True == prefix_cache=False, bit for bit
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", sorted(PAGED_FAMILY_ARCHS))
@pytest.mark.parametrize("kv_bits", [16, 4])
def test_sharing_token_identity(models, family, kv_bits):
    """Shared-prefix trace through on/off engines: identical tokens, a
    real hit rate, and a pristine pool afterwards."""
    arch, params = models[family]
    spec = NOQUANT if kv_bits == 16 else QuantizeSpec(kv_bits=kv_bits)
    trace = lambda: synthetic_trace(
        arch.config, 5, seed=3, prompt_len=6, max_new_low=2, max_new_high=5,
        shared_prefix_tokens=16, n_prefix_groups=2)
    _, toks_off = _run_trace(arch, params, spec, trace(), prefix_cache=False)
    eng, toks_on = _run_trace(arch, params, spec, trace(), prefix_cache=True)
    for a, b in zip(toks_off, toks_on):
        np.testing.assert_array_equal(a, b)
    agg = eng.scheduler.metrics()["aggregate"]
    assert agg["prefix_hit_rate"] > 0
    assert agg["blocks_shared"] > 0
    assert (agg["prefill_tokens_saved"] + agg["prefill_tokens_computed"]
            == sum(r.prompt_tokens for r in eng.scheduler.done))
    eng.pool.check_invariants()


def test_sharing_token_identity_windowed(models):
    """Same identity contract with the in-graph multi-step decode window
    (``steps_per_sync > 1``) — decode never touches shared blocks."""
    arch, params = models["dense"]
    trace = lambda: synthetic_trace(
        arch.config, 5, seed=4, prompt_len=6, max_new_low=3, max_new_high=9,
        shared_prefix_tokens=16, n_prefix_groups=1)
    _, toks_off = _run_trace(arch, params, NOQUANT, trace(),
                             prefix_cache=False, steps_per_sync=4)
    eng, toks_on = _run_trace(arch, params, NOQUANT, trace(),
                              prefix_cache=True, steps_per_sync=4)
    for a, b in zip(toks_off, toks_on):
        np.testing.assert_array_equal(a, b)
    assert eng.scheduler.metrics()["aggregate"]["prefix_hit_rate"] > 0
    eng.pool.check_invariants()


# ---------------------------------------------------------------------------
# Copy-on-write
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kv_bits", [16, 4])
def test_full_hit_cow_exactness(models, kv_bits):
    """Identical (block-aligned) prompts: every admission after the first
    is a full hit served by copy-on-write — one fresh block each, shared
    blocks never rewritten, tokens identical to the unshared run."""
    arch, params = models["dense"]
    spec = NOQUANT if kv_bits == 16 else QuantizeSpec(kv_bits=kv_bits)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, arch.config.vocab, size=(16,)).astype(np.int32)
    from repro.serve.scheduler import Request
    mk = lambda: [Request(prompt=prompt.copy(), max_new_tokens=6)
                  for _ in range(3)]
    _, toks_off = _run_trace(arch, params, spec, mk(), prefix_cache=False)
    eng, toks_on = _run_trace(arch, params, spec, mk(), prefix_cache=True)
    for a, b in zip(toks_off, toks_on):
        np.testing.assert_array_equal(a, b)
    agg = eng.scheduler.metrics()["aggregate"]
    assert agg["cow_copies"] == 2  # admissions 2 and 3 were fully cached
    assert agg["prefill_tokens_saved"] == 2 * (len(prompt) - 1)
    eng.pool.check_invariants()


def test_divergent_suffix_partial_sharing(models):
    """Prompts sharing a prefix but diverging mid-stream share exactly the
    common full blocks; the divergent tail is prefilled fresh."""
    arch, params = models["dense"]
    rng = np.random.default_rng(1)
    prefix = rng.integers(0, arch.config.vocab, size=(16,)).astype(np.int32)
    tails = [rng.integers(0, arch.config.vocab, size=(6,)).astype(np.int32)
             for _ in range(2)]
    from repro.serve.scheduler import Request
    mk = lambda: [Request(prompt=np.concatenate([prefix, t]),
                          max_new_tokens=4) for t in tails]
    _, toks_off = _run_trace(arch, params, NOQUANT, mk(), prefix_cache=False)
    eng, toks_on = _run_trace(arch, params, NOQUANT, mk(), prefix_cache=True)
    for a, b in zip(toks_off, toks_on):
        np.testing.assert_array_equal(a, b)
    agg = eng.scheduler.metrics()["aggregate"]
    # second admission maps the 2 full prefix blocks (16 tokens / T=8) and
    # computes only its 6-token tail
    assert agg["blocks_shared"] == 2
    assert agg["prefill_tokens_saved"] == 16
    assert agg["cow_copies"] == 0


# ---------------------------------------------------------------------------
# Refcounts, eviction, leaks
# ---------------------------------------------------------------------------


def test_refcount_reuse_under_oversubscription(models):
    """More distinct prefixes than the pool can retain: admission evicts
    idle cached blocks on demand, refcounts never double-free, and the
    pool drains leak-free."""
    arch, params = models["dense"]
    trace = synthetic_trace(
        arch.config, 10, seed=5, prompt_len=14, max_new_low=2, max_new_high=4,
        shared_prefix_tokens=8, n_prefix_groups=5)
    eng, _ = _run_trace(arch, params, NOQUANT, trace, prefix_cache=True,
                        block_tokens=4, max_seq=32, pool_blocks=14)
    pc = eng.prefix_cache
    assert pc.stats()["evictions"] > 0
    eng.pool.check_invariants()
    # flush the index: every cached-idle block returns to the free list
    pc.flush()
    assert pc.stats()["cached_blocks"] == 0
    eng.pool.check_leaks()


def test_release_keeps_cached_blocks_resident(models):
    """Releasing a slot whose blocks are indexed keeps them resident
    (off the free list) until evicted; releasing unindexed blocks frees
    them immediately."""
    arch, params = models["dense"]
    eng = ServeEngine(arch, params,
                      ServeConfig(max_seq=64, batch_slots=2, block_tokens=8,
                                  prefix_cache=True), dtype=jnp.float32)
    prompt = np.arange(16, dtype=np.int32) % arch.config.vocab
    eng.submit(prompt, 2)
    eng.drain()
    pool, pc = eng.pool, eng.prefix_cache
    cached = set(pc.blocks())
    assert cached and all(pool.refcount[b] == 0 for b in cached)
    assert not (cached & set(pool.free))  # resident, not reclaimable
    pool.check_invariants()
    # a second identical request re-maps those very blocks (refcount > 0)
    eng.submit(prompt, 2)
    eng.drain()
    assert pc.stats()["hits"] >= 1
    pool.check_invariants()
    pc.flush()
    pool.check_leaks()


def test_no_reclaim_of_live_shared_blocks(models):
    """The pool refuses to reclaim a block that still has table
    references, and refuses a double release."""
    arch, params = models["dense"]
    eng = ServeEngine(arch, params,
                      ServeConfig(max_seq=64, batch_slots=2, block_tokens=8,
                                  prefix_cache=True), dtype=jnp.float32)
    prompt = np.arange(24, dtype=np.int32) % arch.config.vocab
    r = eng.submit(prompt, 8)
    eng.scheduler.step()  # admit + first decode tick; request still active
    assert r.status == "active"
    slot = eng.scheduler.slot_req.index(r)
    live = eng.pool.slot_blocks[slot][0]
    assert eng.pool.refcount[live] > 0
    with pytest.raises(AssertionError):
        eng.pool.reclaim([live])  # live shared block: must refuse
    eng.drain()
    eng.prefix_cache.flush()
    assert live in eng.pool.free
    with pytest.raises(AssertionError):
        eng.pool.reclaim([live])  # already free: double-free must assert
    eng.pool.check_leaks()


def test_eviction_is_lru_leaf_first(models):
    """Eviction removes only leaves and prefers the least recently used:
    a prefix chain is consumed tail-first, never orphaning a child."""
    arch, params = models["dense"]
    eng = ServeEngine(arch, params,
                      ServeConfig(max_seq=64, batch_slots=1, block_tokens=8,
                                  prefix_cache=True), dtype=jnp.float32)
    prompt = np.arange(24, dtype=np.int32) % arch.config.vocab  # 3 blocks
    eng.submit(prompt, 2)
    eng.drain()
    pc = eng.prefix_cache
    chain = [pc.nodes[k].block for k in pc._keys(prompt)]
    assert len(chain) == 3
    assert pc.evict(1) == 1
    assert not pc.holds(chain[2]) and pc.holds(chain[0])  # leaf went first
    assert pc.evict(10) == 2  # rest of the chain, tail-first
    assert pc.stats()["cached_blocks"] == 0
    eng.pool.check_leaks()


def test_capacity_knob_caps_resident_index(models):
    """``ServeConfig(max_cached_blocks=N)`` bounds the index at insert
    time: idle LRU leaves beyond the cap are evicted (counted under
    ``evictions_capacity``, separate from pressure evictions), and the
    capped run stays token-identical to the uncapped one."""
    arch, params = models["dense"]
    mk = lambda: synthetic_trace(arch.config, 6, seed=5, prompt_len=6,
                                 max_new_low=2, max_new_high=4,
                                 shared_prefix_tokens=16, n_prefix_groups=3)

    def run(cap):
        eng = ServeEngine(arch, params, ServeConfig(
            max_seq=96, batch_slots=1, block_tokens=8, prefix_cache=True,
            max_cached_blocks=cap), dtype=jnp.float32)
        reqs = [eng.scheduler.submit(r) for r in mk()]
        eng.drain()
        return eng, [r.token_array() for r in reqs]

    eng_u, toks_u = run(None)
    eng_c, toks_c = run(2)
    for a, b in zip(toks_u, toks_c):
        np.testing.assert_array_equal(a, b)
    st = eng_c.prefix_cache.stats()
    assert st["evictions_capacity"] > 0
    assert st["evictions"] == 0  # no pool pressure in this trace
    assert st["cached_blocks"] <= 2
    assert eng_u.prefix_cache.stats()["cached_blocks"] > 2  # uncapped kept all
    assert eng_u.prefix_cache.stats()["evictions_capacity"] == 0
    # the counter rides the scheduler aggregate
    agg = eng_c.scheduler.metrics()["aggregate"]
    assert agg["prefix_cache"]["evictions_capacity"] == \
        st["evictions_capacity"]
    eng_c.pool.check_invariants()


# ---------------------------------------------------------------------------
# Metrics, trace knobs, gating
# ---------------------------------------------------------------------------


def test_metrics_accounting(models):
    """Every admitted prompt position lands in exactly one bucket, and the
    hit rate is their ratio; the cache stats ride the aggregate."""
    arch, params = models["dense"]
    trace = synthetic_trace(arch.config, 6, seed=9, prompt_len=5,
                            max_new_low=2, max_new_high=4,
                            shared_prefix_tokens=16, n_prefix_groups=2)
    eng, _ = _run_trace(arch, params, NOQUANT, trace, prefix_cache=True)
    agg = eng.scheduler.metrics()["aggregate"]
    total = sum(r.prompt_tokens for r in eng.scheduler.done)
    assert agg["prefill_tokens_saved"] + agg["prefill_tokens_computed"] == total
    assert agg["prefix_hit_rate"] == pytest.approx(
        agg["prefill_tokens_saved"] / total)
    assert agg["prefix_cache"]["lookups"] == 6
    assert agg["prefix_cache"]["hits"] >= 4  # all but each group's first
    eng.scheduler.reset_metrics()
    agg2 = eng.scheduler.metrics()["aggregate"]
    assert agg2["prefill_tokens_saved"] == 0 and agg2["prefix_hit_rate"] is None


def test_trace_knobs_deterministic(models):
    """``shared_prefix_tokens``/``n_prefix_groups`` are seeded and
    deterministic: same knobs -> same prompts, round-robin group
    assignment, no wall-clock anywhere."""
    arch, _ = models["dense"]
    cfg = arch.config
    t1 = synthetic_trace(cfg, 6, seed=11, prompt_len=4,
                         shared_prefix_tokens=8, n_prefix_groups=2)
    t2 = synthetic_trace(cfg, 6, seed=11, prompt_len=4,
                         shared_prefix_tokens=8, n_prefix_groups=2)
    for a, b in zip(t1, t2):
        np.testing.assert_array_equal(a.prompt, b.prompt)
    for i in range(2, 6):  # request i shares its prefix with i - n_groups
        np.testing.assert_array_equal(t1[i].prompt[:8], t1[i - 2].prompt[:8])
    assert not np.array_equal(t1[0].prompt[:8], t1[1].prompt[:8])
    # knob off: draw order matches the pre-knob trace exactly
    base = synthetic_trace(cfg, 2, seed=11, prompt_len=4)
    again = synthetic_trace(cfg, 2, seed=11, prompt_len=4,
                            shared_prefix_tokens=0, n_prefix_groups=3)
    for a, b in zip(base, again):
        np.testing.assert_array_equal(a.prompt, b.prompt)


@pytest.mark.parametrize("name", ["xlstm-1.3b", "zamba2-1.2b"])
def test_recurrent_families_gated(name):
    """Per-slot-state families cannot share KV prefixes: the engine
    silently serves unshared (prefix_cache property is None) and still
    produces correct tokens."""
    arch = get_arch(name, reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    prompts = np.random.default_rng(0).integers(
        0, arch.config.vocab, size=(2, 8)).astype(np.int32)
    eng_on = ServeEngine(arch, params,
                         ServeConfig(max_seq=32, batch_slots=2, block_tokens=8,
                                     prefix_cache=True), dtype=jnp.float32)
    out_on = eng_on.generate(prompts, 4)
    assert eng_on.prefix_cache is None
    eng_off = ServeEngine(arch, params,
                          ServeConfig(max_seq=32, batch_slots=2,
                                      block_tokens=8), dtype=jnp.float32)
    np.testing.assert_array_equal(out_on["tokens"],
                                  eng_off.generate(prompts, 4)["tokens"])


def test_vlm_requests_skip_sharing(models):
    """A request with patch embeds bypasses lookup/insert (its prefix is
    not keyable by token ids) but shares the pool with token requests."""
    arch = get_arch("internvl2-2b", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    cfg = arch.config
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab, size=(8,)).astype(np.int32)
    pe = rng.normal(size=(cfg.n_patches, cfg.d_model)).astype(np.float32) * .02
    eng = ServeEngine(arch, params,
                      ServeConfig(max_seq=64, batch_slots=2, block_tokens=8,
                                  prefix_cache=True), dtype=jnp.float32)
    eng.submit(prompt, 2, patch_embeds=pe)
    eng.submit(prompt, 2)  # token-only: may insert/lookup freely
    eng.drain()
    assert eng.prefix_cache.stats()["lookups"] == 1  # vlm request skipped
    eng.pool.check_invariants()
