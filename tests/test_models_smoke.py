"""Per-architecture smoke tests on reduced configs (CPU).

For every assigned arch: instantiate the reduced config, run one forward
(shape + finite checks), one grad step (finite grads), and verify the
prefill+decode path agrees with the training forward (teacher forcing).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.common import QuantizeSpec
from repro.models.registry import ARCH_IDS, get_arch

B, S = 2, 16


def make_batch(cfg, key, s=S):
    ks = jax.random.split(key, 2)
    if cfg.modality == "audio":
        batch = {"tokens": jax.random.randint(ks[0], (B, s, cfg.n_codebooks), 0, cfg.vocab)}
    else:
        batch = {"tokens": jax.random.randint(ks[0], (B, s), 0, cfg.vocab)}
    if cfg.modality == "vlm":
        batch["patch_embeds"] = jax.random.normal(ks[1], (B, cfg.n_patches, cfg.d_model))
    return batch


@pytest.fixture(scope="module")
def arches():
    out = {}
    for name in ARCH_IDS:
        arch = get_arch(name, reduced=True)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        out[name] = (arch, params)
    return out


@pytest.mark.parametrize("name", ARCH_IDS)
def test_forward_shapes_finite(name, arches):
    arch, params = arches[name]
    cfg = arch.config
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits = arch.forward(params, batch)
    s_total = S + (cfg.n_patches if cfg.modality == "vlm" else 0)
    if cfg.modality == "audio":
        assert logits.shape == (B, S, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (B, s_total, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.slow  # value_and_grad recompiles every arch: the heaviest cells
@pytest.mark.parametrize("name", ARCH_IDS)
def test_train_grad_finite(name, arches):
    from repro.models.common import cross_entropy

    arch, params = arches[name]
    cfg = arch.config
    batch = make_batch(cfg, jax.random.PRNGKey(2))

    def loss_fn(p):
        logits = arch.forward(p, batch)
        toks = batch["tokens"]
        if cfg.modality == "vlm":
            logits = logits[:, cfg.n_patches :]
        if cfg.modality == "audio":
            return cross_entropy(logits[:, :-1], toks[:, 1:])
        return cross_entropy(logits[:, :-1], toks[:, 1:])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
    # something actually flows to the embedding and deepest weights
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.slow  # compiles prefill AND decode per arch on top of forward
@pytest.mark.parametrize("name", ARCH_IDS)
def test_prefill_decode_matches_forward(name, arches):
    """Teacher-forcing: decode(t|prefix) logits == forward logits at t."""
    arch, params = arches[name]
    cfg = arch.config
    batch = make_batch(cfg, jax.random.PRNGKey(3))
    full_logits = arch.forward(params, batch)  # (B, S_tot, V) or (B,S,K,V)

    s_pre = S - 2
    if cfg.modality == "audio":
        pre_batch = {"tokens": batch["tokens"][:, :s_pre]}
        next_tok = batch["tokens"][:, s_pre]  # (B, K)
    else:
        pre_batch = {k: (v[:, :s_pre] if k == "tokens" else v) for k, v in batch.items()}
        next_tok = batch["tokens"][:, s_pre]  # (B,)
    cache = arch.init_cache(B, S + 8, QuantizeSpec(), jnp.float32)
    logits_pre, cache = arch.prefill(params, pre_batch, cache, QuantizeSpec())
    # prefill returns last-position logits
    offset = cfg.n_patches if cfg.modality == "vlm" else 0
    want_last = full_logits[:, offset + s_pre - 1]
    got_last = np.asarray(logits_pre)[:, 0] if logits_pre.ndim > 2 else np.asarray(logits_pre)
    if cfg.modality == "audio":
        got_last = np.asarray(logits_pre)[:, 0]  # (B,K,V)
    np.testing.assert_allclose(
        np.asarray(got_last, np.float32).squeeze(),
        np.asarray(want_last, np.float32).squeeze(),
        rtol=2e-3, atol=2e-3,
    )
    # one decode step
    logits_dec, cache = arch.decode(params, next_tok, cache, QuantizeSpec())
    want_dec = full_logits[:, offset + s_pre]
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32).squeeze(),
        np.asarray(want_dec, np.float32).squeeze(),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("name", ["smollm-135m", "deepseek-moe-16b", "xlstm-1.3b", "zamba2-1.2b"])
def test_quantized_forward_runs(name, arches):
    """W-sim-free sanity: act-quant + online GSR R4 path produces finite logits."""
    arch, params = arches[name]
    cfg = arch.config
    spec = QuantizeSpec(act_bits=8, act_group=32, r4_kind="GSR", r4_group=32)
    batch = make_batch(cfg, jax.random.PRNGKey(4))
    logits = arch.forward(params, batch, spec)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_counts_full_configs():
    """Full (non-reduced) configs land near the published sizes."""
    from repro.models.registry import get_config

    expect = {
        "smollm-135m": (135e6, 0.25),
        "deepseek-7b": (7e9, 0.25),
        "llama2-7b": (6.7e9, 0.25),
        "deepseek-moe-16b": (16.4e9, 0.35),
        "qwen1.5-4b": (4e9, 0.35),
        "minicpm3-4b": (4e9, 0.45),
        "musicgen-medium": (1.5e9, 0.5),
        "xlstm-1.3b": (1.3e9, 0.5),
        "zamba2-1.2b": (1.2e9, 0.5),
        "llama4-maverick-400b-a17b": (400e9, 0.35),
    }
    for name, (target, tol) in expect.items():
        total, active = get_config(name).param_count()
        assert abs(total - target) / target < tol, (name, total, target)
        assert active <= total
