"""Observability subsystem tests: metrics registry, tracer, profiler.

The acceptance contract of ``repro.obs``:

  * the metrics registry is typed (counter/gauge/histogram), label-checked,
    and exports deterministically to Prometheus text and JSON;
  * the exported metric schema (names, kinds, label sets) is identical
    across every ServeConfig feature combination — prefix cache, spec
    decode, and in-graph windows add *values*, never new schema;
  * ``ObsConfig(enabled=False)`` (the default) is invisible: emitted
    tokens and the legacy ``scheduler.metrics()`` view are bit-identical
    to an unobserved engine, and ``obs.wrap`` is the identity;
  * with tracing on, a drained engine exports a valid Chrome trace — one
    complete ``request`` root per request lane with properly nested
    queue/prefill/decode children and monotonic token instants;
  * the drain watchdog (``ServeConfig(drain_timeout_s=...)``) raises with
    the stuck request ids and their last span instead of spinning;
  * the profiler counts jit compiles per site and hears autotune events.

Everything time-dependent runs against a fake injectable clock.
"""
import gc
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import api
from repro.models.registry import get_arch
from repro.obs import ObsConfig, Observability, validate_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import ENGINE_PID, REQUEST_PID, Tracer
from repro.quant.policy import QuantPolicy, RotationPlan, RotationSpec, SiteRule
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.scheduler import run_continuous_trace, synthetic_trace


class FakeClock:
    """Deterministic monotonic clock: advances ``dt`` per call."""

    def __init__(self, t0: float = 1000.0, dt: float = 0.125):
        self.t = t0
        self.dt = dt

    def __call__(self) -> float:
        self.t += self.dt
        return self.t


@pytest.fixture(scope="module")
def dense():
    """(arch, float params) for the dense reduced bench model."""
    arch = get_arch("smollm-135m", reduced=True)
    return arch, arch.init(jax.random.PRNGKey(0), jnp.float32)


@pytest.fixture(scope="module")
def quantized(dense):
    """W4 RTN GSR QuantizedModel — roomy enough for a spec-decode draft."""
    arch, params = dense
    policy = QuantPolicy(
        name="w4-rtn", rules=(SiteRule(pattern="*", bits=4, group=32,
                                       method="rtn"),),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=32)),
        act_bits=16, kv_bits=16)
    return api.quantize(arch, params, policy)


def _prompts(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, size=(b, s)).astype(np.int32)


# ---------------------------------------------------------------------------
# Metrics registry units
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_basics():
    reg = MetricsRegistry()
    c = reg.counter("c_total", "a counter", labels=("k",))
    c.inc(k="a")
    c.inc(2, k="a")
    c.inc(k="b")
    assert c.value(k="a") == 3 and c.value(k="b") == 1
    with pytest.raises(ValueError):
        c.inc(-1, k="a")
    with pytest.raises(ValueError):
        c.inc(wrong="a")  # label name mismatch
    g = reg.gauge("g")
    g.set(5)
    g.dec(2)
    assert g.value() == 3
    h = reg.histogram("h_seconds", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(99.0)
    assert h.count() == 3 and h.sum() == pytest.approx(99.55)


def test_registry_idempotent_and_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("x_total", "help", labels=("k",))
    assert reg.counter("x_total", "help", labels=("k",)) is a
    with pytest.raises(ValueError):
        reg.gauge("x_total", "now a gauge")
    with pytest.raises(ValueError):
        reg.counter("x_total", "same kind, new labels", labels=("other",))


def test_reset_keeps_schema():
    reg = MetricsRegistry()
    reg.counter("a_total", "a").inc()
    reg.histogram("b_seconds", "b").observe(0.5)
    before = reg.schema()
    reg.reset()
    assert reg.schema() == before
    assert reg.counter("a_total").value() == 0
    assert reg.get("b_seconds").count() == 0


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("req_total", "requests", labels=("outcome",)).inc(
        3, outcome="hit")
    reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0)).observe(0.5)
    text = reg.to_prometheus()
    assert "# HELP req_total requests" in text
    assert "# TYPE req_total counter" in text
    assert 'req_total{outcome="hit"} 3' in text
    # histogram: cumulative buckets with +Inf, then _sum/_count
    assert 'lat_seconds_bucket{le="0.1"} 0' in text
    assert 'lat_seconds_bucket{le="1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 1' in text
    assert "lat_seconds_sum 0.5" in text
    assert "lat_seconds_count 1" in text


def test_json_export_deterministic(tmp_path):
    reg = MetricsRegistry()
    reg.counter("b_total").inc()
    reg.counter("a_total", labels=("k",)).inc(k="z")
    reg.counter("a_total", labels=("k",)).inc(k="a")
    doc = reg.to_json()
    assert list(doc) == ["a_total", "b_total"]  # sorted names
    labels = [s["labels"]["k"] for s in doc["a_total"]["series"]]
    assert labels == ["a", "z"]  # sorted label tuples
    p = reg.export(str(tmp_path / "m.json"))
    assert json.load(open(p)) == doc
    prom = reg.export(str(tmp_path / "m.prom"))
    assert open(prom).read() == reg.to_prometheus()


# ---------------------------------------------------------------------------
# Tracer units + validator
# ---------------------------------------------------------------------------


def _request_tree(tr, rid, t0):
    """Record one well-formed request lifecycle starting at ``t0``."""
    tr.label(REQUEST_PID, rid, f"request {rid}")
    root = tr.begin("request", pid=REQUEST_PID, tid=rid, t=t0)
    q = tr.begin("queue", pid=REQUEST_PID, tid=rid, t=t0)
    tr.end(q, t=t0 + 1)
    p = tr.begin("prefill", pid=REQUEST_PID, tid=rid, t=t0 + 1)
    tr.end(p, t=t0 + 2)
    d = tr.begin("decode", pid=REQUEST_PID, tid=rid, t=t0 + 2)
    tr.event("token", pid=REQUEST_PID, tid=rid, t=t0 + 3, i=1)
    tr.end(d, t=t0 + 4)
    tr.end(root, t=t0 + 4)


def test_tracer_chrome_roundtrip():
    tr = Tracer(clock=FakeClock())
    with tr.span("decode_tick", pid=ENGINE_PID, tid=0, active=2):
        pass
    _request_tree(tr, 0, 100.0)
    _request_tree(tr, 1, 102.0)
    doc = tr.to_chrome()
    stats = validate_chrome_trace(doc)
    assert stats["requests"] == 2
    assert stats["spans"] == 9  # 1 engine + 2 x 4 request spans
    # timestamps are rebased to the earliest record, in microseconds
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    assert min(e["ts"] for e in xs) == 0.0


def test_tracer_ring_bounds():
    tr = Tracer(clock=FakeClock(), capacity=2)
    for i in range(3):
        tr.event(f"e{i}")
    assert len(tr) == 2 and tr.dropped == 1
    assert tr.to_chrome()["otherData"]["dropped_records"] == 1


def test_tracer_jsonl_export(tmp_path):
    tr = Tracer(clock=FakeClock())
    _request_tree(tr, 0, 10.0)
    p = tr.export(str(tmp_path / "t.jsonl"))
    lines = [json.loads(l) for l in open(p).read().splitlines()]
    assert len(lines) == len(tr.records())
    assert {l["ph"] for l in lines} <= {"X", "i"}


def test_validator_rejects_malformed():
    tr = Tracer(clock=FakeClock())
    # missing decode child
    tr.label(REQUEST_PID, 0, "request 0")
    root = tr.begin("request", pid=REQUEST_PID, tid=0, t=0.0)
    q = tr.begin("queue", pid=REQUEST_PID, tid=0, t=0.0)
    tr.end(q, t=1.0)
    p = tr.begin("prefill", pid=REQUEST_PID, tid=0, t=1.0)
    tr.end(p, t=2.0)
    tr.end(root, t=2.0)
    with pytest.raises(ValueError, match="missing 'decode'"):
        validate_chrome_trace(tr.to_chrome())
    # two request roots on one lane
    tr2 = Tracer(clock=FakeClock())
    _request_tree(tr2, 0, 0.0)
    extra = tr2.begin("request", pid=REQUEST_PID, tid=0, t=10.0)
    tr2.end(extra, t=11.0)
    with pytest.raises(ValueError, match="exactly one 'request'"):
        validate_chrome_trace(tr2.to_chrome())
    # engine spans only: no request lanes at all
    tr3 = Tracer(clock=FakeClock())
    s = tr3.begin("decode_tick")
    tr3.end(s)
    with pytest.raises(ValueError, match="no request spans"):
        validate_chrome_trace(tr3.to_chrome())
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({"nope": 1})


# ---------------------------------------------------------------------------
# End-to-end: traced engine produces a valid span tree + histograms
# ---------------------------------------------------------------------------


def test_traced_engine_valid_chrome_trace(dense):
    arch, params = dense
    clock = FakeClock()
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8,
        obs=ObsConfig(enabled=True, clock=clock)))
    n = 3
    for r in synthetic_trace(arch.config, n, seed=3, prompt_len=6,
                             max_new_low=2, max_new_high=4):
        eng.scheduler.submit(r)
    eng.drain()
    stats = validate_chrome_trace(eng.obs.tracer.to_chrome())
    assert stats["requests"] == n
    reg = eng.obs.registry
    assert reg.get("serve_ttft_seconds").count() == n
    assert reg.get("serve_queue_wait_seconds").count() == n
    assert reg.get("serve_request_latency_seconds").count() == n
    assert reg.get("serve_decode_utilisation").count() > 0
    text = reg.to_prometheus()
    assert f"serve_ttft_seconds_count {n}" in text
    assert "serve_decode_utilisation_bucket" in text
    # every TTFT came off the fake clock: positive, multiple of dt
    for r in eng.scheduler.done:
        assert r.ttft_s > 0
        assert (r.ttft_s / clock.dt) == pytest.approx(
            round(r.ttft_s / clock.dt))


def test_trace_export_and_cli(dense, tmp_path, capsys):
    from repro.obs.trace import _main

    arch, params = dense
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8,
        obs=ObsConfig(enabled=True, clock=FakeClock())))
    eng.generate(_prompts(arch.config, 2, 6), 3)
    path = eng.obs.export_trace(str(tmp_path / "trace.json"))
    assert _main([path]) == 0
    assert "[trace] ok:" in capsys.readouterr().out
    # corrupting the trace flips the CLI to failure
    doc = json.load(open(path))
    doc["traceEvents"] = [e for e in doc["traceEvents"]
                          if e.get("name") != "decode"]
    bad = tmp_path / "bad.json"
    json.dump(doc, open(bad, "w"))
    assert _main([str(bad)]) == 1
    assert "INVALID" in capsys.readouterr().out


def test_export_trace_requires_enabled(dense):
    arch, params = dense
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                block_tokens=8))
    with pytest.raises(RuntimeError, match="tracing is disabled"):
        eng.obs.export_trace("/tmp/never-written.json")


# ---------------------------------------------------------------------------
# Schema stability across feature combos
# ---------------------------------------------------------------------------

COMBOS = {
    "baseline": {},
    "prefix_cache": {"prefix_cache": True},
    "spec_decode": {"spec_decode": True, "draft_k": 2},
    "window": {"steps_per_sync": 4},
}


@pytest.mark.parametrize("combo", sorted(COMBOS))
def test_metrics_schema_stable_across_combos(quantized, combo):
    """Feature flags change metric *values*, never the exported schema:
    names, kinds, and label sets are declared up front and identical
    across every ServeConfig combination."""
    qm = quantized
    kw = COMBOS[combo]
    draft = api.derive_draft(qm, "draft-w3-rtn") if kw.get("spec_decode") \
        else None
    eng = qm.serve(ServeConfig(max_seq=48, batch_slots=2, block_tokens=8,
                               obs=ObsConfig(enabled=True), **kw),
                   draft=draft)
    eng.generate(_prompts(qm.config, 3, 8), 4)
    base = qm.serve(ServeConfig(max_seq=48, batch_slots=2, block_tokens=8))
    base.scheduler  # the scheduler declares the serving schema on build
    schema = eng.obs.registry.schema()
    assert schema == base.obs.registry.schema()
    # the serving metric families are all present, populated or not
    for name in ("serve_ttft_seconds", "prefix_cache_lookups_total",
                 "serve_spec_windows_total", "serve_host_syncs_total",
                 "jit_compiles_total"):
        assert name in schema, name
    # exporters enumerate the same registered names in both engines
    assert eng.obs.registry.names() == base.obs.registry.names()


# ---------------------------------------------------------------------------
# enabled=False is invisible
# ---------------------------------------------------------------------------


def test_disabled_obs_bit_identical(dense):
    arch, params = dense
    prompts = _prompts(arch.config, 3, 8)

    def run(obs_cfg):
        eng = ServeEngine(arch, params, ServeConfig(
            max_seq=32, batch_slots=2, block_tokens=8, obs=obs_cfg))
        out = eng.generate(prompts, 5)
        return out, eng

    out_off, eng_off = run(ObsConfig())  # the default: disabled
    out_on, eng_on = run(ObsConfig(enabled=True))
    np.testing.assert_array_equal(out_off["tokens"], out_on["tokens"])
    m_off, m_on = eng_off.scheduler.metrics(), eng_on.scheduler.metrics()
    assert set(m_off) == set(m_on)
    assert set(m_off["aggregate"]) == set(m_on["aggregate"])
    for key in ("n_requests", "decode_steps", "busy_slot_steps",
                "tokens_generated", "host_syncs", "prefill_tokens_computed",
                "spec_windows", "blocks_shared"):
        assert m_off["aggregate"][key] == m_on["aggregate"][key], key
    # disabled: no tracer, no profiler, wrap is the identity
    assert eng_off.obs.tracer is None and eng_off.obs.profiler is None
    fn = lambda x: x
    assert eng_off.obs.wrap("anything", fn) is fn


def test_legacy_counter_attributes_registry_backed(dense):
    arch, params = dense
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                block_tokens=8))
    eng.generate(_prompts(arch.config, 2, 6), 3)
    sched = eng.scheduler
    reg = eng.obs.registry
    assert sched.decode_steps > 0
    assert sched.decode_steps == int(
        reg.counter("serve_decode_steps_total").value())
    sched.decode_steps = 0  # the bench warm-up reset idiom
    assert reg.counter("serve_decode_steps_total").value() == 0
    assert sched.metrics()["aggregate"]["decode_steps"] == 0


# ---------------------------------------------------------------------------
# Drain watchdog
# ---------------------------------------------------------------------------


def test_drain_watchdog_names_stuck_requests(dense):
    arch, params = dense
    clock = FakeClock(dt=1.0)
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8, drain_timeout_s=5.0,
        obs=ObsConfig(enabled=True, clock=clock)))
    eng.submit(_prompts(arch.config, 1, 6)[0], 4)
    # wedge the scheduler: steps report progress but move nothing
    eng.scheduler.step = lambda: True
    with pytest.raises(RuntimeError) as e:
        eng.drain()
    msg = str(e.value)
    assert "drain_timeout_s=5.0" in msg
    assert "r0: queued" in msg
    assert "0/4 tokens" in msg
    assert "last span" in msg  # the enqueue record from the tracer


def test_drain_no_progress_raises_immediately(dense):
    arch, params = dense
    eng = ServeEngine(arch, params, ServeConfig(max_seq=32, batch_slots=2,
                                                block_tokens=8))
    eng.submit(_prompts(arch.config, 1, 6)[0], 4)
    eng.scheduler.step = lambda: False
    with pytest.raises(RuntimeError, match="stalled with pending work"):
        eng.drain()


# ---------------------------------------------------------------------------
# Profiler: compile counting + autotune events
# ---------------------------------------------------------------------------


def test_profiler_counts_compiles_and_dispatches():
    obs = Observability(ObsConfig(enabled=True))
    f = obs.wrap("unit_site", jax.jit(lambda x: x + 1))
    f(jnp.zeros((2,), jnp.float32))
    f(jnp.zeros((2,), jnp.float32))  # cache hit: dispatch, no compile
    f(jnp.zeros((3,), jnp.float32))  # new shape: recompile
    reg = obs.registry
    assert reg.get("jit_compiles_total").value(site="unit_site") == 2
    assert reg.get("profile_dispatch_seconds").count(site="unit_site") == 3
    names = [r["name"] for r in obs.tracer.records()]
    assert names.count("jit_compile") == 2


def test_autotune_notifies_subscribed_profiler(tmp_path, monkeypatch):
    from repro.kernels import autotune

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    autotune.reset_cache()
    obs = Observability(ObsConfig(enabled=True))
    # CPU backend, no table entry -> the defaults path fires "default"
    choice = autotune.best("obs_test_op", (4, 4), jnp.float32, {"block": 4})
    assert choice == {"block": 4}
    assert obs.registry.get("autotune_lookups_total").value(
        op="obs_test_op", source="default") == 1
    # a cached entry resolves as a "table" hit with its measured us
    autotune.record("obs_test_op", autotune.key_for((4, 4), jnp.float32),
                    {"block": 8, "us": 12.5})
    autotune.best("obs_test_op", (4, 4), jnp.float32, {"block": 4})
    assert obs.registry.get("autotune_lookups_total").value(
        op="obs_test_op", source="table") == 1
    assert obs.registry.get("autotune_measure_seconds").count(
        op="obs_test_op") == 1  # only the table hit carried a timing
    # dead subscribers are pruned, not called
    del obs
    gc.collect()
    autotune.best("obs_test_op", (4, 4), jnp.float32, {"block": 4})
    autotune.reset_cache()


# ---------------------------------------------------------------------------
# Clock routing: run_continuous_trace wall time is injectable
# ---------------------------------------------------------------------------


def test_run_continuous_trace_uses_injected_clock(dense, capsys):
    arch, params = dense
    clock = FakeClock(t0=5000.0, dt=0.25)
    eng = ServeEngine(arch, params, ServeConfig(
        max_seq=32, batch_slots=2, block_tokens=8,
        obs=ObsConfig(enabled=True, clock=clock)))
    m = run_continuous_trace(eng, n_requests=2, prompt_len=6, max_new=3,
                             quiet=True)
    wall = m["aggregate"]["wall_s"]
    assert wall > 0
    # every sample came from the fake clock: an exact multiple of dt
    assert (wall / clock.dt) == pytest.approx(round(wall / clock.dt))
    for r in eng.scheduler.done:
        assert r.submit_t > 5000.0
