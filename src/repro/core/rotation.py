"""Rotation construction + application for rotation-based PTQ.

The four rotation kinds benchmarked in the paper (Table 1), all orthogonal:

======  ==============================================================
kind    construction
======  ==============================================================
GH      global randomized Hadamard (QuaRot / SpinQuant default)
GW      global Walsh (sequency-ordered Hadamard, deterministic)
LH      local (block-diagonal, per-group) randomized Hadamard
GSR     local (block-diagonal, per-group) Walsh  == the paper's method
I       identity (no rotation; ablation / unquantized reference)
==    ================================================================

A :class:`Rotation` is a *factored* representation: global rotations keep a
single ``(dim, dim)`` matrix (or are applied via the FWHT fast path), local
rotations keep only the ``(group, group)`` block and are applied as a
reshape + small matmul - which is exactly an MXU-shaped ``(…, G) @ (G, G)``
contraction on TPU when ``G == 128``.  This is the TPU-native adaptation of
the paper: on GPUs local online rotation "disables the fast-hadamard-
transform" (paper A.2), but on a TPU a 128x128 block-diagonal rotation maps
*perfectly* onto the 128x128 systolic MXU tile, so GSR's local rotation is
the fast path here rather than a liability.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hadamard as hd

__all__ = ["RotationKind", "Rotation", "make_rotation", "apply_rotation", "fwht"]


class RotationKind(str, enum.Enum):
    IDENTITY = "I"
    GLOBAL_HADAMARD = "GH"
    GLOBAL_WALSH = "GW"
    LOCAL_HADAMARD = "LH"
    GSR = "GSR"

    @property
    def is_local(self) -> bool:
        return self in (RotationKind.LOCAL_HADAMARD, RotationKind.GSR)

    @property
    def is_walsh(self) -> bool:
        return self in (RotationKind.GLOBAL_WALSH, RotationKind.GSR)


@dataclasses.dataclass(frozen=True)
class Rotation:
    """Factored orthogonal rotation of a ``dim``-sized channel axis.

    Attributes:
      kind: one of RotationKind.
      dim: the rotated channel dimension.
      group: block size for local kinds (== quantization group size G).
      matrix: ``(dim, dim)`` for global kinds, ``(group, group)`` single
        shared block for GSR, ``(num_blocks, group, group)`` for LH (each
        block independently randomized), ``None`` for identity.
    """

    kind: RotationKind
    dim: int
    group: Optional[int] = None
    matrix: Optional[np.ndarray] = None

    @property
    def num_blocks(self) -> int:
        if not self.kind.is_local:
            return 1
        return self.dim // self.group

    def dense(self) -> np.ndarray:
        """Materialise the full (dim, dim) orthogonal matrix."""
        if self.kind == RotationKind.IDENTITY:
            return np.eye(self.dim)
        if not self.kind.is_local:
            return np.asarray(self.matrix)
        if self.kind == RotationKind.GSR:
            return hd.block_diag_rotation(np.asarray(self.matrix), self.num_blocks)
        # LH: stacked independent blocks.
        out = np.zeros((self.dim, self.dim), dtype=np.asarray(self.matrix).dtype)
        g = self.group
        for b in range(self.num_blocks):
            out[b * g : (b + 1) * g, b * g : (b + 1) * g] = self.matrix[b]
        return out

    def inverse_dense(self) -> np.ndarray:
        return self.dense().T  # orthogonal


def make_rotation(
    kind: RotationKind | str,
    dim: int,
    *,
    group: Optional[int] = None,
    seed: int = 0,
    dtype=np.float64,
) -> Rotation:
    """Build a rotation per the paper's recipes.

    GH / LH are randomized (RHT) "following common practice in previous
    rotation-based algorithms"; GW / GSR use the deterministic Walsh matrix
    ("when constructing Walsh matrices, the original Hadamard matrix is
    used") - randomizing would scramble the sequency arrangement that the
    method exists to exploit.
    """
    kind = RotationKind(kind)
    if kind == RotationKind.IDENTITY:
        return Rotation(kind=kind, dim=dim)
    if kind == RotationKind.GLOBAL_HADAMARD:
        return Rotation(
            kind=kind, dim=dim, matrix=hd.randomized_hadamard_auto(dim, seed, dtype=dtype)
        )
    if kind == RotationKind.GLOBAL_WALSH:
        return Rotation(kind=kind, dim=dim, matrix=hd.walsh_auto(dim, dtype=dtype))
    if group is None:
        raise ValueError(f"{kind} requires a group size")
    if dim % group != 0:
        raise ValueError(f"dim {dim} not divisible by group {group}")
    if kind == RotationKind.GSR:
        return Rotation(kind=kind, dim=dim, group=group, matrix=hd.walsh(group, dtype=dtype))
    # LH: independent randomized Hadamard per block.
    blocks = np.stack(
        [hd.randomized_hadamard(group, seed + b, dtype=dtype) for b in range(dim // group)]
    )
    return Rotation(kind=kind, dim=dim, group=group, matrix=blocks)


# ---------------------------------------------------------------------------
# Application (jax; differentiable; used online for R4-style rotations and
# offline when fusing into weights).
# ---------------------------------------------------------------------------


def fwht(x: jax.Array, *, normalize: bool = True) -> jax.Array:
    """Fast Walsh-Hadamard transform over the last axis (natural order).

    O(d log d) butterfly; the pure-jnp reference for the Pallas kernel in
    :mod:`repro.kernels.fwht`.  Equivalent to ``x @ hadamard(d)``.
    """
    d = x.shape[-1]
    if not hd.is_pow2(d):
        raise ValueError(f"fwht dim must be power of two, got {d}")
    orig_shape = x.shape
    x = x.reshape(-1, d)
    h = 1
    while h < d:
        x = x.reshape(-1, d // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.stack([a + b, a - b], axis=2)
        h *= 2
    x = x.reshape(orig_shape)
    if normalize:
        x = x * (1.0 / np.sqrt(d)).astype(x.dtype)
    return x


def apply_rotation(x: jax.Array, rot: Rotation, *, inverse: bool = False) -> jax.Array:
    """Apply ``x @ R`` (or ``x @ R^T``) along the last axis.

    Local kinds use the factored form: reshape to (..., N, G) and contract
    the G axis with the (G, G) block - a batched MXU-aligned matmul.
    """
    if rot.kind == RotationKind.IDENTITY:
        return x
    if x.shape[-1] != rot.dim:
        raise ValueError(f"last dim {x.shape[-1]} != rotation dim {rot.dim}")
    dtype = x.dtype
    if not rot.kind.is_local:
        m = jnp.asarray(rot.matrix, dtype=jnp.float32)
        if inverse:
            m = m.T
        return (x.astype(jnp.float32) @ m).astype(dtype)
    g, n = rot.group, rot.num_blocks
    xs = x.astype(jnp.float32).reshape(*x.shape[:-1], n, g)
    if rot.kind == RotationKind.GSR:
        m = jnp.asarray(rot.matrix, dtype=jnp.float32)
        if inverse:
            m = m.T
        out = jnp.einsum("...ng,gh->...nh", xs, m)
    else:  # LH - a different block per group
        m = jnp.asarray(rot.matrix, dtype=jnp.float32)
        if inverse:
            m = jnp.swapaxes(m, -1, -2)
        out = jnp.einsum("...ng,ngh->...nh", xs, m)
    return out.reshape(x.shape).astype(dtype)
