"""Hadamard / Walsh matrix construction and sequency machinery.

This module is the numerical heart of the paper:

  *Grouped Sequency-arranged Rotation: Optimizing Rotation Transformation
  for Quantization for Free* (ACL 2025 SRW).

Everything here is **host-side, training-free construction**: matrices are
built once in numpy (they are static w.r.t. the computation graph) and then
consumed by JAX transforms / Pallas kernels.  The only runtime cost of the
paper's method is a permutation + (optional) block-diagonal structure on top
of a Sylvester Hadamard matrix - i.e. "for free".

Definitions
-----------
Sylvester Hadamard
    H_2 = [[1, 1], [1, -1]] / sqrt(2),  H_{2^n} = H_2 (x) H_{2^{n-1}}.
    Entry closed form (unnormalised):  H[i, j] = (-1)^{popcount(i & j)}.

Sequency
    The number of sign changes along a row.  The natural (Sylvester)
    ordering has scrambled sequencies; e.g. for n=8 the row sequencies are
    [0, 7, 3, 4, 1, 6, 2, 5].

Walsh matrix
    The Hadamard matrix with rows permuted into *ascending sequency*
    ("sequency ordering").  Closed form of the permutation: row ``i`` of the
    Walsh matrix is row ``bit_reverse(gray(i))`` of the Sylvester matrix.

Randomized Hadamard Transform (RHT)
    H @ diag(s), s in {-1, +1}^n, per QuIP# / QuaRot.  Used for the GH / LH
    baselines; the Walsh variants intentionally do *not* randomise (the
    paper uses the deterministic Walsh matrix so the sequency arrangement
    is preserved).
"""
from __future__ import annotations

import functools

import numpy as np

__all__ = [
    "is_pow2",
    "hadamard",
    "hadamard_auto",
    "paley_hadamard",
    "sequency_of_rows",
    "natural_sequency",
    "walsh_permutation",
    "walsh",
    "walsh_auto",
    "random_signs",
    "randomized_hadamard",
    "randomized_hadamard_auto",
    "block_diag_rotation",
    "gsr_matrix",
    "local_hadamard_matrix",
]


def is_pow2(n: int) -> bool:
    return n >= 1 and (n & (n - 1)) == 0


def _check_pow2(n: int) -> None:
    if not is_pow2(n):
        raise ValueError(f"Hadamard/Walsh size must be a power of two, got {n}")


@functools.lru_cache(maxsize=64)
def _hadamard_unnormalized(n: int) -> np.ndarray:
    """Sylvester Hadamard matrix with +-1 entries (un-normalised), cached."""
    _check_pow2(n)
    # Closed form H[i, j] = (-1)^{popcount(i & j)}; vectorised via bit tricks.
    i = np.arange(n, dtype=np.uint64)
    # popcount(i & j) parity table computed by XOR-folding.
    a = i[:, None] & i[None, :]
    parity = np.zeros_like(a)
    while a.any():
        parity ^= a & 1
        a >>= 1
    return np.where(parity.astype(bool), -1.0, 1.0)


def hadamard(n: int, *, normalize: bool = True, dtype=np.float64) -> np.ndarray:
    """Sylvester ("natural order") Hadamard matrix of size n (power of two)."""
    h = _hadamard_unnormalized(n).astype(dtype)
    if normalize:
        h = h / np.sqrt(n).astype(dtype)
    return h


def sequency_of_rows(m: np.ndarray) -> np.ndarray:
    """Number of sign changes along each row of a +-1 (or scaled) matrix."""
    signs = np.sign(m)
    return (signs[:, 1:] != signs[:, :-1]).sum(axis=1)


def _gray(i: np.ndarray) -> np.ndarray:
    return i ^ (i >> 1)


def _bit_reverse(i: np.ndarray, bits: int) -> np.ndarray:
    out = np.zeros_like(i)
    for b in range(bits):
        out = (out << 1) | ((i >> b) & 1)
    return out


def natural_sequency(n: int) -> np.ndarray:
    """Sequency value of the i-th row of the *natural* (Sylvester) matrix.

    Computed analytically; equals ``sequency_of_rows(hadamard(n))``.
    For n=8 this is [0, 7, 3, 4, 1, 6, 2, 5] (paper, Sec. 2.1).
    """
    _check_pow2(n)
    bits = int(np.log2(n))
    i = np.arange(n, dtype=np.uint64)
    # Row i of the Sylvester matrix equals Walsh row s where
    # i = bit_reverse(gray(s)); invert: s = gray_inverse(bit_reverse(i)).
    rev = _bit_reverse(i, bits)
    # Gray-code inverse (binary-to-gray inverse): s = rev ^ (rev>>1) ^ ...
    s = rev.copy()
    shift = 1
    while shift < bits:
        s ^= s >> shift
        shift <<= 1
    return s.astype(np.int64)


def walsh_permutation(n: int) -> np.ndarray:
    """Permutation p with Walsh[i] = Hadamard[p[i]]: p(i) = bitrev(gray(i)).

    Row i of the Walsh (sequency-ordered) matrix has sequency exactly i.
    """
    _check_pow2(n)
    bits = int(np.log2(n))
    i = np.arange(n, dtype=np.uint64)
    return _bit_reverse(_gray(i), bits).astype(np.int64)


def walsh(n: int, *, normalize: bool = True, dtype=np.float64) -> np.ndarray:
    """Walsh (sequency-ordered Hadamard) matrix of size n."""
    h = hadamard(n, normalize=normalize, dtype=dtype)
    return h[walsh_permutation(n)]


# ---------------------------------------------------------------------------
# Non-power-of-two sizes (QuaRot-style mixed Kronecker constructions).
#
# Several assigned archs have d_model = 2^k * m with m in {3, 5, 9}; a global
# Hadamard then needs a base Hadamard matrix of order 12/20/36, built here
# with the Paley constructions (instead of QuaRot's shipped tables).  GSR
# never needs this - its 128-sized Walsh blocks are always Sylvester - which
# is itself a deployment advantage of the paper's method.
# ---------------------------------------------------------------------------


def _legendre(a: int, p: int) -> int:
    a %= p
    if a == 0:
        return 0
    return 1 if pow(a, (p - 1) // 2, p) == 1 else -1


def _jacobsthal(q: int) -> np.ndarray:
    return np.array([[_legendre(i - j, q) for j in range(q)] for i in range(q)], dtype=np.float64)


@functools.lru_cache(maxsize=16)
def paley_hadamard(n: int) -> np.ndarray:
    """Unnormalised Hadamard matrix of order n via Paley I/II."""
    q = n - 1
    if q % 4 == 3 and _is_prime(q):  # Paley I
        jac = _jacobsthal(q)
        # H = I + S, S = [[0, 1^T], [-1, Q]] skew (Q skew for q=3 mod 4)
        h = np.ones((n, n))
        h[1:, 1:] = jac + np.eye(q)
        h[1:, 0] = -1.0
        assert np.allclose(h @ h.T, n * np.eye(n)), f"Paley I failed for {n}"
        return h
    q = n // 2 - 1
    if n % 2 == 0 and q % 4 == 1 and _is_prime(q):  # Paley II
        jac = _jacobsthal(q)
        s = np.zeros((q + 1, q + 1))
        s[0, 1:] = 1.0
        s[1:, 0] = 1.0
        s[1:, 1:] = jac
        a = np.array([[1.0, 1.0], [1.0, -1.0]])
        b = np.array([[1.0, -1.0], [-1.0, -1.0]])
        h = np.kron(s, a) + np.kron(np.eye(q + 1), b)
        assert np.allclose(h @ h.T, n * np.eye(n)), f"Paley II failed for {n}"
        return h
    raise ValueError(f"no Paley construction for order {n}")


def _is_prime(p: int) -> bool:
    if p < 2:
        return False
    return all(p % d for d in range(2, int(p**0.5) + 1))


_BASE_ORDERS = (12, 20, 28, 36, 44)  # Paley-constructible small orders


@functools.lru_cache(maxsize=64)
def _hadamard_auto_unnormalized(n: int) -> np.ndarray:
    if is_pow2(n):
        return _hadamard_unnormalized(n)
    for base in _BASE_ORDERS:
        if n % base == 0 and is_pow2(n // base):
            return np.kron(paley_hadamard(base), _hadamard_unnormalized(n // base))
    raise ValueError(
        f"no Hadamard construction for size {n} (needs 2^k or base*2^k, "
        f"base in {_BASE_ORDERS})"
    )


def hadamard_auto(n: int, *, normalize: bool = True, dtype=np.float64) -> np.ndarray:
    """Hadamard matrix for pow2 or base*2^k sizes (QuaRot-style)."""
    h = _hadamard_auto_unnormalized(n).astype(dtype)
    return h / np.sqrt(n).astype(dtype) if normalize else h


def walsh_auto(n: int, *, normalize: bool = True, dtype=np.float64) -> np.ndarray:
    """Sequency-ordered (ascending sign-change count) Hadamard, any
    constructible size.  For pow2 sizes equals :func:`walsh` exactly."""
    h = hadamard_auto(n, normalize=normalize, dtype=dtype)
    order = np.argsort(sequency_of_rows(h), kind="stable")
    return h[order]


def randomized_hadamard_auto(n: int, seed: int, *, dtype=np.float64) -> np.ndarray:
    return hadamard_auto(n, dtype=dtype) * random_signs(n, seed)[None, :].astype(dtype)


def random_signs(n: int, seed: int) -> np.ndarray:
    """Deterministic +-1 diagonal for the RHT (QuIP#-style randomisation)."""
    rng = np.random.default_rng(seed)
    return rng.choice(np.array([-1.0, 1.0]), size=n)


def randomized_hadamard(n: int, seed: int, *, dtype=np.float64) -> np.ndarray:
    """RHT matrix H @ diag(s): still orthogonal; suppresses incoherence.

    Note (paper Sec. 3.2, "Comparing RHT and Walsh"): the sign flips act on
    *columns* and therefore keep each row's sequency unchanged - the RHT has
    the same (scrambled) sequency arrangement as the plain Hadamard.
    """
    return hadamard(n, dtype=dtype) * random_signs(n, seed)[None, :].astype(dtype)


def block_diag_rotation(block: np.ndarray, num_blocks: int) -> np.ndarray:
    """Materialise blockdiag(block, ..., block) = I_N (x) block.

    Only used for testing / fusion bookkeeping; runtime application uses the
    factored (reshape + small matmul) form, never this dense matrix.
    """
    g = block.shape[0]
    out = np.zeros((num_blocks * g, num_blocks * g), dtype=block.dtype)
    for b in range(num_blocks):
        out[b * g : (b + 1) * g, b * g : (b + 1) * g] = block
    return out


def gsr_matrix(dim: int, group: int, *, dtype=np.float64) -> np.ndarray:
    """The paper's R_GSR = I_{dim/group} (x) Walsh(group)   (Eqn. 3).

    Training-free: a Walsh block per quantization group. Dense materialised
    form - see :mod:`repro.core.rotation` for the factored application.
    """
    if dim % group != 0:
        raise ValueError(f"dim {dim} not divisible by group {group}")
    return block_diag_rotation(walsh(group, dtype=dtype), dim // group)


def local_hadamard_matrix(dim: int, group: int, seed: int, *, dtype=np.float64) -> np.ndarray:
    """LH baseline: block-diagonal randomized Hadamard (per-block RHT)."""
    if dim % group != 0:
        raise ValueError(f"dim {dim} not divisible by group {group}")
    n = dim // group
    out = np.zeros((dim, dim), dtype=dtype)
    for b in range(n):
        out[b * group : (b + 1) * group, b * group : (b + 1) * group] = randomized_hadamard(
            group, seed + b, dtype=dtype
        )
    return out
