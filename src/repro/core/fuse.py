"""Offline rotation fusion: fold R1/R2 (and the R4 pre-rotation) into model
weights, per family.

This is the transferable infrastructure around the paper's contribution:
GSR (or GH/GW/LH) is constructed in :mod:`repro.core.rotation` and *fused*
here, so inference runs on rotated weights at zero runtime cost (the only
online ops are R4/R3, handled by ``QuantizeSpec``).

Invariance contract (tested in ``tests/test_fuse.py``): for any orthogonal
R1 (and R2), ``forward(fuse(params)) == forward(params)`` in fp32, because
every residual-stream producer is post-multiplied by R1 and every consumer
pre-multiplied by R1^T, with RMSNorm scales folded into consumers first
(rms_normalize is rotation-equivariant only without the per-channel scale).

Sides (paper Eqn. 4, W' = R_f^{-1} W R_r):
  front (R_f = R1): wq wk wv w_gate w_up router wq_a wkv_a in_proj wx lm_head
  rear  (R_r = R1): embed patch_proj wo w_down out_proj
  R2 (per-head, standard attention only): wv rear / wo front, per head.
  R4 (online): w_down additionally front-rotated by R4 so the online
  ``apply_r4`` on activations cancels exactly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.rotation import Rotation, RotationKind, make_rotation
from repro.models.common import QuantizeSpec, _r4_blocks


def _rot_in(w: jax.Array, r: np.ndarray) -> jax.Array:
    """W' = R^T W over the second-to-last axis (input/front side)."""
    rm = jnp.asarray(r, jnp.float32)
    return jnp.einsum("ji,...jx->...ix", rm, w.astype(jnp.float32)).astype(w.dtype)


def _rot_out(w: jax.Array, r: np.ndarray) -> jax.Array:
    """W' = W R over the last axis (output/rear side)."""
    rm = jnp.asarray(r, jnp.float32)
    return jnp.einsum("...xj,ji->...xi", w.astype(jnp.float32), rm).astype(w.dtype)


def _fold_norm_into(w: jax.Array, gamma: jax.Array) -> jax.Array:
    """W' = diag(gamma) W over the second-to-last axis; handles stacked
    leading dims (gamma (..., D), w (..., D, X))."""
    return (w.astype(jnp.float32) * gamma.astype(jnp.float32)[..., :, None]).astype(w.dtype)


def _ones_like(x):
    return jnp.ones_like(x)


# ---------------------------------------------------------------------------
# Family-specific fusion
# ---------------------------------------------------------------------------


def _fuse_attn_std(cfg: ModelConfig, lp: Dict, r1: np.ndarray,
                   r2: Optional[np.ndarray]) -> Dict:
    lp = dict(lp)
    # fold attn_norm gamma into q/k/v producers
    for k in ("wq", "wk", "wv"):
        lp[k] = _fold_norm_into(lp[k], lp["attn_norm"])
    lp["attn_norm"] = _ones_like(lp["attn_norm"])
    for k in ("wq", "wk", "wv"):
        lp[k] = _rot_in(lp[k], r1)
    lp["wo"] = _rot_out(lp["wo"], r1)
    if r2 is not None:
        hd = cfg.hd
        wv = lp["wv"]
        shp = wv.shape
        wv = wv.reshape(*shp[:-1], cfg.n_kv_heads, hd)
        lp["wv"] = _rot_out(wv, r2).reshape(shp)
        wo = lp["wo"]
        shpo = wo.shape
        wo = wo.reshape(*shpo[:-2], cfg.n_heads, hd, shpo[-1])
        lp["wo"] = _rot_in(wo, r2).reshape(shpo)
    return lp


def _fuse_mlp_dense(lp: Dict, r1: np.ndarray, r4: Optional[np.ndarray],
                    keys=("w_gate", "w_up", "w_down")) -> Dict:
    """r4 must be the rotation ``apply_r4`` uses at the matching site."""
    lp = dict(lp)
    g, u, dn = keys
    for k in (g, u):
        lp[k] = _rot_in(_fold_norm_into(lp[k], lp["mlp_norm"]), r1)
    lp["mlp_norm"] = _ones_like(lp["mlp_norm"])
    w_down = lp[dn]
    if r4 is not None:
        w_down = _rot_in(w_down, r4)
    lp[dn] = _rot_out(w_down, r1)
    return lp


def _fuse_moe(cfg: ModelConfig, lp: Dict, r1: np.ndarray, r4e: Optional[np.ndarray],
              r4s: Optional[np.ndarray]) -> Dict:
    lp = dict(lp)
    gamma = lp["mlp_norm"] if "mlp_norm" in lp else None
    for k in ("router", "w_gate", "w_up", "shared_gate", "shared_up"):
        if k in lp:
            w = lp[k]
            if gamma is not None:
                gam = gamma
                # experts have an extra E axis between L and D: broadcast
                while gam.ndim < w.ndim - 1:
                    gam = gam[..., None, :]
                w = (w.astype(jnp.float32) * gam.astype(jnp.float32)[..., :, None]).astype(w.dtype)
            lp[k] = _rot_in(w, r1)
    if gamma is not None:
        lp["mlp_norm"] = _ones_like(gamma)
    for k, r4 in (("w_down", r4e), ("shared_down", r4s)):
        if k in lp:
            w = lp[k]
            if r4 is not None:
                w = _rot_in(w, r4)
            lp[k] = _rot_out(w, r1)
    return lp


def _fuse_mla(cfg: ModelConfig, lp: Dict, r1: np.ndarray) -> Dict:
    lp = dict(lp)
    for k in ("wq_a", "wkv_a"):
        lp[k] = _rot_in(_fold_norm_into(lp[k], lp["attn_norm"]), r1)
    lp["attn_norm"] = _ones_like(lp["attn_norm"])
    lp["wo"] = _rot_out(lp["wo"], r1)
    return lp


def _r4_for(spec: QuantizeSpec, dim: int, site: str = "w_down"
            ) -> Optional[np.ndarray]:
    """Dense R4 pre-rotation matrix for ``site`` — the same per-site
    lookup ``apply_r4`` does online, so fusion and inference cancel
    exactly even when a policy assigns different rotations per site."""
    kind, group, seed = spec.r4_for(site)
    if kind == "I":
        return None
    rot = _r4_blocks(kind, dim, group, seed)
    return rot.dense()


# ---------------------------------------------------------------------------
# Public entry
# ---------------------------------------------------------------------------


def fuse_rotations(
    cfg: ModelConfig,
    params: Dict,
    r1: Rotation,
    *,
    r2: Optional[Rotation] = None,
    spec: QuantizeSpec = QuantizeSpec(),
) -> Dict:
    """Return new params with R1/R2 fused (and R4 pre-rotation on w_down).

    ``spec.r4_kind`` must match the spec used at inference so the online
    activation rotation cancels the weight pre-rotation exactly.
    """
    r1m = r1.dense().astype(np.float64)
    r2m = r2.dense().astype(np.float64) if r2 is not None else None
    p = jax.tree.map(lambda x: x, params)  # shallow-ish copy

    if cfg.family in ("dense", "moe", "mla"):
        return _fuse_transformer(cfg, p, r1m, r2m, spec)
    if cfg.family == "ssm":
        return _fuse_xlstm(cfg, p, r1m, spec)
    if cfg.family == "hybrid":
        return _fuse_zamba(cfg, p, r1m, r2m, spec)
    raise ValueError(cfg.family)


def _fuse_head(cfg, p, r1m):
    """Embed (rear), final norm fold + lm_head (front)."""
    p["embed"] = _rot_out(p["embed"], r1m)
    if "patch_proj" in p:
        p["patch_proj"] = _rot_out(p["patch_proj"], r1m)
    lm = _fold_norm_into(p["lm_head"], p["final_norm"])
    p["final_norm"] = _ones_like(p["final_norm"])
    p["lm_head"] = _rot_in(lm, r1m)
    return p


def _fuse_transformer(cfg, p, r1m, r2m, spec):
    layers = dict(p["layers"])
    interleaved = cfg.family == "moe" and cfg.moe_every > 1

    if cfg.family == "mla":
        layers = _fuse_mla(cfg, layers, r1m)
        r4 = _r4_for(spec, cfg.d_ff)
        layers = _fuse_mlp_dense(layers, r1m, r4)
    elif interleaved:
        attn_keys = {k: v for k, v in layers.items() if k not in ("dense_mlp", "moe_mlp")}
        attn_keys = _fuse_attn_std(cfg, attn_keys, r1m, r2m)
        # attn fusion folded mlp_norm? no - mlp_norm lives in attn_keys dict;
        # dense_mlp/moe_mlp fusions need it. Handle by temporarily attaching.
        dense = dict(layers["dense_mlp"])
        dense["mlp_norm"] = attn_keys["mlp_norm"][:, : cfg.moe_every - 1]
        r4d = _r4_for(spec, cfg.d_ff)
        dense = _fuse_mlp_dense(dense, r1m, r4d)
        moe = dict(layers["moe_mlp"])
        moe["mlp_norm"] = attn_keys["mlp_norm"][:, cfg.moe_every - 1]
        de = cfg.d_expert or cfg.d_ff
        moe = _fuse_moe(cfg, moe, r1m, _r4_for(spec, de),
                        _r4_for(spec, de * max(cfg.n_shared_experts, 1),
                                "shared_down"))
        # reassemble the folded norms back into the stacked layout
        mlp_norm = jnp.concatenate(
            [dense.pop("mlp_norm"), moe.pop("mlp_norm")[:, None]], axis=1
        )
        attn_keys["mlp_norm"] = mlp_norm
        layers = {**attn_keys, "dense_mlp": dense, "moe_mlp": moe}
    else:
        layers = _fuse_attn_std(cfg, layers, r1m, r2m)
        if cfg.family == "moe":
            de = cfg.d_expert or cfg.d_ff
            layers = _fuse_moe(cfg, layers, r1m, _r4_for(spec, de),
                               _r4_for(spec, de * max(cfg.n_shared_experts, 1),
                                       "shared_down"))
        else:
            r4 = _r4_for(spec, cfg.d_ff)
            layers = _fuse_mlp_dense(layers, r1m, r4)
    p["layers"] = layers
    return _fuse_head(cfg, p, r1m)


def _fuse_xlstm(cfg, p, r1m, spec):
    m = dict(p["mlstm"])
    for k in ("wq", "wk", "wv", "wi", "wf", "wo_gate"):
        m[k] = _rot_in(_fold_norm_into(m[k], m["norm"]), r1m)
    m["norm"] = _ones_like(m["norm"])
    m["out_proj"] = _rot_out(m["out_proj"], r1m)
    s = dict(p["slstm"])
    s["wx"] = _rot_in(_fold_norm_into(s["wx"], s["norm"]), r1m)
    s["norm"] = _ones_like(s["norm"])
    s["out_proj"] = _rot_out(s["out_proj"], r1m)
    p["mlstm"], p["slstm"] = m, s
    return _fuse_head(cfg, p, r1m)


def _fuse_zamba(cfg, p, r1m, r2m, spec):
    mb = dict(p["mamba"])
    mb["in_proj"] = _rot_in(_fold_norm_into(mb["in_proj"], mb["norm"]), r1m)
    mb["norm"] = _ones_like(mb["norm"])
    mb["out_proj"] = _rot_out(mb["out_proj"], r1m)
    p["mamba"] = mb
    sp = dict(p["shared"])
    sp = _fuse_attn_std(cfg, sp, r1m, r2m)
    r4 = _r4_for(spec, cfg.d_ff)
    sp = _fuse_mlp_dense(sp, r1m, r4)
    p["shared"] = sp
    return _fuse_head(cfg, p, r1m)
