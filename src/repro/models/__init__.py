"""Model zoo: every assigned architecture as a functional JAX model.

Params are plain nested dicts of arrays (pjit-friendly pytrees); layers are
stacked on a leading L axis and executed with ``lax.scan`` so compile time
is O(1) in depth.  Each arch provides train-forward, prefill, and decode
entry points plus ShapeDtypeStruct ``input_specs`` for the dry-run.
"""
