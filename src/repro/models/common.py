"""Shared model primitives: norms, RoPE, flash attention, quant hooks.

Attention is a pure-JAX flash formulation (two-level ``lax.scan`` over
query/key blocks with online softmax) so 32k-token prefill fits HBM
without materialising the (S, S) score matrix.  On TPU the inner block
matmuls are MXU-shaped; a Pallas flash kernel is a further §Perf option
but the scan form is what the dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype=jnp.float32, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    # python float stays weak-typed: a np.float64 scalar would silently
    # promote bf16 params to f32
    s = float(scale if scale is not None else 1.0 / np.sqrt(fan_in))
    return jax.random.normal(key, shape, dtype) * s


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02


# ---------------------------------------------------------------------------
# Norms / positional
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope_freqs(dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, dim, 2, dtype=np.float32) / dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D_rot) with D_rot even; positions: (B, S) or (S,)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta))  # (d/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Quantization hooks (static spec -> online ops inside forward)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantizeSpec:
    """Static description of the *online* quantization/rotation ops.

    Weight rotation+quantization happens offline (core.fuse / quant.gptq);
    this spec controls what runs inside the forward pass: activation
    fake-quant in front of each GEMM (Ay), the online R4 rotation before
    down_proj, the online R3 rotation after RoPE, and KV-cache quant.

    ``r4_sites`` carries *per-site* online rotation overrides from a
    :class:`repro.quant.policy.QuantPolicy`: a tuple of
    ``(site glob, kind, group, seed)`` entries matched first-wins against
    the site name each ``apply_r4`` call passes (``w_down``,
    ``shared_down``, ...); sites with no match fall back to ``r4_kind``.
    The offline fusion (:mod:`repro.core.fuse`) consults the same table,
    so the weight pre-rotation and the online activation rotation always
    cancel site-for-site.

    ``act_sites`` is the activation-side analogue: ``(site glob, bits,
    group, clip)`` entries matched first-wins against the site tag each
    ``act_q`` call passes (``wq``, ``w_down``, ``lm_head``, ...); sites
    with no match fall back to the global ``act_bits``/``act_group``/
    ``act_clip``.  Both tables share one lookup idiom so per-site
    activation precision and per-site online rotation compose.
    """

    act_bits: int = 16
    act_group: int = 128
    act_clip: float = 0.9
    r4_kind: str = "I"  # I | GH | GW | LH | GSR
    r4_group: int = 128
    r4_seed: int = 1234
    r3: bool = False
    kv_bits: int = 16
    use_kernels: bool = False
    r4_sites: Tuple[Tuple[str, str, int, int], ...] = ()
    act_sites: Tuple[Tuple[str, int, int, float], ...] = ()

    @property
    def act_enabled(self) -> bool:
        return self.act_bits < 16 or any(b < 16 for _, b, _, _ in self.act_sites)

    def act_for(self, site: str) -> Tuple[int, int, float]:
        """(bits, group, clip_ratio) of the activation quantizer at ``site``.

        Same resolution idiom as :meth:`r4_for`: ``act_q`` call sites pass
        *bare* site tags, so a slash-qualified rule pattern falls back to
        matching by its last path component; first match wins; no match =
        the spec's global activation settings.
        """
        import fnmatch

        for pattern, bits, group, clip in self.act_sites:
            if (fnmatch.fnmatchcase(site, pattern)
                    or fnmatch.fnmatchcase(site, pattern.rsplit("/", 1)[-1])):
                return bits, group, clip
        return self.act_bits, self.act_group, self.act_clip

    def r4_for(self, site: str) -> Tuple[str, int, int]:
        """(kind, group, seed) of the online R4 rotation at ``site``.

        ``apply_r4`` call sites pass *bare* site names (``w_down``,
        ``shared_down``) — the layer body cannot know its qualified tree
        path — so a slash-qualified rule pattern falls back to matching
        by its last path component (``moe_mlp/w_down`` applies at
        ``w_down``); overlaps resolve first-match-wins like every rule.
        """
        import fnmatch

        for pattern, kind, group, seed in self.r4_sites:
            if (fnmatch.fnmatchcase(site, pattern)
                    or fnmatch.fnmatchcase(site, pattern.rsplit("/", 1)[-1])):
                return kind, group, seed
        return self.r4_kind, self.r4_group, self.r4_seed


NOQUANT = QuantizeSpec()


def act_q(x: jax.Array, spec: QuantizeSpec, site: str) -> jax.Array:
    """Grouped symmetric activation fake-quant (no-op at 16 bits).

    ``site`` tags which GEMM input this activation feeds (``wq``,
    ``w_down``, ``lm_head``, ...) so a policy's per-site activation rules
    (``spec.act_sites``) can spend low-bit precision only where it
    matters; every call site is statically tagged and linted
    (``tests/test_act_sites_lint.py``).
    """
    if not spec.act_enabled:
        return x
    bits, act_group, clip = spec.act_for(site)
    if bits >= 16:
        return x
    group = min(act_group, x.shape[-1])
    if x.shape[-1] % group:
        group = x.shape[-1]
    if spec.use_kernels:
        from repro.kernels import ops as kops

        return kops.rtn_fake_quant(x, bits=bits, group=group, clip_ratio=clip)
    from repro.quant.qtypes import QuantConfig
    from repro.quant.rtn import fake_quant_act_grouped

    cfg = QuantConfig(bits=bits, group=group, symmetric=True, clip_ratio=clip)
    return fake_quant_act_grouped(x, cfg)


# ---------------------------------------------------------------------------
# KV-cache token quantization (one asymmetric group per token vector)
# ---------------------------------------------------------------------------
# Shared by the transformer and MLA prefill/decode paths so that every
# consumer of a cached token dequantizes with byte-identical arithmetic —
# the invariant the prefix-sharing cache rests on: re-quantizing the same
# float vector yields the same codes, and attending a cached block is
# bit-equivalent to recomputing it.


def kv_quant_cfg(spec: QuantizeSpec):
    from repro.quant.qtypes import QuantConfig

    return QuantConfig(bits=spec.kv_bits, group=10**9, symmetric=False)


def kv_quant_tokens(x: jax.Array, spec: QuantizeSpec):
    """x (..., D_group) -> codes, scale, zero (one group per vector)."""
    from repro.quant import rtn

    cfg = kv_quant_cfg(spec)
    xf = x.astype(jnp.float32)
    scale, zero = rtn.compute_qparams(xf, cfg)
    codes = rtn.quantize(xf, scale[..., None], zero[..., None], cfg).astype(jnp.uint8)
    return codes, scale, zero


def kv_dequant_tokens(codes, scale, zero, dtype):
    return ((codes.astype(jnp.float32) - zero[..., None]) * scale[..., None]).astype(dtype)


def kv_roundtrip(x: jax.Array, spec: QuantizeSpec, store_dtype=None) -> jax.Array:
    """x at *stored* precision: the exact values a later reader will see.

    Quantized KV: quantize -> dequantize through the cache codec.  Float
    KV: round-trip through the cache dtype (no-op for f32-in-f32, the
    serving default).  Prefill attention scores through this so a
    continuation over cached blocks reproduces a full prefill bitwise.
    """
    if spec.kv_bits < 16:
        return kv_dequant_tokens(*kv_quant_tokens(x, spec), x.dtype)
    if store_dtype is not None:
        return x.astype(store_dtype).astype(x.dtype)
    return x


@functools.lru_cache(maxsize=32)
def _r4_blocks(kind: str, dim: int, group: int, seed: int):
    from repro.core.rotation import RotationKind, make_rotation

    kind = RotationKind(kind)
    if not kind.is_local:
        try:
            return make_rotation(kind, dim, seed=seed)
        except ValueError:
            # d_ff not Hadamard-constructible globally (e.g. 11008): fall
            # back to the corresponding local kind - the paper's local
            # rotations never hit this (another GSR deployment advantage).
            kind = (
                RotationKind.GSR
                if kind == RotationKind.GLOBAL_WALSH
                else RotationKind.LOCAL_HADAMARD
            )
    g = min(group, dim)
    while dim % g or not (g & (g - 1)) == 0:
        g //= 2
        if g == 0:
            raise ValueError(f"no valid rotation group for dim {dim}")
    return make_rotation(kind, dim, group=g, seed=seed)


def apply_r4(x: jax.Array, spec: QuantizeSpec, site: str = "w_down") -> jax.Array:
    """Online rotation of the down_proj input (QuaRot's R4 position).

    ``site`` selects the per-site rotation when the spec carries a policy
    table (``spec.r4_sites``); the default covers the flat-config case.
    """
    kind, group, seed = spec.r4_for(site)
    if kind == "I":
        return x
    rot = _r4_blocks(kind, x.shape[-1], group, seed)
    if spec.use_kernels and rot.kind.is_local:
        from repro.kernels import ops as kops

        blocks = jnp.asarray(rot.matrix, jnp.float32)
        if blocks.ndim == 2:
            blocks = blocks[None]
        return kops.grouped_rotate(x, blocks)
    if spec.use_kernels and not rot.kind.is_local and rot.kind.value == "GW":
        # GW = FWHT then the Walsh row-permutation of outputs.
        from repro.kernels import ops as kops
        from repro.core.hadamard import walsh_permutation

        y = kops.fwht(x)
        return y[..., np.argsort(walsh_permutation(x.shape[-1]))]
    from repro.core.rotation import apply_rotation

    return apply_rotation(x, rot)


def apply_r3(q: jax.Array, k: jax.Array, spec: QuantizeSpec):
    """Per-head Hadamard on q/k after RoPE (SpinQuant's R3, for KV quant)."""
    if not spec.r3:
        return q, k
    from repro.core.rotation import fwht

    return fwht(q), fwht(k)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, H, D) by repeating each kv head."""
    b, s, kv, d = k.shape
    rep = n_heads // kv
    if rep == 1:
        return k
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, d)).reshape(b, s, n_heads, d)


NEG_INF = -1e30


def _blk_mask(iq, ik, qc, kc, q_off, skv, causal, window):
    qpos = q_off + iq * qc + jnp.arange(qc)
    kpos = ik * kc + jnp.arange(kc)
    mask = (kpos < skv)[None, :]  # kv padding
    if causal:
        mask = mask & (qpos[:, None] >= kpos[None, :])
    if window:
        mask = mask & (qpos[:, None] - kpos[None, :] < window)
    return mask


def _flash_fwd_impl(qs, ks, vs, dims):
    """GQA-aware flash forward, casts per block (input dtype stays bf16).

    qs: (nq, B, KV, rep, qc, d); ks: (nk, B, KV, kc, d); vs may have a
    different feature dim dv (MLA: qk 96 vs v 64).
    Returns out (nq, B, KV, rep, qc, dv) f32 and lse (nq, B, KV, rep, qc).
    """
    b, kv, rep, qc, d = qs.shape[1:]
    nk, kc = ks.shape[0], ks.shape[3]
    dv = vs.shape[-1]
    q_off, skv, causal, window, scale = dims

    def q_block(iq, qb):
        qb = qb.astype(jnp.float32) * scale

        def kv_step(carry, inp):
            ik, kb, vb = inp
            m, l, acc = carry
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb, kb.astype(jnp.float32))
            mask = _blk_mask(iq, ik, qc, kc, q_off, skv, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", p, vb.astype(jnp.float32)
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kv, rep, qc), NEG_INF, jnp.float32),
            jnp.zeros((b, kv, rep, qc), jnp.float32),
            jnp.zeros((b, kv, rep, qc, dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (jnp.arange(nk), ks, vs))
        lsafe = jnp.maximum(l, 1e-30)
        out = acc / lsafe[..., None]
        lse = m + jnp.log(lsafe)
        return out, lse

    outs, lses = jax.lax.map(lambda args: q_block(*args), (jnp.arange(qs.shape[0]), qs))
    return outs, lses


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_core(qs, ks, vs, dims):
    return _flash_fwd_impl(qs, ks, vs, dims)[0]


def _flash_core_fwd(qs, ks, vs, dims):
    out, lse = _flash_fwd_impl(qs, ks, vs, dims)
    return out, (qs, ks, vs, out, lse)


def _flash_core_bwd(dims, res, dout):
    """Blockwise recompute backward: O(block) memory, ~2x fwd flops.

    The rep (GQA expansion) axis contracts in dk/dv - the grouped-head
    gradient reduction falls out of the einsums for free.
    """
    qs, ks, vs, out, lse = res
    nq, b, kv, rep, qc, d = qs.shape
    nk, kc = ks.shape[0], ks.shape[3]
    dvf = vs.shape[-1]  # value feature dim (may differ from d, e.g. MLA)
    q_off, skv, causal, window, scale = dims
    delta = jnp.einsum("nbgrqd,nbgrqd->nbgrq", dout, out)  # rowsum(do*o)

    def dq_block(iq, qb, do_b, lse_b, dl_b):
        qb = qb.astype(jnp.float32) * scale

        def kv_step(dq, inp):
            ik, kb, vb = inp
            kb = kb.astype(jnp.float32)
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb, kb)
            mask = _blk_mask(iq, ik, qc, kc, q_off, skv, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_b[..., None])
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", do_b, vb.astype(jnp.float32))
            ds = p * (dp - dl_b[..., None])
            return dq + jnp.einsum("bgrqk,bgkd->bgrqd", ds, kb), None

        dq, _ = jax.lax.scan(
            kv_step, jnp.zeros((b, kv, rep, qc, d), jnp.float32), (jnp.arange(nk), ks, vs)
        )
        return dq * scale

    dqs = jax.lax.map(lambda a: dq_block(*a), (jnp.arange(nq), qs, dout, lse, delta))

    def dkv_block(ik, kb, vb):
        kb = kb.astype(jnp.float32)
        vb = vb.astype(jnp.float32)

        def q_step(carry, inp):
            iq, qb, do_b, lse_b, dl_b = inp
            qb = qb.astype(jnp.float32) * scale
            dk, dv = carry
            s = jnp.einsum("bgrqd,bgkd->bgrqk", qb, kb)
            mask = _blk_mask(iq, ik, qc, kc, q_off, skv, causal, window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_b[..., None])
            dv = dv + jnp.einsum("bgrqk,bgrqd->bgkd", p, do_b)
            dp = jnp.einsum("bgrqd,bgkd->bgrqk", do_b, vb)
            ds = p * (dp - dl_b[..., None])
            dk = dk + jnp.einsum("bgrqk,bgrqd->bgkd", ds, qb)
            return (dk, dv), None

        init = (
            jnp.zeros((b, kv, kc, d), jnp.float32),
            jnp.zeros((b, kv, kc, dvf), jnp.float32),
        )
        # ds/dk = scale*q, and qb already carries the scale: dk is exact
        (dk, dv), _ = jax.lax.scan(q_step, init, (jnp.arange(nq), qs, dout, lse, delta))
        return dk, dv

    dks, dvs = jax.lax.map(lambda a: dkv_block(*a), (jnp.arange(nk), ks, vs))
    return dqs.astype(qs.dtype), dks.astype(ks.dtype), dvs.astype(vs.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    window: int = 0,
) -> jax.Array:
    """Memory-O(S * chunk) causal attention, custom-VJP flash backward.

    q: (B, Sq, H, D); k/v: (B, Skv, KV, D) with H % KV == 0 (GQA, handled
    without materialising expanded heads).  q positions align to the end
    of k (prefill: Sq == Skv).
    """
    b, sq, h, d = q.shape
    dv = v.shape[-1]
    kv = k.shape[2]
    rep = h // kv
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(d)

    qc = min(q_chunk, sq)
    kc = min(kv_chunk, skv)
    nq, nk = -(-sq // qc), -(-skv // kc)
    pad_q, pad_k = nq * qc - sq, nk * kc - skv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # (nq, B, KV, rep, qc, d) / (nk, B, KV, kc, d|dv); input dtype preserved
    qs = q.reshape(b, nq, qc, kv, rep, d).transpose(1, 0, 3, 4, 2, 5)
    ks = k.reshape(b, nk, kc, kv, d).transpose(1, 0, 3, 2, 4)
    vs = v.reshape(b, nk, kc, kv, dv).transpose(1, 0, 3, 2, 4)
    dims = (skv - sq, skv, bool(causal), int(window), float(scale))
    outs = _flash_core(qs, ks, vs, dims)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * qc, h, dv)
    return out[:, :sq].astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Single-step attention over a (possibly longer, masked) KV cache.

    q: (B, 1, H, D); caches: (B, Smax, KV, D); length: () current fill.
    GQA handled by grouped einsums (no expanded-head or f32 cache copies:
    the contractions accumulate in f32 via preferred_element_type).
    """
    b, _, h, d = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    smax = k_cache.shape[1]
    qg = q.reshape(b, kv, rep, d)
    s = jnp.einsum(
        "bgrd,bsgd->bgrs", qg, k_cache, preferred_element_type=jnp.float32
    ) * (1.0 / np.sqrt(d))
    kpos = jnp.arange(smax)
    mask = kpos[None, None, None, :] < length
    if window:
        mask &= kpos[None, None, None, :] >= length - window
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgrs,bsgd->bgrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, 1, h, d).astype(q.dtype)


def decode_chunk_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    length: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Chunk-causal attention for the speculative-decode verify pass.

    q: (B, K, H, D) — K consecutive pending tokens whose K/V has already
    been written into the caches at positions ``[length, length + K)``;
    caches: (B, Smax, KV, D); length: () fill *before* the chunk.  Query
    ``j`` attends to positions ``< length + 1 + j`` (itself plus
    everything stored earlier), so ``K == 1`` computes exactly
    :func:`decode_attention` and position ``j`` of a longer chunk scores
    the same softmax the j-th sequential decode step would.
    """
    b, kq, h, d = q.shape
    kv = k_cache.shape[2]
    rep = h // kv
    smax = k_cache.shape[1]
    qg = q.reshape(b, kq, kv, rep, d)
    s = jnp.einsum(
        "bqgrd,bsgd->bgrqs", qg, k_cache, preferred_element_type=jnp.float32
    ) * (1.0 / np.sqrt(d))
    kpos = jnp.arange(smax)
    lim = length + 1 + jnp.arange(kq)             # (K,) per-query fill
    mask = kpos[None, :] < lim[:, None]           # (K, Smax)
    if window:
        mask &= kpos[None, :] >= lim[:, None] - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bgrqs,bsgd->bqgrd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, kq, h, d).astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,
    k_pages,
    v_pages,
    k2_pages,
    k_new,
    v_new,
    k2_new,
    tables: jax.Array,
    lengths: jax.Array,
    layer,
    *,
    window: int = 0,
    scale: Optional[float] = None,
    v_is_k1: bool = False,
):
    """Paged variant of :func:`decode_attention` over KV-pool block storage.

    Instead of a contiguous ``(B, Smax, KV, D)`` cache view this reads the
    pool's block-paged storage in place through the slot block table (see
    :mod:`repro.kernels.paged_attention`) and *appends the new token* to
    its block as part of the same fused kernel — the caller never gathers
    blocks into a view or scatters one back.

    q: ``(B, 1, H, dk)``.  ``k_pages``/``v_pages``: 1-tuple of float pages
    ``(L, NB, T, KV, d)`` or 3-tuple ``(codes, scale, zero)`` for
    quantized storage (scales ``(L, NB, T, KV)``); ``k2_pages`` an
    optional extra float K source concatenated on the feature axis (MLA
    RoPE keys) and ``v_is_k1`` makes V the first-source dequant (MLA
    latent).  ``k_new``/``v_new``/``k2_new``: the new token in the same
    layout, shapes ``(B, KV, d)`` / ``(B, KV)``.  ``lengths``: ``(B,)``
    per-slot fill; ``layer``: scalar index into the stacked pool.

    Returns ``(out (B, 1, H, dv) f32, new_pages)`` with ``new_pages`` the
    updated page arrays in input order ``k(+s,z) [,k2] [,v(+s,z)]``.
    """
    from repro.kernels import ops as kops

    b, _, h, dk = q.shape
    kv = k_pages[0].shape[3]
    rep = h // kv
    qg = q.reshape(b, kv, rep, dk)
    out, new_pages = kops.paged_attention(
        qg, tables, lengths, layer, k_pages, v_pages, k2_pages, k_new, v_new,
        k2_new, window=window, scale=scale, v_is_k1=v_is_k1)
    return out.reshape(b, 1, h, out.shape[-1]), new_pages


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def swiglu(x: jax.Array, wgate: jax.Array, wup: jax.Array, wdown: jax.Array,
           spec: QuantizeSpec = NOQUANT, site: str = "w_down") -> jax.Array:
    # the gate/up input tag is derived from the down-projection site so
    # shared-expert blocks resolve their own rules (shared_down ->
    # shared_gate)
    xq = act_q(x, spec, site=site.replace("down", "gate"))
    hidden = jax.nn.silu(xq @ wgate) * (xq @ wup)
    hidden = apply_r4(hidden, spec, site)  # online R4 before down projection
    hidden = act_q(hidden, spec, site=site)
    return hidden @ wdown


def cross_entropy(logits: jax.Array, labels: jax.Array, mask: Optional[jax.Array] = None):
    """Mean token NLL in f32. logits (..., V); labels (...) int32."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
