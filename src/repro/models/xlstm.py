"""xLSTM decoder (assigned arch ``xlstm-1.3b``): mLSTM + sLSTM blocks.

Layer pattern: one sLSTM block every ``cfg.slstm_every`` layers, the rest
mLSTM - structured as scan-over-groups of (slstm_every-1 mLSTM + 1 sLSTM)
so compile time stays O(1) in depth.

mLSTM: multi-head matrix memory via the shared chunkwise linear-attention
engine (``ssm_common``), with sigmoid forget/input gates in log space
(DESIGN.md documents the omitted max-stabilizer).  sLSTM: per-head
recurrent cell run with ``lax.scan`` over time (inherently sequential -
the paper's sLSTM has no parallel form).

No FFN (d_ff = 0): each block carries its own in/out projections, matching
the xLSTM paper's block design.  R1 rotation applies to the residual
stream (in_proj front side, out_proj rear side); the paper's attention-
specific R2/R3 have no analogue here (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import NOQUANT, QuantizeSpec, act_q, rmsnorm
from repro.models.ssm_common import (
    chunked_linear_attention,
    linear_attention_step,
)


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, mlstm_per_group, n_slstm)."""
    every = cfg.slstm_every or cfg.n_layers + 1
    if cfg.n_layers % every == 0:
        groups = cfg.n_layers // every
        return groups, every - 1, groups
    return 0, 0, 0


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    d, v = cfg.d_model, cfg.vocab
    h = cfg.n_heads
    dh = d // h
    groups, m_per, _ = _layout(cfg)
    assert groups > 0, f"n_layers {cfg.n_layers} % slstm_every {cfg.slstm_every} != 0"
    nm = groups * m_per
    ks = jax.random.split(key, 12)

    def mstack(k, shape):
        return common.dense_init(k, (nm,) + shape, dtype)

    def sstack(k, shape):
        return common.dense_init(k, (groups,) + shape, dtype)

    return {
        "embed": common.embed_init(ks[0], (v, d), dtype),
        "mlstm": {
            "norm": jnp.ones((nm, d), dtype),
            "wq": mstack(ks[1], (d, d)),
            "wk": mstack(ks[2], (d, d)),
            "wv": mstack(ks[3], (d, d)),
            "wi": mstack(ks[4], (d, h)),  # input gate (per head)
            "wf": mstack(ks[5], (d, h)),  # forget gate (per head)
            "wo_gate": mstack(ks[6], (d, d)),  # output gate (per channel)
            "out_proj": mstack(ks[7], (d, d)),
        },
        "slstm": {
            "norm": jnp.ones((groups, d), dtype),
            "wx": sstack(ks[8], (d, 4 * d)),  # z, i, f, o from input
            "rh": sstack(ks[9], (h, dh, 4 * dh)),  # per-head recurrence
            "out_proj": sstack(ks[10], (d, d)),
        },
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": common.dense_init(ks[11], (d, v), dtype),
    }


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _mlstm_qkvg(cfg, lp, x, spec):
    b, s, d = x.shape
    h = cfg.n_heads
    dh = d // h
    xq = act_q(x, spec, site="wq")
    q = (xq @ lp["wq"]).reshape(b, s, h, dh)
    k = (xq @ lp["wk"]).reshape(b, s, h, dh) / np.sqrt(dh)
    v = (xq @ lp["wv"]).reshape(b, s, h, dh)
    log_i = jax.nn.log_sigmoid(xq @ lp["wi"]).astype(jnp.float32)  # (B,S,H)
    log_f = jax.nn.log_sigmoid(xq @ lp["wf"]).astype(jnp.float32)
    ogate = jax.nn.sigmoid(xq @ lp["wo_gate"])  # (B,S,D)
    return q, k, v, log_i, log_f, ogate


def mlstm_block(cfg, lp, hres, spec, state=None, *, chunk=128):
    """Returns (h, final_state)."""
    x = rmsnorm(hres, lp["norm"], cfg.norm_eps)
    q, k, v, log_i, log_f, ogate = _mlstm_qkvg(cfg, lp, x, spec)
    y, new_state = chunked_linear_attention(
        q, k, v, log_f, log_i, chunk=chunk, normalize=True, state=state
    )
    b, s, d = x.shape
    y = y.reshape(b, s, d) * ogate
    y = act_q(y, spec, site="out_proj")
    return hres + y @ lp["out_proj"], new_state


def mlstm_block_step(cfg, lp, hres, spec, state):
    """Single-token decode step. hres: (B, 1, D)."""
    x = rmsnorm(hres, lp["norm"], cfg.norm_eps)
    q, k, v, log_i, log_f, ogate = _mlstm_qkvg(cfg, lp, x, spec)
    sq = lambda a: a[:, 0]
    y, new_state = linear_attention_step(
        sq(q), sq(k), sq(v), sq(log_f), sq(log_i), state, normalize=True
    )
    b, _, d = x.shape
    y = y.reshape(b, 1, d) * ogate
    y = act_q(y, spec, site="out_proj")
    return hres + y @ lp["out_proj"], new_state


def _slstm_cell(cfg, lp, gx, state):
    """gx: (B, 4D) pre-activations from input; state: (c, n, h) each (B,H,dh)."""
    b = gx.shape[0]
    h_heads, dh = cfg.n_heads, cfg.d_model // cfg.n_heads
    c, n, hprev = state
    rec = jnp.einsum("bhd,hde->bhe", hprev, lp["rh"])  # (B,H,4dh)
    g = gx.reshape(b, h_heads, 4 * dh) + rec
    z, i, f, o = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c_new = f * c + i * z
    n_new = f * n + i
    h_new = o * (c_new / jnp.maximum(n_new, 1.0))
    return h_new, (c_new, n_new, h_new)


def slstm_block(cfg, lp, hres, spec, state=None):
    """Sequential scan over time. Returns (h, final_state)."""
    b, s, d = hres.shape
    h_heads = cfg.n_heads
    dh = d // h_heads
    x = rmsnorm(hres, lp["norm"], cfg.norm_eps)
    gx = act_q(x, spec, site="wx") @ lp["wx"]  # (B,S,4D)
    if state is None:
        z = jnp.zeros((b, h_heads, dh), jnp.float32)
        state = (z, z, z)

    def step(carry, gxt):
        h_new, carry = _slstm_cell(cfg, lp, gxt, carry)
        return carry, h_new

    state, ys = jax.lax.scan(step, state, gx.astype(jnp.float32).swapaxes(0, 1))
    y = ys.swapaxes(0, 1).reshape(b, s, d).astype(hres.dtype)
    y = act_q(y, spec, site="out_proj")
    return hres + y @ lp["out_proj"], state


def slstm_block_step(cfg, lp, hres, spec, state):
    b, _, d = hres.shape
    x = rmsnorm(hres, lp["norm"], cfg.norm_eps)
    gx = (act_q(x, spec, site="wx") @ lp["wx"])[:, 0].astype(jnp.float32)
    h_new, state = _slstm_cell(cfg, lp, gx, state)
    y = h_new.reshape(b, 1, d).astype(hres.dtype)
    y = act_q(y, spec, site="out_proj")
    return hres + y @ lp["out_proj"], state


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _group_scan(cfg, params, h, spec, m_state=None, s_state=None, *, chunk=128,
                emit_state=True):
    """Scan over (m_per mLSTM + 1 sLSTM) groups. States stacked per layer.

    ``emit_state=False`` (training) drops the state scan-outputs so the
    per-layer final states are never materialised across layers.
    """
    groups, m_per, _ = _layout(cfg)
    ml = jax.tree.map(lambda a: a.reshape(groups, m_per, *a.shape[1:]), params["mlstm"])

    def group_fn(h, xs):
        mlp_g, slp_g, mst_g, sst_g = xs

        def mstep(h, xs2):
            lp, st = xs2
            h, st2 = mlstm_block(cfg, lp, h, spec, st, chunk=chunk)
            return h, st2 if emit_state else None

        h, mst2 = jax.lax.scan(mstep, h, (mlp_g, mst_g))
        h, sst2 = slstm_block(cfg, slp_g, h, spec, sst_g)
        if not emit_state:
            sst2 = None
        return h, (mst2, sst2)

    h, (m_state2, s_state2) = jax.lax.scan(
        group_fn, h, (ml, params["slstm"], m_state, s_state)
    )
    return h, m_state2, s_state2


def init_state(cfg: ModelConfig, batch: int) -> Dict:
    groups, m_per, _ = _layout(cfg)
    h, d = cfg.n_heads, cfg.d_model
    dh = d // h
    return {
        "m": (
            jnp.zeros((groups, m_per, batch, h, dh, dh), jnp.float32),
            jnp.zeros((groups, m_per, batch, h, dh), jnp.float32),
        ),
        "s": (
            jnp.zeros((groups, batch, h, dh), jnp.float32),
            jnp.zeros((groups, batch, h, dh), jnp.float32),
            jnp.zeros((groups, batch, h, dh), jnp.float32),
        ),
        "length": jnp.zeros((), jnp.int32),
    }


def forward(cfg: ModelConfig, params: Dict, batch: Dict, spec: QuantizeSpec = NOQUANT,
            *, remat: bool = True, chunk: int = 128,
            return_hidden: bool = False) -> jax.Array:
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    b = h.shape[0]
    st = init_state(cfg, b)
    h, _, _ = _group_scan(cfg, params, h, spec, st["m"], st["s"], chunk=chunk,
                          emit_state=False)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    h = act_q(h, spec, site="lm_head")
    if return_hidden:
        return h
    return h @ params["lm_head"]


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict,
            spec: QuantizeSpec = NOQUANT, *, chunk: int = 128):
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    h, m2, s2 = _group_scan(cfg, params, h, spec, cache["m"], cache["s"], chunk=chunk)
    hn = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = act_q(hn, spec, site="lm_head") @ params["lm_head"]
    return logits, {"m": m2, "s": s2, "length": jnp.asarray(h.shape[1], jnp.int32)}


def decode(cfg: ModelConfig, params: Dict, tokens: jax.Array, cache: Dict,
           spec: QuantizeSpec = NOQUANT):
    """tokens: (B,). One step; state-based, O(1) in context length."""
    groups, m_per, _ = _layout(cfg)
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :]
    ml = jax.tree.map(lambda a: a.reshape(groups, m_per, *a.shape[1:]), params["mlstm"])

    def group_fn(h, xs):
        mlp_g, slp_g, mst_g, sst_g = xs

        def mstep(h, xs2):
            lp, st = xs2
            h, st2 = mlstm_block_step(cfg, lp, h, spec, st)
            return h, st2

        h, mst2 = jax.lax.scan(mstep, h, (mlp_g, mst_g))
        h, sst2 = slstm_block_step(cfg, slp_g, h, spec, sst_g)
        return h, (mst2, sst2)

    h, (m2, s2) = jax.lax.scan(group_fn, h, (ml, params["slstm"], cache["m"], cache["s"]))
    hn = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = act_q(hn, spec, site="lm_head") @ params["lm_head"]
    return logits[:, 0], {"m": m2, "s": s2, "length": cache["length"] + 1}
