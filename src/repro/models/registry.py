"""Architecture registry: uniform API over all model families.

``get_arch(name)`` -> :class:`Arch` bundling config + init/forward/prefill/
decode + ShapeDtypeStruct input specs for the dry-run.  The ``--arch``
flag of every launcher resolves through here.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.common import NOQUANT, QuantizeSpec

ARCH_IDS = [
    "deepseek-moe-16b",
    "llama4-maverick-400b-a17b",
    "internvl2-2b",
    "minicpm3-4b",
    "qwen1.5-4b",
    "smollm-135m",
    "deepseek-7b",
    "xlstm-1.3b",
    "zamba2-1.2b",
    "musicgen-medium",
    # the paper's own evaluation model
    "llama2-7b",
]

_MODULE_FOR_ID = {i: i.replace("-", "_").replace(".", "_") for i in ARCH_IDS}


@dataclasses.dataclass
class Arch:
    config: ModelConfig
    init: Callable  # (key, dtype) -> params
    forward: Callable  # (params, batch, spec, remat=) -> logits
    prefill: Callable  # (params, batch, cache, spec) -> (logits, cache)
    decode: Callable  # (params, tokens, cache, spec) -> (logits, cache)
    init_cache: Callable  # (batch, max_seq, spec, dtype) -> cache pytree
    # (params, batch, cache, true_length, spec) -> (logits at the *true*
    # last token, cache with length=true_length) for right-padded prompts
    # (prompt-length bucketing).  None for recurrent-state families whose
    # scan integrates every padded token.
    padded_prefill: Optional[Callable] = None
    # (params, tokens, paged, state, tables, lengths, spec) ->
    # (logits, paged, state): one decode tick straight over block-paged
    # pool storage (fused serving path; per-slot lengths, the paged
    # attention kernel walks the block table in place).  None for pure
    # per-slot-state families (xLSTM), which keep the vmapped pool step.
    decode_paged: Optional[Callable] = None
    # (params, batch, cache, start, spec) -> (logits, cache): continuation
    # prefill over a cache whose first `start` positions are already
    # populated (prefix-sharing serving path) — the batch carries only the
    # tail tokens, stored at [start, start+s).  `start` is static (one
    # compile per distinct prefix length).  None for recurrent-state
    # families: their per-token state scan cannot resume from a KV prefix.
    prefill_from: Optional[Callable] = None
    # (params, tokens (B, K), cache, spec) -> (logits (B, K, V), cache):
    # multi-token chunk-causal verify step for speculative decoding —
    # writes the chunk's K/V at [length, length+K) and returns logits at
    # *every* chunk position.  None for recurrent-state families (their
    # per-token state cannot be rewound after a rejected draft).
    decode_chunk: Optional[Callable] = None

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig, *, per_device_batch: Optional[int] = None
                    ) -> Dict:
        """ShapeDtypeStruct stand-ins for the step inputs (no allocation).

        For train/prefill: the token batch.  For decode: one new token per
        sequence (the KV/state cache spec comes from ``cache_specs``).
        Modality frontends are stubs: vlm supplies precomputed patch
        embeddings, audio supplies EnCodec token ids (K codebooks).
        """
        cfg = self.config
        b = per_device_batch or shape.global_batch
        tok = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)
        if shape.kind == "decode":
            if cfg.modality == "audio":
                return {"tokens": tok(b, cfg.n_codebooks)}
            return {"tokens": tok(b)}
        s = shape.seq_len
        if cfg.modality == "audio":
            batch = {"tokens": tok(b, s, cfg.n_codebooks)}
        else:
            batch = {"tokens": tok(b, s)}
        if cfg.modality == "vlm":
            batch["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), jnp.bfloat16
            )
        return batch

    def param_specs(self, dtype=jnp.bfloat16):
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return jax.eval_shape(lambda k: self.init(k, dtype), key)

    def cache_specs(self, batch: int, max_seq: int, spec: QuantizeSpec = NOQUANT,
                    dtype=jnp.bfloat16):
        return jax.eval_shape(lambda: self.init_cache(batch, max_seq, spec, dtype))


def _build_transformer(cfg: ModelConfig) -> Arch:
    from repro.models import transformer as t

    return Arch(
        config=cfg,
        init=lambda key, dtype=jnp.float32: t.init_params(cfg, key, dtype),
        forward=lambda p, b, spec=NOQUANT, **kw: t.forward(cfg, p, b, spec, **kw),
        prefill=lambda p, b, c, spec=NOQUANT: t.prefill(cfg, p, b, c, spec),
        decode=lambda p, tok, c, spec=NOQUANT: t.decode(cfg, p, tok, c, spec),
        init_cache=lambda batch, max_seq, spec=NOQUANT, dtype=jnp.bfloat16: t.init_cache(
            cfg, batch, max_seq, spec, dtype
        ),
        padded_prefill=lambda p, b, c, n, spec=NOQUANT: t.prefill(
            cfg, p, b, c, spec, true_length=n
        ),
        decode_paged=lambda p, tok, pg, st, tb, ln, spec=NOQUANT:
            t.decode_paged(cfg, p, tok, pg, st, tb, ln, spec),
        prefill_from=lambda p, b, c, start, spec=NOQUANT: t.prefill(
            cfg, p, b, c, spec, start=start
        ),
        decode_chunk=(None if cfg.modality == "audio" else
                      lambda p, tok, c, spec=NOQUANT:
                      t.decode_chunk(cfg, p, tok, c, spec)),
    )


def _build_xlstm(cfg: ModelConfig) -> Arch:
    from repro.models import xlstm as x

    return Arch(
        config=cfg,
        init=lambda key, dtype=jnp.float32: x.init_params(cfg, key, dtype),
        forward=lambda p, b, spec=NOQUANT, **kw: x.forward(cfg, p, b, spec, **kw),
        prefill=lambda p, b, c, spec=NOQUANT: x.prefill(cfg, p, b, c, spec),
        decode=lambda p, tok, c, spec=NOQUANT: x.decode(cfg, p, tok, c, spec),
        init_cache=lambda batch, max_seq, spec=NOQUANT, dtype=jnp.bfloat16: x.init_state(
            cfg, batch
        ),
    )


def _build_zamba(cfg: ModelConfig) -> Arch:
    from repro.models import zamba as z

    return Arch(
        config=cfg,
        init=lambda key, dtype=jnp.float32: z.init_params(cfg, key, dtype),
        forward=lambda p, b, spec=NOQUANT, **kw: z.forward(cfg, p, b, spec, **kw),
        prefill=lambda p, b, c, spec=NOQUANT: z.prefill(cfg, p, b, c, spec),
        decode=lambda p, tok, c, spec=NOQUANT: z.decode(cfg, p, tok, c, spec),
        init_cache=lambda batch, max_seq, spec=NOQUANT, dtype=jnp.bfloat16: z.init_state(
            cfg, batch, max_seq, dtype
        ),
        decode_paged=lambda p, tok, pg, st, tb, ln, spec=NOQUANT:
            z.decode_paged(cfg, p, tok, pg, st, tb, ln, spec),
    )


def build_arch(cfg: ModelConfig) -> Arch:
    if cfg.family == "ssm":
        return _build_xlstm(cfg)
    if cfg.family == "hybrid":
        return _build_zamba(cfg)
    return _build_transformer(cfg)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULE_FOR_ID:
        raise KeyError(f"unknown arch {name!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR_ID[name]}")
    return mod.CONFIG


def get_arch(name: str, *, reduced: bool = False) -> Arch:
    cfg = get_config(name)
    if reduced:
        cfg = cfg.reduced()
    return build_arch(cfg)
