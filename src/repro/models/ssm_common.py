"""Chunkwise-parallel linear attention with per-step scalar decay.

One engine serves both recurrent families:

  * mLSTM (xLSTM): matrix memory C += i_t * v_t k_t^T with forget decay,
    normalizer n, output C q / max(|n.q|, 1).
  * Mamba2 (SSD): state S = a_t S + (dt_t x_t) B_t^T, output C_t . S,
    no normalizer (decay/input magnitudes live in a_t and v_t).

Within a chunk of P steps everything is a masked (P, P) matmul against a
decay matrix (MXU-shaped); across chunks a small (dk, dv) state is carried
by ``lax.scan``.  This is the standard chunkwise scan used by production
linear-attention kernels, in pure JAX; wall-clock-critical deployments
would move the intra-chunk matmuls into a Pallas kernel, but the HLO here
is already matmul-dominated.

Numerics: decays are handled in log space; log_f <= 0 (sigmoid-derived)
keeps every exp() argument non-positive, so no running-max stabilizer is
needed (see DESIGN.md on the omitted xLSTM m-stabilizer).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_linear_attention(
    q: jax.Array,  # (B, S, H, dk)
    k: jax.Array,  # (B, S, H, dk)
    v: jax.Array,  # (B, S, H, dv)
    log_f: jax.Array,  # (B, S, H) per-step log forget decay (<= 0)
    log_i: jax.Array,  # (B, S, H) per-step log input gate (<= 0 for stability)
    *,
    chunk: int = 128,
    normalize: bool = False,
    state: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Returns (y (B,S,H,dv), (state (B,H,dk,dv), norm (B,H,dk)))."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    p = min(chunk, s)
    pad = (-s) % p
    if pad:
        zf = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        q, k, v, log_f = zf(q), zf(k), zf(v), zf(log_f)
        # padded steps: forget 0 (keep state), input -inf (no contribution)
        log_i = jnp.pad(log_i, ((0, 0), (0, pad), (0, 0)), constant_values=-1e30)
    sp = q.shape[1]
    nc = sp // p

    def to_chunks(x):
        return x.reshape(b, nc, p, *x.shape[2:]).swapaxes(0, 1)  # (nc, B, P, ...)

    qs, ks, vs, lfs, lis = map(to_chunks, (q, k, v, log_f, log_i))

    if state is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
        n0 = jnp.zeros((b, h, dk), jnp.float32)
    else:
        s0, n0 = state

    idx = jnp.arange(p)
    causal = idx[:, None] >= idx[None, :]

    def chunk_step(carry, xs):
        st, nt = carry
        qc, kc, vc, lf, li = xs  # (B,P,H,*) / (B,P,H)
        qc = qc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        a = jnp.cumsum(lf, axis=1)  # (B,P,H) inclusive log-decay prefix
        # intra-chunk decay matrix: exp(a_i - a_j + li_j), j <= i
        expo = a[:, :, None, :] - a[:, None, :, :] + li[:, None, :, :]
        dmat = jnp.where(causal[None, :, :, None], jnp.exp(jnp.minimum(expo, 0.0)), 0.0)
        scores = jnp.einsum("bihd,bjhd->bijh", qc, kc) * dmat  # (B,P,P,H)
        y_intra = jnp.einsum("bijh,bjhe->bihe", scores, vc)
        # inter-chunk from carried state
        qdec = qc * jnp.exp(a)[..., None]
        y_inter = jnp.einsum("bihd,bhde->bihe", qdec, st)
        y = y_intra + y_inter
        if normalize:
            denom_intra = scores.sum(axis=2)  # (B,P,H): sum_j D_ij q_i.k_j
            denom_inter = jnp.einsum("bihd,bhd->bih", qdec, nt)
            denom = jnp.abs(denom_intra + denom_inter)
            y = y / jnp.maximum(denom, 1.0)[..., None]
        # state update
        a_last = a[:, -1, :]  # (B,H)
        wk = jnp.exp(jnp.minimum(a_last[:, None, :] - a + li, 0.0))  # (B,P,H)
        st_new = st * jnp.exp(a_last)[:, :, None, None] + jnp.einsum(
            "bjhd,bjh,bjhe->bhde", kc, wk, vc
        )
        nt_new = nt * jnp.exp(a_last)[:, :, None] + jnp.einsum("bjhd,bjh->bhd", kc, wk)
        return (st_new, nt_new), y

    (sf, nf), ys = jax.lax.scan(chunk_step, (s0, n0), (qs, ks, vs, lfs, lis))
    y = ys.swapaxes(0, 1).reshape(b, sp, h, dv)[:, :s]
    return y.astype(q.dtype), (sf, nf)


def linear_attention_step(
    q: jax.Array,  # (B, H, dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, dv)
    log_f: jax.Array,  # (B, H)
    log_i: jax.Array,
    state: Tuple[jax.Array, jax.Array],
    *,
    normalize: bool = False,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single recurrent step (decode path); same numerics as chunked form."""
    st, nt = state
    f = jnp.exp(log_f.astype(jnp.float32))[..., None]
    i = jnp.exp(jnp.minimum(log_i.astype(jnp.float32), 0.0))[..., None]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    st_new = st * f[..., None] + (kf * i)[..., :, None] * vf[..., None, :]
    nt_new = nt * f + kf * i
    qf = q.astype(jnp.float32)
    y = jnp.einsum("bhd,bhde->bhe", qf, st_new)
    if normalize:
        denom = jnp.abs(jnp.einsum("bhd,bhd->bh", qf, nt_new))
        y = y / jnp.maximum(denom, 1.0)[..., None]
    return y.astype(q.dtype), (st_new, nt_new)


def linear_attention_sequential(q, k, v, log_f, log_i, *, normalize=False, state=None):
    """Step-by-step oracle for testing the chunked form."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    if state is None:
        state = (
            jnp.zeros((b, h, dk, dv), jnp.float32),
            jnp.zeros((b, h, dk), jnp.float32),
        )

    def step(carry, xs):
        qt, kt, vt, lft, lit = xs
        y, carry = linear_attention_step(qt, kt, vt, lft, lit, carry, normalize=normalize)
        return carry, y

    xs = tuple(x.swapaxes(0, 1) for x in (q, k, v, log_f, log_i))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.swapaxes(0, 1), state


def causal_conv1d(x: jax.Array, w: jax.Array, *, state: Optional[jax.Array] = None):
    """Depthwise causal conv along S. x: (B, S, C); w: (W, C).

    Returns (y, new_state) where state holds the last W-1 inputs (decode).
    """
    width = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1) :, :] if width > 1 else state
    return jax.nn.silu(y), new_state
