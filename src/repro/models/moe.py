"""Mixture-of-Experts layer with sort-based capacity routing.

TPU-native formulation: instead of GShard's one-hot dispatch einsums
(whose (T, E, C) contractions inflate HLO FLOPs by orders of magnitude),
tokens are *sorted by expert id* and scattered into a static (E, C, D)
buffer, the experts run as one batched einsum over the E axis, and results
scatter back.  All shapes are static; the only data-dependent values are
the gather/scatter indices, which XLA lowers to dynamic-gather - cheap in
bytes and zero in MACs, keeping ``cost_analysis`` FLOPs honest for the
roofline.

Expert parallelism: the (E, ...) axes shard over the model axis (EP).
Under plain pjit the token scatter/gather becomes GSPMD-inserted
collectives; an explicit shard_map all-to-all schedule is provided in
``repro.dist.collectives`` as the optimized variant (§Perf).

Covers both assigned MoE archs:
  * deepseek-moe-16b: 64 routed top-6 + 2 shared experts, fine-grained
    (d_expert=1408), softmax gate renormalised over the top-k.
  * llama4-maverick: 128 routed top-1 + 1 shared expert, sigmoid gate.
"""
from __future__ import annotations

import contextlib
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import QuantizeSpec, act_q, apply_r4
from repro.quant.packed import dense_w

# ---------------------------------------------------------------------------
# Expert-FFN schedule selection (ROADMAP item: data-driven default flip)
#
# "gspmd": the historical path — pin the dispatch buffer to
# P(dp, "model", ...) and let the partitioner infer collectives around the
# expert einsums.  "explicit": the dist.collectives.expert_ffn_ep
# shard_map schedule (batch-spread dispatch + two all-to-alls, provably
# minimal wire volume).  launch.dryrun flips the default per cell from
# the recorded per-layer HLO collective bytes (`moe_ep` in each MoE cell
# record); off-mesh (CPU tests, single device) both select gspmd's plain
# einsums because the explicit schedule needs a concrete mesh.
# ---------------------------------------------------------------------------

MOE_EP_IMPLS = ("gspmd", "explicit")
_MOE_EP_IMPL = "gspmd"


def get_moe_ep_impl() -> str:
    return _MOE_EP_IMPL


def set_moe_ep_impl(impl: str) -> str:
    """Set the expert-FFN schedule; returns the previous setting."""
    global _MOE_EP_IMPL
    if impl not in MOE_EP_IMPLS:
        raise ValueError(f"unknown MoE EP impl {impl!r}; want {MOE_EP_IMPLS}")
    prev = _MOE_EP_IMPL
    _MOE_EP_IMPL = impl
    return prev


@contextlib.contextmanager
def moe_ep_impl(impl: str):
    prev = set_moe_ep_impl(impl)
    try:
        yield
    finally:
        set_moe_ep_impl(prev)


def _explicit_ep_mesh(b: int, e: int):
    """The concrete mesh to run the explicit EP schedule on, or None when
    infeasible (no mesh / no model axis / indivisible dispatch layout —
    the same feasibility the dry-run records per cell)."""
    if _MOE_EP_IMPL != "explicit":
        return None
    from jax.interpreters import pxla

    mesh = pxla.thread_resources.env.physical_mesh
    if mesh.empty or "model" not in mesh.axis_names:
        return None
    sizes = dict(zip(mesh.axis_names, np.shape(mesh.devices)))
    if e % sizes["model"] or b % int(np.prod(list(sizes.values()))):
        return None
    return mesh


def _ambient_mesh():
    """The mesh visible at trace time, or None outside any mesh context."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:  # jax >= 0.5
        mesh = get_abstract()
        return None if getattr(mesh, "empty", True) else mesh
    from jax.interpreters import pxla  # jax 0.4.x: `with mesh:` context

    mesh = pxla.thread_resources.env.physical_mesh
    return None if mesh.empty else mesh


def _pin(x: jax.Array, *spec) -> jax.Array:
    """Sharding hint, active only under an ambient mesh (pjit lowering).

    Pins the expert-parallel layout of the dispatch/compute buffers:
    batch on the data axes, experts on the model axis - without this
    GSPMD tends to replicate the E axis of the (B, E, cap, D) buffers.
    Non-divisible placements are dropped by ``dist.sharding``'s
    sanitizer, the same gate the launchers use.
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import sanitize_pspecs

    mesh = _ambient_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    dp = tuple(n for n in mesh.axis_names if n != "model")
    dp_ax = dp if len(dp) > 1 else (dp[0] if dp else None)
    parts = [dp_ax if a == "data" else ("model" if a == "model" else None)
             for a in spec]
    pspec = sanitize_pspecs(mesh, P(*parts), jax.ShapeDtypeStruct(x.shape, x.dtype))
    return jax.lax.with_sharding_constraint(x, pspec)


def init_moe_params(key, cfg: ModelConfig, n_layers: int, dtype) -> Dict:
    de = cfg.d_expert or cfg.d_ff
    d = cfg.d_model
    e = cfg.n_experts
    ks = jax.random.split(key, 7)
    p = {
        "router": common.dense_init(ks[0], (n_layers, d, e), dtype),
        "w_gate": common.dense_init(ks[1], (n_layers, e, d, de), dtype),
        "w_up": common.dense_init(ks[2], (n_layers, e, d, de), dtype),
        "w_down": common.dense_init(ks[3], (n_layers, e, de, d), dtype),
    }
    if cfg.n_shared_experts:
        ds = de * cfg.n_shared_experts
        p["shared_gate"] = common.dense_init(ks[4], (n_layers, d, ds), dtype)
        p["shared_up"] = common.dense_init(ks[5], (n_layers, d, ds), dtype)
        p["shared_down"] = common.dense_init(ks[6], (n_layers, ds, d), dtype)
    return p


def capacity(cfg: ModelConfig, tokens: int) -> int:
    c = int(np.ceil(tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(c, 1)


def moe_apply(lp: Dict, x: jax.Array, cfg: ModelConfig, spec: QuantizeSpec = common.NOQUANT
              ) -> jax.Array:
    """x: (B, S, D) -> (B, S, D). lp holds one layer's (un-stacked) params.

    Routing is *grouped per sequence* (the GShard group concept): every
    argsort/gather/scatter carries an explicit leading B axis, so under
    pjit with batch-sharded activations the index ops stay shard-local -
    a globally-flattened dispatch would make GSPMD all-gather the entire
    (B*S, D) token tensor per layer (measured: 108 GiB peak on
    deepseek-moe prefill; see EXPERIMENTS.md §Perf).  The only cross-shard
    movement left is the activation-sized expert all-to-all implied by
    the (B, E, cap, D) <-> expert-sharded einsums.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k

    # Sequence-chunked dispatch: the (B, E, cap, D) buffer is ~k*cf x the
    # activation volume (top-6 tokens visit 6 experts), so long prefills
    # process the MoE in 4k-token chunks under lax.scan - same routing,
    # 1/nc the live dispatch memory (EXPERIMENTS.md §Perf cell B).
    chunk = 4096
    if s > chunk and s % chunk == 0:
        nc = s // chunk
        xs = x.reshape(b, nc, chunk, d).swapaxes(0, 1)  # (nc, B, c, D)

        def chunk_fn(_, xc):
            return None, moe_apply(lp, xc, cfg, spec)

        _, ys = jax.lax.scan(chunk_fn, None, xs)
        return ys.swapaxes(0, 1).reshape(b, s, d)

    cap = capacity(cfg, s)  # per-sequence capacity (k <= cap by construction)
    xq = act_q(x, spec, site="router")  # (B, S, D): feeds router + experts

    # --- routing (per sequence) ---
    logits = xq.astype(jnp.float32) @ lp["router"].astype(jnp.float32)  # (B,S,E)
    if cfg.top_k == 1:  # llama4-style sigmoid gate
        gates_all = jax.nn.sigmoid(logits)
    else:
        gates_all = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(gates_all, k)  # (B, S, k)
    if cfg.top_k > 1:  # deepseek: renormalise over selected experts
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # --- sort token-assignments by expert, within each sequence ---
    sk = s * k
    eid = idx.reshape(b, sk)
    tid = jnp.broadcast_to(jnp.repeat(jnp.arange(s), k)[None], (b, sk))
    order = jnp.argsort(eid, axis=1)  # stable per row
    take = lambda a: jnp.take_along_axis(a, order, axis=1)
    es, ts, gs = take(eid), take(tid), take(gates.reshape(b, sk))
    # segment starts via searchsorted on the sorted expert ids
    seg_start = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(e)))(es)
    rank = jnp.arange(sk)[None, :] - jnp.take_along_axis(seg_start, es, axis=1)
    keep = rank < cap
    slot = jnp.where(keep, es * cap + rank, e * cap)  # overflow -> waste row

    # --- dispatch (scatter into per-sequence expert-major buffer) ---
    x_sel = jnp.take_along_axis(xq, ts[..., None], axis=1)  # (B, S*k, D)

    def scatter_row(slots, vals):
        return jnp.zeros((e * cap + 1, d), vals.dtype).at[slots].set(vals)

    xe = jax.vmap(scatter_row)(slot, x_sel)[:, : e * cap].reshape(b, e, cap, d)

    ep_mesh = _explicit_ep_mesh(b, e)
    if ep_mesh is not None:
        # Explicit shard_map EP schedule: batch-spread dispatch + two
        # all-to-alls, expert FFN purely local (W4A4 hooks applied
        # inside) — selected per dry-run cell from the recorded
        # collective bytes.  einsum cannot dispatch on PackedWeight, so
        # expert stacks materialize before entering the shard_map.
        from repro.dist.collectives import expert_ffn_ep

        dp = tuple(n for n in ep_mesh.axis_names if n != "model")
        ye = expert_ffn_ep(
            xe, dense_w(lp["w_gate"]), dense_w(lp["w_up"]),
            dense_w(lp["w_down"]), ep_mesh, data_axes=dp, spec=spec)
    else:
        xe = _pin(xe, "data", "model", None, None)  # the expert all-to-all

        # --- expert computation (batched over B and E; MXU einsums) ---
        # einsum cannot dispatch on PackedWeight: materialize expert stacks
        # explicitly (dequant-on-use; XLA fuses it into the contraction).
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, dense_w(lp["w_gate"]))) * jnp.einsum(
            "becd,edf->becf", xe, dense_w(lp["w_up"])
        )
        h = apply_r4(h, spec, "w_down")
        h = act_q(h, spec, site="w_down")
        ye = jnp.einsum("becf,efd->becd", h, dense_w(lp["w_down"]))  # (B, E, cap, D)
        ye = _pin(ye, "data", "model", None, None)

    # --- combine (gather back, weight, unsort-scatter-add per sequence) ---
    ybuf = jnp.concatenate(
        [ye.reshape(b, e * cap, d), jnp.zeros((b, 1, d), ye.dtype)], axis=1
    )
    y_assign = jnp.take_along_axis(ybuf, slot[..., None], axis=1)
    y_assign = y_assign * (gs * keep)[..., None]

    def combine_row(t_idx, vals):
        return jnp.zeros((s, d), vals.dtype).at[t_idx].add(vals)

    y = jax.vmap(combine_row)(ts, y_assign)  # (B, S, D)

    # --- shared experts (always-on dense path) ---
    if cfg.n_shared_experts:
        hs = jax.nn.silu(xq @ lp["shared_gate"]) * (xq @ lp["shared_up"])
        hs = apply_r4(hs, spec, "shared_down")
        hs = act_q(hs, spec, site="shared_down")
        y = y + hs @ lp["shared_down"]
    return y.reshape(b, s, d).astype(x.dtype)


def moe_aux_loss(logits_mean_prob: jax.Array, counts_frac: jax.Array) -> jax.Array:
    """Standard load-balancing auxiliary loss (Switch): E * <f, p>."""
    e = logits_mean_prob.shape[-1]
    return e * jnp.sum(logits_mean_prob * counts_frac)
