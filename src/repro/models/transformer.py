"""Unified decoder for the dense / MoE / MLA transformer families.

Covers 8 of the 10 assigned archs (all but xLSTM and Zamba2):
dense (smollm, deepseek-7b, qwen1.5), MoE (deepseek-moe, llama4-maverick),
MLA (minicpm3), VLM backbone (internvl2, patch-embed prefix stub), audio
(musicgen, K-codebook token stub).

Layers are stacked on a leading L axis and run under ``lax.scan``
(compile-time O(1) in depth).  Three entry points:

  * ``forward``  - full-sequence training forward (flash attention).
  * ``prefill``  - forward + populate a KV cache.
  * ``decode``   - one token against the cache (quantized KV supported).

Every GEMM input runs through the QuantizeSpec activation hook, and the
R4 online rotation sits before each down projection, so the same code
serves fp, W2A16, and W2A4 evaluation.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common, mla as mla_mod, moe as moe_mod
from repro.models.common import NOQUANT, QuantizeSpec, act_q, apply_r3, apply_rope, rmsnorm
from repro.quant.qtypes import QuantConfig


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    l, d, v = cfg.n_layers, cfg.d_model, cfg.vocab
    hd = cfg.hd
    keys = jax.random.split(key, 16)
    if cfg.modality == "audio":
        embed = common.embed_init(keys[0], (cfg.n_codebooks, v, d), dtype)
        lm_head = common.dense_init(keys[1], (cfg.n_codebooks, d, v), dtype)
    else:
        embed = common.embed_init(keys[0], (v, d), dtype)
        lm_head = common.dense_init(keys[1], (d, v), dtype)
    layers: Dict = {
        "attn_norm": jnp.ones((l, d), dtype),
        "mlp_norm": jnp.ones((l, d), dtype),
    }
    if cfg.family == "mla":
        layers.update(mla_mod.init_mla_params(keys[2], cfg, l, dtype))
    else:
        layers.update(
            {
                "wq": common.dense_init(keys[2], (l, d, cfg.n_heads * hd), dtype),
                "wk": common.dense_init(keys[3], (l, d, cfg.n_kv_heads * hd), dtype),
                "wv": common.dense_init(keys[4], (l, d, cfg.n_kv_heads * hd), dtype),
                "wo": common.dense_init(keys[5], (l, cfg.n_heads * hd, d), dtype),
            }
        )
        if cfg.qkv_bias:
            layers["bq"] = jnp.zeros((l, cfg.n_heads * hd), dtype)
            layers["bk"] = jnp.zeros((l, cfg.n_kv_heads * hd), dtype)
            layers["bv"] = jnp.zeros((l, cfg.n_kv_heads * hd), dtype)
    if cfg.family == "moe" and cfg.moe_every == 1:
        layers.update(moe_mod.init_moe_params(keys[6], cfg, l, dtype))
    elif cfg.family == "moe":
        # Interleaved (llama4-style): groups of (moe_every-1 dense + 1 MoE).
        every = cfg.moe_every
        assert l % every == 0, f"n_layers {l} % moe_every {every} != 0"
        g = l // every
        layers = jax.tree.map(lambda a: a.reshape(g, every, *a.shape[1:]), layers)
        layers["dense_mlp"] = {
            "w_gate": common.dense_init(keys[7], (g, every - 1, d, cfg.d_ff), dtype),
            "w_up": common.dense_init(keys[8], (g, every - 1, d, cfg.d_ff), dtype),
            "w_down": common.dense_init(keys[9], (g, every - 1, cfg.d_ff, d), dtype),
        }
        layers["moe_mlp"] = moe_mod.init_moe_params(keys[6], cfg, g, dtype)
    else:
        layers.update(
            {
                "w_gate": common.dense_init(keys[7], (l, d, cfg.d_ff), dtype),
                "w_up": common.dense_init(keys[8], (l, d, cfg.d_ff), dtype),
                "w_down": common.dense_init(keys[9], (l, cfg.d_ff, d), dtype),
            }
        )
    params = {
        "embed": embed,
        "layers": layers,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": lm_head,
    }
    if cfg.modality == "vlm":
        # Identity projection for the (precomputed) patch embeddings; exists
        # so R1 rotation has a weight to fuse into on the vision prefix.
        params["patch_proj"] = jnp.eye(d, dtype=dtype)
    return params


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_inputs(cfg: ModelConfig, params: Dict, batch: Dict) -> jax.Array:
    """-> h (B, S_total, D)."""
    if cfg.modality == "audio":
        toks = batch["tokens"]  # (B, S, K)
        parts = [jnp.take(params["embed"][k], toks[..., k], axis=0)
                 for k in range(cfg.n_codebooks)]
        h = sum(parts)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)  # (B, S, D)
    if cfg.modality == "vlm" and "patch_embeds" in batch:
        # Vision prefix (absent on decode steps, which extend the text).
        pe = batch["patch_embeds"] @ params["patch_proj"]
        h = jnp.concatenate([pe.astype(h.dtype), h], axis=1)
    return h


def lm_logits(cfg: ModelConfig, params: Dict, h: jax.Array, spec: QuantizeSpec) -> jax.Array:
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    h = act_q(h, spec, site="lm_head")
    if cfg.modality == "audio":
        return jnp.einsum("bsd,kdv->bskv", h, params["lm_head"])
    return h @ params["lm_head"]


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------


def _qkv(cfg: ModelConfig, lp: Dict, x: jax.Array, positions, spec: QuantizeSpec):
    b, s, _ = x.shape
    hd = cfg.hd
    xq = act_q(x, spec, site="wq")
    q = xq @ lp["wq"]
    k = xq @ lp["wk"]
    v = xq @ lp["wv"]
    if cfg.qkv_bias:
        q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(b, s, cfg.n_heads, hd)
    k = k.reshape(b, s, cfg.n_kv_heads, hd)
    v = v.reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q, k = apply_r3(q, k, spec)
    return q, k, v


def attn_block_train(cfg, lp, h, positions, spec) -> jax.Array:
    x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
    if cfg.family == "mla":
        out, _, _ = mla_mod.mla_prefill_attention(lp, x, cfg, positions, spec)
        return h + out
    q, k, v = _qkv(cfg, lp, x, positions, spec)
    attn = common.flash_attention(q, k, v, causal=True, window=cfg.sliding_window)
    b, s = x.shape[:2]
    attn = act_q(attn.reshape(b, s, cfg.n_heads * cfg.hd), spec, site="wo")
    return h + attn @ lp["wo"]


def mlp_block(cfg, lp, h, spec, kind: Optional[str] = None) -> jax.Array:
    kind = kind or ("moe" if cfg.family == "moe" else "dense")
    x = rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
    if kind == "moe":
        return h + moe_mod.moe_apply(lp, x, cfg, spec)
    return h + common.swiglu(x, lp["w_gate"], lp["w_up"], lp["w_down"], spec)


def _interleaved(cfg) -> bool:
    return cfg.family == "moe" and cfg.moe_every > 1


def _group_slices(cfg, layers_grp):
    """Per-group param dicts: [(lp, kind), ...] of length moe_every.

    layers_grp: one group's slice - attn keys (every, ...), dense_mlp
    (every-1, ...), moe_mlp (flat).  Static python unroll (moe_every <= 4).
    """
    every = cfg.moe_every
    attn_keys = [k for k in layers_grp if k not in ("dense_mlp", "moe_mlp")]
    out = []
    for j in range(every - 1):
        lp = {k: layers_grp[k][j] for k in attn_keys}
        lp.update({k: v[j] for k, v in layers_grp["dense_mlp"].items()})
        out.append((lp, "dense"))
    lp = {k: layers_grp[k][every - 1] for k in attn_keys}
    lp.update(layers_grp["moe_mlp"])
    out.append((lp, "moe"))
    return out


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Dict,
    batch: Dict,
    spec: QuantizeSpec = NOQUANT,
    *,
    remat: bool = True,
    capture: bool = False,
    return_hidden: bool = False,
) -> jax.Array | Tuple[jax.Array, Dict]:
    """Full-sequence logits. With capture=True also returns per-layer
    post-norm activations (calibration inputs for GPTQ Hessians).
    return_hidden=True returns the final-norm hidden states instead of
    logits (the chunked-loss path never materialises full f32 logits)."""
    h = embed_inputs(cfg, params, batch)
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]

    if _interleaved(cfg):
        assert not capture, "calibration capture unsupported for interleaved MoE"

        def group_fn(h, grp):
            for lp, kind in _group_slices(cfg, grp):
                h = attn_block_train(cfg, lp, h, positions, spec)
                h = mlp_block(cfg, lp, h, spec, kind=kind)
            return h, None

        f = group_fn
        if remat:
            f = jax.checkpoint(group_fn, policy=jax.checkpoint_policies.nothing_saveable)
        h, caps = jax.lax.scan(f, h, params["layers"])
        if return_hidden:
            return act_q(rmsnorm(h, params["final_norm"], cfg.norm_eps),
                         spec, site="lm_head")
        return lm_logits(cfg, params, h, spec)

    def layer_fn(h, lp):
        h = attn_block_train(cfg, lp, h, positions, spec)
        h = mlp_block(cfg, lp, h, spec)
        caps = None
        if capture:
            caps = {
                "attn_in": rmsnorm(h, lp["attn_norm"], cfg.norm_eps),
                "mlp_in": rmsnorm(h, lp["mlp_norm"], cfg.norm_eps),
            }
        return h, caps

    f = layer_fn
    if remat and not capture:
        f = jax.checkpoint(layer_fn, policy=jax.checkpoint_policies.nothing_saveable)
    h, caps = jax.lax.scan(f, h, params["layers"])
    if return_hidden:
        return act_q(rmsnorm(h, params["final_norm"], cfg.norm_eps),
                     spec, site="lm_head")
    logits = lm_logits(cfg, params, h, spec)
    if capture:
        return logits, caps
    return logits


# ---------------------------------------------------------------------------
# KV cache (stacked over layers; quantized storage supported)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, spec: QuantizeSpec,
               dtype=jnp.bfloat16) -> Dict:
    l = cfg.n_layers
    kvq = spec.kv_bits < 16
    code_dtype = jnp.uint8 if kvq else dtype
    if cfg.family == "mla":
        rank, rope = cfg.kv_lora_rank, cfg.qk_rope_dim
        cache = {
            "ckv": jnp.zeros((l, batch, max_seq, rank), code_dtype),
            "krope": jnp.zeros((l, batch, max_seq, rope), dtype),  # rope kept hi-prec
        }
        if kvq:
            cache["ckv_scale"] = jnp.zeros((l, batch, max_seq), jnp.float32)
            cache["ckv_zero"] = jnp.zeros((l, batch, max_seq), jnp.float32)
    else:
        kv, hd = cfg.n_kv_heads, cfg.hd
        cache = {
            "k": jnp.zeros((l, batch, max_seq, kv, hd), code_dtype),
            "v": jnp.zeros((l, batch, max_seq, kv, hd), code_dtype),
        }
        if kvq:
            cache.update(
                k_scale=jnp.zeros((l, batch, max_seq, kv), jnp.float32),
                k_zero=jnp.zeros((l, batch, max_seq, kv), jnp.float32),
                v_scale=jnp.zeros((l, batch, max_seq, kv), jnp.float32),
                v_zero=jnp.zeros((l, batch, max_seq, kv), jnp.float32),
            )
    cache["length"] = jnp.zeros((), jnp.int32)
    return cache


def _kv_cfg(spec: QuantizeSpec) -> QuantConfig:
    return common.kv_quant_cfg(spec)


# One asymmetric group per token vector; shared with mla.py through
# common so every cache writer/reader agrees bit-for-bit (the invariant
# the prefix-sharing KV cache depends on).
_quant_tokens = common.kv_quant_tokens
_dequant_tokens = common.kv_dequant_tokens


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def _prefill_std_layer(cfg, lp, lc, h, positions, spec, kvq, b, s, start=0):
    """Standard-attention prefill layer body (shared by both layouts).

    Attention scores K/V at *stored* precision (`common.kv_roundtrip`):
    the values a later decode step — or a prefix-cache continuation —
    will read back out of the cache.  With ``start > 0`` the query covers
    only the tail ``[start, start + s)``; the prefix K/V is read straight
    from ``lc`` (dequantized), so a continuation over cached blocks is
    bit-identical to a full prefill of the same tokens.
    """
    x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
    q, k, v = _qkv(cfg, lp, x, positions, spec)
    if kvq:
        kc, ks_, kz = _quant_tokens(k, spec)
        vc, vs_, vz = _quant_tokens(v, spec)
        k_at = _dequant_tokens(kc, ks_, kz, h.dtype)
        v_at = _dequant_tokens(vc, vs_, vz, h.dtype)
    else:
        k_at = common.kv_roundtrip(k, spec, lc["k"].dtype)
        v_at = common.kv_roundtrip(v, spec, lc["v"].dtype)
    if start:
        if kvq:
            kp = _dequant_tokens(lc["k"][:, :start], lc["k_scale"][:, :start],
                                 lc["k_zero"][:, :start], h.dtype)
            vp = _dequant_tokens(lc["v"][:, :start], lc["v_scale"][:, :start],
                                 lc["v_zero"][:, :start], h.dtype)
        else:
            kp = lc["k"][:, :start].astype(k.dtype)
            vp = lc["v"][:, :start].astype(v.dtype)
        k_at = jnp.concatenate([kp, k_at], axis=1)
        v_at = jnp.concatenate([vp, v_at], axis=1)
    # flash_attention aligns q to the end of k: offset causal mask covers
    # the continuation shape (Sq == s, Skv == start + s) natively.
    attn = common.flash_attention(q, k_at, v_at, causal=True, window=cfg.sliding_window)
    attn = act_q(attn.reshape(b, s, cfg.n_heads * cfg.hd), spec, site="wo")
    h = h + attn @ lp["wo"]
    if kvq:
        lc = dict(lc, k=_store(lc["k"], kc, start), v=_store(lc["v"], vc, start),
                  k_scale=_store(lc["k_scale"], ks_, start), k_zero=_store(lc["k_zero"], kz, start),
                  v_scale=_store(lc["v_scale"], vs_, start), v_zero=_store(lc["v_zero"], vz, start))
    else:
        lc = dict(lc, k=_store(lc["k"], k.astype(lc["k"].dtype), start),
                  v=_store(lc["v"], v.astype(lc["v"].dtype), start))
    return h, lc


def _prefill_mla_layer(cfg, lp, lc, h, positions, spec, kvq, s, start=0):
    """MLA prefill layer body (direct-form attention, latent cache)."""
    x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
    prefix = None
    if start:
        if kvq:
            ckv_p = _dequant_tokens(lc["ckv"][:, :start], lc["ckv_scale"][:, :start],
                                    lc["ckv_zero"][:, :start], h.dtype)
        else:
            ckv_p = lc["ckv"][:, :start].astype(h.dtype)
        prefix = (ckv_p, lc["krope"][:, :start].astype(h.dtype))
    out, ckv, krope = mla_mod.mla_prefill_attention(
        lp, x, cfg, positions, spec, stored_precision=True,
        store_dtype=lc["krope"].dtype, prefix=prefix)
    h = h + out
    if kvq:
        codes, scale, zero = _quant_tokens(ckv, spec)
        lc = dict(lc, ckv=_store(lc["ckv"], codes, start),
                  ckv_scale=_store(lc["ckv_scale"], scale, start),
                  ckv_zero=_store(lc["ckv_zero"], zero, start),
                  krope=_store(lc["krope"], krope.astype(lc["krope"].dtype), start))
    else:
        lc = dict(lc, ckv=_store(lc["ckv"], ckv.astype(lc["ckv"].dtype), start),
                  krope=_store(lc["krope"], krope.astype(lc["krope"].dtype), start))
    return h, lc


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict,
            spec: QuantizeSpec = NOQUANT, *,
            true_length: Optional[jax.Array] = None,
            start: int = 0) -> Tuple[jax.Array, Dict]:
    """Run the full prompt, returning last-position logits + filled cache.

    ``true_length`` enables right-padded prompts (prompt-length
    bucketing): the batch may be padded past the real prompt, logits are
    taken at position ``true_length - 1`` (the *true* last token — a
    padded prefill would otherwise sample the first generated token from
    a padding position) and the cache length is set to ``true_length`` so
    decode masks the padded garbage KV.  Causal attention means padding
    can never influence positions before it, so the returned logits are
    identical to an exact-length prefill.  (Per-sequence recurrent-state
    families — xLSTM/Zamba — cannot use this: their state integrates
    every scanned token; the engine gates on family.)

    ``start`` (static) enables *continuation* prefill over a cache whose
    first ``start`` positions are already populated (the prefix-sharing
    serving path): the batch carries only the tail tokens, attention for
    each tail position runs over the cached prefix K/V plus the fresh
    tail, and the tail is stored at ``[start, start + s)``.  Because
    prefill attention always scores at stored precision, the result is
    bit-identical to a full prefill of prefix + tail.  Incompatible with
    ``true_length`` (the engine never buckets shared prefills).
    """
    assert not (start and true_length is not None), \
        "continuation prefill does not compose with prompt bucketing"
    h = embed_inputs(cfg, params, batch)
    b, s, _ = h.shape
    positions = start + jnp.arange(s)[None, :]
    kvq = spec.kv_bits < 16
    layer_caches = {k: v for k, v in cache.items() if k != "length"}

    if _interleaved(cfg):
        every = cfg.moe_every
        g = cfg.n_layers // every
        grp_caches = jax.tree.map(
            lambda a: a.reshape(g, every, *a.shape[1:]), layer_caches
        )

        def group_fn(h, xs):
            grp, gc = xs
            new_slices = []
            for j, (lp, kind) in enumerate(_group_slices(cfg, grp)):
                lc = jax.tree.map(lambda a: a[j], gc)
                h, lc = _prefill_std_layer(cfg, lp, lc, h, positions, spec, kvq, b, s,
                                           start=start)
                h = mlp_block(cfg, lp, h, spec, kind=kind)
                new_slices.append(lc)
            gc2 = jax.tree.map(lambda *xs2: jnp.stack(xs2), *new_slices)
            return h, gc2

        h, new_grp = jax.lax.scan(group_fn, h, (params["layers"], grp_caches))
        new_caches = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), new_grp)
        logits = lm_logits(cfg, params, _last_positions(h, true_length), spec)
        new_caches["length"] = _fill_length(start + s, true_length)
        return logits, new_caches

    def layer_fn(h, xs):
        lp, lc = xs
        if cfg.family == "mla":
            h, lc = _prefill_mla_layer(cfg, lp, lc, h, positions, spec, kvq, s,
                                       start=start)
        else:
            h, lc = _prefill_std_layer(cfg, lp, lc, h, positions, spec, kvq, b, s,
                                       start=start)
        h = mlp_block(cfg, lp, h, spec)
        return h, lc

    h, new_caches = jax.lax.scan(layer_fn, h, (params["layers"], layer_caches))
    logits = lm_logits(cfg, params, _last_positions(h, true_length), spec)
    new_caches["length"] = _fill_length(start + s, true_length)
    return logits, new_caches


def _last_positions(h: jax.Array, true_length) -> jax.Array:
    """(B, S, D) -> (B, 1, D) at the true last token (S-1 when exact)."""
    if true_length is None:
        return h[:, -1:]
    idx = jnp.asarray(true_length, jnp.int32) - 1
    return jax.lax.dynamic_slice_in_dim(h, idx, 1, axis=1)


def _fill_length(s: int, true_length) -> jax.Array:
    if true_length is None:
        return jnp.asarray(s, jnp.int32)
    return jnp.asarray(true_length, jnp.int32)


def _store(buf, val, start=0):
    """Write val along the sequence axis (axis 1) starting at ``start``."""
    idx = (0, start) + (0,) * (buf.ndim - 2)
    return jax.lax.dynamic_update_slice(buf, val, idx)


def decode(cfg: ModelConfig, params: Dict, tokens: jax.Array, cache: Dict,
           spec: QuantizeSpec = NOQUANT, extra: Optional[Dict] = None
           ) -> Tuple[jax.Array, Dict]:
    """One decode step. tokens: (B,) int32 (audio: (B, K)). Returns
    (logits, cache) with the new token's KV appended.

    The stacked cache rides the scan *carry* and is updated with one
    (layer, position)-indexed dynamic_update_slice per layer - the
    in-place pattern XLA aliases, so decode holds exactly one cache copy
    (scan xs/ys caches would double-buffer the whole thing).
    """
    length = cache["length"]
    if cfg.modality == "audio":
        batch = {"tokens": tokens[:, None, :]}
    else:
        batch = {"tokens": tokens[:, None]}
    h = embed_inputs(cfg, params, batch)  # (B, 1, D)
    b = h.shape[0]
    position = length
    kvq = spec.kv_bits < 16
    caches0 = {k: v for k, v in cache.items() if k != "length"}

    def _write(buf, val, i, *trail):
        idx = (i,) + trail + (0,) * (buf.ndim - 1 - len(trail))
        return jax.lax.dynamic_update_slice(buf, val[None], idx)

    def _layer(caches, i):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), caches
        )

    def _std_layer(lp, caches, i, h):
        x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        positions = jnp.broadcast_to(position, (b, 1))
        q, k, v = _qkv(cfg, lp, x, positions, spec)
        if kvq:
            kc, ks_, kz = _quant_tokens(k, spec)
            vc, vs_, vz = _quant_tokens(v, spec)
            caches = dict(
                caches,
                k=jax.lax.dynamic_update_slice(caches["k"], kc[None], (i, 0, position, 0, 0)),
                v=jax.lax.dynamic_update_slice(caches["v"], vc[None], (i, 0, position, 0, 0)),
                k_scale=jax.lax.dynamic_update_slice(caches["k_scale"], ks_[None], (i, 0, position, 0)),
                k_zero=jax.lax.dynamic_update_slice(caches["k_zero"], kz[None], (i, 0, position, 0)),
                v_scale=jax.lax.dynamic_update_slice(caches["v_scale"], vs_[None], (i, 0, position, 0)),
                v_zero=jax.lax.dynamic_update_slice(caches["v_zero"], vz[None], (i, 0, position, 0)),
            )
            lc = _layer(caches, i)
            k_all = _dequant_tokens(lc["k"], lc["k_scale"], lc["k_zero"], h.dtype)
            v_all = _dequant_tokens(lc["v"], lc["v_scale"], lc["v_zero"], h.dtype)
        else:
            caches = dict(
                caches,
                k=jax.lax.dynamic_update_slice(
                    caches["k"], k.astype(caches["k"].dtype)[None], (i, 0, position, 0, 0)),
                v=jax.lax.dynamic_update_slice(
                    caches["v"], v.astype(caches["v"].dtype)[None], (i, 0, position, 0, 0)),
            )
            lc = _layer(caches, i)
            k_all, v_all = lc["k"], lc["v"]
        attn = common.decode_attention(q, k_all, v_all, length + 1, window=cfg.sliding_window)
        attn = act_q(attn.reshape(b, 1, cfg.n_heads * cfg.hd), spec,
                     site="wo")
        return h + attn @ lp["wo"], caches

    def _mla_layer(lp, caches, i, h):
        x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        ckv_new, krope_new = mla_mod._project_latent(
            lp, x, cfg, jnp.broadcast_to(position, (b, 1)), spec
        )
        if kvq:
            codes, scale, zero = _quant_tokens(ckv_new, spec)
            caches = dict(
                caches,
                ckv=jax.lax.dynamic_update_slice(caches["ckv"], codes[None], (i, 0, position, 0)),
                ckv_scale=jax.lax.dynamic_update_slice(caches["ckv_scale"], scale[None], (i, 0, position)),
                ckv_zero=jax.lax.dynamic_update_slice(caches["ckv_zero"], zero[None], (i, 0, position)),
                krope=jax.lax.dynamic_update_slice(
                    caches["krope"], krope_new.astype(caches["krope"].dtype)[None], (i, 0, position, 0)),
            )
            lc = _layer(caches, i)
            ckv_all = _dequant_tokens(lc["ckv"], lc["ckv_scale"], lc["ckv_zero"], h.dtype)
            krope_all = lc["krope"]
        else:
            caches = dict(
                caches,
                ckv=jax.lax.dynamic_update_slice(
                    caches["ckv"], ckv_new.astype(caches["ckv"].dtype)[None], (i, 0, position, 0)),
                krope=jax.lax.dynamic_update_slice(
                    caches["krope"], krope_new.astype(caches["krope"].dtype)[None], (i, 0, position, 0)),
            )
            lc = _layer(caches, i)
            ckv_all, krope_all = lc["ckv"], lc["krope"]
        out = mla_mod.mla_decode_attention(
            lp, x, cfg, position, ckv_all, krope_all, length + 1, spec
        )
        return h + out, caches

    if _interleaved(cfg):
        every = cfg.moe_every

        def group_fn(carry, grp):
            h, caches, g = carry
            for j, (lp, kind) in enumerate(_group_slices(cfg, grp)):
                i = g * every + j
                h, caches = _std_layer(lp, caches, i, h)
                h = mlp_block(cfg, lp, h, spec, kind=kind)
            return (h, caches, g + 1), None

        (h, caches, _), _ = jax.lax.scan(
            group_fn, (h, caches0, jnp.asarray(0, jnp.int32)), params["layers"]
        )
    else:
        def layer_fn(carry, lp):
            h, caches, i = carry
            if cfg.family == "mla":
                h, caches = _mla_layer(lp, caches, i, h)
            else:
                h, caches = _std_layer(lp, caches, i, h)
            h = mlp_block(cfg, lp, h, spec)
            return (h, caches, i + 1), None

        (h, caches, _), _ = jax.lax.scan(
            layer_fn, (h, caches0, jnp.asarray(0, jnp.int32)), params["layers"]
        )
    logits = lm_logits(cfg, params, h, spec)
    caches["length"] = length + 1
    return logits[:, 0], caches


def decode_chunk(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 cache: Dict, spec: QuantizeSpec = NOQUANT
                 ) -> Tuple[jax.Array, Dict]:
    """Multi-token verify step (speculative decoding).

    tokens: (B, K) int32 — K consecutive pending tokens (the current
    pending token followed by K-1 draft continuations).  Writes the
    chunk's K/V at positions ``[length, length + K)`` — the per-token
    cache codec makes the writes bitwise identical to K sequential
    :func:`decode` steps — and returns *all* chunk logits (B, K, V):
    ``logits[:, j]`` scores the next token after consuming
    ``tokens[:, :j + 1]``, exactly what the (j+1)-th sequential decode
    step would return.  Cache length advances by K.

    Mirrors :func:`decode` body-for-body; only the query axis widens and
    the attention mask becomes chunk-causal
    (:func:`common.decode_chunk_attention`).
    """
    assert cfg.modality != "audio", \
        "spec-decode verify is undefined for codebook token groups"
    length = cache["length"]
    b, kq = tokens.shape
    h = embed_inputs(cfg, params, {"tokens": tokens})  # (B, K, D)
    position = length  # write start of the chunk slab
    positions = jnp.broadcast_to(length + jnp.arange(kq)[None, :], (b, kq))
    kvq = spec.kv_bits < 16
    caches0 = {k: v for k, v in cache.items() if k != "length"}

    def _layer(caches, i):
        return jax.tree.map(
            lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False), caches
        )

    def _std_layer(lp, caches, i, h):
        x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp, x, positions, spec)  # (B,K,H|KV,hd)
        if kvq:
            kc, ks_, kz = _quant_tokens(k, spec)
            vc, vs_, vz = _quant_tokens(v, spec)
            caches = dict(
                caches,
                k=jax.lax.dynamic_update_slice(caches["k"], kc[None], (i, 0, position, 0, 0)),
                v=jax.lax.dynamic_update_slice(caches["v"], vc[None], (i, 0, position, 0, 0)),
                k_scale=jax.lax.dynamic_update_slice(caches["k_scale"], ks_[None], (i, 0, position, 0)),
                k_zero=jax.lax.dynamic_update_slice(caches["k_zero"], kz[None], (i, 0, position, 0)),
                v_scale=jax.lax.dynamic_update_slice(caches["v_scale"], vs_[None], (i, 0, position, 0)),
                v_zero=jax.lax.dynamic_update_slice(caches["v_zero"], vz[None], (i, 0, position, 0)),
            )
            lc = _layer(caches, i)
            k_all = _dequant_tokens(lc["k"], lc["k_scale"], lc["k_zero"], h.dtype)
            v_all = _dequant_tokens(lc["v"], lc["v_scale"], lc["v_zero"], h.dtype)
        else:
            caches = dict(
                caches,
                k=jax.lax.dynamic_update_slice(
                    caches["k"], k.astype(caches["k"].dtype)[None], (i, 0, position, 0, 0)),
                v=jax.lax.dynamic_update_slice(
                    caches["v"], v.astype(caches["v"].dtype)[None], (i, 0, position, 0, 0)),
            )
            lc = _layer(caches, i)
            k_all, v_all = lc["k"], lc["v"]
        attn = common.decode_chunk_attention(q, k_all, v_all, length,
                                             window=cfg.sliding_window)
        attn = act_q(attn.reshape(b, kq, cfg.n_heads * cfg.hd), spec,
                     site="wo")
        return h + attn @ lp["wo"], caches

    def _mla_layer(lp, caches, i, h):
        x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        ckv_new, krope_new = mla_mod._project_latent(
            lp, x, cfg, positions, spec
        )
        if kvq:
            codes, scale, zero = _quant_tokens(ckv_new, spec)
            caches = dict(
                caches,
                ckv=jax.lax.dynamic_update_slice(caches["ckv"], codes[None], (i, 0, position, 0)),
                ckv_scale=jax.lax.dynamic_update_slice(caches["ckv_scale"], scale[None], (i, 0, position)),
                ckv_zero=jax.lax.dynamic_update_slice(caches["ckv_zero"], zero[None], (i, 0, position)),
                krope=jax.lax.dynamic_update_slice(
                    caches["krope"], krope_new.astype(caches["krope"].dtype)[None], (i, 0, position, 0)),
            )
            lc = _layer(caches, i)
            ckv_all = _dequant_tokens(lc["ckv"], lc["ckv_scale"], lc["ckv_zero"], h.dtype)
            krope_all = lc["krope"]
        else:
            caches = dict(
                caches,
                ckv=jax.lax.dynamic_update_slice(
                    caches["ckv"], ckv_new.astype(caches["ckv"].dtype)[None], (i, 0, position, 0)),
                krope=jax.lax.dynamic_update_slice(
                    caches["krope"], krope_new.astype(caches["krope"].dtype)[None], (i, 0, position, 0)),
            )
            lc = _layer(caches, i)
            ckv_all, krope_all = lc["ckv"], lc["krope"]
        out = mla_mod.mla_decode_chunk_attention(
            lp, x, cfg, positions, ckv_all, krope_all, length, spec
        )
        return h + out, caches

    if _interleaved(cfg):
        every = cfg.moe_every

        def group_fn(carry, grp):
            h, caches, g = carry
            for j, (lp, kind) in enumerate(_group_slices(cfg, grp)):
                i = g * every + j
                h, caches = _std_layer(lp, caches, i, h)
                h = mlp_block(cfg, lp, h, spec, kind=kind)
            return (h, caches, g + 1), None

        (h, caches, _), _ = jax.lax.scan(
            group_fn, (h, caches0, jnp.asarray(0, jnp.int32)), params["layers"]
        )
    else:
        def layer_fn(carry, lp):
            h, caches, i = carry
            if cfg.family == "mla":
                h, caches = _mla_layer(lp, caches, i, h)
            else:
                h, caches = _std_layer(lp, caches, i, h)
            h = mlp_block(cfg, lp, h, spec)
            return (h, caches, i + 1), None

        (h, caches, _), _ = jax.lax.scan(
            layer_fn, (h, caches0, jnp.asarray(0, jnp.int32)), params["layers"]
        )
    logits = lm_logits(cfg, params, h, spec)
    caches["length"] = length + kq
    return logits, caches


def decode_paged(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 paged: Dict, state: Dict, tables: jax.Array,
                 lengths: jax.Array, spec: QuantizeSpec = NOQUANT
                 ) -> Tuple[jax.Array, Dict, Dict]:
    """One decode step straight over block-paged pool storage (fused path).

    The serving pool's gather->vmapped-decode->scatter step copies every
    slot's whole cache view twice per tick; this variant never builds a
    view: per layer, attention runs through the paged Pallas kernel
    (:func:`repro.models.common.paged_decode_attention`) which walks
    ``tables`` directly, dequantizes quantized KV blocks in place, and
    appends the new token to its block inside the same kernel.

    ``tokens``: (S,) int32 (audio: (S, K)); ``paged``: pool block storage
    keyed by cache-leaf name, stacked over layers (e.g. ``k``:
    ``(L, NB, T, KV, hd)``); ``state``: per-slot non-paged leaves (empty
    for attention-cache families, returned unchanged); ``lengths``: (S,)
    per-slot fill — RoPE positions and masks are per-slot, unlike
    :func:`decode`'s shared scalar ``length``.

    Returns ``(logits, paged, state)`` with the new token written at
    ``lengths[s]`` in each slot's block chain.
    """
    if cfg.modality == "audio":
        batch = {"tokens": tokens[:, None, :]}
    else:
        batch = {"tokens": tokens[:, None]}
    h = embed_inputs(cfg, params, batch)  # (S, 1, D)
    b = h.shape[0]
    positions = lengths[:, None]  # (S, 1) per-slot RoPE positions
    kvq = spec.kv_bits < 16

    def _std_layer(lp, pg, i, h):
        x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        q, k, v = _qkv(cfg, lp, x, positions, spec)  # (S,1,H,hd)/(S,1,KV,hd)
        if kvq:
            kc, ks_, kz = _quant_tokens(k, spec)
            vc, vs_, vz = _quant_tokens(v, spec)
            k_new = (kc[:, 0], ks_[:, 0], kz[:, 0])
            v_new = (vc[:, 0], vs_[:, 0], vz[:, 0])
            k_pages = (pg["k"], pg["k_scale"], pg["k_zero"])
            v_pages = (pg["v"], pg["v_scale"], pg["v_zero"])
            order = ("k", "k_scale", "k_zero", "v", "v_scale", "v_zero")
        else:
            k_new, v_new = (k[:, 0],), (v[:, 0],)
            k_pages, v_pages = (pg["k"],), (pg["v"],)
            order = ("k", "v")
        attn, new_pages = common.paged_decode_attention(
            q, k_pages, v_pages, None, k_new, v_new, None,
            tables, lengths, i, window=cfg.sliding_window)
        pg = dict(pg)
        pg.update(zip(order, new_pages))
        attn = act_q(attn.astype(h.dtype).reshape(b, 1, cfg.n_heads * cfg.hd),
                     spec, site="wo")
        return h + attn @ lp["wo"], pg

    def _mla_layer(lp, pg, i, h):
        x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
        ckv_new, krope_new = mla_mod._project_latent(lp, x, cfg, positions,
                                                     spec)
        if kvq:
            codes, scale, zero = _quant_tokens(ckv_new, spec)
            k1_new = (codes[:, 0], scale[:, 0], zero[:, 0])
            k1_pages = (pg["ckv"], pg["ckv_scale"], pg["ckv_zero"])
            order = ("ckv", "ckv_scale", "ckv_zero", "krope")
        else:
            k1_new = (ckv_new[:, 0],)
            k1_pages = (pg["ckv"],)
            order = ("ckv", "krope")
        out, new_pages = mla_mod.mla_paged_decode_attention(
            lp, x, cfg, positions, k1_pages, pg["krope"], k1_new,
            krope_new[:, 0], tables, lengths, i, spec)
        pg = dict(pg)
        pg.update(zip(order, new_pages))
        return h + out, pg

    if _interleaved(cfg):
        every = cfg.moe_every

        def group_fn(carry, grp):
            h, pg, g = carry
            for j, (lp, kind) in enumerate(_group_slices(cfg, grp)):
                h, pg = _std_layer(lp, pg, g * every + j, h)
                h = mlp_block(cfg, lp, h, spec, kind=kind)
            return (h, pg, g + 1), None

        (h, pg, _), _ = jax.lax.scan(
            group_fn, (h, paged, jnp.asarray(0, jnp.int32)), params["layers"])
    else:
        def layer_fn(carry, lp):
            h, pg, i = carry
            if cfg.family == "mla":
                h, pg = _mla_layer(lp, pg, i, h)
            else:
                h, pg = _std_layer(lp, pg, i, h)
            h = mlp_block(cfg, lp, h, spec)
            return (h, pg, i + 1), None

        (h, pg, _), _ = jax.lax.scan(
            layer_fn, (h, paged, jnp.asarray(0, jnp.int32)), params["layers"])
    logits = lm_logits(cfg, params, h, spec)
    return logits[:, 0], pg, state
