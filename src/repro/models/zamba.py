"""Zamba2-style hybrid decoder (assigned arch ``zamba2-1.2b``).

Backbone: Mamba2 (SSD) layers; one *shared* full-attention transformer
block (single weight copy) applied after every ``cfg.attn_every`` Mamba
layers, as in the Zamba papers.  Decode state = per-layer SSD state +
conv tail + one KV cache per shared-block application site, so 500k-token
decode is O(1) in memory for the backbone and tiny for the shared sites.

Structured as scan-over-groups of (attn_every Mamba + shared block) with
a trailing scan for the remainder layers; the shared block's weights are
closed over (same copy every application - that is the point of Zamba).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import NOQUANT, QuantizeSpec, act_q, apply_rope, rmsnorm
from repro.models.ssm_common import (
    causal_conv1d,
    chunked_linear_attention,
    linear_attention_step,
)


def _layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_groups, n_trailing)."""
    every = cfg.attn_every or (cfg.n_layers + 1)
    return cfg.n_layers // every, cfg.n_layers % every


def _di(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    d, v = cfg.d_model, cfg.vocab
    di = _di(cfg)
    nh = cfg.ssm_heads
    st = cfg.ssm_state
    l = cfg.n_layers
    ks = jax.random.split(key, 14)
    conv_ch = di + 2 * st
    mamba = {
        "norm": jnp.ones((l, d), dtype),
        "in_proj": common.dense_init(ks[0], (l, d, 2 * di + 2 * st + nh), dtype),
        "conv_w": common.dense_init(ks[1], (l, cfg.conv_width, conv_ch), dtype, scale=0.5),
        "A_log": jnp.zeros((l, nh), dtype),
        "D_skip": jnp.ones((l, nh), dtype),
        "dt_bias": jnp.zeros((l, nh), dtype),
        "out_proj": common.dense_init(ks[2], (l, di, d), dtype),
    }
    hd = cfg.hd
    shared = {
        "attn_norm": jnp.ones((d,), dtype),
        "wq": common.dense_init(ks[3], (d, cfg.n_heads * hd), dtype),
        "wk": common.dense_init(ks[4], (d, cfg.n_kv_heads * hd), dtype),
        "wv": common.dense_init(ks[5], (d, cfg.n_kv_heads * hd), dtype),
        "wo": common.dense_init(ks[6], (cfg.n_heads * hd, d), dtype),
        "mlp_norm": jnp.ones((d,), dtype),
        "w_gate": common.dense_init(ks[7], (d, cfg.d_ff), dtype),
        "w_up": common.dense_init(ks[8], (d, cfg.d_ff), dtype),
        "w_down": common.dense_init(ks[9], (cfg.d_ff, d), dtype),
    }
    return {
        "embed": common.embed_init(ks[10], (v, d), dtype),
        "mamba": mamba,
        "shared": shared,
        "final_norm": jnp.ones((d,), dtype),
        "lm_head": common.dense_init(ks[11], (d, v), dtype),
    }


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------


def _ssd_inputs(cfg, lp, x, spec, conv_state=None):
    """Project + conv; returns (z, q, k, v, log_f, new_conv_state)."""
    b, s, d = x.shape
    di = _di(cfg)
    nh, st = cfg.ssm_heads, cfg.ssm_state
    dh = di // nh
    xq = act_q(x, spec, site="in_proj")
    proj = xq @ lp["in_proj"]  # (B,S,2di+2st+nh)
    z, xin, bmat, cmat, dt_raw = jnp.split(
        proj, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], axis=-1
    )
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    conv_out, conv_state = causal_conv1d(conv_in, lp["conv_w"], state=conv_state)
    xin, bmat, cmat = jnp.split(conv_out, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    log_f = -dt * jnp.exp(lp["A_log"].astype(jnp.float32))  # (B,S,nh) <= 0
    xh = xin.reshape(b, s, nh, dh)
    v = xh * dt[..., None].astype(xh.dtype)  # discretized input
    q = jnp.broadcast_to(cmat[:, :, None, :], (b, s, nh, st))  # C (shared grp)
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, nh, st))  # B
    return z, xh, q, k, v, log_f, conv_state


def mamba_block(cfg, lp, hres, spec, ssm_state=None, conv_state=None, *, chunk=128):
    x = rmsnorm(hres, lp["norm"], cfg.norm_eps)
    b, s, d = x.shape
    di = _di(cfg)
    z, xh, q, k, v, log_f, conv_state = _ssd_inputs(cfg, lp, x, spec, conv_state)
    log_i = jnp.zeros_like(log_f)
    y, (ssm_s, ssm_n) = chunked_linear_attention(
        q, k, v, log_f, log_i, chunk=chunk, normalize=False,
        state=ssm_state,
    )
    y = y + lp["D_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = (y.reshape(b, s, di) * jax.nn.silu(z)).astype(hres.dtype)
    y = act_q(y, spec, site="out_proj")
    return hres + y @ lp["out_proj"], (ssm_s, ssm_n), conv_state


def mamba_block_step(cfg, lp, hres, spec, ssm_state, conv_state):
    x = rmsnorm(hres, lp["norm"], cfg.norm_eps)
    b, _, d = x.shape
    di = _di(cfg)
    z, xh, q, k, v, log_f, conv_state = _ssd_inputs(cfg, lp, x, spec, conv_state)
    sq = lambda a: a[:, 0]
    y, ssm_state = linear_attention_step(
        sq(q), sq(k), sq(v), sq(log_f), jnp.zeros_like(sq(log_f)), ssm_state,
        normalize=False,
    )
    y = y + lp["D_skip"].astype(jnp.float32)[None, :, None] * sq(xh)
    y = (y.reshape(b, 1, di) * jax.nn.silu(z)).astype(hres.dtype)
    y = act_q(y, spec, site="out_proj")
    return hres + y @ lp["out_proj"], ssm_state, conv_state


# ---------------------------------------------------------------------------
# Shared attention block
# ---------------------------------------------------------------------------


def _shared_qkv(cfg, sp, x, positions, spec):
    b, s, _ = x.shape
    hd = cfg.hd
    xq = act_q(x, spec, site="wq")
    q = (xq @ sp["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (xq @ sp["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (xq @ sp["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def shared_block(cfg, sp, hres, positions, spec, kv=None, length=None):
    """Train/prefill form. If kv is given, returns the new (k, v) to cache."""
    b, s, _ = hres.shape
    x = rmsnorm(hres, sp["attn_norm"], cfg.norm_eps)
    q, k, v = _shared_qkv(cfg, sp, x, positions, spec)
    attn = common.flash_attention(q, k, v, causal=True)
    attn = act_q(attn.reshape(b, s, cfg.n_heads * cfg.hd), spec, site="wo")
    h = hres + attn @ sp["wo"]
    x2 = rmsnorm(h, sp["mlp_norm"], cfg.norm_eps)
    h = h + common.swiglu(x2, sp["w_gate"], sp["w_up"], sp["w_down"], spec)
    return h, (k, v)


def shared_block_step(cfg, sp, hres, position, spec, k_cache, v_cache, length):
    """Decode form against this application-site's KV cache."""
    b = hres.shape[0]
    x = rmsnorm(hres, sp["attn_norm"], cfg.norm_eps)
    positions = jnp.broadcast_to(position, (b, 1))
    q, k, v = _shared_qkv(cfg, sp, x, positions, spec)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, position, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, position, 0, 0))
    attn = common.decode_attention(q, k_cache, v_cache, length + 1)
    attn = act_q(attn.reshape(b, 1, cfg.n_heads * cfg.hd), spec, site="wo")
    h = hres + attn @ sp["wo"]
    x2 = rmsnorm(h, sp["mlp_norm"], cfg.norm_eps)
    h = h + common.swiglu(x2, sp["w_gate"], sp["w_up"], sp["w_down"], spec)
    return h, k_cache, v_cache


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def init_state(cfg: ModelConfig, batch: int, max_attn_seq: int, dtype=jnp.bfloat16) -> Dict:
    groups, trailing = _layout(cfg)
    di = _di(cfg)
    nh, st = cfg.ssm_heads, cfg.ssm_state
    dh = di // nh
    conv_ch = di + 2 * st
    l = cfg.n_layers
    return {
        "ssm_s": jnp.zeros((l, batch, nh, st, dh), jnp.float32),
        "ssm_n": jnp.zeros((l, batch, nh, st), jnp.float32),
        "conv": jnp.zeros((l, batch, cfg.conv_width - 1, conv_ch), dtype),
        "k": jnp.zeros((groups, batch, max_attn_seq, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((groups, batch, max_attn_seq, cfg.n_kv_heads, cfg.hd), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def _split_layers(cfg, mamba_params):
    groups, trailing = _layout(cfg)
    every = cfg.attn_every
    head = jax.tree.map(lambda a: a[: groups * every].reshape(groups, every, *a.shape[1:]),
                        mamba_params)
    tail = jax.tree.map(lambda a: a[groups * every :], mamba_params)
    return head, tail, groups, trailing


def _run(cfg, params, h, positions, spec, state, *, chunk, collect_kv=True):
    """Shared full-sequence runner for forward/prefill."""
    head, tail, groups, trailing = _split_layers(cfg, params["mamba"])
    sp = params["shared"]
    every = cfg.attn_every
    ge = groups * every
    rs = lambda a: a[:ge].reshape(groups, every, *a.shape[1:])
    s_ssm = rs(state["ssm_s"]) if groups else None
    n_ssm = rs(state["ssm_n"]) if groups else None
    c_ssm = rs(state["conv"]) if groups else None

    def group_fn(h, xs):
        mlp_g, ss_g, nn_g, cv_g = xs

        def mstep(h, xs2):
            lp, ss, nn, cv = xs2
            h, (ss2, nn2), cv2 = mamba_block(cfg, lp, h, spec, (ss, nn), cv, chunk=chunk)
            return h, ((ss2, nn2, cv2) if collect_kv else None)

        h, sts = jax.lax.scan(mstep, h, (mlp_g, ss_g, nn_g, cv_g))
        h, kv = shared_block(cfg, sp, h, positions, spec)
        if collect_kv:
            ss2, nn2, cv2 = sts
            return h, (ss2, nn2, cv2, kv)
        return h, None

    kvs = None
    ss2 = nn2 = cv2 = None
    if groups:
        h, outs = jax.lax.scan(group_fn, h, (head, s_ssm, n_ssm, c_ssm))
        if collect_kv:
            ss2, nn2, cv2, kvs = outs
    # trailing mamba layers (no shared block after)
    if trailing:
        t_ss = state["ssm_s"][groups * every :]
        t_nn = state["ssm_n"][groups * every :]
        t_cv = state["conv"][groups * every :]

        def tstep(h, xs2):
            lp, ss, nn, cv = xs2
            h, (ss2, nn2), cv2 = mamba_block(cfg, lp, h, spec, (ss, nn), cv, chunk=chunk)
            return h, ((ss2, nn2, cv2) if collect_kv else None)

        h, touts = jax.lax.scan(tstep, h, (tail, t_ss, t_nn, t_cv))
        if collect_kv:
            tss2, tnn2, tcv2 = touts
            if groups:
                ss2 = jnp.concatenate([ss2.reshape(-1, *ss2.shape[2:]), tss2])
                nn2 = jnp.concatenate([nn2.reshape(-1, *nn2.shape[2:]), tnn2])
                cv2 = jnp.concatenate([cv2.reshape(-1, *cv2.shape[2:]), tcv2])
            else:
                ss2, nn2, cv2 = tss2, tnn2, tcv2
    elif collect_kv and groups:
        ss2 = ss2.reshape(-1, *ss2.shape[2:])
        nn2 = nn2.reshape(-1, *nn2.shape[2:])
        cv2 = cv2.reshape(-1, *cv2.shape[2:])
    return h, ss2, nn2, cv2, kvs


def forward(cfg: ModelConfig, params: Dict, batch: Dict, spec: QuantizeSpec = NOQUANT,
            *, remat: bool = True, chunk: int = 128,
            return_hidden: bool = False) -> jax.Array:
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    state = init_state(cfg, b, max_attn_seq=1, dtype=h.dtype)
    h, *_ = _run(cfg, params, h, positions, spec, state, chunk=chunk, collect_kv=False)
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    h = act_q(h, spec, site="lm_head")
    if return_hidden:
        return h
    return h @ params["lm_head"]


def prefill(cfg: ModelConfig, params: Dict, batch: Dict, cache: Dict,
            spec: QuantizeSpec = NOQUANT, *, chunk: int = 128):
    h = jnp.take(params["embed"], batch["tokens"], axis=0)
    b, s, _ = h.shape
    positions = jnp.arange(s)[None, :]
    h, ss2, nn2, cv2, kvs = _run(cfg, params, h, positions, spec, cache,
                                 chunk=chunk, collect_kv=True)
    if kvs is not None:
        k_new, v_new = kvs  # (groups, B, S, kv, hd)
        cache = dict(cache,
                     k=jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                                    (0, 0, 0, 0, 0)),
                     v=jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                                    (0, 0, 0, 0, 0)))
    cache = dict(cache, ssm_s=ss2, ssm_n=nn2, conv=cv2,
                 length=jnp.asarray(s, jnp.int32))
    hn = rmsnorm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    return act_q(hn, spec, site="lm_head") @ params["lm_head"], cache


def decode(cfg: ModelConfig, params: Dict, tokens: jax.Array, cache: Dict,
           spec: QuantizeSpec = NOQUANT):
    groups, trailing = _layout(cfg)
    every = cfg.attn_every
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :]
    length = cache["length"]
    sp = params["shared"]
    head, tail, _, _ = _split_layers(cfg, params["mamba"])
    rs = lambda a: a[: groups * every].reshape(groups, every, *a.shape[1:])

    def group_fn(h, xs):
        mlp_g, ss_g, nn_g, cv_g, kc, vc = xs

        def mstep(h, xs2):
            lp, ss, nn, cv = xs2
            h, ssm2, cv2 = mamba_block_step(cfg, lp, h, spec, (ss, nn), cv)
            return h, (*ssm2, cv2)

        h, (ss2, nn2, cv2) = jax.lax.scan(mstep, h, (mlp_g, ss_g, nn_g, cv_g))
        h, kc2, vc2 = shared_block_step(cfg, sp, h, length, spec, kc, vc, length)
        return h, (ss2, nn2, cv2, kc2, vc2)

    if groups:
        h, (ss2, nn2, cv2, k2, v2) = jax.lax.scan(
            group_fn, h,
            (head, rs(cache["ssm_s"]), rs(cache["ssm_n"]), rs(cache["conv"]),
             cache["k"], cache["v"]),
        )
        ss2 = ss2.reshape(-1, *ss2.shape[2:])
        nn2 = nn2.reshape(-1, *nn2.shape[2:])
        cv2 = cv2.reshape(-1, *cv2.shape[2:])
    else:
        ss2 = nn2 = cv2 = None
        k2, v2 = cache["k"], cache["v"]
    if trailing:
        def tstep(h, xs2):
            lp, ss, nn, cv = xs2
            h, ssm2, cv2_ = mamba_block_step(cfg, lp, h, spec, (ss, nn), cv)
            return h, (*ssm2, cv2_)

        off = groups * every
        h, (tss2, tnn2, tcv2) = jax.lax.scan(
            tstep, h,
            (tail, cache["ssm_s"][off:], cache["ssm_n"][off:], cache["conv"][off:]),
        )
        ss2 = jnp.concatenate([ss2, tss2]) if ss2 is not None else tss2
        nn2 = jnp.concatenate([nn2, tnn2]) if nn2 is not None else tnn2
        cv2 = jnp.concatenate([cv2, tcv2]) if cv2 is not None else tcv2
    hn = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = act_q(hn, spec, site="lm_head") @ params["lm_head"]
    return logits[:, 0], dict(cache, ssm_s=ss2, ssm_n=nn2, conv=cv2, k=k2, v=v2,
                              length=length + 1)


def decode_paged(cfg: ModelConfig, params: Dict, tokens: jax.Array,
                 paged: Dict, state: Dict, tables: jax.Array,
                 lengths: jax.Array, spec: QuantizeSpec = NOQUANT):
    """Hybrid fused decode over the serving pool: the shared-block KV half
    reads/writes block-paged storage through the paged attention kernel
    (``paged``: ``k``/``v`` stacked over application sites, ``(G, NB, T,
    KV, hd)``), while SSD/conv state stays per-slot (``state``:
    ``ssm_s``/``ssm_n``/``conv`` with the slot axis where decode expects
    batch).  ``lengths``: (S,) per-slot attention positions.  Returns
    ``(logits, paged, state)``.
    """
    groups, trailing = _layout(cfg)
    every = cfg.attn_every
    h = jnp.take(params["embed"], tokens, axis=0)[:, None, :]
    b = h.shape[0]
    positions = lengths[:, None]
    sp = params["shared"]
    head, tail, _, _ = _split_layers(cfg, params["mamba"])
    rs = lambda a: a[: groups * every].reshape(groups, every, *a.shape[1:])

    def mstep(h, xs2):
        lp, ss, nn, cv = xs2
        h, ssm2, cv2 = mamba_block_step(cfg, lp, h, spec, (ss, nn), cv)
        return h, (*ssm2, cv2)

    def group_fn(carry, xs):
        h, kpg, vpg, g = carry
        mlp_g, ss_g, nn_g, cv_g = xs
        h, (ss2, nn2, cv2) = jax.lax.scan(mstep, h, (mlp_g, ss_g, nn_g, cv_g))
        x = rmsnorm(h, sp["attn_norm"], cfg.norm_eps)
        q, k, v = _shared_qkv(cfg, sp, x, positions, spec)
        attn, (kpg, vpg) = common.paged_decode_attention(
            q, (kpg,), (vpg,), None, (k[:, 0],), (v[:, 0],), None,
            tables, lengths, g)
        attn = act_q(attn.astype(h.dtype).reshape(b, 1, cfg.n_heads * cfg.hd),
                     spec, site="wo")
        h = h + attn @ sp["wo"]
        x2 = rmsnorm(h, sp["mlp_norm"], cfg.norm_eps)
        h = h + common.swiglu(x2, sp["w_gate"], sp["w_up"], sp["w_down"], spec)
        return (h, kpg, vpg, g + 1), (ss2, nn2, cv2)

    kpg, vpg = paged["k"], paged["v"]
    if groups:
        (h, kpg, vpg, _), (ss2, nn2, cv2) = jax.lax.scan(
            group_fn, (h, kpg, vpg, jnp.asarray(0, jnp.int32)),
            (head, rs(state["ssm_s"]), rs(state["ssm_n"]), rs(state["conv"])),
        )
        ss2 = ss2.reshape(-1, *ss2.shape[2:])
        nn2 = nn2.reshape(-1, *nn2.shape[2:])
        cv2 = cv2.reshape(-1, *cv2.shape[2:])
    else:
        ss2 = nn2 = cv2 = None
    if trailing:
        off = groups * every
        h, (tss2, tnn2, tcv2) = jax.lax.scan(
            mstep, h,
            (tail, state["ssm_s"][off:], state["ssm_n"][off:],
             state["conv"][off:]),
        )
        ss2 = jnp.concatenate([ss2, tss2]) if ss2 is not None else tss2
        nn2 = jnp.concatenate([nn2, tnn2]) if nn2 is not None else tnn2
        cv2 = jnp.concatenate([cv2, tcv2]) if cv2 is not None else tcv2
    hn = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits = act_q(hn, spec, site="lm_head") @ params["lm_head"]
    return (logits[:, 0], dict(paged, k=kpg, v=vpg),
            dict(state, ssm_s=ss2, ssm_n=nn2, conv=cv2))
