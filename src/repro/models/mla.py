"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Prefill path materialises per-head K/V from the latent (direct form);
decode path uses the *absorbed* form - queries are projected into the
latent space so attention runs directly against the cached latent
``c_kv`` (kv_lora_rank) plus the shared RoPE key, avoiding the per-step
re-expansion of the whole cache.  The cache is therefore
(B, S, kv_lora_rank + qk_rope_dim) - MLA's memory win, and the natural
target for KV quantization (one group per latent vector).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import QuantizeSpec, act_q, apply_rope
from repro.quant.packed import dense_w


def init_mla_params(key, cfg: ModelConfig, n_layers: int, dtype) -> Dict:
    d = cfg.d_model
    h = cfg.n_heads
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    ks = jax.random.split(key, 5)
    return {
        "wq_a": common.dense_init(ks[0], (n_layers, d, cfg.q_lora_rank), dtype),
        "q_norm": jnp.ones((n_layers, cfg.q_lora_rank), dtype),
        "wq_b": common.dense_init(ks[1], (n_layers, cfg.q_lora_rank, h * qk_head), dtype),
        "wkv_a": common.dense_init(
            ks[2], (n_layers, d, cfg.kv_lora_rank + cfg.qk_rope_dim), dtype
        ),
        "kv_norm": jnp.ones((n_layers, cfg.kv_lora_rank), dtype),
        # (rank, H, nope + v): sliced into K-expand and V-expand halves
        "wkv_b": common.dense_init(
            ks[3],
            (n_layers, cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim),
            dtype,
        ),
        "wo": common.dense_init(ks[4], (n_layers, h * cfg.v_head_dim, d), dtype),
    }


def _project_q(lp, x, cfg: ModelConfig, positions, spec):
    b, s, _ = x.shape
    h = cfg.n_heads
    qk_head = cfg.qk_nope_dim + cfg.qk_rope_dim
    xq = act_q(x, spec, site="wq_a")
    q_lat = xq @ lp["wq_a"]
    q_lat = common.rmsnorm(q_lat, lp["q_norm"], cfg.norm_eps)
    q = (act_q(q_lat, spec, site="wq_b") @ lp["wq_b"]).reshape(b, s, h, qk_head)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_latent(lp, x, cfg: ModelConfig, positions, spec):
    xq = act_q(x, spec, site="wkv_a")
    kv = xq @ lp["wkv_a"]  # (B, S, rank + rope)
    c_kv = common.rmsnorm(kv[..., : cfg.kv_lora_rank], lp["kv_norm"], cfg.norm_eps)
    k_rope = kv[..., cfg.kv_lora_rank :][:, :, None, :]  # shared single head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return c_kv, k_rope[:, :, 0, :]


def mla_prefill_attention(
    lp: Dict, x: jax.Array, cfg: ModelConfig, positions: jax.Array, spec: QuantizeSpec,
    *, stored_precision: bool = False, store_dtype=None,
    prefix: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Direct form. Returns (attn_out (B,S,D), c_kv, k_rope) for caching.

    ``stored_precision``: score the latent at cache precision (the values
    a decode step or a prefix-cache continuation reads back) — the
    prefill path sets this; the training forward keeps float attention.
    ``prefix``: optional (c_kv, k_rope) already-dequantized cached prefix
    (B, start, ...) to attend over; queries then cover only the tail and
    flash attention's end-aligned causal mask supplies the offset.  The
    returned c_kv/k_rope are always the *raw* tail projections so the
    caller stores through the one codec path.
    """
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _project_q(lp, x, cfg, positions, spec)
    c_kv, k_rope = _project_latent(lp, x, cfg, positions, spec)
    if stored_precision:
        ckv_att = common.kv_roundtrip(c_kv, spec, store_dtype)
        krope_att = (k_rope.astype(store_dtype).astype(k_rope.dtype)
                     if store_dtype is not None else k_rope)
    else:
        ckv_att, krope_att = c_kv, k_rope
    if prefix is not None:
        ckv_att = jnp.concatenate([prefix[0], ckv_att], axis=1)
        krope_att = jnp.concatenate([prefix[1], krope_att], axis=1)
    skv = ckv_att.shape[1]
    # einsum cannot dispatch on PackedWeight: materialize wkv_b explicitly
    kv = jnp.einsum("bsr,rhe->bshe", ckv_att, dense_w(lp["wkv_b"]))  # (B,Skv,H,nope+v)
    k_nope, v = kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim :]
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope_att[:, :, None, :], (b, skv, h, cfg.qk_rope_dim))], -1
    )
    out = common.flash_attention(q, k, v, causal=True)  # (B,S,H,v)
    out = act_q(out.reshape(b, s, h * cfg.v_head_dim), spec, site="wo")
    return out @ lp["wo"], c_kv, k_rope


def mla_paged_decode_attention(
    lp: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    k1_pages,
    krope_pages: jax.Array,
    k1_new,
    krope_new: jax.Array,
    tables: jax.Array,
    lengths: jax.Array,
    layer,
    spec: QuantizeSpec,
):
    """Absorbed-form decode against *paged* latent storage.

    MLA maps onto the generic paged kernel as 1-KV-head attention: the
    per-head query is ``concat(q_latent, q_rope)``, K source 1 is the
    latent block (``k1_pages``: 1-tuple of float pages ``(L, NB, T,
    rank)`` or 3-tuple codes/scale/zero), K source 2 the shared RoPE key
    pages ``(L, NB, T, rope)``, and V *is* the dequantized latent
    (``v_is_k1``).  ``k1_new``/``krope_new`` carry the new token in the
    same layout (``(B, rank)`` / ``(B, rope)``, scales ``(B,)``).

    Returns ``(attn_out (B, 1, D), new_pages)`` — new_pages in kernel
    order ``latent(+scale,zero), krope`` with the KV axis stripped back
    off.
    """
    b = x.shape[0]
    h = cfg.n_heads
    q_nope, q_rope = _project_q(lp, x, cfg, positions, spec)  # (B,1,H,*)
    wkv_b = dense_w(lp["wkv_b"])
    wk = wkv_b[..., : cfg.qk_nope_dim]  # (rank, H, nope)
    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, wk)  # (B,1,H,rank)
    q_cat = jnp.concatenate(
        [q_lat.astype(jnp.float32), q_rope.astype(jnp.float32)], -1)
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)

    kv_ax = lambda a: a[..., None, :]  # (L,NB,T,d) -> (L,NB,T,1,d)
    sc_ax = lambda a: a[..., None]  # (L,NB,T) -> (L,NB,T,1); (B,)->(B,1)
    norm_pages = lambda tup: (kv_ax(tup[0]),) + tuple(sc_ax(a) for a in tup[1:])
    norm_new = lambda tup: (tup[0][:, None, :],) + tuple(
        sc_ax(a) for a in tup[1:])

    out_lat, new_pages = common.paged_decode_attention(
        q_cat, norm_pages(k1_pages), None, kv_ax(krope_pages),
        norm_new(k1_new), None, krope_new[:, None, :],
        tables, lengths, layer, scale=scale, v_is_k1=True)
    # strip the synthetic KV axis back off: pages k1(+s,z) then krope
    out_pages = tuple(jnp.squeeze(p, axis=3) for p in new_pages)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat.astype(x.dtype),
                     wkv_b[..., cfg.qk_nope_dim:])
    out = act_q(out.reshape(b, 1, h * cfg.v_head_dim), spec, site="wo")
    return out @ lp["wo"], out_pages


def mla_decode_attention(
    lp: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    position: jax.Array,
    ckv_cache: jax.Array,
    krope_cache: jax.Array,
    length: jax.Array,
    spec: QuantizeSpec,
) -> jax.Array:
    """Absorbed form against the latent cache.

    ckv_cache: (B, Smax, rank); krope_cache: (B, Smax, rope).
    """
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.broadcast_to(position, (b, 1))
    q_nope, q_rope = _project_q(lp, x, cfg, positions, spec)  # (B,1,H,*)
    wkv_b = dense_w(lp["wkv_b"])  # einsum consumer: materialize explicitly
    # absorb K-expansion into the query: q_lat = q_nope @ W_kvb_K^T
    wk = wkv_b[..., : cfg.qk_nope_dim]  # (rank, H, nope)
    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, wk)  # (B,1,H,rank)
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhe,bse->bhqs", q_rope.astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (s_lat + s_rope) * scale
    mask = jnp.arange(ckv_cache.shape[1])[None, None, None, :] < length
    scores = jnp.where(mask, scores, common.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", p, ckv_cache.astype(jnp.float32))  # (B,1,H,rank)
    wv = wkv_b[..., cfg.qk_nope_dim :]  # (rank, H, v)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat.astype(x.dtype), wv)
    out = act_q(out.reshape(b, 1, h * cfg.v_head_dim), spec, site="wo")
    return out @ lp["wo"]


def mla_decode_chunk_attention(
    lp: Dict,
    x: jax.Array,
    cfg: ModelConfig,
    positions: jax.Array,
    ckv_cache: jax.Array,
    krope_cache: jax.Array,
    length: jax.Array,
    spec: QuantizeSpec,
) -> jax.Array:
    """Absorbed-form chunk-causal attention (spec-decode verify).

    x: (B, K, D) — K consecutive pending tokens whose latents are already
    stored at positions ``[length, length + K)``; positions: (B, K);
    length: () fill *before* the chunk.  Query ``j`` attends to positions
    ``< length + 1 + j``; the absorbed einsums already carry a query axis,
    so ``K == 1`` computes exactly :func:`mla_decode_attention`.
    """
    b, kq, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _project_q(lp, x, cfg, positions, spec)  # (B,K,H,*)
    wkv_b = dense_w(lp["wkv_b"])
    wk = wkv_b[..., : cfg.qk_nope_dim]  # (rank, H, nope)
    q_lat = jnp.einsum("bqhe,rhe->bqhr", q_nope, wk)  # (B,K,H,rank)
    s_lat = jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                       ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum("bqhe,bse->bhqs", q_rope.astype(jnp.float32),
                        krope_cache.astype(jnp.float32))
    scale = 1.0 / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (s_lat + s_rope) * scale
    lim = length + 1 + jnp.arange(kq)                       # (K,)
    mask = jnp.arange(ckv_cache.shape[1])[None, :] < lim[:, None]  # (K, Smax)
    scores = jnp.where(mask[None, None], scores, common.NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", p, ckv_cache.astype(jnp.float32))
    wv = wkv_b[..., cfg.qk_nope_dim :]  # (rank, H, v)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat.astype(x.dtype), wv)
    out = act_q(out.reshape(b, kq, h * cfg.v_head_dim), spec, site="wo")
    return out @ lp["wo"]
