from repro.data.synthetic import SyntheticLM, calibration_batches  # noqa: F401
