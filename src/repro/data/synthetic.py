"""Deterministic synthetic LM data pipeline (offline container: no corpora).

The stream is a seeded order-1 Markov chain with Zipf-ish marginals and
local repetition structure, so it is genuinely *learnable*: a trained
model reaches materially lower perplexity than chance, which is what the
quantization benchmarks need (PPL deltas between rotation variants are
meaningful only on a model that has learned structure).

Sharding: batches are generated per (step, shard) pair - each data-parallel
host generates only its slice, no host ever materialises the global batch
(the same contract a production loader over GCS shards satisfies).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    seed: int = 0
    branching: int = 24  # successors per state: lower = more predictable

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab
        # sparse transition structure: each token has `branching` successors
        # with Zipf-weighted probabilities
        self._succ = rng.integers(0, v, size=(v, self.branching))
        w = 1.0 / np.arange(1, self.branching + 1) ** 1.2
        self._w = (w / w.sum()).astype(np.float64)

    # ------------------------------------------------------------------
    def batch(self, step: int, shard: int, batch_size: int,
              n_codebooks: int = 0) -> np.ndarray:
        """Tokens (batch, seq) (or (batch, seq, K)) for this step+shard."""
        rng = np.random.default_rng((self.seed, step, shard))
        k = max(n_codebooks, 1)
        out = np.empty((batch_size, self.seq_len, k), np.int32)
        cur = rng.integers(0, self.vocab, size=(batch_size, k))
        for t in range(self.seq_len):
            out[:, t] = cur
            choice = rng.choice(self.branching, size=(batch_size, k), p=self._w)
            cur = self._succ[cur, choice]
        return out if n_codebooks else out[..., 0]

    def batches(self, shard: int, batch_size: int, start_step: int = 0,
                n_codebooks: int = 0) -> Iterator[np.ndarray]:
        step = start_step
        while True:
            yield self.batch(step, shard, batch_size, n_codebooks)
            step += 1


def make_batch_for(cfg, data: SyntheticLM, step: int, shard: int, batch_size: int,
                   patch_rng_seed: int = 7) -> Dict[str, np.ndarray]:
    """Model-ready batch dict for any assigned arch (modality stubs filled)."""
    if cfg.modality == "audio":
        toks = data.batch(step, shard, batch_size, n_codebooks=cfg.n_codebooks)
        return {"tokens": toks}
    batch = {"tokens": data.batch(step, shard, batch_size)}
    if cfg.modality == "vlm":
        rng = np.random.default_rng((patch_rng_seed, step, shard))
        batch["patch_embeds"] = rng.normal(
            size=(batch_size, cfg.n_patches, cfg.d_model)
        ).astype(np.float32) * 0.02
    return batch


def calibration_batches(cfg, n_samples: int, seq_len: int, seed: int = 123):
    """GPTQ calibration stream (the paper samples 128x2048-token contexts)."""
    data = SyntheticLM(cfg.vocab, seq_len, seed=seed)
    for i in range(n_samples):
        yield make_batch_for(cfg, data, step=i, shard=0, batch_size=1)
