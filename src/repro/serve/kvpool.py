"""Block-paged KV/state pool for continuous-batching serving.

The static :class:`~repro.serve.engine.ServeEngine` cache is one
monolithic allocation per ``generate()`` call: every slot's KV lives at a
fixed batch index, and admitting a new sequence means re-allocating (and
re-placing) the whole tree.  The pool breaks the *sequence axis* of every
cache leaf into fixed-size token blocks with a free list, so a finished
slot returns its blocks and a new request is admitted by writing only its
own blocks — surviving slots are never re-allocated, copied, or even
touched.

Layout trick: block storage is allocated through the model's own
``init_cache(batch=n_blocks, max_seq=block_tokens)``, i.e. the batch axis
*is* the block axis.  That makes the pool generic over every family:

* transformer / MLA leaves ``(L, B, S, ...)`` page on ``S`` (including
  the quantized-KV code/scale/zero leaves from ``quant.kv_cache`` — a
  block of a quantized cache is packed uint8 codes plus its scales, and
  dequantization keeps happening at attention time inside the model);
* Zamba pages its shared-block KV and keeps SSD/conv state per slot;
* xLSTM has no sequence axis at all and degenerates to per-slot state.

Which axes are batch/sequence is *probed*, not hard-coded: the pool
evaluates ``cache_specs`` at two batch sizes and two sequence lengths and
records, per leaf, which axis moved.  Leaves with a sequence axis are
paged; leaves without are per-slot state; the scalar ``length`` leaf is
replaced by a per-slot length vector.

Block 0 is a reserved scratch block: free slots and unallocated table
entries point at it, so the gather/scatter decode step runs with fully
static shapes and inactive lanes read and write only scratch.

Two decode steps share one signature (``tick(params, tokens, lengths,
tables, paged, state)``):

* the **fused path** (:meth:`KVPool.make_fused_tick`, the default for
  every family with a paged cache) hands pool storage to the model's
  ``decode_paged``: the Pallas paged-attention kernel walks each slot's
  block table in place and appends the new token inside the kernel —
  zero per-tick gather/scatter of pool storage;
* the **baseline** (:meth:`KVPool.make_tick`) gathers each slot's blocks
  into a contiguous per-slot view, runs the model's unmodified
  ``decode`` under ``jax.vmap`` (one lane per slot, per-slot lengths),
  and scatters the updated blocks back.  Pure-state families (xLSTM)
  always use it; it is also the fused path's A/B reference
  (``ServeConfig(paged_kernel=False)``).

Either tick is jitted+bound by :meth:`KVPool.bind_step` for the
single-step scheduler loop, or embedded unjitted in the engine's
in-graph multi-step decode window (``ServeConfig.steps_per_sync``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import NOQUANT, QuantizeSpec

SCRATCH = 0  # reserved block id; never allocated, absorbs inactive-lane writes


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _path_str(path) -> str:
    return "/".join(_key_str(k) for k in path)


@dataclasses.dataclass(frozen=True)
class LeafMeta:
    """Where a cache leaf keeps its batch/sequence axes (probed)."""

    batch_ax: Optional[int]  # None only for the scalar `length` leaf
    seq_ax: Optional[int]  # None for per-slot state leaves

    @property
    def paged(self) -> bool:
        return self.seq_ax is not None


def _diff_axes(a: Tuple[int, ...], b: Tuple[int, ...]) -> List[int]:
    assert len(a) == len(b), (a, b)
    return [i for i, (x, y) in enumerate(zip(a, b)) if x != y]


class KVPool:
    """Paged cache storage shared by all decode slots of one engine.

    Host-side bookkeeping (free list, per-slot block chains, lengths) is
    plain Python/numpy; device-side storage is two pytree fragments
    (``paged`` block storage, ``state`` per-slot storage) updated
    functionally by admit/step.
    """

    def __init__(self, arch, spec: QuantizeSpec = NOQUANT, dtype=jnp.float32, *,
                 n_slots: int, max_seq: int, block_tokens: int = 16,
                 n_blocks: Optional[int] = None, round_blocks_to: int = 1):
        """``round_blocks_to`` rounds the total block count up to a
        multiple (the engine passes the data-parallel mesh size, so the
        block axis stays divisible and ``pool_pspecs`` placements survive
        ``sanitize_pspecs`` instead of silently replicating the pool)."""
        if block_tokens < 1:
            raise ValueError("block_tokens must be >= 1")
        self.arch = arch
        self.spec = spec
        self.dtype = dtype
        self.n_slots = n_slots
        self.block_tokens = block_tokens
        self.blocks_per_slot = max(1, math.ceil(max_seq / block_tokens))
        self.view_tokens = self.blocks_per_slot * block_tokens

        # --- probe which axis of each leaf is batch / sequence ------------
        t = block_tokens
        ref = arch.cache_specs(2, 2 * t, spec, dtype)
        flat, self.treedef = jax.tree_util.tree_flatten_with_path(ref)
        self.paths: List[str] = [_path_str(p) for p, _ in flat]
        alt_b = jax.tree.leaves(arch.cache_specs(3, 2 * t, spec, dtype))
        alt_s = jax.tree.leaves(arch.cache_specs(2, 3 * t, spec, dtype))
        self.meta: Dict[str, LeafMeta] = {}
        self.length_path: Optional[str] = None
        for (path, leaf), lb, ls in zip(flat, alt_b, alt_s):
            name = _path_str(path)
            ba = _diff_axes(leaf.shape, lb.shape)
            sa = _diff_axes(leaf.shape, ls.shape)
            if not ba:
                assert name.endswith("length") and leaf.ndim == 0, (
                    f"cache leaf {name} has no batch axis and is not `length`")
                self.length_path = name
                self.meta[name] = LeafMeta(batch_ax=None, seq_ax=None)
                continue
            assert len(ba) == 1, f"ambiguous batch axis for {name}: {ba}"
            assert len(sa) <= 1, f"ambiguous sequence axis for {name}: {sa}"
            m = LeafMeta(batch_ax=ba[0], seq_ax=sa[0] if sa else None)
            if m.paged:
                assert m.seq_ax == m.batch_ax + 1, (
                    f"{name}: pool assumes the sequence axis immediately "
                    f"follows the batch axis, got {m}")
            self.meta[name] = m
        assert self.length_path is not None, "cache tree has no `length` leaf"
        self.has_paged = any(m.paged for m in self.meta.values())

        # --- device storage ----------------------------------------------
        if n_blocks is None:
            n_blocks = n_slots * self.blocks_per_slot + 1  # + scratch
        r = max(1, round_blocks_to)
        n_blocks = -(-n_blocks // r) * r
        if n_blocks < 2:
            raise ValueError("need at least one real block besides scratch")
        self.n_blocks = n_blocks
        block_tree = arch.init_cache(n_blocks, block_tokens, spec, dtype)
        slot_tree = arch.init_cache(n_slots, block_tokens, spec, dtype)
        bflat = dict(zip(self.paths, jax.tree.leaves(block_tree)))
        sflat = dict(zip(self.paths, jax.tree.leaves(slot_tree)))
        self.paged: Dict[str, jax.Array] = {
            p: bflat[p] for p, m in self.meta.items() if m.paged}
        self.state: Dict[str, jax.Array] = {
            p: sflat[p] for p, m in self.meta.items()
            if m.batch_ax is not None and not m.paged}

        # --- host bookkeeping ----------------------------------------------
        self.free: List[int] = list(range(1, n_blocks))
        self.slot_blocks: List[List[int]] = [[] for _ in range(n_slots)]
        self.tables = np.full((n_slots, self.blocks_per_slot), SCRATCH, np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self._reserved = np.zeros((n_slots,), np.int32)  # worst-case blocks
        # Per-block reference count == number of slot tables mapping the
        # block (prefix sharing maps one block into many tables).  The
        # free list holds exactly the refcount-0 blocks *not* retained by
        # the attached prefix cache; release decrements and only reclaims
        # blocks nobody references or retains.
        self.refcount = np.zeros((n_blocks,), np.int32)
        # Optional prefix-cache hook (set by serve.prefixcache.PrefixCache).
        # Duck-typed protocol: holds(b) -> bool (retain a refcount-0 block
        # at release), evict(n) -> int (reclaim up to n idle cached blocks
        # back to the free list), evictable() -> int (how many it could),
        # blocks() -> iterable of retained block ids (invariant checking).
        self.prefix = None
        self._write_prefix_jit = None
        # Optional observability bundle (set by the engine): block
        # alloc/release counters + the free-list gauge flow through its
        # registry.  None = standalone pool, no accounting.
        self.obs = None
        # Optional fault injector (set by the engine when ServeConfig
        # carries a FaultPlan): release() notifies it so planned
        # free-list leaks land at deterministic ordinals.  None (the
        # default) keeps the hot path to a single attribute check.
        self.faults = None

    # ------------------------------------------------------------------
    # Admission accounting
    # ------------------------------------------------------------------

    def blocks_for(self, n_tokens: int) -> int:
        if not self.has_paged:
            return 0
        return max(1, math.ceil(n_tokens / self.block_tokens))

    @property
    def capacity_blocks(self) -> int:
        return self.n_blocks - 1

    def _outstanding(self) -> int:
        """Blocks active slots may still demand under their reservations."""
        return int(sum(max(0, int(self._reserved[s]) - len(self.slot_blocks[s]))
                       for s in range(self.n_slots)))

    def _evictable(self) -> int:
        return self.prefix.evictable() if self.prefix is not None else 0

    def can_admit(self, worst_tokens: int, shared_blocks: int = 0) -> bool:
        """Conservative policy: admit only if the request's worst case fits
        after every running request takes its own worst case — decode can
        then never starve mid-flight (no preemption needed).

        ``shared_blocks`` prefix-cache-mapped blocks arrive already
        populated and never touch the free list; idle cached blocks count
        as supply because ``_alloc`` evicts them on demand."""
        if not self.has_paged:
            return True
        need = self.blocks_for(worst_tokens) - shared_blocks
        return len(self.free) + self._evictable() >= self._outstanding() + need

    # ------------------------------------------------------------------
    # Slot lifecycle
    # ------------------------------------------------------------------

    def _alloc(self, slot: int) -> int:
        if not self.free and self.prefix is not None:
            self.prefix.evict(1)
        if not self.free:
            raise RuntimeError("KV pool out of blocks (admission bug)")
        blk = self.free.pop()
        assert self.refcount[blk] == 0, f"free block {blk} had live refs"
        self.refcount[blk] = 1
        self.slot_blocks[slot].append(blk)
        self.tables[slot, len(self.slot_blocks[slot]) - 1] = blk
        if self.obs is not None:
            self.obs.registry.counter("kvpool_blocks_allocated_total").inc()
            self.obs.registry.gauge("kvpool_free_blocks").set(len(self.free))
        return blk

    def _map_shared(self, slot: int, blk: int) -> None:
        """Map an already-populated block into ``slot``'s table (refcount++)."""
        assert 0 < blk < self.n_blocks and blk != SCRATCH, blk
        self.refcount[blk] += 1
        self.slot_blocks[slot].append(blk)
        self.tables[slot, len(self.slot_blocks[slot]) - 1] = blk

    def admit(self, slot: int, cache_tree, n_tokens: int, worst_tokens: int,
              shared: Sequence[int] = ()) -> None:
        """Install a freshly prefilled batch=1 cache into ``slot``.

        ``cache_tree``'s paged leaves must carry ``ceil(n_tokens /
        block_tokens) * block_tokens`` sequence positions.  Only this
        slot's blocks and state row are written.

        ``shared``: prefix-cache block ids covering the first
        ``len(shared) * block_tokens`` positions.  They are *mapped*
        (refcount++) instead of allocated, and their storage is not
        rewritten — the cache_tree's leading positions merely mirror
        their contents (the continuation-prefill view).  Blocks from
        ``len(shared)`` on are allocated fresh and written; a
        copy-on-write block is simply a fresh block here (the scheduler
        drops it from ``shared`` so its recomputed contents land in
        private storage, never mutating the cached original).
        """
        assert not self.slot_blocks[slot], f"slot {slot} already occupied"
        if worst_tokens > self.view_tokens:
            raise ValueError(
                f"request needs {worst_tokens} cache positions, pool view "
                f"holds {self.view_tokens}")
        nb0 = self.blocks_for(n_tokens)
        shared = list(shared)
        assert len(shared) <= nb0, (shared, nb0)
        assert len(set(shared)) == len(shared), "duplicate shared block"
        self._reserved[slot] = self.blocks_for(worst_tokens)
        for blk in shared:
            self._map_shared(slot, blk)
        fresh = [self._alloc(slot) for _ in range(nb0 - len(shared))]
        leaves = dict(zip(self.paths, jax.tree.leaves(cache_tree)))
        t = self.block_tokens
        skip = len(shared)
        for path, m in self.meta.items():
            if m.batch_ax is None:
                continue
            val = jnp.squeeze(leaves[path], axis=m.batch_ax)
            if m.paged:
                if not fresh:
                    continue  # fully shared: nothing to write
                # (.., V', ..) -> (.., nb, T, ..) -> pool[.., fresh, T, ..]
                sa = m.seq_ax - 1  # after squeezing the batch axis
                shape = val.shape
                assert shape[sa] >= nb0 * t, (path, shape, nb0, t)
                val = jax.lax.slice_in_dim(val, skip * t, nb0 * t, axis=sa)
                val = val.reshape(shape[:sa] + (nb0 - skip, t) + shape[sa + 1:])
                idx = (slice(None),) * m.batch_ax + (jnp.asarray(fresh),)
                self.paged[path] = self.paged[path].at[idx].set(
                    val.astype(self.paged[path].dtype))
            else:
                idx = (slice(None),) * m.batch_ax + (slot,)
                self.state[path] = self.state[path].at[idx].set(
                    val.astype(self.state[path].dtype))
        self.lengths[slot] = n_tokens

    def write_prefix(self, cache_tree, blocks: Sequence[int]):
        """Return ``cache_tree`` (batch=1) with positions ``[0,
        len(blocks) * block_tokens)`` of every paged leaf filled from pool
        block storage — the gather half of a shared-prefix admission: the
        engine continuation-prefills the tail over this view.

        The whole gather runs as one jitted dispatch (retraced per
        distinct block count): admission sits on the TTFT path, where the
        per-leaf eager take/scatter overhead would cost more than the
        prefill compute the shared prefix saves."""
        if not blocks:
            return cache_tree
        if self._write_prefix_jit is None:
            paged = [(p, self.meta[p]) for p in self.paths
                     if self.meta[p].paged]
            t = self.block_tokens

            def wp(paged_leaves, cache_leaves, ids):
                n = ids.shape[0] * t
                out = dict(cache_leaves)
                for (path, m), src in zip(paged, paged_leaves):
                    ba = m.batch_ax
                    g = jnp.take(src, ids, axis=ba)  # (.., nb, T, ..)
                    shape = g.shape
                    val = g.reshape(shape[:ba] + (n,) + shape[ba + 2:])
                    leaf = out[path]
                    assert leaf.shape[m.seq_ax] >= n, (path, leaf.shape, n)
                    idx = (slice(None),) * ba + (0, slice(0, n))
                    out[path] = leaf.at[idx].set(val.astype(leaf.dtype))
                return out

            self._write_prefix_jit = jax.jit(wp)
        ids = jnp.asarray(list(blocks))
        leaves = dict(zip(self.paths, jax.tree.leaves(cache_tree)))
        new = self._write_prefix_jit(
            tuple(self.paged[p] for p in self.paths if self.meta[p].paged),
            leaves, ids)
        return jax.tree_util.tree_unflatten(
            self.treedef, [new[p] for p in self.paths])

    def ensure(self, slot: int) -> None:
        """Grow ``slot`` so the next decode write position is backed by a
        real block (conservative admission guarantees the free list can
        serve it)."""
        self.ensure_until(slot, int(self.lengths[slot]))

    def ensure_until(self, slot: int, last_pos: int) -> None:
        """Back every position up to ``last_pos`` inclusive with real
        blocks — the multi-step in-graph decode window writes up to
        ``steps_per_sync`` tokens between host syncs, so its blocks must
        all exist before the window launches (table entries are fixed for
        the window's duration).  Stays within the slot's conservative
        admission reservation by construction (``last_pos <= worst - 1``)."""
        if not self.has_paged:
            return
        if last_pos >= self.view_tokens:
            raise RuntimeError(f"slot {slot} exceeded pool view ({last_pos})")
        while len(self.slot_blocks[slot]) * self.block_tokens <= last_pos:
            self._alloc(slot)

    def advance(self, slot: int) -> None:
        self.lengths[slot] += 1

    def rewind(self, slot: int, n_tokens: int) -> None:
        """Truncate ``slot`` back to ``n_tokens`` stored positions — the
        speculative-decoding rollback.  Free on block-paged storage:
        rejected draft/verify positions simply fall outside the length
        mask, stay inside the slot's reservation (over-allocation is
        legal — see :meth:`check_invariants`), and the next write
        overwrites them in place, so no block ever moves and the block
        table is untouched."""
        assert 0 <= n_tokens <= int(self.lengths[slot]), \
            f"rewind extends slot {slot}: {n_tokens} > {int(self.lengths[slot])}"
        if self.has_paged and n_tokens:
            assert len(self.slot_blocks[slot]) * self.block_tokens >= n_tokens, \
                f"rewind target past slot {slot}'s allocation"
        self.lengths[slot] = n_tokens

    def release(self, slot: int) -> None:
        """Decrement refcounts on the slot's blocks; reclaim only blocks
        that hit zero references *and* are not retained by the prefix
        cache (a cached-idle block stays resident, off the free list,
        until the cache evicts it under pressure)."""
        freed = 0
        for blk in self.slot_blocks[slot]:
            assert self.refcount[blk] > 0, f"double release of block {blk}"
            self.refcount[blk] -= 1
            if self.refcount[blk] == 0 and not (
                    self.prefix is not None and self.prefix.holds(blk)):
                self.free.append(blk)
                freed += 1
        self.slot_blocks[slot] = []
        self.tables[slot, :] = SCRATCH
        self.lengths[slot] = 0
        self._reserved[slot] = 0
        if self.obs is not None:
            if freed:
                self.obs.registry.counter(
                    "kvpool_blocks_released_total").inc(freed)
            self.obs.registry.gauge("kvpool_free_blocks").set(len(self.free))
        if self.faults is not None:
            self.faults.on_release(self)

    def reclaim(self, blocks: Sequence[int]) -> None:
        """Return idle cached blocks to the free list (prefix-cache
        eviction path).  Reclaiming a block a slot still references is a
        bug — the cache must only evict refcount-0 entries."""
        n = 0
        for blk in blocks:
            assert self.refcount[blk] == 0, \
                f"reclaim of live shared block {blk} (refcount {self.refcount[blk]})"
            assert blk not in self.free, f"double-free of block {blk}"
            self.free.append(blk)
            n += 1
        if self.obs is not None and n:
            self.obs.registry.counter("kvpool_blocks_released_total").inc(n)
            self.obs.registry.gauge("kvpool_free_blocks").set(len(self.free))

    # ------------------------------------------------------------------
    # Invariants (exercised by tests after every admit/step/release)
    # ------------------------------------------------------------------

    def audit(self) -> List[str]:
        """Non-raising invariant sweep: every violated invariant as a
        human-readable issue string (empty = healthy).  The health cycle
        runs this periodically and feeds the result to :meth:`recover`;
        :meth:`check_invariants` asserts it is empty."""
        issues: List[str] = []
        owned = [b for blocks in self.slot_blocks for b in blocks]
        counts: Dict[int, int] = {}
        for b in owned:
            counts[b] = counts.get(b, 0) + 1
        cached = set(self.prefix.blocks()) if self.prefix is not None else set()
        if SCRATCH in owned:
            issues.append("scratch block was allocated")
        if SCRATCH in self.free:
            issues.append("scratch block on the free list")
        if SCRATCH in cached:
            issues.append("scratch block in the prefix cache")
        if len(set(self.free)) != len(self.free):
            issues.append("free list duplicate")
        both = set(owned) & set(self.free)
        if both:
            issues.append(f"block both free and owned: {sorted(both)}")
        stale = cached & set(self.free)
        if stale:
            issues.append(f"cached block on the free list: {sorted(stale)}")
        leaked = set(range(1, self.n_blocks)) - set(owned) - set(self.free) \
            - cached
        if leaked:
            issues.append(f"block leaked: {sorted(leaked)}")
        for b in range(1, self.n_blocks):
            if int(self.refcount[b]) != counts.get(b, 0):
                issues.append(
                    f"block {b}: refcount {int(self.refcount[b])} != "
                    f"{counts.get(b, 0)} table references")
        for s in range(self.n_slots):
            blocks = self.slot_blocks[s]
            if len(set(blocks)) != len(blocks):
                issues.append(f"block twice in slot {s}")
            if list(self.tables[s, : len(blocks)]) != blocks:
                issues.append(f"slot {s} table disagrees with its blocks")
            if not all(b == SCRATCH for b in self.tables[s, len(blocks):]):
                issues.append(f"slot {s} table tail not scratch")
            if blocks:
                need = self.blocks_for(max(1, int(self.lengths[s])))
                if len(blocks) < need:
                    issues.append(f"slot {s} under-allocated")
        return issues

    def check_invariants(self) -> None:
        issues = self.audit()
        assert not issues, "; ".join(issues)

    def recover(self) -> Dict[str, int]:
        """Self-heal the host bookkeeping the audit can fix without
        touching any live slot: resync refcounts to the actual table
        references, drop duplicate/contradictory free-list entries, and
        reclaim orphaned blocks (not owned, not free, not cached) back
        to the free list.  Returns what was repaired — the health cycle
        counts it as a recoverable event instead of tearing down.
        Device storage is never touched (an orphaned block's stale
        contents are dead weight, masked by tables/lengths)."""
        cached = set(self.prefix.blocks()) if self.prefix is not None else set()
        counts: Dict[int, int] = {}
        for blocks in self.slot_blocks:
            for b in blocks:
                counts[b] = counts.get(b, 0) + 1
        refcounts_fixed = 0
        for b in range(1, self.n_blocks):
            want = counts.get(b, 0)
            if int(self.refcount[b]) != want:
                self.refcount[b] = want
                refcounts_fixed += 1
        seen: set = set()
        free: List[int] = []
        free_dropped = 0
        for b in self.free:
            if b in seen or b in counts or b in cached or b == SCRATCH:
                free_dropped += 1
                continue
            seen.add(b)
            free.append(b)
        orphans = [b for b in range(1, self.n_blocks)
                   if b not in counts and b not in seen and b not in cached]
        free.extend(orphans)
        self.free = free
        if self.obs is not None:
            if orphans:
                self.obs.registry.counter(
                    "kvpool_blocks_recovered_total").inc(len(orphans))
            self.obs.registry.gauge("kvpool_free_blocks").set(len(self.free))
        return {"blocks_reclaimed": len(orphans),
                "refcounts_fixed": refcounts_fixed,
                "free_entries_dropped": free_dropped}

    def check_leaks(self) -> None:
        """Teardown leak check: with every slot released, each block must
        be on the free list or retained by the prefix cache, and no
        references may remain.  Raises RuntimeError naming the leaked
        blocks otherwise."""
        held = [b for blocks in self.slot_blocks for b in blocks]
        if held:
            raise RuntimeError(f"pool torn down with occupied slots: {held}")
        live = [b for b in range(1, self.n_blocks) if self.refcount[b] != 0]
        if live:
            raise RuntimeError(f"dangling refcounts at teardown: {live}")
        cached = set(self.prefix.blocks()) if self.prefix is not None else set()
        leaked = set(range(1, self.n_blocks)) - set(self.free) - cached
        if leaked:
            raise RuntimeError(f"blocks leaked at teardown: {sorted(leaked)}")

    # ------------------------------------------------------------------
    # The jitted gather -> vmapped decode -> scatter step
    # ------------------------------------------------------------------

    def make_tick(self, decode_fn: Callable) -> Callable:
        """``decode_fn(params, tokens_1d, cache) -> (logits, cache)`` is the
        model's unmodified single-step decode; the returned *pure* tick
        runs it once per slot (per-slot lengths) over block-gathered views:

            logits, paged, state, lengths = tick(
                params, tokens, lengths, tables, paged, state)

        ``tokens``: (n_slots,) int32 (audio: (n_slots, K)); ``lengths``:
        (n_slots,) int32; ``tables``: (n_slots, blocks_per_slot) int32.
        Inactive lanes run on scratch-backed views and only ever write the
        scratch block / their own state row.

        This is the gather/scatter *baseline*: every tick copies each
        slot's blocks into a contiguous view and scatters them back.  The
        fused path (:meth:`make_fused_tick`) has the same signature and
        never builds a view.
        """
        meta, paths, treedef = self.meta, self.paths, self.treedef
        t, mb = self.block_tokens, self.blocks_per_slot
        paged_paths = sorted(self.paged)
        state_paths = sorted(self.state)

        in_axes: List[int] = []
        for path in paths:
            m = meta[path]
            in_axes.append(0 if m.batch_ax is None else m.batch_ax)

        def step(params, tokens, lengths, tables, paged, state):
            def one(tok, *leaves):
                cache_leaves = []
                for path, leaf in zip(paths, leaves):
                    m = meta[path]
                    if m.batch_ax is None:
                        cache_leaves.append(leaf)  # per-slot scalar length
                    else:
                        cache_leaves.append(jnp.expand_dims(leaf, m.batch_ax))
                cache = jax.tree_util.tree_unflatten(treedef, cache_leaves)
                logits, cache2 = decode_fn(params, tok[None], cache)
                flat2, treedef2 = jax.tree_util.tree_flatten(cache2)
                assert treedef2 == treedef, "decode changed the cache structure"
                out = []
                for path, leaf in zip(paths, flat2):
                    m = meta[path]
                    out.append(leaf if m.batch_ax is None
                               else jnp.squeeze(leaf, axis=m.batch_ax))
                return logits[0], tuple(out)

            gathered = []
            for path in paths:
                m = meta[path]
                if m.batch_ax is None:
                    gathered.append(lengths)
                elif m.paged:
                    ba = m.batch_ax
                    g = jnp.take(paged[path], tables, axis=ba)
                    shape = g.shape  # (.., n_slots, mb, T, ..)
                    gathered.append(
                        g.reshape(shape[:ba + 1] + (mb * t,) + shape[ba + 3:]))
                else:
                    gathered.append(state[path])

            fn = jax.vmap(lambda tok, *ls: one(tok, *ls),
                          in_axes=(0,) + tuple(in_axes),
                          out_axes=(0, tuple(in_axes)))
            logits, new_leaves = fn(tokens, *gathered)

            new_paged, new_state, new_lengths = {}, {}, lengths
            for path, leaf in zip(paths, new_leaves):
                m = meta[path]
                if m.batch_ax is None:
                    new_lengths = leaf
                elif m.paged:
                    ba = m.batch_ax
                    shape = leaf.shape  # (.., n_slots, V, ..)
                    val = leaf.reshape(
                        shape[:ba + 1] + (mb, t) + shape[ba + 2:])
                    idx = (slice(None),) * ba + (tables,)
                    new_paged[path] = paged[path].at[idx].set(val)
                else:
                    new_state[path] = leaf
            # keep untouched fragments (e.g. pure-state archs have no paged)
            for path in paged_paths:
                new_paged.setdefault(path, paged[path])
            for path in state_paths:
                new_state.setdefault(path, state[path])
            return logits, new_paged, new_state, new_lengths

        return step

    def make_fused_tick(self, decode_paged_fn: Callable) -> Callable:
        """Tick built on the model's fused paged decode — same signature
        as :meth:`make_tick` but with **zero** per-tick gather/scatter of
        pool storage: ``decode_paged_fn(params, tokens, paged, state,
        tables, lengths) -> (logits, paged, state)`` reads KV blocks in
        place through the block table (paged attention kernel) and
        appends each slot's new token inside the kernel."""

        def step(params, tokens, lengths, tables, paged, state):
            logits, new_paged, new_state = decode_paged_fn(
                params, tokens, paged, state, tables, lengths)
            return logits, new_paged, new_state, lengths + 1

        return step

    def bind_step(self, tick: Callable) -> Callable:
        """Jit ``tick`` (donating pool storage) and bind it to this pool's
        device fragments:

            logits, lengths = run(params, tokens, lengths, tables)
        """
        jitted = jax.jit(tick, donate_argnums=(4, 5))

        def run(params, tokens, lengths, tables):
            logits, paged, state, new_lengths = jitted(
                params, jnp.asarray(tokens), jnp.asarray(lengths),
                jnp.asarray(tables), self.paged, self.state)
            self.paged, self.state = paged, state
            return logits, new_lengths

        # expose the inner jit so the profiler (repro.obs.profile) can
        # watch this tick's compile cache through the closure
        run._jitted = jitted
        return run

    def build_step(self, decode_fn: Callable) -> Callable:
        """Back-compat wrapper: gather/scatter tick, jitted and bound."""
        return self.bind_step(self.make_tick(decode_fn))
