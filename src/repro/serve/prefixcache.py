"""Prefix-sharing index over the block-paged KV pool.

Production serving traffic is dominated by shared prefixes — system
prompts, few-shot templates, multi-turn replays.  The block-paged pool
(:mod:`repro.serve.kvpool`) already stores KV at exactly the right
granularity: a block id in a slot's table is a block id no matter how
many tables reference it, and the fused paged-attention kernel walks
tables without caring who else maps a block.  This module adds the
missing pieces:

* a **hash-chain index over full token blocks** — block ``j`` of a
  prompt is keyed by ``H(key_{j-1}, tokens[jT:(j+1)T], quant signature)``,
  so equal keys imply equal *entire prefixes* (a radix tree flattened
  into a dict: each node's key already encodes the whole path).  The
  quant signature ties entries to the cache codec (kv_bits, storage
  dtype, block size, arch), since a block of 4-bit codes from one codec
  is garbage under another;
* **refcount bookkeeping** via :class:`~repro.serve.kvpool.KVPool`:
  mapping a cached block into a new slot's table increments its
  refcount, release decrements, and a refcount-0 block retained here
  stays *resident but off the free list* until evicted;
* **eviction** of idle (refcount-0, unpinned) cached blocks, leaf-first
  in least-recently-used order, under pool pressure — ``KVPool._alloc``
  calls back into :meth:`evict` when the free list runs dry, and
  ``can_admit`` counts idle cached blocks as supply;
* **pinning** for the lookup→prefill→admit window: matched blocks are
  pinned so the tail-block allocation of the very admission that found
  them cannot evict (and recycle) them mid-flight.

Only *full, immutable* prompt blocks are ever indexed: a partially
filled last block is private to its slot, and decode appends always
land past the prompt — combined with the scheduler's copy-on-write on
fully-cached prompts, no indexed block is ever written again, which is
what makes sharing bit-exact (quantized KV doubly so: identical codes,
identical scales, zero recomputation).
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.serve.kvpool import KVPool


@dataclasses.dataclass
class _Node:
    """One cached full block.  ``key`` hashes the whole prefix up to and
    including this block, so parent/child edges mirror prompt extension."""

    key: bytes
    block: int
    parent: Optional["_Node"]
    children: Dict[bytes, "_Node"]
    stamp: int  # logical LRU clock (no wall-clock: traces stay replayable)


@dataclasses.dataclass
class Hit:
    """A lookup result: the longest cached full-block prefix.

    ``blocks`` are pinned until :meth:`PrefixCache.unpin` (the scheduler
    releases the pin right after admission maps/copies them)."""

    blocks: List[int]

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)


class PrefixCache:
    """Refcounted radix/hash index mapping token prefixes to block chains.

    Attaches itself as ``pool.prefix`` (the duck-typed hook consulted by
    release/alloc/invariants).  All bookkeeping is host-side Python —
    device storage is untouched except through ``pool.reclaim``.
    """

    def __init__(self, pool: KVPool, sig: str = "",
                 capacity: Optional[int] = None, obs=None):
        assert pool.has_paged, "prefix sharing needs a paged cache"
        assert capacity is None or capacity >= 0, capacity
        self.pool = pool
        # Optional observability bundle (repro.obs.Observability): mirrors
        # the deterministic counters below into the metrics registry and
        # emits eviction trace events.  None = standalone cache.
        self.obs = obs
        self.t = pool.block_tokens
        self.sig = sig.encode()
        # max indexed blocks retained (ServeConfig.max_cached_blocks);
        # enforced at insert time against *idle* entries only — blocks
        # still referenced by live slots are never evicted, so the index
        # may transiently exceed the cap while sharers are active
        self.capacity = capacity
        self.nodes: Dict[bytes, _Node] = {}
        self._blocks: Dict[int, _Node] = {}
        self._pinned: Dict[int, int] = {}  # block id -> pin count
        self._stamp = 0
        # counters (logical, deterministic)
        self.lookups = 0
        self.hits = 0
        self.inserts = 0
        self.evictions = 0
        self.evictions_capacity = 0
        self.invalidations = 0
        self.bypass_lookups = 0
        # Self-bypass: when the health cycle finds index corruption
        # (check_invariants), the cache de-indexes everything and serves
        # unshared (lookup -> empty hit, insert -> no-op) instead of
        # crashing the engine.  One-way until flush() resets it.
        self.bypassed = False
        # Optional fault injector (set by the engine): insert() notifies
        # it so planned index corruption lands at deterministic ordinals.
        self.faults = None
        pool.prefix = self

    # ------------------------------------------------------------------
    # Keying
    # ------------------------------------------------------------------

    def _keys(self, tokens: np.ndarray) -> Iterable[bytes]:
        """Chained keys for each *full* block of ``tokens`` (S,) / (S, K)."""
        toks = np.ascontiguousarray(np.asarray(tokens))
        key = self.sig
        for j in range(toks.shape[0] // self.t):
            blk = toks[j * self.t:(j + 1) * self.t]
            key = hashlib.sha1(key + blk.tobytes()).digest()
            yield key

    # ------------------------------------------------------------------
    # Lookup / insert
    # ------------------------------------------------------------------

    def _touch(self, node: _Node) -> None:
        self._stamp += 1
        node.stamp = self._stamp

    def lookup(self, tokens: np.ndarray) -> Hit:
        """Longest cached full-block prefix of ``tokens``.

        Pins the matched blocks (eviction skips them) until
        :meth:`unpin`; touches their LRU stamps.  A bypassed cache
        always misses (served unshared, counted as ``prefix_bypass``)."""
        self.lookups += 1
        if self.bypassed:
            self.bypass_lookups += 1
            if self.obs is not None:
                self.obs.registry.counter("prefix_cache_lookups_total").inc(
                    outcome="bypass")
            return Hit(blocks=[])
        blocks: List[int] = []
        for key in self._keys(tokens):
            node = self.nodes.get(key)
            if node is None:
                break
            self._touch(node)
            self._pinned[node.block] = self._pinned.get(node.block, 0) + 1
            blocks.append(node.block)
        if blocks:
            self.hits += 1
        if self.obs is not None:
            self.obs.registry.counter("prefix_cache_lookups_total").inc(
                outcome="hit" if blocks else "miss")
        return Hit(blocks=blocks)

    def unpin(self, hit: Hit) -> None:
        for blk in hit.blocks:
            n = self._pinned.get(blk, 0) - 1
            if n <= 0:
                self._pinned.pop(blk, None)
            else:
                self._pinned[blk] = n

    def insert(self, tokens: np.ndarray, blocks: Sequence[int]) -> None:
        """Register the full prompt blocks of an admitted request.

        ``blocks``: the owning slot's block chain (``pool.slot_blocks``),
        at least ``len(tokens) // block_tokens`` long.  Existing entries
        are only touched (first writer wins — the incoming duplicate
        block is already mapped or will simply be released with its
        slot); new entries are linked under their parent."""
        if self.bypassed:
            return
        parent: Optional[_Node] = None
        for j, key in enumerate(self._keys(tokens)):
            node = self.nodes.get(key)
            if node is None:
                blk = int(blocks[j])
                if blk in self._blocks:
                    # block already indexed under a different key — cannot
                    # happen for distinct chains (slot chains are unique),
                    # but guard against re-registration
                    break
                node = _Node(key=key, block=blk, parent=parent,
                             children={}, stamp=0)
                self.nodes[key] = node
                self._blocks[blk] = node
                if parent is not None:
                    parent.children[key] = node
                self.inserts += 1
                if self.obs is not None:
                    self.obs.registry.counter(
                        "prefix_cache_inserts_total").inc()
            self._touch(node)
            parent = node
        self._enforce_capacity()
        if self.faults is not None:
            self.faults.on_insert(self)

    def _enforce_capacity(self) -> None:
        """Evict idle LRU leaves until the index fits ``capacity`` (the
        ``ServeConfig.max_cached_blocks`` knob).  Entries referenced by
        live slots (or pinned mid-admission) are not evictable; if only
        those remain the index stays over the cap until they idle."""
        if self.capacity is None:
            return
        while len(self._blocks) > self.capacity:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: (nd.stamp, nd.block))
            self._drop(victim)
            self.pool.reclaim([victim.block])
            self.evictions_capacity += 1
            self._note_eviction(victim.block, "capacity")

    # ------------------------------------------------------------------
    # Pool protocol (duck-typed hook: see KVPool.prefix)
    # ------------------------------------------------------------------

    def holds(self, block: int) -> bool:
        return block in self._blocks

    def blocks(self) -> Iterable[int]:
        return self._blocks.keys()

    def evictable(self) -> int:
        """Idle cached blocks eviction could reclaim right now.

        refcount-0 ∧ unpinned is descendant-closed (a slot referencing a
        child block references every ancestor block in its table, and
        lookup pins whole prefix chains), so the count equals the set
        size — whole subtrees go leaf-first."""
        rc = self.pool.refcount
        return sum(1 for b in self._blocks
                   if rc[b] == 0 and b not in self._pinned)

    def _evictable_leaves(self) -> List[_Node]:
        rc = self.pool.refcount
        return [n for n in self._blocks.values()
                if not n.children and rc[n.block] == 0
                and n.block not in self._pinned]

    def _drop(self, node: _Node) -> None:
        assert not node.children, "evicting an internal node"
        del self.nodes[node.key]
        del self._blocks[node.block]
        if node.parent is not None:
            node.parent.children.pop(node.key, None)

    def evict(self, n: int) -> int:
        """Evict up to ``n`` idle cached blocks (leaf-first LRU, ties by
        block id for determinism), returning them to the pool free list."""
        done = 0
        while done < n:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            victim = min(leaves, key=lambda nd: (nd.stamp, nd.block))
            self._drop(victim)
            self.pool.reclaim([victim.block])
            self.evictions += 1
            self._note_eviction(victim.block, "pressure")
            done += 1
        return done

    def _note_eviction(self, block: int, reason: str) -> None:
        if self.obs is None:
            return
        self.obs.registry.counter("prefix_cache_evictions_total").inc(
            reason=reason)
        if self.obs.tracer is not None:
            self.obs.tracer.event("prefix_evict", cat="prefixcache",
                                  block=block, reason=reason)

    def flush(self) -> None:
        """Drop the whole index.  Idle blocks go back to the free list;
        blocks still referenced by live slots are merely de-indexed (their
        storage returns through the normal release path).  Also re-arms a
        bypassed cache (the corrupt index is gone)."""
        self.evict(len(self._blocks))
        for node in list(self._blocks.values()):
            # still-referenced (or pinned) leftovers: de-index only
            del self.nodes[node.key]
            del self._blocks[node.block]
            node.children.clear()
            if node.parent is not None:
                node.parent.children.pop(node.key, None)
        self.bypassed = False

    # ------------------------------------------------------------------
    # Health: invariant audit, self-bypass, targeted invalidation
    # ------------------------------------------------------------------

    def check_invariants(self) -> List[str]:
        """Non-raising index audit (issue strings; empty = healthy):
        every indexed block must be a real pool block, off the free
        list, consistently keyed, and properly linked.  The scheduler's
        health cycle bypasses the cache on any issue."""
        issues: List[str] = []
        free = set(self.pool.free)
        for blk, node in self._blocks.items():
            if not (0 < blk < self.pool.n_blocks):
                issues.append(f"indexed block {blk} outside the pool")
                continue
            if blk in free:
                issues.append(f"indexed block {blk} is on the free list")
            if node.block != blk:
                issues.append(f"index maps block {blk} to node holding "
                              f"{node.block}")
            if self.nodes.get(node.key) is not node:
                issues.append(f"block {blk}: key chain entry missing or "
                              f"aliased")
            if node.parent is not None and \
                    node.parent.children.get(node.key) is not node:
                issues.append(f"block {blk}: broken parent link")
        for key, node in self.nodes.items():
            if self._blocks.get(node.block) is not node:
                issues.append(f"node for block {node.block} not in the "
                              f"block index")
        return issues

    def bypass(self) -> None:
        """Stop sharing: de-index every entry *without* reclaiming any
        storage (a corrupt index cannot be trusted to know which blocks
        are really idle) and serve unshared from now on.  Blocks still
        mapped by live slots return through the normal release path;
        orphaned idle blocks are reclaimed by ``KVPool.recover`` in the
        same health cycle."""
        self.bypassed = True
        for node in list(self._blocks.values()):
            node.children.clear()
        self.nodes.clear()
        self._blocks.clear()
        self._pinned.clear()

    def invalidate(self, blocks: Sequence[int]) -> int:
        """De-index ``blocks`` and every descendant chain (a quarantined
        request's blocks may be suspect — e.g. written while its logits
        went non-finite — so the whole subtree built on them is dropped).
        De-index only: storage still referenced by live slots returns
        through release; idle storage through release/recover.  Returns
        the number of entries dropped."""
        dropped = 0
        for blk in list(blocks):
            node = self._blocks.get(int(blk))
            if node is not None:
                dropped += self._drop_subtree(node)
        self.invalidations += dropped
        return dropped

    def _drop_subtree(self, node: _Node) -> int:
        n = 0
        for child in list(node.children.values()):
            n += self._drop_subtree(child)
        self.nodes.pop(node.key, None)
        self._blocks.pop(node.block, None)
        self._pinned.pop(node.block, None)
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        return n + 1

    def _plant_corruption(self) -> None:
        """Fault-injection hook: plant a bogus node claiming a free-list
        block — exactly the inconsistency :meth:`check_invariants`
        exists to catch.  Only ever called by a FaultInjector."""
        # peeked, not popped, from the *bottom* of the LIFO free list (the
        # last block allocation would touch), so the block-both-free-and-
        # indexed contradiction survives until a health cycle sees it
        blk = self.pool.free[0]
        key = b"corrupt:%d" % blk
        node = _Node(key=key, block=blk, parent=None, children={}, stamp=0)
        self.nodes[key] = node
        self._blocks[blk] = node

    # ------------------------------------------------------------------

    def stats(self) -> Dict[str, int]:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "evictions_capacity": self.evictions_capacity,
            "invalidations": self.invalidations,
            "bypassed": self.bypassed,
            "bypass_lookups": self.bypass_lookups,
            "cached_blocks": len(self._blocks),
        }
