"""Serving subsystem: engine + continuous-batching scheduler + paged KV pool."""
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.faults import FaultPlan, InjectedFault
from repro.serve.kvpool import KVPool
from repro.serve.scheduler import (ContinuousScheduler, QueueFull, Request,
                                   synthetic_trace)

__all__ = [
    "ContinuousScheduler", "FaultPlan", "InjectedFault", "KVPool",
    "QueueFull", "Request", "ServeConfig", "ServeEngine", "synthetic_trace",
]
