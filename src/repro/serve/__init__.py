"""Serving subsystem: engine + continuous-batching scheduler + paged KV pool."""
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.kvpool import KVPool
from repro.serve.scheduler import ContinuousScheduler, Request, synthetic_trace

__all__ = [
    "ContinuousScheduler", "KVPool", "Request", "ServeConfig", "ServeEngine",
    "synthetic_trace",
]
