"""Deterministic fault injection for the serving stack.

The robustness layer (request isolation, deadlines/backpressure, graceful
degradation, health cycles) is only trustworthy if its failure paths are
*exercised*, and real failures — a NaN blowing up in W2 logits, a user
callback throwing mid-stream, a draft overlay going sideways — are rare
and nondeterministic.  This module makes them reproducible:

* :class:`FaultPlan` — a frozen, JSON-serializable description of *which*
  failures to inject *where*, keyed entirely by logical coordinates
  (request id, emitted-token index, window/release/insert ordinals) so
  the same plan replays bit-identically on any machine.  Injected via
  ``ServeConfig(faults=plan)``; ``faults=None`` (the default) keeps every
  injection site compiled/branched out — the same zero-overhead
  discipline as ``ObsConfig(enabled=False)``.
* :class:`FaultInjector` — the per-engine mutable runtime: ordinal
  counters plus the predicates the scheduler/engine/pool consult at each
  named injection point.
* :class:`InjectedFault` — the exception raised at injected raise-points
  (``on_token`` callbacks, draft windows), so tests can distinguish
  injected failures from real bugs.
* :class:`StallClock` — a monotonic-clock wrapper that adds planned
  offsets at given call ordinals, driving deadline expiry and the drain
  watchdog deterministically (no sleeps, no wall-clock in tests).

Injection points and the hardening they exercise:

==================  ====================================================
``nan_logits``      request *r*'s logits become NaN at emitted-token
                    index *n* -> on-device non-finite detection in the
                    sampler, per-slot quarantine (``status="failed"``,
                    blocks released, survivors untouched)
``callback_raise``  ``on_token`` raises for (r, n) -> guarded callbacks,
                    mid-window-replay isolation
``draft_fail``      the k-th spec window raises before dispatch ->
                    plain-decode fallback + auto-disable after repeated
                    failures (token-identical degradation)
``leak_block``      the k-th pool release drops a free-list entry ->
                    periodic health cycle audits and reclaims it as a
                    counted recoverable event
``corrupt_prefix``  the k-th prefix-cache insert plants a bogus index
                    entry -> ``check_invariants`` detects it and the
                    cache self-bypasses (serving unshared) instead of
                    crashing
``clock_stall``     the k-th clock read jumps forward by s seconds ->
                    deadline/TTL expiry and drain-watchdog paths
==================  ====================================================
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Tuple, Union

__all__ = ["FaultPlan", "FaultInjector", "InjectedFault", "StallClock"]


class InjectedFault(RuntimeError):
    """Raised at injected raise-points; never raised without a plan."""


def _pairs(v) -> Tuple[Tuple[int, int], ...]:
    return tuple((int(a), int(b)) for a, b in v)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Deterministic failure schedule, injected via ``ServeConfig(faults=...)``.

    All coordinates are logical: ``rid`` is the scheduler-assigned
    request id, token indices are 0-based emitted-token positions,
    ordinals count events of that kind since engine build (0-based).
    An empty plan arms the injection machinery without firing anything —
    the bench's ``faults_off`` overhead row measures exactly that.
    """

    # (rid, token_idx): non-finite logits when request rid samples its
    # token_idx-th new token (prefill sample included at idx 0)
    nan_logits: Tuple[Tuple[int, int], ...] = ()
    # (rid, token_idx): the on_token callback slot raises after request
    # rid emits its token_idx-th token (fires whether or not the request
    # installed a callback)
    callback_raise: Tuple[Tuple[int, int], ...] = ()
    # spec-window ordinals that raise InjectedFault before dispatch
    draft_fail: Tuple[int, ...] = ()
    # release ordinals after which one free-list entry silently vanishes
    leak_block: Tuple[int, ...] = ()
    # prefix-cache insert ordinals after which a bogus node is planted
    corrupt_prefix: Tuple[int, ...] = ()
    # (call_ordinal, seconds): the clock jumps forward at that read
    clock_stall: Tuple[Tuple[int, float], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "nan_logits", _pairs(self.nan_logits))
        object.__setattr__(self, "callback_raise",
                           _pairs(self.callback_raise))
        object.__setattr__(self, "draft_fail",
                           tuple(int(v) for v in self.draft_fail))
        object.__setattr__(self, "leak_block",
                           tuple(int(v) for v in self.leak_block))
        object.__setattr__(self, "corrupt_prefix",
                           tuple(int(v) for v in self.corrupt_prefix))
        object.__setattr__(self, "clock_stall", tuple(
            (int(a), float(b)) for a, b in self.clock_stall))

    @classmethod
    def from_json(cls, spec: Union[str, Dict]) -> "FaultPlan":
        """Build a plan from a JSON object / string / ``@path`` (the
        launchers' ``--inject-faults`` argument)."""
        if isinstance(spec, str):
            if spec.startswith("@"):
                with open(spec[1:]) as f:
                    spec = json.load(f)
            else:
                spec = json.loads(spec)
        if not isinstance(spec, dict):
            raise ValueError(f"fault plan must be a JSON object, "
                             f"got {type(spec).__name__}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = sorted(set(spec) - known)
        if unknown:
            raise ValueError(f"unknown fault plan keys {unknown}; "
                             f"known: {sorted(known)}")
        return cls(**spec)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @property
    def empty(self) -> bool:
        return not any(dataclasses.astuple(self))


class FaultInjector:
    """Per-engine runtime: ordinal counters + injection-point predicates.

    Each ``(rid, idx)`` entry fires at most once; ordinal-keyed faults
    fire when their event counter passes the planned ordinal.  The
    injector never mutates engine state except where documented
    (``on_release`` removes a free-list entry, ``on_insert`` plants an
    index node) — every other method is a pure predicate.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.releases = 0
        self.inserts = 0
        self.spec_windows = 0
        self._nan = set(plan.nan_logits)
        self._cb = set(plan.callback_raise)
        self.leaked_blocks: List[int] = []
        self.fired: List[str] = []  # audit log of what actually fired

    # -- logits / callbacks --------------------------------------------
    def poison_token(self, rid: int, idx: int) -> bool:
        """True exactly once when request ``rid`` samples token ``idx``."""
        if (rid, idx) in self._nan:
            self._nan.discard((rid, idx))
            self.fired.append(f"nan_logits r{rid} t{idx}")
            return True
        return False

    def poison_from(self, rid: int, count: int,
                    limit: Optional[int] = None) -> int:
        """Earliest planned poison index in ``[count, limit)`` for ``rid``
        (the window paths poison by token count), or -1.  Entries beyond
        ``limit`` stay planned for a later window."""
        hits = [i for r, i in self._nan if r == rid and i >= count
                and (limit is None or i < limit)]
        if not hits:
            return -1
        idx = min(hits)
        self._nan.discard((rid, idx))
        self.fired.append(f"nan_logits r{rid} t{idx}")
        return idx

    def callback_raises(self, rid: int, idx: int) -> bool:
        if (rid, idx) in self._cb:
            self._cb.discard((rid, idx))
            self.fired.append(f"callback_raise r{rid} t{idx}")
            return True
        return False

    # -- spec decode ----------------------------------------------------
    def draft_window_fails(self) -> bool:
        """Consulted once per spec window, before dispatch."""
        w = self.spec_windows
        self.spec_windows += 1
        if w in self.plan.draft_fail:
            self.fired.append(f"draft_fail w{w}")
            return True
        return False

    # -- pool / prefix corruption --------------------------------------
    def on_release(self, pool) -> None:
        """Called after each ``KVPool.release``; at planned ordinals one
        free-list entry vanishes (simulating lost bookkeeping) for the
        health cycle's audit/recover path to find."""
        r = self.releases
        self.releases += 1
        if r in self.plan.leak_block and pool.free:
            blk = pool.free.pop()
            pool.refcount[blk] = 0
            self.leaked_blocks.append(blk)
            self.fired.append(f"leak_block #{r} -> block {blk}")

    def on_insert(self, cache) -> None:
        """Called after each ``PrefixCache.insert``; at planned ordinals
        plants a bogus node claiming a free-list block, for
        ``PrefixCache.check_invariants`` to flag (-> self-bypass)."""
        i = self.inserts
        self.inserts += 1
        if i in self.plan.corrupt_prefix and cache.pool.free:
            cache._plant_corruption()
            self.fired.append(f"corrupt_prefix #{i}")


class StallClock:
    """Monotonic clock with planned forward jumps at call ordinals.

    Wraps the engine's configured clock *before* the Observability bundle
    is built (the tracer captures its clock reference at construction),
    so every consumer — scheduler timestamps, deadlines, the drain
    watchdog, trace spans — sees the same stalled timeline.
    """

    def __init__(self, base: Callable[[], float],
                 stalls: Tuple[Tuple[int, float], ...]):
        self._base = base
        self._stalls = dict(stalls)
        self._calls = 0
        self._offset = 0.0

    def __call__(self) -> float:
        jump = self._stalls.get(self._calls)
        if jump is not None:
            self._offset += float(jump)
        self._calls += 1
        return self._base() + self._offset


def build_injector(plan: Optional[FaultPlan]) -> Optional[FaultInjector]:
    """``None`` plan -> ``None`` injector: callers keep a single
    ``is not None`` check as their only overhead when faults are off."""
    return None if plan is None else FaultInjector(plan)
