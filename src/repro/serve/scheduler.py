"""Continuous-batching request scheduler over the paged KV pool.

The static engine decodes a fixed batch until the *longest* sequence
finishes — one straggler holds every slot hostage.  The scheduler keeps a
FIFO request queue and drives the engine slot-by-slot instead:

* **admission**: whenever a slot is free and the pool's conservative
  block reservation accepts the queue head, the request is prefilled
  immediately (prefill-on-admit, batch=1, exact prompt length) and its
  first token sampled from the prefill logits;
* **decode**: one batched pool step per tick runs *all* active slots
  (per-slot lengths via the vmapped block-gathered views — see
  :mod:`repro.serve.kvpool`), so slots never wait for each other;
* **stop + refill**: a slot that hits its ``max_new_tokens`` (or stop
  token) releases its blocks and is refilled on the same tick — no
  reallocation or copying of surviving slots;
* **sync cadence**: sampling runs *on device* (greedy argmax or the
  per-request categorical key chain) so only token ids cross to the
  host — one (n_slots,) transfer per tick, never the (n_slots, V)
  logits.  With ``ServeConfig(steps_per_sync=N)`` the per-token
  round-trip disappears entirely: the engine runs an in-graph window of
  up to N decode ticks with per-slot stop/length masks and a device-side
  done bitmap, and the host syncs once per window to flush callbacks and
  refill freed slots (``metrics()["aggregate"]["host_syncs"]`` counts
  the decode-path transfers);
* **streaming**: every sampled token is pushed through the request's
  ``on_token`` callback the tick (or window flush) it is produced, in
  token order per request;
* **metrics**: per-request queue wait / TTFT / latency and aggregate
  decode-slot utilisation (busy slot-ticks over total slot-ticks) and
  tokens/s.

Token-identity: with greedy sampling the scheduler reproduces the static
``generate()`` tokens exactly — prefill and decode are per-sequence
computations, so batch composition (and therefore scheduling order)
cannot change any sequence's logits.  With ``temperature > 0`` each
request draws from its own fold_in(seed, rid) key stream instead of the
static engine's shared per-step stream.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import ENGINE_PID, REQUEST_PID, Observability
from repro.obs.profile import register_profile_metrics
from repro.serve.faults import InjectedFault


class QueueFull(RuntimeError):
    """Raised by ``submit`` under ``ServeConfig(max_queue=N,
    queue_policy="raise")`` when the admission queue is at capacity."""


def register_serving_metrics(reg) -> None:
    """Declare the full serving metric schema up front.

    Registration is feature-independent — prefix-cache and spec-decode
    metrics exist (at zero) even when those features are off — so the
    exported name/kind/label schema is identical across every
    ``ServeConfig`` combination (frozen by the schema test).
    """
    c = reg.counter
    c("serve_requests_submitted_total", "Requests accepted by submit()")
    c("serve_requests_finished_total", "Requests run to completion")
    c("serve_decode_steps_total",
      "Batched decode ticks (verify steps under spec decode)")
    c("serve_busy_slot_steps_total", "Slot-ticks that decoded a live request")
    c("serve_tokens_generated_total", "Tokens emitted across all requests")
    c("serve_host_syncs_total", "Device->host transfers on the decode path")
    c("serve_prefill_tokens_computed_total",
      "Prompt positions actually prefilled")
    c("serve_prefill_tokens_saved_total",
      "Prompt positions served from the prefix cache")
    c("serve_blocks_shared_total", "Cached blocks mapped into slot tables")
    c("serve_cow_copies_total",
      "Copy-on-write block copies (fully-cached prompts)")
    c("serve_spec_windows_total", "Draft-k/verify-1 windows run")
    c("serve_spec_draft_tokens_total", "Draft tokens proposed")
    c("serve_spec_accepted_tokens_total", "Draft tokens the target confirmed")
    c("kvpool_blocks_allocated_total", "KV blocks taken off the free list")
    c("kvpool_blocks_released_total", "KV blocks returned to the free list")
    c("prefix_cache_lookups_total", "Prefix-cache lookups by outcome",
      labels=("outcome",))
    c("prefix_cache_inserts_total", "Prompt-block runs indexed by the cache")
    c("prefix_cache_evictions_total", "Cached blocks evicted by cause",
      labels=("reason",))
    # robustness: failure / degradation / self-healing accounting
    c("serve_requests_failed_total",
      "Requests that did not finish, by failure reason",
      labels=("reason",))
    c("serve_degraded_events_total",
      "Graceful-degradation events (subsystem disabled or self-healed)",
      labels=("subsystem",))
    c("serve_draft_failures_total", "Spec-decode draft windows that raised")
    c("kvpool_blocks_recovered_total",
      "Leaked KV blocks reclaimed by the pool health cycle")
    reg.gauge("serve_queue_depth", "Requests waiting for admission")
    reg.gauge("serve_active_slots", "Slots decoding a live request")
    reg.gauge("kvpool_free_blocks", "KV blocks on the pool free list")
    reg.histogram("serve_queue_wait_seconds", "Submit -> admission wait")
    reg.histogram("serve_ttft_seconds", "Submit -> first token")
    reg.histogram("serve_request_latency_seconds", "Submit -> finish")
    reg.histogram("serve_decode_utilisation",
                  "Busy-slot fraction per decode step",
                  buckets=tuple(i / 8 for i in range(1, 9)))
    reg.histogram("serve_spec_accepted_per_window",
                  "Accepted draft tokens per slot-window",
                  buckets=tuple(float(i) for i in range(9)))
    register_profile_metrics(reg)


class _LegacyCounter:
    """Scheduler counter attribute backed by the metrics registry.

    Preserves the historical plain-int API (``self.decode_steps += 1`` in
    the step paths, ``eng.scheduler.decode_steps = 0`` in the serving
    bench's warm-up reset) while the value lives in a registry
    :class:`~repro.obs.metrics.Counter`, so the legacy ``metrics()`` view
    and the Prometheus/JSON exports can never disagree.
    """

    def __init__(self, metric: str):
        self.metric = metric

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return int(obj.reg.counter(self.metric).value())

    def __set__(self, obj, v):
        obj.reg.counter(self.metric)._set(float(v))


@dataclasses.dataclass
class Request:
    """One generation request.

    ``prompt``: (S,) int32 token ids (audio: (S, K) codebook ids).
    ``on_token(request, token, done)`` streams each sampled token the
    tick it is produced (token is an int, or a (K,) array for audio).
    """

    prompt: np.ndarray
    max_new_tokens: int
    patch_embeds: Optional[np.ndarray] = None  # vlm: (P, D) prefix
    stop_token: Optional[int] = None
    on_token: Optional[Callable[["Request", object, bool], None]] = None
    # TTL from submission: the request expires with status="timeout" in
    # queue or mid-decode once deadline_s has elapsed (None = no deadline)
    deadline_s: Optional[float] = None

    # -- filled by the scheduler ----------------------------------------
    rid: int = -1
    tokens: List = dataclasses.field(default_factory=list)
    # queued | active | done | failed | timeout | rejected | aborted
    status: str = "queued"
    error: Optional[str] = None  # set when the request did not finish
    submit_t: float = 0.0
    admit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None
    prompt_tokens: int = 0

    @property
    def queue_wait_s(self) -> Optional[float]:
        return None if self.admit_t is None else self.admit_t - self.submit_t

    @property
    def ttft_s(self) -> Optional[float]:
        return None if self.first_token_t is None else (
            self.first_token_t - self.submit_t)

    def token_array(self) -> np.ndarray:
        if not self.tokens:  # rejected / expired before the first token
            return np.zeros((0,), np.int32)
        return np.stack(self.tokens).astype(np.int32)


class ContinuousScheduler:
    """Admission loop + per-slot stop/refill over a ``ServeEngine``.

    The engine supplies prefill (``engine.prefill_one``), the pool step
    (``engine.pool`` / ``engine.pool_step``) and the sampling config;
    the scheduler owns request/slot lifecycle and metrics.  ``clock`` is
    injectable so tests stay deterministic.
    """

    # Aggregate counters: the historical int attributes, now registry-backed
    # (see _LegacyCounter).  decode_steps counts verify steps under spec
    # decode; host_syncs counts device->host transfers on the decode path;
    # the prefix/spec groups stay zero when those features are off.
    decode_steps = _LegacyCounter("serve_decode_steps_total")
    busy_slot_steps = _LegacyCounter("serve_busy_slot_steps_total")
    tokens_generated = _LegacyCounter("serve_tokens_generated_total")
    host_syncs = _LegacyCounter("serve_host_syncs_total")
    prefill_tokens_computed = _LegacyCounter(
        "serve_prefill_tokens_computed_total")
    prefill_tokens_saved = _LegacyCounter("serve_prefill_tokens_saved_total")
    blocks_shared = _LegacyCounter("serve_blocks_shared_total")
    cow_copies = _LegacyCounter("serve_cow_copies_total")
    spec_windows = _LegacyCounter("serve_spec_windows_total")
    spec_draft_tokens = _LegacyCounter("serve_spec_draft_tokens_total")
    spec_accepted_tokens = _LegacyCounter("serve_spec_accepted_tokens_total")

    def __init__(self, engine, clock: Optional[Callable[[], float]] = None):
        self.engine = engine
        self.obs: Observability = getattr(engine, "obs", None) or Observability()
        self.reg = self.obs.registry
        self.tracer = self.obs.tracer
        self.clock = clock or self.obs.clock
        register_serving_metrics(self.reg)
        self.queue: deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * engine.pool.n_slots
        self.slot_next: List[Optional[np.ndarray]] = [None] * engine.pool.n_slots
        self.done: List[Request] = []
        self.failed: List[Request] = []  # failed / timeout / rejected / aborted
        self._next_rid = 0
        self._spans: Dict[int, Dict[str, object]] = {}  # rid -> live spans
        if self.tracer is not None:
            self.tracer.label(ENGINE_PID, 0, "scheduler")
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None
        # -- robustness state (all inert when the knobs are unset) -------
        self._faults = getattr(engine, "faults", None)
        self._has_deadlines = False  # flips on the first deadline_s submit
        scfg = engine.scfg
        self._health_every = getattr(scfg, "health_every_syncs", None)
        self._last_health = 0
        self.spec_degraded = False  # spec decode globally disabled
        self._spec_fail_streak = 0  # consecutive draft-window raises
        self._spec_bypass: set = set()  # rids decoding plainly (per-slot)
        self._req_spec: Dict[int, List[int]] = {}  # rid -> [windows, drafted, accepted]
        self._acc_recent: deque = deque(
            maxlen=max(1, int(getattr(scfg, "spec_accept_window", 8))))

    def reset_metrics(self) -> None:
        """Zero every aggregate counter and histogram series and drop
        finished-request records (bench warm-up isolation).  Pool and
        prefix-cache contents are untouched — flush the prefix cache
        separately for a cold run."""
        self.reg.reset()
        self.done = []
        self.failed = []
        self._t_first = None
        self._t_last = None

    # ------------------------------------------------------------------
    @property
    def pool(self):
        return self.engine.pool

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def submit(self, req: Request) -> Request:
        cfg = self.engine.cfg
        req.prompt = np.asarray(req.prompt, np.int32)
        if req.prompt.ndim != (2 if cfg.modality == "audio" else 1):
            raise ValueError(f"prompt rank {req.prompt.ndim} invalid for "
                             f"modality {cfg.modality}")
        if req.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if req.stop_token is not None and cfg.modality == "audio":
            raise ValueError("stop_token undefined for audio requests "
                             "(tokens are per-codebook vectors)")
        s_total = req.prompt.shape[0]
        if cfg.modality == "vlm" and req.patch_embeds is not None:
            s_total += req.patch_embeds.shape[0]
        req.prompt_tokens = s_total
        # spec_margin: a spec-decode window writes up to draft_k positions
        # past the pending token before the host accepts/rewinds, so the
        # worst-case reservation covers the overshoot (0 when disabled)
        worst = (s_total + max(0, req.max_new_tokens - 1)
                 + getattr(self.engine, "spec_margin", 0))
        if worst > self.pool.view_tokens:
            raise ValueError(
                f"request needs up to {worst} cache positions; pool view "
                f"holds {self.pool.view_tokens} (raise ServeConfig.max_seq)")
        if self.pool.blocks_for(worst) > self.pool.capacity_blocks:
            raise ValueError(
                f"request needs {self.pool.blocks_for(worst)} blocks; pool "
                f"has {self.pool.capacity_blocks}")
        mq = getattr(self.engine.scfg, "max_queue", None)
        if mq is not None and len(self.queue) >= mq:
            policy = getattr(self.engine.scfg, "queue_policy", "reject")
            if policy == "raise":
                raise QueueFull(
                    f"admission queue full ({len(self.queue)}/{mq} waiting); "
                    f"retry later or raise ServeConfig.max_queue")
            # "reject": the request comes back with status="rejected" and
            # req.error set, never enqueued — load-shedding under overload
            req.rid = self._next_rid
            self._next_rid += 1
            req.submit_t = self.clock()
            self._fail(None, req, "queue_full",
                       f"rejected: admission queue full ({mq} waiting)",
                       status="rejected")
            return req
        if req.deadline_s is not None:
            self._has_deadlines = True
        req.rid = self._next_rid
        self._next_rid += 1
        req.submit_t = self.clock()
        req.status = "queued"
        self.queue.append(req)
        self.reg.counter("serve_requests_submitted_total").inc()
        self.reg.gauge("serve_queue_depth").set(len(self.queue))
        if self.tracer is not None:
            tr = self.tracer
            tr.label(REQUEST_PID, req.rid, f"request {req.rid}")
            self._spans[req.rid] = {
                "request": tr.begin(
                    "request", pid=REQUEST_PID, tid=req.rid, t=req.submit_t,
                    rid=req.rid, prompt_tokens=req.prompt_tokens,
                    max_new=req.max_new_tokens),
                "queue": tr.begin("queue", pid=REQUEST_PID, tid=req.rid,
                                  t=req.submit_t),
            }
            tr.event("enqueue", pid=REQUEST_PID, tid=req.rid, t=req.submit_t,
                     rid=req.rid)
        return req

    # ------------------------------------------------------------------
    def _sample(self, logits: np.ndarray, req: Request):
        """logits: (V,) or (K, V) float. Greedy unless temperature > 0.

        Delegates to the engine's one sampler (the same jitted function
        the decode tick and the in-graph window use), so the per-request
        fold_in(seed, rid) -> fold_in(key, n_emitted) draw chain has a
        single implementation.  Returns ``(token, bad)`` — ``bad`` is the
        sampler's on-device non-finite flag for this request."""
        logits = jnp.asarray(logits)
        if (self._faults is not None
                and self._faults.poison_token(req.rid, len(req.tokens))):
            logits = jnp.full_like(logits, jnp.nan)
        tok, bad = self.engine.sample_slots(
            logits[None], np.asarray([req.rid], np.int32),
            np.asarray([len(req.tokens)], np.int32))
        return np.asarray(tok)[0].astype(np.int32), bool(np.asarray(bad)[0])

    def _emit(self, slot: int, req: Request, tok: np.ndarray) -> bool:
        """Record one sampled token; returns True when the request stops."""
        now = self.clock()
        first = req.first_token_t is None
        if first:
            req.first_token_t = now
        req.tokens.append(tok)
        self.tokens_generated += 1
        self._t_last = now
        done = len(req.tokens) >= req.max_new_tokens or (
            req.stop_token is not None and np.ndim(tok) == 0
            and int(tok) == req.stop_token)
        spans = self._spans.get(req.rid) if self.tracer is not None else None
        if spans is not None:
            if first:
                spans["decode"] = self.tracer.begin(
                    "decode", pid=REQUEST_PID, tid=req.rid, t=now)
            self.tracer.event("token", pid=REQUEST_PID, tid=req.rid, t=now,
                              i=len(req.tokens), done=done)
        try:
            if (self._faults is not None
                    and self._faults.callback_raises(req.rid,
                                                     len(req.tokens) - 1)):
                raise InjectedFault(f"injected on_token failure r{req.rid}")
            if req.on_token is not None:
                req.on_token(req, tok, done)
        except Exception as e:
            # user code raised mid-stream: quarantine this request (it
            # keeps the tokens emitted so far) and keep the tick/window
            # replay running for every other slot
            self._fail(slot, req, "callback",
                       f"on_token callback raised: {e!r}")
            return True
        if done:
            req.status = "done"
            req.finish_t = now
            self.done.append(req)
            self.pool.release(slot)
            self.slot_req[slot] = None
            self.slot_next[slot] = None
            self.reg.counter("serve_requests_finished_total").inc()
            self.reg.histogram("serve_queue_wait_seconds").observe(
                req.queue_wait_s)
            self.reg.histogram("serve_ttft_seconds").observe(req.ttft_s)
            self.reg.histogram("serve_request_latency_seconds").observe(
                req.finish_t - req.submit_t)
            self.reg.gauge("serve_active_slots").set(self.n_active)
            if spans is not None:
                self.tracer.end(spans["decode"], t=now,
                                new_tokens=len(req.tokens))
                self.tracer.end(spans["request"], t=now,
                                new_tokens=len(req.tokens))
                del self._spans[req.rid]
        else:
            self.slot_next[slot] = np.asarray(tok, np.int32)
        return done

    def _fail(self, slot: Optional[int], req: Request, reason: str,
              error: str, *, status: str = "failed") -> None:
        """Quarantine one request: record the failure, release its slot's
        blocks (and de-index any shared ones), and keep serving.

        ``slot`` is None for requests failed outside a slot (queued
        timeout, queue-full rejection, abort of queued work).  Survivor
        isolation: nothing here touches any other slot or the queue, and
        per-sequence compute + per-request sampling keys mean the freed
        slot changing hands cannot perturb surviving token streams."""
        now = self.clock()
        req.status = status
        req.error = error
        req.finish_t = now
        self.failed.append(req)
        if slot is not None:
            pool = self.pool
            pc = getattr(self.engine, "prefix_cache", None)
            if pc is not None and not pc.bypassed and reason == "nan_logits":
                # the poisoned slot's KV blocks may be indexed for
                # sharing; drop them (and dependent suffixes) before the
                # release can hand them to a future prefill
                pc.invalidate(list(pool.slot_blocks[slot]))
            pool.release(slot)
            self.slot_req[slot] = None
            self.slot_next[slot] = None
            self.reg.gauge("serve_active_slots").set(self.n_active)
        self.reg.counter("serve_requests_failed_total").inc(reason=reason)
        if self.tracer is not None:
            spans = self._spans.pop(req.rid, None)
            if spans is not None:
                for name in ("queue", "decode"):
                    if name in spans:
                        self.tracer.end(spans[name], t=now)
                self.tracer.end(spans["request"], t=now, status=status,
                                error=error)
            self.tracer.event("failed", pid=REQUEST_PID, tid=req.rid, t=now,
                              reason=reason, error=error)

    def _expire_deadlines(self) -> None:
        """Fail every queued or active request whose TTL has elapsed."""
        now = self.clock()
        expired = [r for r in self.queue if r.deadline_s is not None
                   and now - r.submit_t >= r.deadline_s]
        if expired:
            # by identity: Request.__eq__ compares the prompt arrays
            dead = {id(r) for r in expired}
            self.queue = deque(r for r in self.queue if id(r) not in dead)
        for r in expired:
            self._fail(None, r, "timeout",
                       f"deadline_s={r.deadline_s} expired after "
                       f"{now - r.submit_t:.3f}s in queue", status="timeout")
        if expired:
            self.reg.gauge("serve_queue_depth").set(len(self.queue))
        for s, r in enumerate(self.slot_req):
            if (r is not None and r.deadline_s is not None
                    and now - r.submit_t >= r.deadline_s):
                self._fail(s, r, "timeout",
                           f"deadline_s={r.deadline_s} expired after "
                           f"{now - r.submit_t:.3f}s "
                           f"({len(r.tokens)} tokens emitted)",
                           status="timeout")

    def abort(self) -> List[Request]:
        """Cancel all in-flight work: every queued and active request is
        failed with ``status="aborted"`` and its resources released — the
        mid-stream shutdown path.  Afterwards the pool reconciles
        (``check_invariants``/``check_leaks`` pass) and the scheduler can
        keep serving new submissions."""
        aborted = []
        while self.queue:
            r = self.queue.popleft()
            self._fail(None, r, "aborted", "scheduler aborted",
                       status="aborted")
            aborted.append(r)
        self.reg.gauge("serve_queue_depth").set(0)
        for s, r in enumerate(self.slot_req):
            if r is not None:
                self._fail(s, r, "aborted", "scheduler aborted",
                           status="aborted")
                aborted.append(r)
        return aborted

    def _admit(self) -> int:
        admitted = 0
        pc = getattr(self.engine, "prefix_cache", None)
        while self.queue:
            try:
                slot = self.slot_req.index(None)
            except ValueError:
                break  # no free slot
            req = self.queue[0]
            worst = (req.prompt_tokens + max(0, req.max_new_tokens - 1)
                     + getattr(self.engine, "spec_margin", 0))
            # longest cached full-block prefix (token-modal requests only:
            # a vlm patch-embed prefix is not keyable by token ids)
            hit = None
            if pc is not None and req.patch_embeds is None:
                hit = pc.lookup(req.prompt)
            start, n_cow = 0, 0
            mapped: List[int] = []
            if hit is not None and hit.blocks:
                hit_tokens = hit.n_blocks * self.pool.block_tokens
                assert hit_tokens <= req.prompt_tokens
                if hit_tokens == req.prompt_tokens:
                    # fully cached prompt: re-run the last position for its
                    # logits and copy-on-write its block, so the fresh KV
                    # store never writes into shared storage
                    start, n_cow = req.prompt_tokens - 1, 1
                else:
                    start = hit_tokens
                if start > 0:
                    mapped = hit.blocks[:hit.n_blocks - n_cow]
                else:  # 1-token prompt fully cached: plain prefill
                    n_cow = 0
            if not self.pool.can_admit(worst, shared_blocks=len(mapped)):
                if hit is not None:
                    pc.unpin(hit)
                break  # FIFO: head waits for blocks, later ticks retry
            self.queue.popleft()
            req.admit_t = self.clock()
            if self._t_first is None:
                self._t_first = req.admit_t
            self.reg.gauge("serve_queue_depth").set(len(self.queue))
            spans = (self._spans.get(req.rid)
                     if self.tracer is not None else None)
            if spans is not None:
                self.tracer.end(spans.pop("queue"), t=req.admit_t)
                self.tracer.event("admit", pid=REQUEST_PID, tid=req.rid,
                                  t=req.admit_t, slot=slot)
                spans["prefill"] = self.tracer.begin(
                    "prefill", pid=REQUEST_PID, tid=req.rid, t=req.admit_t,
                    prompt_tokens=req.prompt_tokens, cached_tokens=start)
            if start > 0:
                last_logits, cache, n_tokens = self.engine.prefill_shared(
                    req.prompt, start, hit.blocks)
            else:
                last_logits, cache, n_tokens = self.engine.prefill_one(
                    req.prompt, req.patch_embeds)
            assert n_tokens == req.prompt_tokens, (n_tokens, req.prompt_tokens)
            self.slot_req[slot] = req
            req.status = "active"
            self.pool.admit(slot, cache, n_tokens, worst, shared=mapped)
            if hit is not None:
                pc.unpin(hit)  # the table now holds its own references
            if pc is not None and req.patch_embeds is None:
                pc.insert(req.prompt, self.pool.slot_blocks[slot])
            self.prefill_tokens_computed += n_tokens - start
            self.prefill_tokens_saved += start
            self.blocks_shared += len(mapped)
            self.cow_copies += n_cow
            self.reg.gauge("serve_active_slots").set(self.n_active)
            if spans is not None:
                self.tracer.end(spans["prefill"], computed=n_tokens - start,
                                saved=start, blocks_shared=len(mapped),
                                cow_copies=n_cow)
            tok, bad = self._sample(last_logits, req)
            if bad:
                self._fail(slot, req, "nan_logits",
                           "non-finite logits at the prefill sample")
            else:
                # may stop immediately (max_new == 1)
                self._emit(slot, req, tok)
            admitted += 1
        return admitted

    def _token_buf(self) -> np.ndarray:
        cfg = self.engine.cfg
        if cfg.modality == "audio":
            return np.zeros((self.pool.n_slots, cfg.n_codebooks), np.int32)
        return np.zeros((self.pool.n_slots,), np.int32)

    def step(self) -> bool:
        """One scheduler tick: expire deadlines, admit into free slots,
        then decode across all active slots — one batched pool step
        (``steps_per_sync <= 1``) or one in-graph multi-step window.
        Returns False when idle."""
        if self._has_deadlines:
            self._expire_deadlines()
        admitted = self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            self._maybe_health()
            return admitted > 0
        if (getattr(self.engine.scfg, "spec_decode", False)
                and not self.spec_degraded):
            self._step_spec(active)
        else:
            w = int(getattr(self.engine.scfg, "steps_per_sync", 1))
            if w > 1:
                self._step_window(active, w)
            else:
                self._step_plain(active)
        self._maybe_health()
        return True

    def _step_plain(self, active: List[int]) -> None:
        """One batched decode tick across ``active`` (the non-window,
        non-spec path; also the degradation fallback for both)."""
        pool = self.pool
        tick_span = (self.tracer.begin("decode_tick", pid=ENGINE_PID, tid=0,
                                       active=len(active))
                     if self.tracer is not None else None)
        for s in active:
            pool.ensure(s)
        tokens = self._token_buf()
        rids = np.zeros((pool.n_slots,), np.int32)
        counts = np.zeros((pool.n_slots,), np.int32)
        for s in active:
            tokens[s] = self.slot_next[s]
            rids[s] = self.slot_req[s].rid
            counts[s] = len(self.slot_req[s].tokens)
        logits, _ = self.engine.pool_step(tokens, pool.lengths, pool.tables)
        if self._faults is not None:
            mask = np.zeros((pool.n_slots,), bool)
            for s in active:
                req = self.slot_req[s]
                if self._faults.poison_token(req.rid, len(req.tokens)):
                    mask[s] = True
            if mask.any():
                shape = (pool.n_slots,) + (1,) * (logits.ndim - 1)
                logits = jnp.where(jnp.asarray(mask).reshape(shape),
                                   jnp.nan, logits)
        self.decode_steps += 1
        self.busy_slot_steps += len(active)
        self.reg.histogram("serve_decode_utilisation").observe(
            len(active) / pool.n_slots)
        # sample on device: only the token ids + the non-finite bitmap
        # cross to the host (the full (n_slots, V) logits never
        # materialize host-side)
        toks, bad = self.engine.sample_slots(logits, rids, counts)
        toks, bad = np.asarray(toks), np.asarray(bad)
        self.host_syncs += 1
        for s in active:
            req = self.slot_req[s]
            pool.advance(s)  # the decode wrote this slot's KV at `length`
            if bad[s]:
                self._fail(s, req, "nan_logits",
                           f"non-finite logits at token {len(req.tokens)}")
            else:
                self._emit(s, req, toks[s].astype(np.int32))
        if tick_span is not None:
            self.tracer.end(tick_span)

    def _step_window(self, active: List[int], w: int) -> None:
        """One in-graph decode window: up to ``w`` ticks on device with
        on-device sampling and a done bitmap; the host syncs once, then
        replays the emission buffers in step order so streaming callbacks
        still fire in token order per request."""
        pool = self.pool
        n = pool.n_slots
        win_span = (self.tracer.begin("decode_window", pid=ENGINE_PID, tid=0,
                                      w=w, active=len(active))
                    if self.tracer is not None else None)
        tokens = self._token_buf()
        counts = np.zeros((n,), np.int32)
        rids = np.zeros((n,), np.int32)
        stops = np.full((n,), -1, np.int32)
        max_new = np.zeros((n,), np.int32)
        alive = np.zeros((n,), bool)
        poison = None
        if self._faults is not None:
            poison = np.full((n,), -1, np.int32)
        for s in active:
            req = self.slot_req[s]
            tokens[s] = self.slot_next[s]
            counts[s] = len(req.tokens)
            rids[s] = req.rid
            if req.stop_token is not None:
                stops[s] = req.stop_token
            max_new[s] = req.max_new_tokens
            alive[s] = True
            if poison is not None:
                # earliest planned poison index this window can reach
                # (later ones stay planned for a later window)
                poison[s] = self._faults.poison_from(
                    req.rid, len(req.tokens), len(req.tokens) + w)
            # pre-allocate every block this slot can write inside the
            # window (its table entries are frozen while the loop runs)
            future = min(w, req.max_new_tokens - len(req.tokens))
            pool.ensure_until(s, int(pool.lengths[s]) + future - 1)
        tok_buf, emit_buf, bad_buf = self.engine.run_window(
            tokens, pool.lengths, pool.tables, counts, rids, stops, max_new,
            alive, poison)
        tok_buf, emit_buf, bad_buf = (np.asarray(tok_buf),
                                      np.asarray(emit_buf),
                                      np.asarray(bad_buf))
        self.host_syncs += 1
        reqs0 = list(self.slot_req)  # guards the replay against mid-loop
        #                              failures freeing/refilling a slot
        for i in range(emit_buf.shape[0]):
            fired = emit_buf[i] | bad_buf[i]
            if not fired.any():
                break  # the device loop exited early (all slots done)
            self.decode_steps += 1
            self.reg.histogram("serve_decode_utilisation").observe(
                int(fired.sum()) / n)
            for s in active:
                req = self.slot_req[s]
                if req is None or req is not reqs0[s]:
                    continue  # failed earlier in this replay: slot freed
                if not fired[s]:
                    continue
                pool.advance(s)
                self.busy_slot_steps += 1
                if bad_buf[i, s]:
                    self._fail(s, req, "nan_logits",
                               f"non-finite logits at token "
                               f"{len(req.tokens)}")
                else:
                    self._emit(s, req, tok_buf[i, s])
        if win_span is not None:
            self.tracer.end(win_span)

    def _step_spec(self, active: List[int]) -> None:
        """One draft-k/verify-1 speculative window (``spec_decode``).

        The engine drafts ``k`` greedy tokens per slot with the draft
        weights and verifies the (k+1)-token chunk with the target
        weights in one batched call (``engine.run_spec_window``); the
        host then, per slot, accepts the longest draft prefix matching
        the target chain plus the target's correction token (a bonus
        token when all k match), rewinds the pool to the pre-window fill
        and re-advances over the verified chunk.  Every emitted token is
        a *target* argmax, so greedy output is token-identical to the
        non-spec path — draft quality only moves the acceptance rate.

        Degradation ladder (graceful, token-identical at every rung):
        a window that raises falls back to one plain tick for this step
        and, after ``spec_fail_threshold`` consecutive failures, disables
        spec decode globally; with ``spec_min_acceptance`` set, a request
        whose acceptance collapses below the floor over
        ``spec_accept_window`` windows is bypassed per-slot (only the
        verified correction token is taken), and a collapsed trailing
        mean disables globally."""
        pool = self.pool
        scfg = self.engine.scfg
        k = int(scfg.draft_k)
        spec_span = (self.tracer.begin("spec_window", pid=ENGINE_PID, tid=0,
                                       k=k, active=len(active))
                     if self.tracer is not None else None)
        tokens = self._token_buf()
        for s in active:
            tokens[s] = self.slot_next[s]
            # the window writes positions [n0, n0 + k] (draft appends +
            # the verify chunk); all inside the spec_margin reservation
            pool.ensure_until(s, int(pool.lengths[s]) + k)
        n0 = pool.lengths.copy()
        try:
            drafted, target, bad = self.engine.run_spec_window(
                tokens, pool.lengths, pool.tables)
        except Exception as e:
            # draft window failed before touching pool storage: decode
            # this step plainly (the extra ensure_until blocks stay
            # inside the reservation) and count the failure
            self.reg.counter("serve_draft_failures_total").inc()
            self._spec_fail_streak += 1
            if spec_span is not None:
                self.tracer.end(spec_span, error=repr(e))
            thresh = max(1, int(getattr(scfg, "spec_fail_threshold", 2)))
            if not self.spec_degraded and self._spec_fail_streak >= thresh:
                self.spec_degraded = True
                self._degrade(
                    "specdecode",
                    f"disabled after {self._spec_fail_streak} consecutive "
                    f"draft-window failures (last: {e!r})")
            self._step_plain(active)
            return
        self._spec_fail_streak = 0
        drafted, target, bad = (np.asarray(drafted), np.asarray(target),
                                np.asarray(bad))
        self.host_syncs += 1
        self.decode_steps += 1  # one target verify step per window
        self.spec_windows += 1
        self.busy_slot_steps += len(active)
        self.reg.histogram("serve_decode_utilisation").observe(
            len(active) / pool.n_slots)
        floor = getattr(scfg, "spec_min_acceptance", None)
        win = max(1, int(getattr(scfg, "spec_accept_window", 8)))
        win_drafted = win_accepted = 0
        for s in active:
            req = self.slot_req[s]
            if bad[s]:
                # quarantine before any emission: rewind the draft
                # overshoot so release sees the pre-window fill
                pool.rewind(s, int(n0[s]))
                self._fail(s, req, "nan_logits",
                           f"non-finite verify logits at token "
                           f"{len(req.tokens)}")
                continue
            g, t = drafted[s], target[s]
            bypassed = req.rid in self._spec_bypass
            acc = 0
            if not bypassed:
                while acc < k and g[acc] == t[acc]:
                    acc += 1
                self.spec_draft_tokens += k
                self.spec_accepted_tokens += acc
                self.reg.histogram(
                    "serve_spec_accepted_per_window").observe(acc)
                win_drafted += k
                win_accepted += acc
            # fault plan: a poison index among the tokens this window
            # will emit fails the request at exactly that position (the
            # on-device bad mask covers organically non-finite verify
            # logits; injection is host-side here)
            pidx = -1
            if self._faults is not None:
                pidx = self._faults.poison_from(
                    req.rid, len(req.tokens), len(req.tokens) + acc + 1)
            # rollback: truncate draft-appended K/V to the pre-window fill
            # (free on paged storage — the verify pass already overwrote
            # positions [n0, n0+k] with target KV, and re-advancing below
            # exposes exactly the accepted ones)
            pool.rewind(s, int(n0[s]))
            for tok in t[:acc + 1]:  # accepted run + correction/bonus
                pool.advance(s)
                if pidx >= 0 and len(req.tokens) == pidx:
                    self._fail(s, req, "nan_logits",
                               f"non-finite logits at token {pidx}")
                    break
                if self._emit(s, req, np.int32(tok)):
                    break  # stop token / max_new mid-window: drop the rest
            if floor is not None and not bypassed:
                st = self._req_spec.setdefault(req.rid, [0, 0, 0])
                st[0] += 1
                st[1] += k
                st[2] += acc
                if (st[0] >= win and st[1]
                        and st[2] / st[1] < floor
                        and req.rid not in self._spec_bypass
                        and self.slot_req[s] is req):
                    self._spec_bypass.add(req.rid)
                    self._degrade(
                        "specdecode",
                        f"r{req.rid} bypassed: acceptance "
                        f"{st[2] / st[1]:.2f} < {floor} over "
                        f"{st[0]} windows")
        if floor is not None and win_drafted:
            self._acc_recent.append(win_accepted / win_drafted)
            mean = sum(self._acc_recent) / len(self._acc_recent)
            if (len(self._acc_recent) == self._acc_recent.maxlen
                    and not self.spec_degraded and mean < floor):
                self.spec_degraded = True
                self._degrade(
                    "specdecode",
                    f"disabled: mean acceptance {mean:.2f} < {floor} over "
                    f"the last {len(self._acc_recent)} windows")
        if spec_span is not None:
            self.tracer.end(spec_span)

    # -- health / degradation ------------------------------------------
    def _degrade(self, subsystem: str, detail: str) -> None:
        """Count + trace one graceful-degradation event."""
        self.reg.counter("serve_degraded_events_total").inc(
            subsystem=subsystem)
        if self.tracer is not None:
            self.tracer.event("degraded", pid=ENGINE_PID, tid=0,
                              subsystem=subsystem, detail=detail)

    def _maybe_health(self) -> None:
        if self._health_every is None:
            return
        if self.host_syncs - self._last_health >= int(self._health_every):
            self._health_cycle()

    def _health_cycle(self) -> None:
        """Periodic self-healing sweep (``health_every_syncs``): bypass a
        corrupted prefix-cache index, then audit the pool and reclaim
        anything leaked — counted recoverable events instead of a
        teardown-time ``RuntimeError``."""
        self._last_health = self.host_syncs
        pool = self.pool
        pc = getattr(self.engine, "prefix_cache", None)
        # bypass before the pool audit so blocks orphaned by the dropped
        # index are reclaimed in the same sweep
        if pc is not None and not pc.bypassed:
            issues = pc.check_invariants()
            if issues:
                pc.bypass()
                self._degrade("prefixcache",
                              f"index corruption -> serving unshared "
                              f"({issues[0]})")
        issues = pool.audit()
        if issues:
            fixed = pool.recover()
            self._degrade("kvpool",
                          f"audit found {len(issues)} issue(s), recovered "
                          f"{fixed} ({issues[0]})")

    def drain(self, max_steps: Optional[int] = None) -> List[Request]:
        """Run to completion.  With ``ServeConfig(drain_timeout_s=...)`` a
        clock-driven watchdog raises once no token, finish, or admission
        has happened for that long — naming the stuck requests and their
        last trace span — instead of spinning on a wedged slot forever."""
        steps = 0
        timeout = getattr(self.engine.scfg, "drain_timeout_s", None)
        last_state = (self.tokens_generated, len(self.done),
                      len(self.failed), self.n_active, len(self.queue))
        last_progress_t = self.clock()
        while self.queue or self.n_active:
            progressed = self.step()
            if not progressed and (self.queue or self.n_active):
                raise self._stall_error("scheduler stalled with pending work")
            if timeout is not None:
                state = (self.tokens_generated, len(self.done),
                         len(self.failed), self.n_active, len(self.queue))
                now = self.clock()
                if state != last_state:
                    last_state, last_progress_t = state, now
                elif now - last_progress_t > timeout:
                    raise self._stall_error(
                        f"scheduler stalled with pending work: no progress "
                        f"for {now - last_progress_t:.2f}s "
                        f"(drain_timeout_s={timeout})")
            steps += 1
            if max_steps is not None and steps >= max_steps:
                break
        if self._health_every is not None:
            self._health_cycle()  # reclaim anything leaked mid-run before
            #                       teardown-time check_leaks can trip
        return self.done

    def _stall_error(self, reason: str) -> RuntimeError:
        """Stall diagnostics: every stuck request's id, status, token
        progress, and (when tracing is on) its last completed span."""
        stuck = [(r, f"active in slot {s}")
                 for s, r in enumerate(self.slot_req) if r is not None]
        stuck += [(r, "queued") for r in self.queue]
        lines = [reason]
        for req, where in stuck:
            desc = (f"  r{req.rid}: {where}, status={req.status}, "
                    f"{len(req.tokens)}/{req.max_new_tokens} tokens")
            if self.tracer is not None:
                last = self.tracer.last_record(REQUEST_PID, req.rid)
                if last is not None:
                    desc += (f", last span {last['name']!r} "
                             f"at t={last['t0']:.6f}")
            lines.append(desc)
        return RuntimeError("\n".join(lines))

    # ------------------------------------------------------------------
    def metrics(self) -> Dict:
        reqs = []
        for r in self.done:
            reqs.append({
                "rid": r.rid,
                "prompt_tokens": r.prompt_tokens,
                "new_tokens": len(r.tokens),
                "queue_wait_s": r.queue_wait_s,
                "ttft_s": r.ttft_s,
                "latency_s": (None if r.finish_t is None
                              else r.finish_t - r.submit_t),
            })
        slot_steps = self.decode_steps * self.pool.n_slots
        elapsed = (None if self._t_first is None or self._t_last is None
                   else max(self._t_last - self._t_first, 1e-9))
        agg = {
            "n_requests": len(self.done),
            "decode_steps": self.decode_steps,
            "busy_slot_steps": self.busy_slot_steps,
            "slot_utilisation": (self.busy_slot_steps / slot_steps
                                 if slot_steps else None),
            "tokens_generated": self.tokens_generated,
            "host_syncs": self.host_syncs,
            "tokens_per_s": (self.tokens_generated / elapsed
                             if elapsed else None),
            "mean_queue_wait_s": _mean([r["queue_wait_s"] for r in reqs]),
            "mean_ttft_s": _mean([r["ttft_s"] for r in reqs]),
            # prefix sharing (token-level hit rate: prompt positions served
            # from cache over all prompt positions admitted)
            "prefill_tokens_computed": self.prefill_tokens_computed,
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "prefix_hit_rate": (
                self.prefill_tokens_saved
                / (self.prefill_tokens_saved + self.prefill_tokens_computed)
                if self.prefill_tokens_saved + self.prefill_tokens_computed
                else None),
            "blocks_shared": self.blocks_shared,
            "cow_copies": self.cow_copies,
            # speculative decoding (decode_steps counts *verify* steps
            # when spec_decode is on — one per window)
            "spec_windows": self.spec_windows,
            "spec_draft_tokens": self.spec_draft_tokens,
            "spec_accepted_tokens": self.spec_accepted_tokens,
            "spec_acceptance_rate": (
                self.spec_accepted_tokens / self.spec_draft_tokens
                if self.spec_draft_tokens else None),
        }
        pc = getattr(self.engine, "prefix_cache", None)
        agg["prefix_cache"] = pc.stats() if pc is not None else None
        return {"requests": reqs, "aggregate": agg}


def _mean(vals):
    vals = [v for v in vals if v is not None]
    return sum(vals) / len(vals) if vals else None


# ---------------------------------------------------------------------------
# Synthetic request traces (launchers + serving bench)
# ---------------------------------------------------------------------------


def synthetic_trace(cfg, n_requests: int, *, seed: int = 0,
                    prompt_len: int = 12, prompt_jitter: int = 0,
                    max_new_low: int = 4, max_new_high: int = 16,
                    shared_prefix_tokens: int = 0, n_prefix_groups: int = 1,
                    on_token: Optional[Callable] = None) -> List[Request]:
    """Mixed-length trace: fixed-ish prompts, decode lengths drawn from
    ``[max_new_low, max_new_high]`` — the regime where static batching
    idles slots behind the longest sequence of each batch.

    ``shared_prefix_tokens > 0`` prepends a common prefix to every prompt
    (system-prompt traffic): ``n_prefix_groups`` distinct prefixes are
    drawn once up front and assigned round-robin, so request ``i`` shares
    its prefix with requests ``i ± n_prefix_groups`` — the workload the
    prefix cache is built for.  Fully seeded: the same (seed, knobs)
    always produce the same token ids, no wall-clock anywhere."""
    rng = np.random.default_rng(seed)
    shape = ((lambda s: (s, cfg.n_codebooks)) if cfg.modality == "audio"
             else (lambda s: (s,)))
    prefixes = [
        rng.integers(0, cfg.vocab, size=shape(shared_prefix_tokens))
        .astype(np.int32)
        for _ in range(max(1, n_prefix_groups))
    ] if shared_prefix_tokens > 0 else []
    reqs = []
    for i in range(n_requests):
        s = prompt_len + (int(rng.integers(0, prompt_jitter + 1))
                          if prompt_jitter else 0)
        prompt = rng.integers(0, cfg.vocab, size=shape(s))
        if prefixes:
            prompt = np.concatenate(
                [prefixes[i % len(prefixes)], prompt], axis=0)
        pe = None
        if cfg.modality == "vlm":
            pe = (rng.normal(size=(cfg.n_patches, cfg.d_model))
                  .astype(np.float32) * 0.02)
        reqs.append(Request(
            prompt=prompt.astype(np.int32),
            max_new_tokens=int(rng.integers(max_new_low, max_new_high + 1)),
            patch_embeds=pe, on_token=on_token,
        ))
    return reqs


def run_continuous_trace(engine, *, n_requests: int = 8, prompt_len: int = 12,
                         prompt_jitter: int = 0, max_new: int = 16,
                         seed: int = 0, stream_first: bool = True,
                         shared_prefix_tokens: int = 0,
                         n_prefix_groups: int = 1,
                         quiet: bool = False) -> Dict:
    """Replay a synthetic mixed-length trace through ``engine``'s
    continuous scheduler (the launchers' ``--continuous`` mode) and return
    the metrics dict, annotated with wall time, the emitted-token digest
    (CI diffs it across prefix-cache on/off runs) and the static-batch
    baseline utilisation for the same FCFS trace."""
    import hashlib

    cfg = engine.cfg
    trace = synthetic_trace(
        cfg, n_requests, seed=seed, prompt_len=prompt_len,
        prompt_jitter=prompt_jitter,
        max_new_low=max(1, max_new // 4), max_new_high=max_new,
        shared_prefix_tokens=shared_prefix_tokens,
        n_prefix_groups=n_prefix_groups)
    if stream_first and not quiet:
        def cb(req, tok, done):
            print(f"[trace] r{req.rid} token {len(req.tokens)}: {tok}"
                  f"{' (done)' if done else ''}")
        trace[0].on_token = cb
    # wall time through the scheduler's injectable clock, so tests and the
    # trace layer can fake time deterministically (satellite of ISSUE 9)
    clock = engine.scheduler.clock
    t0 = clock()
    for r in trace:
        engine.scheduler.submit(r)
    engine.drain()
    wall = clock() - t0
    m = engine.scheduler.metrics()
    a = m["aggregate"]
    a["wall_s"] = wall
    a["static_baseline_utilisation"] = static_baseline_utilisation(
        trace, engine.pool.n_slots)
    a["tokens_sha1"] = hashlib.sha1(b"".join(
        np.ascontiguousarray(r.token_array()).tobytes()
        for r in sorted(trace, key=lambda r: r.rid))).hexdigest()[:16]
    if not quiet:
        fmt = lambda v, scale=1.0, unit="": (
            "n/a" if v is None else f"{v * scale:.2f}{unit}")
        print(f"[continuous] {a['n_requests']} requests, "
              f"{a['tokens_generated']} tokens in {wall:.2f}s "
              f"({a['tokens_generated'] / max(wall, 1e-9):.1f} tok/s); "
              f"decode-slot "
              f"utilisation {fmt(a['slot_utilisation'])} vs static baseline "
              f"{a['static_baseline_utilisation']:.2f}; mean TTFT "
              f"{fmt(a['mean_ttft_s'], 1e3, ' ms')}, mean queue wait "
              f"{fmt(a['mean_queue_wait_s'], 1e3, ' ms')}")
        print(f"[continuous] tokens sha1 {a['tokens_sha1']}")
        # per-request digests: the chaos CI cell diffs the surviving
        # (status=done) lines of a fault-injected run against the clean
        # run's — bit-identical survivors is the isolation invariant
        for r in sorted(trace, key=lambda r: r.rid):
            digest = hashlib.sha1(np.ascontiguousarray(
                r.token_array()).tobytes()).hexdigest()[:16]
            print(f"[req] r{r.rid} status={r.status} "
                  f"tokens={len(r.tokens)} sha1={digest}")
        unfinished = [r for r in trace if r.status != "done"]
        if unfinished:
            print(f"[continuous] {len(unfinished)} request(s) failed: "
                  + ", ".join(f"r{r.rid}={r.status}" for r in unfinished))
        if a["prefix_cache"] is not None:
            hr = a["prefix_hit_rate"]
            print(f"[continuous] prefix cache: hit rate "
                  f"{fmt(hr)} ({a['prefill_tokens_saved']} prompt tokens "
                  f"saved / {a['prefill_tokens_computed']} computed), "
                  f"{a['blocks_shared']} blocks shared, "
                  f"{a['cow_copies']} cow copies, "
                  f"{a['prefix_cache']['evictions']} evictions")
        if a["spec_windows"]:
            print(f"[continuous] spec decode: acceptance "
                  f"{fmt(a['spec_acceptance_rate'])} "
                  f"({a['spec_accepted_tokens']}/{a['spec_draft_tokens']} "
                  f"draft tokens), {a['decode_steps']} verify steps over "
                  f"{a['spec_windows']} windows")
    return m


def static_baseline_utilisation(trace: List[Request], slots: int) -> float:
    """Decode-slot utilisation a static fixed-batch engine achieves on the
    same FCFS trace: each group of ``slots`` requests decodes for the
    group's *maximum* length while shorter members idle their slot."""
    busy = total = 0
    reqs = list(trace)
    for i in range(0, len(reqs), slots):
        group = reqs[i:i + slots]
        steps = max(r.max_new_tokens for r in group)
        total += steps * slots
        busy += sum(r.max_new_tokens for r in group)
    return busy / total if total else 0.0
