"""Serving engine: continuous batching over a paged quantized-KV pool.

Two serving modes share one set of jitted model entry points (prefill
once per admission, decode once per tick across all slots — the pair the
dry-run lowers):

* **continuous** (the default production path): ``submit()`` enqueues
  requests, ``step()`` runs one scheduler tick (admission with
  prefill-on-admit, one batched decode across all slots, per-slot stop +
  immediate refill), ``drain()`` runs to completion.  Cache storage
  lives in a :class:`repro.serve.kvpool.KVPool` — fixed-size token
  blocks with a free list.  Decode runs **fused** by default
  (``ServeConfig.paged_kernel``): the models' ``decode_paged`` reads KV
  blocks in place through the Pallas paged-attention kernel (quantized
  blocks dequantized in-kernel, new token appended in-kernel) with no
  per-tick gather/scatter of pool storage; pure-state families and
  ``paged_kernel=False`` take the vmapped contiguous-view baseline.
  Sampling is on-device, and ``ServeConfig.steps_per_sync`` batches up
  to N decode ticks into one in-graph window per host sync.

* **static** (``generate_static()``): the original fixed-slot batch loop,
  kept as the baseline the serving bench and the token-identity tests
  compare against.  ``generate()`` is a thin compatibility wrapper that
  round-trips through the continuous scheduler and returns the same
  ``{"tokens", "final_length"}`` dict (greedy tokens are identical —
  prefill/decode are per-sequence computations, so batch composition
  cannot change any sequence's logits).

Params may be plain float trees *or* the packed artifact form
(``repro.quant.packed.PackedWeight`` leaves, e.g. from
``repro.api.QuantizedModel``), executing through the pluggable weight
backend (``"reference"`` dequant-on-use vs ``"pallas"`` fused
dequant-matmul).  With a ``mesh``, params, the static cache, and the KV
pool (via ``dist.sharding.pool_pspecs`` — blocks shard on the same mesh
axes as the static cache) are placed by the ``repro.dist`` rules.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import NOQUANT, QuantizeSpec
from repro.obs import ObsConfig, Observability
from repro.serve.faults import (FaultPlan, InjectedFault, StallClock,
                                build_injector)


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    batch_slots: int = 4
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0
    # --- continuous-batching / paged-KV pool geometry ---
    block_tokens: int = 16  # tokens per KV block
    pool_blocks: Optional[int] = None  # None: full provisioning (+1 scratch)
    # Right-pad admission prefills to the next block boundary so a trace
    # with N distinct prompt lengths compiles ceil(N / block) prefills
    # instead of N.  Token-identical to exact-length prefill (logits are
    # read at the *true* last token); only attention-cache families
    # support it (recurrent state would integrate the padding) — the
    # engine falls back to exact-length prefill elsewhere.
    bucket_prompts: bool = False
    # --- fused decode hot path ---
    # Route pool decode through the model's fused paged path (the Pallas
    # paged-attention kernel walks each slot's block table in place —
    # no per-tick gather/scatter of pool storage).  Families without a
    # fused decode (pure-state xLSTM) keep the vmapped baseline; set
    # False to force the baseline everywhere (A/B measurement).
    paged_kernel: bool = True
    # Decode ticks per host synchronization.  1 = classic behavior (one
    # sample + stop check round-trip per token); N > 1 runs an in-graph
    # while_loop of up to N ticks with on-device sampling, per-slot
    # stop-token/length masks and a device-side done bitmap — the host
    # only syncs to refill slots and flush streaming callbacks.
    steps_per_sync: int = 1
    # --- prefix sharing ---
    # Index full prompt blocks in a refcounted prefix cache
    # (repro.serve.prefixcache): admission maps cached blocks into the new
    # slot's table without re-prefilling them and continuation-prefills
    # only the tail, copy-on-write protecting fully-cached prompts.
    # Token output is bit-identical to prefix_cache=False (prefill scores
    # at stored precision, so a cached block equals a recomputed one).
    # Only fully-paged attention-cache families share (dense/MoE/MLA);
    # recurrent-state families silently serve unshared.
    prefix_cache: bool = False
    # Cap idle cached-block retention: the prefix cache evicts its
    # least-recently-used idle leaves beyond this count at insert time
    # (None = unbounded — only pool pressure evicts).  Blocks still
    # referenced by live slots never count against the cap.
    max_cached_blocks: Optional[int] = None
    # --- speculative decoding ---
    # Draft-k/verify-1 self-speculation (repro.serve.specdecode): each
    # scheduler window drafts ``draft_k`` tokens per slot with the
    # engine's draft weights (api.derive_draft — same artifact, harsher
    # weight overlay) over the *same* block-paged pool, then verifies the
    # chunk in one batched call with the target weights and rolls back
    # rejected positions by rewinding per-slot lengths.  Greedy output is
    # token-identical to spec_decode=False; requires a draft
    # (``qm.serve(..., draft=...)``), a fully paged family, temperature=0
    # and steps_per_sync=1 (validated at engine build).
    spec_decode: bool = False
    draft_k: int = 4
    # --- observability ---
    # Tracing + profiling switches (repro.obs).  The default
    # ObsConfig(enabled=False) keeps spans and jit-dispatch wrappers
    # entirely out of the hot loop; the metrics registry itself is always
    # live (it backs scheduler.metrics()).  Launchers flip this on via
    # --trace-out / --metrics-out.
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    # Stall watchdog for drain(): raise (with the stuck request ids and
    # their last trace span) once no token / finish / admission has
    # happened for this many clock seconds.  None = no watchdog (the
    # historical behavior: only a no-progress step raises).
    drain_timeout_s: Optional[float] = None
    # --- robustness: backpressure, degradation, health, fault injection ---
    # Bound the admission queue: submit() beyond this depth either
    # returns the request rejected (status="rejected", never enqueued;
    # queue_policy="reject") or raises QueueFull (queue_policy="raise").
    # None = unbounded (the historical behavior).
    max_queue: Optional[int] = None
    queue_policy: str = "reject"  # reject | raise
    # Spec-decode graceful degradation: after this many *consecutive*
    # draft-window failures the scheduler disables drafting globally and
    # serves plain decode (token-identical); each failed window already
    # falls back to a plain tick on its own.
    spec_fail_threshold: int = 2
    # Acceptance floor: once a request (then the whole engine) has run
    # spec_accept_window windows with acceptance below this fraction,
    # drafting is bypassed for it (then disabled globally) — drafting
    # that mostly misses costs more than plain decode.  None = no floor.
    spec_min_acceptance: Optional[float] = None
    spec_accept_window: int = 8
    # Health self-checks: every N host syncs the scheduler audits the
    # prefix-cache index (bypassing it on corruption) and the pool
    # bookkeeping (reclaiming leaked blocks), counting each repair as a
    # degraded event instead of failing at teardown.  A final cycle runs
    # at the end of every drain().  None = off (historical behavior).
    health_every_syncs: Optional[int] = None
    # Deterministic fault injection (repro.serve.faults.FaultPlan): the
    # chaos harness behind tests/test_faults.py and the launchers'
    # --inject-faults.  None (the default) compiles/branches every
    # injection site out — tokens and metrics are bit-identical to an
    # engine without the robustness layer.
    faults: Optional[FaultPlan] = None


class ServeEngine:
    """Single-device by default; pass ``mesh`` to serve sharded.

    With a mesh, parameters and cache storage (static cache and the paged
    pool alike) are placed with the ``repro.dist.sharding`` rules
    (tensor/expert parallel weights, batch/block-sharded cache) and the
    jitted entry points run under the mesh context, so the in-graph
    sharding hints (e.g. the MoE dispatch pin) are active — the same
    layout the 512-device dry-run compiles.
    """

    def __init__(self, arch, params, scfg: ServeConfig, spec: QuantizeSpec = NOQUANT,
                 dtype=jnp.float32, mesh=None, backend: Optional[str] = None,
                 draft_params=None):
        from repro.quant.packed import set_backend

        self.arch = arch
        self.cfg = arch.config
        self.scfg = scfg
        self.spec = spec
        self.faults = build_injector(scfg.faults)
        obs_cfg = scfg.obs
        if scfg.faults is not None and scfg.faults.clock_stall:
            # the tracer/profiler capture their clock reference at
            # construction, so the stall wrapper must be installed first
            obs_cfg = dataclasses.replace(
                obs_cfg, clock=StallClock(obs_cfg.clock or time.perf_counter,
                                          scfg.faults.clock_stall))
        self.obs = Observability(obs_cfg)
        if backend is not None:
            params = set_backend(params, backend)
            if draft_params is not None:
                draft_params = set_backend(draft_params, backend)
        self.params = params
        self.backend = backend
        self.dtype = dtype
        self.mesh = mesh
        self._cache_shardings = None
        if mesh is not None:
            from repro.dist.sharding import (
                _axis_sizes, cache_pspecs, param_pspecs, sanitize_pspecs,
            )
            from repro.launch.mesh import dp_axes_of

            dp = dp_axes_of(mesh)
            model_size = _axis_sizes(mesh).get("model", 1)
            params_sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
            )
            pspec = sanitize_pspecs(
                mesh, param_pspecs(self.cfg, params_sds), params_sds
            )
            cache_sds = arch.cache_specs(scfg.batch_slots, scfg.max_seq, spec, dtype)
            cspec = sanitize_pspecs(
                mesh,
                cache_pspecs(self.cfg, cache_sds, dp, model_size=model_size),
                cache_sds,
            )
            ns = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, P),
            )
            self.params = jax.device_put(params, ns(pspec))
            self._cache_shardings = ns(cspec)
            if draft_params is not None:
                # the draft tree takes the *same* placement rules as the
                # target: param_pspecs keys off logical weight shapes, and
                # derive_draft preserves every leaf's logical shape (only
                # bits/group change), so draft and target shards align
                # slot-for-slot on the mesh
                draft_sds = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    draft_params)
                dspec = sanitize_pspecs(
                    mesh, param_pspecs(self.cfg, draft_sds), draft_sds)
                draft_params = jax.device_put(draft_params, ns(dspec))
        self.draft_params = draft_params
        self._prefill = self.obs.wrap(
            "prefill", jax.jit(lambda p, b, c: arch.prefill(p, b, c, spec)))
        self._decode = self.obs.wrap(
            "decode_static",
            jax.jit(lambda p, t, c: arch.decode(p, t, c, spec)))
        self._prefill_padded = None
        if arch.padded_prefill is not None:
            self._prefill_padded = self.obs.wrap(
                "prefill_padded",
                jax.jit(lambda p, b, c, n: arch.padded_prefill(p, b, c, n,
                                                               spec)))
        # continuous-batching machinery, built lazily on first submit()
        self._pool = None
        self._pool_step_fn = None
        self._tick_fn = None
        self._verify_tick = None
        self._window_jit = None
        self._spec_jit = None
        self._sample_jit = None
        self._sched = None
        self.fused_decode = False
        self._prefix_cache = None
        self._prefill_from_jit: Dict[int, object] = {}

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _place_cache(self, cache):
        if self._cache_shardings is None:
            return cache
        return jax.device_put(cache, self._cache_shardings)

    def _place_step_inputs(self, *arrays):
        """Host-side control inputs of a decode tick/window, placed with
        ``dist.sharding.step_input_pspecs`` (replicated) under a mesh."""
        arrays = tuple(jnp.asarray(a) for a in arrays)
        if self.mesh is None:
            return arrays
        from repro.dist.sharding import step_input_pspecs

        specs = step_input_pspecs(arrays)
        return tuple(
            jax.device_put(a, NamedSharding(self.mesh, s))
            for a, s in zip(arrays, specs))

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)

    # ------------------------------------------------------------------
    # Continuous batching: submit / step / drain (scheduler-driven)
    # ------------------------------------------------------------------

    @property
    def pool(self):
        if self._pool is None:
            self._build_continuous()
        return self._pool

    @property
    def scheduler(self):
        if self._sched is None:
            self._build_continuous()
        return self._sched

    @property
    def prefix_cache(self):
        """The attached :class:`~repro.serve.prefixcache.PrefixCache`, or
        None (disabled, or the family cannot share — per-slot state)."""
        if self._pool is None:
            self._build_continuous()
        return self._prefix_cache

    def _build_continuous(self):
        from repro.serve.kvpool import KVPool
        from repro.serve.scheduler import ContinuousScheduler

        scfg = self.scfg
        round_to = 1
        if self.mesh is not None:
            from repro.dist.sharding import _axis_sizes
            from repro.launch.mesh import dp_axes_of

            sizes = _axis_sizes(self.mesh)
            for a in dp_axes_of(self.mesh):
                round_to *= sizes[a]
        self._pool = KVPool(
            self.arch, self.spec, self.dtype,
            n_slots=scfg.batch_slots, max_seq=scfg.max_seq,
            block_tokens=scfg.block_tokens, n_blocks=scfg.pool_blocks,
            round_blocks_to=round_to,
        )
        if self.mesh is not None:
            self._place_pool()
        self.fused_decode = bool(
            scfg.paged_kernel
            and self.arch.decode_paged is not None
            and self._pool.has_paged)
        if self.fused_decode:
            tick = self._pool.make_fused_tick(
                lambda p, tok, pg, st, tb, ln: self.arch.decode_paged(
                    p, tok, pg, st, tb, ln, self.spec))
        else:
            tick = self._pool.make_tick(
                lambda p, t, c: self.arch.decode(p, t, c, self.spec))
        self._tick_fn = tick
        self._pool.obs = self.obs
        self._pool.faults = self.faults
        # bind_step exposes its inner jit as ._jitted, so the profiler can
        # watch the paged-attention tick's compile cache
        self._pool_step_fn = self.obs.wrap("decode_tick",
                                           self._pool.bind_step(tick))
        self._verify_tick = None
        if scfg.spec_decode:
            from repro.serve import specdecode

            specdecode.validate_spec_config(self)
            # chunked verify rides the vmapped gather/scatter tick: the
            # per-lane decode just widens to (k+1) tokens per call
            self._verify_tick = self._pool.make_tick(
                lambda p, t, c: self.arch.decode_chunk(p, t, c, self.spec))
        self._prefix_cache = None
        if (scfg.prefix_cache and self._pool.has_paged and not self._pool.state
                and self.arch.prefill_from is not None):
            # Sharing needs every cache leaf paged (no per-slot recurrent
            # state) and a continuation-capable prefill.  The signature
            # ties entries to this engine's cache codec: a block of codes
            # is only reusable under the same kv_bits/dtype/block/arch.
            from repro.serve.prefixcache import PrefixCache

            sig = (f"{self.cfg.name}/kv{self.spec.kv_bits}/"
                   f"{jnp.dtype(self.dtype).name}/T{scfg.block_tokens}")
            self._prefix_cache = PrefixCache(self._pool, sig=sig,
                                             capacity=scfg.max_cached_blocks,
                                             obs=self.obs)
            self._prefix_cache.faults = self.faults
        self._sched = ContinuousScheduler(self)

    def _place_pool(self):
        """Shard the pool's block/state storage like the static cache."""
        from repro.dist.sharding import pool_pspecs, sanitize_pspecs, _axis_sizes
        from repro.launch.mesh import dp_axes_of

        pool = self._pool
        dp = dp_axes_of(self.mesh)
        model_size = _axis_sizes(self.mesh).get("model", 1)
        for tree_name, batch in (("paged", pool.n_blocks),
                                 ("state", pool.n_slots)):
            sds = self.arch.cache_specs(batch, pool.block_tokens, self.spec,
                                        self.dtype)
            specs = sanitize_pspecs(
                self.mesh, pool_pspecs(self.cfg, sds, dp, model_size=model_size),
                sds)
            flat = dict(zip(pool.paths, jax.tree.leaves(specs)))
            store = getattr(pool, tree_name)
            for path in store:
                store[path] = jax.device_put(
                    store[path], NamedSharding(self.mesh, flat[path]))

    def pool_step(self, tokens, lengths, tables):
        """One batched decode tick over every pool slot (scheduler hook)."""
        tokens, lengths, tables = self._place_step_inputs(
            tokens, lengths, tables)
        with self._mesh_ctx():
            return self._pool_step_fn(self.params, tokens, lengths, tables)

    @property
    def spec_margin(self) -> int:
        """Extra cache positions one scheduler step may write past the
        classic one-token worst case: the spec-decode verify chunk writes
        positions ``[n, n + draft_k]``, so admission reserves ``draft_k``
        more (the scheduler folds this into its worst-case bound)."""
        return int(self.scfg.draft_k) if self.scfg.spec_decode else 0

    def run_spec_window(self, tokens, lengths, tables):
        """One draft-k/verify-1 speculative window over the pool
        (scheduler hook for ``spec_decode``).  Drafts ``draft_k`` greedy
        tokens per slot with the draft weights, verifies the chunk with
        the target weights from the *original* lengths (overwriting draft
        KV with target KV in place), and returns ``(drafted (S, k),
        target (S, k+1), bad (S,))`` for the host-side accept/rewind
        (``bad`` flags slots whose verify logits went non-finite).  Pool
        storage is updated in place; host ``pool.lengths`` are never
        advanced by the window itself."""
        from repro.serve import specdecode

        if self.faults is not None and self.faults.draft_window_fails():
            # raised before any pool mutation, so the scheduler's plain
            # fallback sees exactly the pre-window state
            raise InjectedFault("injected draft-window failure")
        if self._spec_jit is None:
            self._spec_jit = self.obs.wrap(
                "spec_window", specdecode.build_spec_window(self))
        pool = self.pool
        inputs = self._place_step_inputs(tokens, lengths, tables)
        with self._mesh_ctx():
            drafted, target, bad, paged, state = self._spec_jit(
                self.params, self.draft_params, *inputs, pool.paged,
                pool.state)
        pool.paged, pool.state = paged, state
        return drafted, target, bad

    # ------------------------------------------------------------------
    # On-device sampling + the in-graph multi-step decode window
    # ------------------------------------------------------------------

    def _make_sampler(self):
        """(logits (S,V)|(S,K,V), rids (S,), counts (S,)) ->
        ((S[,K]) int32 tokens, (S,) bool bad).

        Greedy argmax, or per-request categorical from the same
        fold_in(seed, rid) -> fold_in(key, n_emitted) chain the host
        sampler uses — on-device sampling is draw-for-draw identical.

        ``bad`` flags slots whose logits contain any non-finite value —
        detected on device (one reduction over logits already resident
        there) and surfaced to the host at the sync it already pays, so
        the scheduler can quarantine the poisoned request instead of
        emitting garbage tokens forever."""
        temp, seed = self.scfg.temperature, self.scfg.seed

        def sample(logits, rids, counts):
            flat = logits.reshape((logits.shape[0], -1))
            bad = ~jnp.isfinite(flat).all(axis=-1)
            if temp <= 0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), bad
            base = jax.random.PRNGKey(seed)

            def one(lg, r, c):
                key = jax.random.fold_in(jax.random.fold_in(base, r), c)
                return jax.random.categorical(key, lg / temp).astype(jnp.int32)

            return jax.vmap(one)(logits, rids, counts), bad

        return sample

    def sample_slots(self, logits, rids, counts):
        """Sample every slot's next token on device; only the (S,) int
        ids and the (S,) non-finite bitmap ever cross to the host (the
        scheduler's per-token sync).  Returns ``(tokens, bad)``."""
        if self._sample_jit is None:
            self._sample_jit = self.obs.wrap("sample",
                                             jax.jit(self._make_sampler()))
        with self._mesh_ctx():
            return self._sample_jit(logits, jnp.asarray(rids),
                                    jnp.asarray(counts))

    def _build_window(self):
        """Jit the in-graph decode window: a while_loop of up to
        ``steps_per_sync`` pool ticks with on-device sampling, per-slot
        stop-token / max-length masks and a device-side ``alive`` bitmap
        (early exit once every slot is done).  Pool storage rides the
        loop carry (donated), so the whole window is one dispatch and one
        host sync."""
        w = self.scfg.steps_per_sync
        tick = self._tick_fn
        sample = self._make_sampler()
        audio = self.cfg.modality == "audio"
        inject = self.faults is not None

        def window(params, tokens, lengths, tables, counts, rids, stops,
                   max_new, alive, poison_at, paged, state):
            s = tokens.shape[0]
            wide = (lambda m: m[:, None]) if audio else (lambda m: m)
            tok_buf = jnp.zeros((w,) + tokens.shape, jnp.int32)
            emit_buf = jnp.zeros((w, s), bool)
            bad_buf = jnp.zeros((w, s), bool)

            def cond(c):
                i, _, _, _, alive, _, _, _, _, _ = c
                return (i < w) & alive.any()

            def body(c):
                i, tokens, lengths, counts, alive, paged, state, tb, eb, bb = c
                logits, paged, state, lengths2 = tick(
                    params, tokens, lengths, tables, paged, state)
                # done slots keep their length frozen (their lane decodes
                # scratch garbage until the host releases them)
                lengths = jnp.where(alive, lengths2, lengths)
                if inject:  # fault plan armed: poison the planned slots
                    hit = counts == poison_at
                    shape = (s,) + (1,) * (logits.ndim - 1)
                    logits = jnp.where(hit.reshape(shape), jnp.nan, logits)
                nxt, bad = sample(logits, rids, counts)
                bad = bad & alive
                stop_hit = (jnp.zeros((s,), bool) if audio
                            else nxt == stops)
                emit = alive & ~bad
                tb = tb.at[i].set(jnp.where(wide(emit), nxt, 0))
                eb = eb.at[i].set(emit)
                bb = bb.at[i].set(bad)
                counts = counts + emit.astype(jnp.int32)
                alive = emit & ~stop_hit & (counts < max_new)
                tokens = jnp.where(wide(alive), nxt, tokens)
                return (i + 1, tokens, lengths, counts, alive, paged, state,
                        tb, eb, bb)

            init = (jnp.asarray(0, jnp.int32), tokens, lengths, counts,
                    alive, paged, state, tok_buf, emit_buf, bad_buf)
            (_, _, lengths, _, _, paged, state, tok_buf, emit_buf,
             bad_buf) = jax.lax.while_loop(cond, body, init)
            return tok_buf, emit_buf, bad_buf, paged, state

        return jax.jit(window, donate_argnums=(10, 11))

    def run_window(self, tokens, lengths, tables, counts, rids, stops,
                   max_new, alive, poison_at=None):
        """Execute one in-graph decode window over the pool (scheduler
        hook for ``steps_per_sync > 1``).  Returns the per-step token,
        emission, and non-finite buffers; pool storage is updated in
        place.  ``poison_at`` (S,) is the fault-injection schedule (-1 =
        never; only consulted when a plan is armed)."""
        if self._window_jit is None:
            self._window_jit = self.obs.wrap("decode_window",
                                             self._build_window())
        pool = self.pool
        if poison_at is None:
            poison_at = np.full((pool.n_slots,), -1, np.int32)
        inputs = self._place_step_inputs(
            tokens, lengths, tables, counts, rids, stops, max_new, alive,
            poison_at)
        with self._mesh_ctx():
            tok_buf, emit_buf, bad_buf, paged, state = self._window_jit(
                self.params, *inputs, pool.paged, pool.state)
        pool.paged, pool.state = paged, state
        return tok_buf, emit_buf, bad_buf

    def prefill_one(self, prompt: np.ndarray, patch_embeds: Optional[np.ndarray]
                    ) -> tuple:
        """Prefill a single request into a batch=1 cache sized to whole
        pool blocks (so admit can copy it block-for-block).  Returns
        (last_logits (V,)|(K,V), cache, n_tokens).

        By default the prompt runs at its exact length, retracing the
        jitted prefill once per distinct prompt length.  With
        ``ServeConfig.bucket_prompts`` (attention-cache families only)
        the prompt is right-padded to the block boundary and run through
        the padded-prefill variant — logits come from the *true* last
        token and the cache length masks the padded KV, so tokens are
        identical while compiles are bounded by the number of distinct
        block counts."""
        pool = self.pool
        s_total = prompt.shape[0]
        if self.cfg.modality == "vlm" and patch_embeds is not None:
            s_total += patch_embeds.shape[0]
        nb0 = max(1, math.ceil(s_total / pool.block_tokens))
        cache0 = self.arch.init_cache(1, nb0 * pool.block_tokens, self.spec,
                                      self.dtype)
        bucketed = (self.scfg.bucket_prompts
                    and self._prefill_padded is not None)
        tokens = prompt
        if bucketed:
            pad = nb0 * pool.block_tokens - s_total
            if pad:
                width = ((0, pad),) + ((0, 0),) * (prompt.ndim - 1)
                tokens = np.pad(prompt, width)
        batch = {"tokens": jnp.asarray(tokens[None])}
        if self.cfg.modality == "vlm" and patch_embeds is not None:
            batch["patch_embeds"] = jnp.asarray(patch_embeds[None])
        with self._mesh_ctx():
            if bucketed:
                logits, cache = self._prefill_padded(
                    self.params, batch, cache0,
                    jnp.asarray(s_total, jnp.int32))
            else:
                logits, cache = self._prefill(self.params, batch, cache0)
        # stays on device: the scheduler samples it there and transfers
        # only the token id (no (V,) logits round trip per admission)
        last = logits[0]
        if last.ndim >= 2 and last.shape[0] == 1:  # (1, V) / (1, K, V)
            last = last[0]
        return last, cache, s_total

    def prefill_shared(self, prompt: np.ndarray, start: int,
                       blocks: List[int]) -> tuple:
        """Prefill a request whose first ``start`` positions are covered by
        cached pool blocks: gather ``blocks`` into a contiguous batch=1
        view, continuation-prefill only ``prompt[start:]`` over it, and
        return the same (last_logits, cache, n_tokens) contract as
        :meth:`prefill_one` — admit then maps the shared blocks and writes
        only the fresh tail blocks.

        ``start`` is static (one retrace per distinct (prefix, tail)
        length pair — shared-prefix traffic repeats both).  Bucketing is
        never applied here: the tail runs at exact length.
        """
        pool = self.pool
        s_total = prompt.shape[0]
        assert 0 < start < s_total, (start, s_total)
        assert len(blocks) * pool.block_tokens >= start, (blocks, start)
        nb0 = max(1, math.ceil(s_total / pool.block_tokens))
        cache0 = self.arch.init_cache(1, nb0 * pool.block_tokens, self.spec,
                                      self.dtype)
        fn = self._prefill_from_jit.get(start)
        if fn is None:
            fn = self.obs.wrap("prefill_shared", jax.jit(
                lambda p, b, c, s=start: self.arch.prefill_from(
                    p, b, c, s, self.spec)))
            self._prefill_from_jit[start] = fn
        batch = {"tokens": jnp.asarray(prompt[start:][None])}
        with self._mesh_ctx():
            cache0 = pool.write_prefix(cache0, blocks)
            logits, cache = fn(self.params, batch, cache0)
        last = logits[0]
        if last.ndim >= 2 and last.shape[0] == 1:  # (1, V) / (1, K, V)
            last = last[0]
        return last, cache, s_total

    def submit(self, prompt: np.ndarray, max_new_tokens: int, *,
               patch_embeds: Optional[np.ndarray] = None,
               stop_token: Optional[int] = None,
               on_token=None, deadline_s: Optional[float] = None):
        """Enqueue one request; returns the :class:`Request` handle (its
        ``tokens`` fill in as the scheduler produces them).
        ``deadline_s`` is a TTL from submission: the request expires with
        ``status="timeout"`` in queue or mid-decode once it elapses."""
        from repro.serve.scheduler import Request

        return self.scheduler.submit(Request(
            prompt=np.asarray(prompt), max_new_tokens=max_new_tokens,
            patch_embeds=patch_embeds, stop_token=stop_token,
            on_token=on_token, deadline_s=deadline_s))

    def step(self) -> bool:
        """One scheduler tick (admit + batched decode). False when idle."""
        return self.scheduler.step()

    def drain(self) -> List:
        """Run the scheduler until queue and slots are empty; returns the
        finished requests (see ``scheduler.metrics()`` for aggregates)."""
        return self.scheduler.drain()

    def health(self) -> Dict:
        """Point-in-time health snapshot of the serving stack.

        ``status`` is ``"ok"`` unless any subsystem has degraded (spec
        decode disabled, prefix cache bypassed, pool invariants
        currently violated) — degradation is sticky for spec decode and
        the prefix cache, but a recovered pool reports healthy again."""
        sched = self.scheduler
        pool = self._pool
        pc = self._prefix_cache
        issues = pool.audit()
        degraded = bool(issues) or sched.spec_degraded or (
            pc is not None and pc.bypassed)
        out = {
            "status": "degraded" if degraded else "ok",
            "queue_depth": len(sched.queue),
            "active_slots": sum(r is not None for r in sched.slot_req),
            "requests_done": len(sched.done),
            "requests_failed": len(sched.failed),
            "pool": {
                "free_blocks": len(pool.free),
                "capacity_blocks": pool.n_blocks,
                "invariants_ok": not issues,
                "issues": issues,
            },
            "prefix_cache": None,
            "spec_decode": {
                "enabled": bool(self.scfg.spec_decode),
                "degraded": sched.spec_degraded,
            },
        }
        if pc is not None:
            out["prefix_cache"] = {
                "bypassed": pc.bypassed,
                "cached_blocks": len(pc._blocks),
            }
        return out

    # ------------------------------------------------------------------
    # Generation entry points
    # ------------------------------------------------------------------

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 patch_embeds: Optional[np.ndarray] = None) -> Dict:
        """Compatibility wrapper: round-trips through the continuous
        scheduler (submit all prompts, drain) and returns the static
        ``{"tokens": (B, T[,K]), "final_length": int}`` contract.  Greedy
        outputs are token-identical to :meth:`generate_static`; prompts
        beyond ``batch_slots`` simply queue."""
        prompts = np.asarray(prompts)
        reqs = []
        for i in range(prompts.shape[0]):
            pe = None if patch_embeds is None else np.asarray(patch_embeds[i])
            reqs.append(self.submit(prompts[i], max_new_tokens,
                                    patch_embeds=pe))
        self.drain()
        gen = np.stack([r.token_array() for r in reqs])  # (B, T) or (B, T, K)
        final = reqs[-1].prompt_tokens + max_new_tokens
        return {"tokens": gen, "final_length": int(final)}

    def generate_static(self, prompts: np.ndarray, max_new_tokens: int,
                        patch_embeds: Optional[np.ndarray] = None) -> Dict:
        """The original fixed-slot batch loop: one monolithic cache, all
        slots prefilled together, decode until the longest sequence is
        done.  Kept as the baseline for the continuous scheduler (token
        identity + the serving bench's utilisation comparison)."""
        cfg, scfg = self.cfg, self.scfg
        b = prompts.shape[0]
        assert b <= scfg.batch_slots, "more prompts than batch slots"
        pad_b = scfg.batch_slots - b
        if pad_b:
            prompts = np.concatenate([prompts, np.zeros((pad_b,) + prompts.shape[1:],
                                                        prompts.dtype)])
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.modality == "vlm" and patch_embeds is not None:
            if pad_b:
                patch_embeds = np.concatenate(
                    [patch_embeds, np.zeros((pad_b,) + patch_embeds.shape[1:],
                                            patch_embeds.dtype)])
            batch["patch_embeds"] = jnp.asarray(patch_embeds)

        cache = self._place_cache(
            self.arch.init_cache(scfg.batch_slots, scfg.max_seq, self.spec, self.dtype)
        )
        with self._mesh_ctx():
            logits, cache = self._prefill(self.params, batch, cache)
            key = jax.random.PRNGKey(scfg.seed)
            outs = []
            last = logits.reshape(scfg.batch_slots, *logits.shape[1:])
            if last.ndim >= 3:  # (B, 1, V) -> (B, V); audio (B, 1, K, V)
                last = last[:, 0]
            for t in range(max_new_tokens):
                key, sub = jax.random.split(key)
                tok = self._sample(last, sub)
                outs.append(np.asarray(tok[:b]))
                logits, cache = self._decode(self.params, tok, cache)
                last = logits
        gen = np.stack(outs, axis=1)  # (B, T) or (B, T, K)
        return {"tokens": gen, "final_length": int(cache["length"])}
