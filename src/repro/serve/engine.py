"""Batched serving engine: prefill + decode over fixed batch slots.

A deliberately production-shaped loop: fixed-size slot batch (padding
short prompts), greedy/temperature sampling, per-slot stop tracking, and
quantized execution via the QuantizeSpec (rotated+quantized weights come
from the PTQ pipeline; KV quantization handled inside the model decode).

Params may be plain float trees *or* the packed artifact form
(``repro.quant.packed.PackedWeight`` leaves, e.g. from
``repro.api.QuantizedModel``).  Packed weights execute through a
pluggable per-launch weight backend — ``backend="reference"``
(dequant-on-use, the oracle) or ``backend="pallas"`` (fused
``dequant_matmul`` streaming the packed bytes; interpret mode off-TPU) —
and are co-sharded with their scales by the ``dist.sharding`` rules.

Continuous batching at cluster scale is a scheduler concern layered on
these two jitted entry points (prefill once per admission, decode once
per step across all active slots) - exactly the pair the dry-run lowers.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import NOQUANT, QuantizeSpec


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 512
    batch_slots: int = 4
    temperature: float = 0.0  # 0 = greedy
    seed: int = 0


class ServeEngine:
    """Single-device by default; pass ``mesh`` to serve sharded.

    With a mesh, parameters and the KV/state cache are placed with the
    ``repro.dist.sharding`` rules (tensor/expert parallel weights,
    batch-sharded cache) and both jitted entry points run under the mesh
    context, so the in-graph sharding hints (e.g. the MoE dispatch pin)
    are active — the same layout the 512-device dry-run compiles.
    """

    def __init__(self, arch, params, scfg: ServeConfig, spec: QuantizeSpec = NOQUANT,
                 dtype=jnp.float32, mesh=None, backend: Optional[str] = None):
        from repro.quant.packed import set_backend

        self.arch = arch
        self.cfg = arch.config
        self.scfg = scfg
        self.spec = spec
        if backend is not None:
            params = set_backend(params, backend)
        self.params = params
        self.backend = backend
        self.dtype = dtype
        self.mesh = mesh
        self._cache_shardings = None
        if mesh is not None:
            from repro.dist.sharding import (
                _axis_sizes, cache_pspecs, param_pspecs, sanitize_pspecs,
            )
            from repro.launch.mesh import dp_axes_of

            dp = dp_axes_of(mesh)
            model_size = _axis_sizes(mesh).get("model", 1)
            params_sds = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
            )
            pspec = sanitize_pspecs(
                mesh, param_pspecs(self.cfg, params_sds), params_sds
            )
            cache_sds = arch.cache_specs(scfg.batch_slots, scfg.max_seq, spec, dtype)
            cspec = sanitize_pspecs(
                mesh,
                cache_pspecs(self.cfg, cache_sds, dp, model_size=model_size),
                cache_sds,
            )
            ns = lambda tree: jax.tree.map(
                lambda s: NamedSharding(mesh, s), tree,
                is_leaf=lambda x: isinstance(x, P),
            )
            self.params = jax.device_put(params, ns(pspec))
            self._cache_shardings = ns(cspec)
        self._prefill = jax.jit(lambda p, b, c: arch.prefill(p, b, c, spec))
        self._decode = jax.jit(lambda p, t, c: arch.decode(p, t, c, spec))

    def _mesh_ctx(self):
        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    def _place_cache(self, cache):
        if self._cache_shardings is None:
            return cache
        return jax.device_put(cache, self._cache_shardings)

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / self.scfg.temperature).astype(jnp.int32)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 patch_embeds: Optional[np.ndarray] = None) -> Dict:
        """prompts: (B, S_prompt) int32 (audio: (B, S, K)). Returns dict with
        generated tokens (B, max_new) and per-step logits stats."""
        cfg, scfg = self.cfg, self.scfg
        b = prompts.shape[0]
        assert b <= scfg.batch_slots, "more prompts than batch slots"
        pad_b = scfg.batch_slots - b
        if pad_b:
            prompts = np.concatenate([prompts, np.zeros((pad_b,) + prompts.shape[1:],
                                                        prompts.dtype)])
        batch = {"tokens": jnp.asarray(prompts)}
        if cfg.modality == "vlm" and patch_embeds is not None:
            if pad_b:
                patch_embeds = np.concatenate(
                    [patch_embeds, np.zeros((pad_b,) + patch_embeds.shape[1:],
                                            patch_embeds.dtype)])
            batch["patch_embeds"] = jnp.asarray(patch_embeds)

        cache = self._place_cache(
            self.arch.init_cache(scfg.batch_slots, scfg.max_seq, self.spec, self.dtype)
        )
        with self._mesh_ctx():
            logits, cache = self._prefill(self.params, batch, cache)
            key = jax.random.PRNGKey(scfg.seed)
            outs = []
            last = logits.reshape(scfg.batch_slots, *logits.shape[1:])
            if last.ndim == 3:  # (B, 1, V) -> (B, V)
                last = last[:, 0]
            for t in range(max_new_tokens):
                key, sub = jax.random.split(key)
                tok = self._sample(last, sub)
                outs.append(np.asarray(tok[:b]))
                logits, cache = self._decode(self.params, tok, cache)
                last = logits
        gen = np.stack(outs, axis=1)  # (B, T) or (B, T, K)
        return {"tokens": gen, "final_length": int(cache["length"])}
