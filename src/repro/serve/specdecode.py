"""Self-drafted speculative decoding over the block-paged pool.

The paper's premise — harsher quantization stays usable when the rotation
is right — means a packed artifact already *contains* its own draft
model: re-quantize the same packed weights under a one-rule harsher
:class:`~repro.quant.policy.QuantPolicy` overlay (``draft-w2-rtn``) and
the draft shares rotations (already fused into the weights), activation
rules, the KV cache codec, and therefore the *block tables* with the
target.  No second checkpoint, no calibration, no separate pool.

This module provides the two halves:

* **artifact side** — :func:`derive_draft_params` walks an artifact tree
  and re-quantizes every :class:`~repro.quant.packed.PackedWeight` leaf
  under the draft overlay (float leaves are shared by reference), with
  construction-time validation (:func:`validate_draft_policy`) that the
  overlay is layer-uniform, calibration-free, and strictly cheaper, and
  never touches rotation/activation rules that would desync the shared
  cache layout.  :func:`combined_policy` prepends the overlay's weight
  rules to the target policy so a saved draft artifact round-trips
  through ``save``/``load`` with the *identical* serving spec;
* **serving side** — :func:`build_spec_window` jits the draft-k/verify-1
  window: k ordinary decode ticks with the draft weights (fused paged
  kernel or the vmapped baseline — whichever the engine built), feeding
  each greedy token back in, then one (k+1)-token chunked verify pass
  with the target weights *from the original lengths*, overwriting the
  draft KV with target KV in place.  The host-side accept/rollback lives
  in :meth:`ContinuousScheduler._step_spec`; the only new pool operation
  is :meth:`KVPool.rewind`, which truncates draft-appended K/V back to
  the accepted fill (free on block-paged storage: rejected positions
  simply fall outside the length mask).

Greedy spec-decode output is token-identical to greedy non-spec output
by construction: every emitted token is a *target* argmax — accepted
draft tokens are exactly those the target chain would have produced, and
the first mismatch is replaced by the target's own correction token.
The draft quality only moves the acceptance rate (throughput), never the
text.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.quant.packed import PackedWeight, is_packed
from repro.quant.policy import QuantPolicy, _err

__all__ = [
    "packed_sites",
    "validate_draft_policy",
    "derive_draft_params",
    "combined_policy",
    "validate_spec_config",
    "build_spec_window",
]


# ----------------------------------------------------------------------
# Artifact side: deriving the draft
# ----------------------------------------------------------------------

def packed_sites(params: Dict) -> List[Tuple[str, PackedWeight]]:
    """``(site, leaf)`` for every packed weight in an artifact tree.

    Sites are named the way :func:`~repro.quant.policy.resolve_policy`
    names them — path components joined by ``/`` with the ``layers``
    level dropped (``"w_down"``, ``"moe_mlp/w_down"``) — so draft rules
    written against the usual patterns match.  We walk the tree directly
    rather than via ``enumerate_sites`` because that helper looks for
    float ``ndim >= 2`` leaves and is blind to packed ones.
    """
    out: List[Tuple[str, PackedWeight]] = []

    def walk(node, path):
        if is_packed(node):
            out.append(("/".join(p for p in path if p != "layers"), node))
            return
        if isinstance(node, dict):
            for name in sorted(node):
                walk(node[name], path + (name,))

    walk(params, ())
    return out


def validate_draft_policy(draft: QuantPolicy) -> None:
    """Construction-time checks on a draft overlay policy.

    The draft must share the target's rotations, activation rules and KV
    cache codec (that is the whole point: same pool, same block tables,
    one serving spec), so an overlay rule may only change the *weight*
    quantizer — layer-uniformly and without calibration.  Raises
    :class:`ValueError` with an actionable hint, mirroring the
    ``SiteRule`` validation style.
    """
    if not draft.rules:
        raise _err("draft policy has no rules",
                   hint="an overlay needs at least one weight rule, "
                        "e.g. SiteRule(pattern='*', bits=2, group=128, "
                        "method='rtn') — or use the 'draft-w2-rtn' preset")
    for r in draft.rules:
        where = f"draft rule {r.pattern!r}"
        if r.layers is not None:
            raise _err(
                f"{where} is layer-restricted (layers={r.layers!r})",
                hint="a draft overlay must be layer-uniform: the draft "
                     "reuses the target's scanned layer body, so every "
                     "layer of a site re-quantizes under one rule")
        if r.rotation is not None:
            raise _err(
                f"{where} overrides the online rotation "
                f"({r.rotation!r})",
                hint="rotations are shared with the target artifact — "
                     "they are already fused into the packed weights the "
                     "draft re-quantizes; drop the rotation field")
        if r.has_act_override:
            raise _err(
                f"{where} overrides activation quantization",
                hint="activation rules are shared with the target: the "
                     "draft runs through the same QuantizeSpec so the KV "
                     "cache layout (and block tables) stay identical; "
                     "drop act_bits/act_group/act_clip")
        if r.method != "rtn":
            raise _err(
                f"{where} uses method {r.method!r}",
                hint="derive_draft re-quantizes packed weights without "
                     "calibration data; only 'rtn' is available")
        if r.bits >= 16:
            raise _err(
                f"{where} keeps weights in float (bits={r.bits})",
                hint="a draft must be strictly cheaper than the target; "
                     "pick bits < 16, e.g. the 'draft-w2-rtn' preset")


def derive_draft_params(params: Dict, draft: QuantPolicy) -> Dict:
    """Re-quantize every packed leaf of ``params`` under ``draft``.

    Float leaves (norms, embeddings, rotations, any site the target left
    unquantized) are shared by reference — the draft tree costs only its
    packed codes.  Validates full coverage and strict cheapness against
    the *actual* leaves, raising actionable errors.
    """
    sites = packed_sites(params)
    if not sites:
        raise _err(
            "artifact has no packed weights to derive a draft from",
            hint="derive_draft needs a quantized artifact "
                 "(api.quantize / api.load_quantized), not a float "
                 "param tree")
    plan: Dict[str, object] = {}
    cheaper = 0
    for site, leaf in sites:
        rule = draft.rule_for(site)
        if rule is None:
            raise _err(
                f"draft policy leaves packed site {site!r} uncovered",
                hint="every quantized site of the target must "
                     "re-quantize under the overlay; add a trailing "
                     "SiteRule(pattern='*') default")
        if rule.bits > leaf.bits:
            raise _err(
                f"draft rule {rule.pattern!r} puts {site!r} at "
                f"{rule.bits} bits, above the target's {leaf.bits}",
                hint="a draft must be at most the target's width at "
                     "every site (and strictly below somewhere); lower "
                     "the rule's bits or drop spec decode for this "
                     "artifact")
        plan[site] = rule
        if rule.bits < leaf.bits:
            cheaper += 1
    if not cheaper:
        raise _err(
            "draft policy is not strictly cheaper than the target "
            "(no site drops below its target width)",
            hint="self-drafting only pays when the draft is harsher; "
                 "lower bits on at least one site, e.g. 'draft-w2-rtn' "
                 "against a W4 target")

    def walk(node, path=()):
        if is_packed(node):
            site = "/".join(p for p in path if p != "layers")
            rule = plan[site]
            if rule.bits == node.bits and rule.group == node.group:
                return node  # same grid family: share the packed leaf
            return PackedWeight.from_float(
                node.dequantize(), rule.weight_cfg(node.c),
                backend=node.backend)
        if isinstance(node, dict):
            return {name: walk(v, path + (name,)) for name, v in
                    node.items()}
        return node  # float leaf: shared by reference

    return walk(params)


def combined_policy(target: QuantPolicy, draft: QuantPolicy) -> QuantPolicy:
    """The draft artifact's policy: overlay weight rules, target globals.

    Overlay rules are *prepended* — weight resolution is first-match-wins
    so they claim every site — while rotation plan and act/kv/calib
    globals copy from the target.  Because the overlay carries no
    rotation/act overrides (validated), ``combined.spec()`` lowers to
    exactly the target's spec: a saved draft artifact reloads with the
    shared cache layout, which is the save/load round-trip invariant
    spec decode depends on.
    """
    import dataclasses

    combined = dataclasses.replace(
        target,
        rules=tuple(draft.rules) + tuple(target.rules),
        name=f"{draft.name or 'draft'}@{target.name or 'target'}",
    )
    assert combined.spec() == target.spec(), \
        "draft overlay changed the serving spec (validation bug)"
    return combined


# ----------------------------------------------------------------------
# Serving side: the in-graph draft/verify window
# ----------------------------------------------------------------------

def validate_spec_config(engine) -> None:
    """Gate ``ServeConfig(spec_decode=True)`` at engine-build time.

    Raises :class:`ValueError` with an actionable hint for every
    unsupported combination rather than producing wrong tokens later.
    """
    scfg = engine.scfg
    if engine.draft_params is None:
        raise _err(
            "spec_decode=True but the engine has no draft weights",
            hint="derive one from the same artifact and pass it in: "
                 "draft = api.derive_draft(qm); "
                 "qm.serve(scfg, draft=draft)")
    if engine.cfg.modality == "audio":
        raise _err(
            f"spec_decode is undefined for audio ({engine.cfg.name}): "
            "codebook-grouped tokens have no scalar greedy chain",
            hint="serve audio models with spec_decode=False")
    if getattr(engine.arch, "decode_chunk", None) is None:
        raise _err(
            f"{engine.cfg.name} has no multi-token verify path",
            hint="spec decode needs Arch.decode_chunk (transformer "
                 "families); recurrent-state families cannot rewind a "
                 "draft window")
    pool = engine._pool
    if not pool.has_paged or pool.state:
        raise _err(
            f"{engine.cfg.name} cache is not fully block-paged",
            hint="draft rollback rewinds per-slot lengths over paged "
                 "KV; per-slot recurrent state cannot be rewound")
    if scfg.temperature > 0:
        raise _err(
            "spec_decode requires greedy sampling (temperature=0)",
            hint="acceptance compares draft and target argmax chains; "
                 "sampled verification is not implemented")
    if scfg.steps_per_sync != 1:
        raise _err(
            f"spec_decode composes with steps_per_sync=1 only "
            f"(got {scfg.steps_per_sync})",
            hint="the spec window is itself the multi-token device "
                 "batch: draft_k draft ticks + one verify per host sync")
    if scfg.draft_k < 1:
        raise _err(f"draft_k must be >= 1, got {scfg.draft_k}")


def build_spec_window(engine):
    """Jit the in-graph draft-k/verify-1 window for ``engine``.

    Returns ``window(params, draft_params, tokens, lengths, tables,
    paged, state) -> (drafted (S, k), target (S, k+1), bad (S,), paged,
    state)``, where ``bad`` flags slots whose verify logits contain any
    non-finite value (the scheduler quarantines those requests; the
    emitted chain for a healthy slot is unaffected).

    The k draft ticks run the engine's ordinary decode tick (fused paged
    kernel or vmapped baseline) with the *draft* weights, feeding each
    argmax back in; they append draft KV at positions ``[n, n+k)``.  The
    verify pass then pushes the (k+1)-token chunk ``[t0, g1..gk]``
    through the target weights *from the original lengths*, overwriting
    every draft-written position with target KV — the per-token cache
    codec (:func:`~repro.models.common.kv_quant_tokens`) makes the chunk
    write bitwise identical to k+1 sequential decode writes, so accepted
    positions hold exactly what non-spec decode would have stored.
    ``target[s, j]`` is the target's greedy token after consuming the
    chunk prefix ``[t0, g1..gj]``; the host accepts the longest matching
    run plus the correction (or bonus) token.
    """
    k = int(engine.scfg.draft_k)
    tick = engine._tick_fn
    verify = engine._verify_tick
    assert verify is not None, "engine built without a verify tick"
    obs = getattr(engine, "obs", None)
    if obs is not None and obs.tracer is not None:
        obs.tracer.event("spec_window_build", cat="serve", k=k,
                         slots=engine.pool.n_slots)

    def window(params, draft_params, tokens, lengths, tables, paged, state):
        toks, fill = tokens, lengths
        drafted = []
        for _ in range(k):  # static unroll: k is small
            logits, paged, state, fill = tick(
                draft_params, toks, fill, tables, paged, state)
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            drafted.append(toks)
        drafted = jnp.stack(drafted, axis=1)                   # (S, k)
        chunk = jnp.concatenate([tokens[:, None], drafted], axis=1)
        vlogits, paged, state, _ = verify(
            params, chunk, lengths, tables, paged, state)
        target = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)  # (S, k+1)
        bad = ~jnp.isfinite(
            vlogits.reshape((vlogits.shape[0], -1))).all(axis=-1)  # (S,)
        return drafted, target, bad, paged, state

    return jax.jit(window, donate_argnums=(5, 6))
