"""Fused paged decode-attention Pallas kernel over the KV-pool block table.

One grid program per decode slot.  The program walks the slot's block
table directly: each KV block is loaded from pool storage *in place*
(``pl.load`` at the table-indexed block id — no host-side gather into a
contiguous per-slot view, no scatter back), the new token's K/V are
appended to the right block through an aliased output, and attention
runs as a flash-style running softmax over the valid tokens only
(``ceil(length / T)`` blocks, not the worst-case view).

Pool storage arrives *stacked over layers* — ``(L, n_blocks, T, KV, d)``
— with the current layer index as a scalar input, so the caller's
layer scan passes the whole pool through unchanged (XLA aliases the
donated carry; the kernel touches only the blocks the table names).

Quantized KV blocks (uint8 codes + per-token scale/zero, the
``quant.kv_cache`` layout) are dequantized in-register right after the
block load — the packed pool bytes are the only KV HBM traffic, which
is the paper's low-bit-KV deployment story: R3/GSR-rotated KV lives in
HBM at 4-8 bits and is consumed inside the attention kernel instead of
being materialized twice per tick.  The new token arrives pre-quantized
(codes+scale+zero) so the score it contributes matches the
quantize→dequantize roundtrip the reference path computes.

MLA's absorbed decode maps onto the same kernel: KV-heads = 1, the
query is ``concat(q_latent, q_rope)`` per head, K is the latent block
(optionally quantized) concatenated with a *second* float block source
(the RoPE key, ``k2``), and V aliases the dequantized latent
(``v_is_k1``) — so one kernel serves dense/GQA, MoE, Zamba's hybrid KV
half, and MLA.

TPU deployment note: block shapes here keep the full pool resident
(interpret-mode semantics; fine on CPU and for pool sizes within VMEM).
On a real TPU the pool refs move to ``pltpu.ANY`` memory space with
explicit per-block DMA — the grid, table walk, and running-softmax body
are unchanged.  ``block_pages`` (how many T-token blocks each inner
iteration consumes) is the measured-autotune knob
(:mod:`repro.kernels.autotune`).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _dequant(codes, scale, zero):
    """codes (..., d) uint8, scale/zero (...,) f32 -> f32 values."""
    return (codes.astype(jnp.float32) - zero[..., None]) * scale[..., None]


def _paged_attn_kernel(
    *refs,
    n_pages: int,
    page_tokens: int,
    block_pages: int,
    window: int,
    scale: float,
    quant_k: bool,
    quant_v: bool,
    has_k2: bool,
    v_is_k1: bool,
):
    """Grid (n_slots,). See ``paged_attention_pallas`` for the ref layout."""
    it = iter(refs)
    nxt = lambda: next(it)
    tbl_ref, len_ref, layer_ref, q_ref = nxt(), nxt(), nxt(), nxt()
    k_ref = nxt()
    ks_ref, kz_ref = (nxt(), nxt()) if quant_k else (None, None)
    k2_ref = nxt() if has_k2 else None
    if v_is_k1:
        v_ref, vs_ref, vz_ref = None, None, None
    else:
        v_ref = nxt()
        vs_ref, vz_ref = (nxt(), nxt()) if quant_v else (None, None)
    kn_ref = nxt()
    kns_ref, knz_ref = (nxt(), nxt()) if quant_k else (None, None)
    k2n_ref = nxt() if has_k2 else None
    if v_is_k1:
        vn_ref, vns_ref, vnz_ref = None, None, None
    else:
        vn_ref = nxt()
        vns_ref, vnz_ref = (nxt(), nxt()) if quant_v else (None, None)
    o_ref = nxt()
    out_writes = list(it)  # aliased page outputs, same order as page inputs

    t = page_tokens
    length = len_ref[0]
    layer = layer_ref[0]
    kv, rep, dk = q_ref.shape[1:]
    d1 = k_ref.shape[-1]
    dv = o_ref.shape[-1]
    q = q_ref[0].astype(jnp.float32) * scale  # (KV, rep, dk)

    def load_page(ref, blk):
        """(L, NB, T, KV, d) | (L, NB, T, KV) at [layer, blk] -> block."""
        idx = (pl.dslice(layer, 1), pl.dslice(blk, 1)) + tuple(
            pl.dslice(0, s) for s in ref.shape[2:]
        )
        return pl.load(ref, idx)[0, 0]

    def load_kv_page(blk):
        """-> k (T, KV, dk) f32 (k2 concatenated), v (T, KV, dv) f32."""
        if quant_k:
            k = _dequant(load_page(k_ref, blk), load_page(ks_ref, blk),
                         load_page(kz_ref, blk))
        else:
            k = load_page(k_ref, blk).astype(jnp.float32)
        if v_is_k1:
            v = k[..., :dv]
        elif quant_v:
            v = _dequant(load_page(v_ref, blk), load_page(vs_ref, blk),
                         load_page(vz_ref, blk))
        else:
            v = load_page(v_ref, blk).astype(jnp.float32)
        if has_k2:
            k = jnp.concatenate([k, load_page(k2_ref, blk).astype(jnp.float32)],
                                axis=-1)
        return k, v

    def accumulate(carry, sc, v, valid):
        """One running-softmax update. sc (KV,rep,n) f32, v (n,KV,dv)."""
        m, l, acc = carry
        sc = jnp.where(valid[None, None, :], sc, NEG_INF)
        m2 = jnp.maximum(m, sc.max(-1))
        p = jnp.exp(sc - m2[..., None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + p.sum(-1)
        acc2 = acc * corr[..., None] + jnp.einsum(
            "grt,tgd->grd", p, v, preferred_element_type=jnp.float32)
        return m2, l2, acc2

    init = (
        jnp.full((kv, rep), NEG_INF, jnp.float32),
        jnp.zeros((kv, rep), jnp.float32),
        jnp.zeros((kv, rep, dv), jnp.float32),
    )

    pages_needed = (length + t - 1) // t  # only blocks holding real tokens
    u = block_pages
    n_iter = (pages_needed + u - 1) // u

    def body(i, carry):
        for uu in range(u):  # static unroll of block_pages pages
            jj = i * u + uu
            # overrun pages of the last unrolled chunk: clamp the table
            # read in bounds but keep positions unclamped so the
            # `kpos < length` mask discards the duplicate load entirely
            blk = tbl_ref[0, jnp.minimum(jj, n_pages - 1)]
            k, v = load_kv_page(blk)
            sc = jnp.einsum("grd,tgd->grt", q, k,
                            preferred_element_type=jnp.float32)
            kpos = jj * t + jnp.arange(t)
            valid = kpos < length
            if window:
                valid &= kpos >= length + 1 - window
            carry = accumulate(carry, sc, v, valid)
        return carry

    m, l, acc = jax.lax.fori_loop(0, n_iter, body, init)

    # --- the freshly produced token (position `length`) -------------------
    # Float pages: round through the page dtype first — the baseline
    # stores the token then attends over the *stored* value, so the
    # fused score must see the same rounding (bf16 pools).
    if quant_k:
        knew = _dequant(kn_ref[0], kns_ref[0], knz_ref[0])  # (KV, d1)
    else:
        knew = kn_ref[0].astype(k_ref.dtype).astype(jnp.float32)
    if v_is_k1:
        vnew = knew[..., :dv]
    elif quant_v:
        vnew = _dequant(vn_ref[0], vns_ref[0], vnz_ref[0])
    else:
        vnew = vn_ref[0].astype(v_ref.dtype).astype(jnp.float32)
    kq = jnp.concatenate(
        [knew, k2n_ref[0].astype(k2_ref.dtype).astype(jnp.float32)], -1) \
        if has_k2 else knew
    sc = jnp.einsum("grd,gd->gr", q, kq,
                    preferred_element_type=jnp.float32)[..., None]
    m, l, acc = accumulate((m, l, acc), sc, vnew[None], jnp.ones((1,), bool))

    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(o_ref.dtype)

    # --- append the new token to its block (aliased in-place write) -------
    blk = tbl_ref[0, length // t]
    off = length % t

    def store_page(out, val):
        idx = (pl.dslice(layer, 1), pl.dslice(blk, 1), pl.dslice(off, 1)) + \
            tuple(pl.dslice(0, s) for s in out.shape[3:])
        pl.store(out, idx, val[None, None, None].astype(out.dtype))

    writes = iter(out_writes)
    store_page(next(writes), kn_ref[0])
    if quant_k:
        store_page(next(writes), kns_ref[0])
        store_page(next(writes), knz_ref[0])
    if has_k2:
        store_page(next(writes), k2n_ref[0])
    if not v_is_k1:
        store_page(next(writes), vn_ref[0])
        if quant_v:
            store_page(next(writes), vns_ref[0])
            store_page(next(writes), vnz_ref[0])


@functools.partial(
    jax.jit,
    static_argnames=("window", "scale", "quant_k", "quant_v", "v_is_k1",
                     "block_pages", "interpret"),
)
def paged_attention_pallas(
    q: jax.Array,
    tables: jax.Array,
    lengths: jax.Array,
    layer: jax.Array,
    k_pages: Tuple[jax.Array, ...],
    v_pages: Optional[Tuple[jax.Array, ...]],
    k2_pages: Optional[jax.Array],
    k_new: Tuple[jax.Array, ...],
    v_new: Optional[Tuple[jax.Array, ...]],
    k2_new: Optional[jax.Array],
    *,
    window: int = 0,
    scale: Optional[float] = None,
    quant_k: bool = False,
    quant_v: bool = False,
    v_is_k1: bool = False,
    block_pages: int = 1,
    interpret: bool = True,
):
    """Fused append-and-attend over paged pool storage.

    Args:
      q: ``(S, KV, rep, dk)`` queries (one decode token per slot).
      tables: ``(S, MB)`` int32 block table (scratch id 0 for unbacked).
      lengths: ``(S,)`` int32 — tokens already cached per slot; the new
        token is written at this position and included in attention.
      layer: scalar int32 — which layer of the stacked pool to touch.
      k_pages: ``(pages,)`` or ``(codes, scale, zero)`` when ``quant_k``;
        pages ``(L, NB, T, KV, d1)``, scales ``(L, NB, T, KV)``.
      v_pages: like ``k_pages`` (``quant_v``); None with ``v_is_k1``
        (V = dequantized K source 1 truncated to the output feature dim).
      k2_pages: optional second float K source ``(L, NB, T, KV, d2)``
        concatenated to K on the feature axis (MLA RoPE keys); the query
        must already carry ``dk = d1 + d2``.
      k_new/v_new/k2_new: the new token in the same (possibly quantized)
        layout, shapes ``(S, KV, d)`` / ``(S, KV)``.
      window: sliding-window size (0 = full causal).
      scale: score scale; default ``1/sqrt(dk)``.
      block_pages: pages consumed per inner iteration (autotuned).

    Returns ``(out, new_pages)``: out ``(S, KV, rep, dv)`` f32 and the
    page arrays with the new token appended, in input order
    ``k (+scale,zero) [, k2] [, v (+scale,zero)]`` — aliased to the
    inputs, so donate them.
    """
    s, kv, rep, dk = q.shape
    mb = tables.shape[1]
    t = k_pages[0].shape[2]
    d1 = k_pages[0].shape[-1]
    if v_is_k1:
        dv = d1
    else:
        dv = v_pages[0].shape[-1]
    if scale is None:
        scale = 1.0 / np.sqrt(dk)

    page_inputs = list(k_pages)
    new_inputs = list(k_new)
    if k2_pages is not None:
        page_inputs.append(k2_pages)
        new_inputs.append(k2_new)
    if not v_is_k1:
        page_inputs.extend(v_pages)
        new_inputs.extend(v_new)

    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    slot = lambda a: pl.BlockSpec((1,) + a.shape[1:],
                                  lambda i: (i,) + (0,) * (a.ndim - 1))
    layer_arr = jnp.asarray(layer, jnp.int32).reshape(1)

    inputs = [tables, lengths, layer_arr, q] + page_inputs + new_inputs
    in_specs = [slot(tables), slot(lengths), full(layer_arr), slot(q)]
    in_specs += [full(a) for a in page_inputs]
    in_specs += [slot(a) for a in new_inputs]

    out_shape = [jax.ShapeDtypeStruct((s, kv, rep, dv), jnp.float32)]
    out_specs = [pl.BlockSpec((1, kv, rep, dv), lambda i: (i, 0, 0, 0))]
    aliases = {}
    for pi, arr in enumerate(page_inputs):
        aliases[4 + pi] = len(out_shape)
        out_shape.append(jax.ShapeDtypeStruct(arr.shape, arr.dtype))
        out_specs.append(full(arr))

    kernel = functools.partial(
        _paged_attn_kernel,
        n_pages=mb,
        page_tokens=t,
        block_pages=block_pages,
        window=window,
        scale=float(scale),
        quant_k=quant_k,
        quant_v=quant_v,
        has_k2=k2_pages is not None,
        v_is_k1=v_is_k1,
    )
    outs = pl.pallas_call(
        kernel,
        grid=(s,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(*inputs)
    return outs[0], tuple(outs[1:])
