"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the exact numerics the kernel is required to
reproduce; tests sweep shapes/dtypes and assert_allclose kernel vs oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.rotation import fwht as _fwht_core
from repro.quant import pack as packmod
from repro.quant import rtn
from repro.quant.qtypes import QuantConfig, QuantizedTensor


def fwht_ref(x: jax.Array, *, normalize: bool = True) -> jax.Array:
    """(M, D) Hadamard transform along D, natural (Sylvester) order."""
    return _fwht_core(x, normalize=normalize)


def grouped_rotate_ref(x: jax.Array, blocks: jax.Array, *, inverse: bool = False) -> jax.Array:
    """(M, C) block-diagonal rotation; blocks (N|1, G, G)."""
    m, c = x.shape
    nb, g, _ = blocks.shape
    n = c // g
    b = blocks if not inverse else jnp.swapaxes(blocks, -1, -2)
    xs = x.astype(jnp.float32).reshape(m, n, g)
    if nb == 1:
        out = jnp.einsum("mng,gh->mnh", xs, b[0].astype(jnp.float32))
    else:
        out = jnp.einsum("mng,ngh->mnh", xs, b.astype(jnp.float32))
    return out.reshape(m, c).astype(x.dtype)


def dequant_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """y = x @ dequant(Wq) in f32, cast back to x.dtype."""
    if qt.packed:
        qt = packmod.unpack(qt)
    w = rtn.dequantize_weight(qt)
    return (x.astype(jnp.float32) @ w.astype(jnp.float32)).astype(x.dtype)


def rtn_fake_quant_ref(
    x: jax.Array, *, bits: int = 4, group: int = 128, clip_ratio: float = 0.9
) -> jax.Array:
    """Symmetric grouped fake-quant, same conventions as the kernel."""
    cfg = QuantConfig(bits=bits, group=group, symmetric=True, clip_ratio=clip_ratio)
    return rtn.fake_quant_act_grouped(x, cfg)


def gsr_rotate_quant_ref(
    x: jax.Array, blocks: jax.Array, *, bits: int = 4, clip_ratio: float = 0.9
) -> jax.Array:
    """Oracle: grouped rotation, then grouped symmetric RTN (group == G)."""
    y = grouped_rotate_ref(x, blocks)
    g = blocks.shape[-1]
    return rtn_fake_quant_ref(y, bits=bits, group=g, clip_ratio=clip_ratio)
