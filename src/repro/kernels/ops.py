"""jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode; on a real TPU
deployment ``INTERPRET`` flips to False and the same BlockSpecs compile to
Mosaic.  Wrappers accept arbitrary leading batch dims and restore them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dequant_matmul import dequant_matmul_pallas
from repro.kernels.fwht import fwht_pallas
from repro.kernels.grouped_rotate import grouped_rotate_pallas
from repro.kernels.gsr_quant import gsr_rotate_quant_pallas
from repro.kernels.rtn_quant import rtn_fake_quant_pallas
from repro.quant.qtypes import QuantizedTensor

# Pallas interpret mode: required on CPU; flipped off on TPU backends.
INTERPRET = jax.default_backend() != "tpu"


def _flatten_batch(x: jax.Array):
    lead = x.shape[:-1]
    return x.reshape(-1, x.shape[-1]), lead


def fwht(x: jax.Array, *, normalize: bool = True) -> jax.Array:
    """Hadamard transform along the last axis (any leading dims)."""
    x2, lead = _flatten_batch(x)
    return fwht_pallas(x2, normalize=normalize, interpret=INTERPRET).reshape(*lead, -1)


def grouped_rotate(x: jax.Array, blocks: jax.Array, *, inverse: bool = False) -> jax.Array:
    """Block-diagonal rotation along the last axis; blocks (N|1, G, G)."""
    x2, lead = _flatten_batch(x)
    out = grouped_rotate_pallas(x2, blocks, inverse=inverse, interpret=INTERPRET)
    return out.reshape(*lead, -1)


def dequant_matmul(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """x (..., C) @ dequant(Wq (C, H)) -> (..., H)."""
    x2, lead = _flatten_batch(x)
    out = dequant_matmul_pallas(x2, qt, interpret=INTERPRET)
    return out.reshape(*lead, out.shape[-1])


def rtn_fake_quant(
    x: jax.Array, *, bits: int = 4, group: int = 128, clip_ratio: float = 0.9
) -> jax.Array:
    """Grouped symmetric activation fake-quant along the last axis."""
    x2, lead = _flatten_batch(x)
    out = rtn_fake_quant_pallas(
        x2, bits=bits, group=group, clip_ratio=clip_ratio, interpret=INTERPRET
    )
    return out.reshape(*lead, -1)


def paged_attention(q, tables, lengths, layer, k_pages, v_pages, k2_pages,
                    k_new, v_new, k2_new, *, window: int = 0,
                    scale=None, v_is_k1: bool = False):
    """Fused paged decode attention + new-token append over pool blocks.

    See :func:`repro.kernels.paged_attention.paged_attention_pallas`;
    this wrapper resolves quantization flags from the tuple arity and the
    autotuned ``block_pages`` for the shape at hand.
    """
    from repro.kernels import autotune
    from repro.kernels.paged_attention import paged_attention_pallas

    s, kv, rep, dk = q.shape
    mb = tables.shape[1]
    t = k_pages[0].shape[2]
    bp = autotune.best(
        "paged_attention", (s, mb, t, kv, rep, dk), q.dtype,
        {"block_pages": 1})["block_pages"]
    return paged_attention_pallas(
        q, tables, lengths, layer, tuple(k_pages),
        None if v_pages is None else tuple(v_pages), k2_pages,
        tuple(k_new), None if v_new is None else tuple(v_new), k2_new,
        window=window, scale=scale, quant_k=len(k_pages) == 3,
        quant_v=v_pages is not None and len(v_pages) == 3,
        v_is_k1=v_is_k1, block_pages=min(bp, mb), interpret=INTERPRET)


def gsr_rotate_quant(
    x: jax.Array, blocks: jax.Array, *, bits: int = 4, clip_ratio: float = 0.9
) -> jax.Array:
    """Fused online R4 (GSR/LH) + A-bit activation fake-quant."""
    x2, lead = _flatten_batch(x)
    out = gsr_rotate_quant_pallas(
        x2, blocks, bits=bits, clip_ratio=clip_ratio, interpret=INTERPRET
    )
    return out.reshape(*lead, -1)
