"""Fused GSR-rotate + RTN activation-quantize Pallas kernel.

The W2A4 serving path runs ``act_quant(grouped_rotate(x))`` in front of
every down projection (the paper's online R4 followed by the A4
quantizer).  As two kernels that is two full HBM round-trips of the
activation; fused, the rotated block never leaves VMEM before being
quantized - halving the HBM traffic of the hottest online op in the
paper's deployment (a beyond-paper optimization enabled by GSR's local
structure: the rotation group and the quantization group coincide, so
one (bm, G) VMEM tile sees everything both steps need.  A *global*
Hadamard R4 cannot fuse this way - the quantizer groups would straddle
the full-width transform).

Grid (M/bm, N): x block (bm, G) at (i, n); rotation (1|N, G, G); output
fake-quantized in x.dtype (int8-codes emission differs only in the final
store).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gsr_quant_kernel(x_ref, r_ref, o_ref, *, qmax: int, clip_ratio: float):
    x = x_ref[...].astype(jnp.float32)  # (bm, G)
    r = r_ref[0].astype(jnp.float32)  # (G, G)
    y = jax.lax.dot(x, r, precision=jax.lax.Precision.HIGHEST)
    # per-(row, group) symmetric RTN - the group IS this block's lane axis
    amax = jnp.max(jnp.abs(y), axis=-1, keepdims=True) * clip_ratio
    scale = jnp.where(amax <= 0, 1.0, amax / qmax)
    q = jnp.clip(jnp.round(y / scale), -qmax - 1, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bits", "clip_ratio", "block_m", "interpret")
)
def gsr_rotate_quant_pallas(
    x: jax.Array,
    blocks: jax.Array,
    *,
    bits: int = 4,
    clip_ratio: float = 0.9,
    block_m: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, C); blocks: (N|1, G, G). Fused y = fq(x @ blockdiag(R))."""
    m, c = x.shape
    nb, g, g2 = blocks.shape
    assert g == g2
    if c % g:
        raise ValueError(f"C={c} not divisible by G={g}")
    n = c // g
    if nb not in (1, n):
        raise ValueError(f"blocks leading dim {nb} must be 1 or {n}")
    qmax = 2 ** (bits - 1) - 1
    bm = block_m or min(256, m)
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    mp = x.shape[0]
    rot_idx = (lambda i, j: (0, 0, 0)) if nb == 1 else (lambda i, j: (j, 0, 0))
    out = pl.pallas_call(
        functools.partial(_gsr_quant_kernel, qmax=qmax, clip_ratio=clip_ratio),
        grid=(mp // bm, n),
        in_specs=[
            pl.BlockSpec((bm, g), lambda i, j: (i, j)),
            pl.BlockSpec((1, g, g), rot_idx),
        ],
        out_specs=pl.BlockSpec((bm, g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, c), x.dtype),
        interpret=interpret,
    )(x, blocks)
    return out[:m] if pad else out
