"""Grouped symmetric RTN activation quantization Pallas kernel.

The A4 path quantizes every GEMM input activation online (paper A.1:
symmetric RTN, clip ratio 0.9, group 128).  This runs on *every* token at
serving time, so it must be a single streaming pass: one block read, a
per-(row, group) max-reduce, scale, round, write.

Fake-quant form (quantize-dequantize) is emitted here; the real-int8 form
only changes the store dtype and is handled by the wrapper.

Blocks: ``(block_m, G)`` at grid (i, g) - group g of row stripe i; the
reduction is over the last (lane) axis which is the cheap axis on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rtn_kernel(x_ref, o_ref, *, qmax: int, clip_ratio: float):
    x = x_ref[...].astype(jnp.float32)  # (bm, G)
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True) * clip_ratio
    scale = jnp.where(amax <= 0, 1.0, amax / qmax)
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    o_ref[...] = (q * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "group", "clip_ratio", "block_m", "interpret"))
def rtn_fake_quant_pallas(
    x: jax.Array,
    *,
    bits: int = 4,
    group: int = 128,
    clip_ratio: float = 0.9,
    block_m: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, C) -> fake-quantized x, groups of `group` along C."""
    m, c = x.shape
    if c % group != 0:
        raise ValueError(f"C={c} not divisible by group={group}")
    qmax = 2 ** (bits - 1) - 1
    bm = block_m or min(512, m)
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    mp = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_rtn_kernel, qmax=qmax, clip_ratio=clip_ratio),
        grid=(mp // bm, c // group),
        in_specs=[pl.BlockSpec((bm, group), lambda i, g: (i, g))],
        out_specs=pl.BlockSpec((bm, group), lambda i, g: (i, g)),
        out_shape=jax.ShapeDtypeStruct((mp, c), x.dtype),
        interpret=interpret,
    )(x)
    return out[:m] if pad else out
