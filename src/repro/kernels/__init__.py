"""Pallas TPU kernels for the performance-critical hot spots.

The paper's pipeline has four compute hot spots on the serving path, each
with a kernel here, a jit'd wrapper in :mod:`repro.kernels.ops`, and a
pure-jnp oracle in :mod:`repro.kernels.ref`:

  * ``fwht``            - global fast Walsh-Hadamard transform (the GH/GW
                          online rotation, e.g. QuaRot's R4).
  * ``grouped_rotate``  - block-diagonal (local) rotation: LH / GSR.  On
                          TPU with G=128 this is a single MXU tile per
                          group - the reason GSR's local online rotation is
                          *cheap* here, unlike the GPU caveat in paper A.2.
  * ``dequant_matmul``  - fused packed-W2/W4 dequantize + matmul (streams
                          packed bytes HBM->VMEM; the W2/W4 decode-path
                          memory-roofline win).
  * ``rtn_quant``       - grouped symmetric RTN activation fake-quant
                          (the A4 online quantizer in front of every GEMM).
  * ``gsr_quant``       - FUSED grouped-rotate + activation-quantize: the
                          W2A4 serving path's online R4->A4 in one VMEM
                          pass (half the HBM traffic of the two-kernel
                          pipeline; only possible because GSR's rotation
                          group coincides with the quantization group).
  * ``paged_attention`` - fused paged decode attention over the serving
                          pool's block table (in-place block reads,
                          in-kernel KV dequant, in-kernel new-token
                          append — the no-gather decode hot path).

Block sizes are resolved through :mod:`repro.kernels.autotune` — a
measure-and-cache JSON table keyed by shape x dtype x backend, with the
shipped defaults as the interpret-mode fallback.

All kernels are written against ``pl.pallas_call`` with explicit BlockSpec
VMEM tiling for TPU as the *target*, and validated on CPU in interpret
mode (kernel bodies run in Python) against the oracles.
"""
