"""Fused packed-low-bit dequantize + matmul Pallas kernel.

``y = x @ dequant(Wq)`` where Wq is a (C, H) weight RTN/GPTQ-quantized to
2/4/8 bits with groups of G along C and bit-packed along C (see
:mod:`repro.quant.pack`).

Why a kernel: quantized *decode* is memory-roofline-bound on the weight
bytes.  Streaming the packed codes (0.25-1 byte per weight) from HBM and
unpacking in VMEM cuts the dominant roofline term by 4-8x vs bf16 - this
is the paper's W2/W4 deployment story made concrete on TPU.

Grid: ``(M/bm, H/bn, C/bk)`` with ``bk == G`` so each K-step covers exactly
one quantization group and needs a single ``(1, bn)`` scale/zero row.
The output block index map ignores k, so the f32 accumulator tile stays
resident in VMEM across the K loop (TPU 'arbitrary' grid semantics);
``@pl.when(k == 0)`` zero-initialises it.

VMEM per step: x (bm*G*4) + codes (G/pb * bn) + out (bm*bn*4) - e.g.
bm=bn=256, G=128: 128KiB + 8-32KiB + 256KiB, comfortably inside VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant.pack import codes_per_byte
from repro.quant.qtypes import QuantizedTensor


def _dq_mm_kernel(x_ref, codes_ref, scale_ref, zero_ref, o_ref, *, bits: int, asym: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    pb = codes_per_byte(bits)
    mask = (1 << bits) - 1
    packed = codes_ref[...]  # (bk // pb, bn) uint8
    # Unpack: code i within a byte belongs to input-channel row byte*pb + i.
    parts = [((packed >> (bits * i)) & mask).astype(jnp.float32) for i in range(pb)]
    w = jnp.stack(parts, axis=1).reshape(packed.shape[0] * pb, packed.shape[1])
    if asym:
        w = (w - zero_ref[...]) * scale_ref[...]  # (1, bn) broadcasts over bk
    else:
        offset = float(1 << (bits - 1))
        w = (w - offset) * scale_ref[...]
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot(x, w, precision=jax.lax.Precision.HIGHEST)


@functools.partial(
    jax.jit, static_argnames=("bits", "group", "asym", "block_m", "block_n", "interpret")
)
def _dequant_matmul_impl(
    x, codes, scale, zero, *, bits, group, asym, block_m, block_n, interpret
):
    m, c = x.shape
    cp, h = codes.shape
    pb = codes_per_byte(bits)
    assert cp * pb == c, f"packed codes rows {cp}*{pb} != C={c}"
    assert c % group == 0
    bm = min(block_m, m)
    bn = min(block_n, h)
    pad_m, pad_n = (-m) % bm, (-h) % bn
    if pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, 0)))
    if pad_n:
        codes = jnp.pad(codes, ((0, 0), (0, pad_n)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_n)))
        zero = jnp.pad(zero, ((0, 0), (0, pad_n)))
    mp, hp = x.shape[0], codes.shape[1]
    bk = group
    grid = (mp // bm, hp // bn, c // bk)
    out = pl.pallas_call(
        functools.partial(_dq_mm_kernel, bits=bits, asym=asym),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // pb, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, hp), jnp.float32),
        interpret=interpret,
    )(x, codes, scale, zero)
    return out[:m, :h]


def dequant_matmul_pallas(
    x: jax.Array,
    qt: QuantizedTensor,
    *,
    block_m: int | None = None,
    block_n: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """y = x @ dequant(qt); qt must be packed. Returns x.dtype.

    ``block_m``/``block_n`` default to the autotuned choice for this
    (M, C, H) shape (measured table, see :mod:`repro.kernels.autotune`)
    falling back to the conservative 256x256 tiles.
    """
    if not qt.packed:
        raise ValueError("dequant_matmul_pallas requires packed codes")
    if block_m is None or block_n is None:
        from repro.kernels import autotune

        tuned = autotune.best(
            "dequant_matmul", (x.shape[0], x.shape[1], qt.codes.shape[1]),
            x.dtype, {"block_m": 256, "block_n": 256})
        block_m = block_m or tuned["block_m"]
        block_n = block_n or tuned["block_n"]
    asym = qt.zero is not None
    zero = qt.zero if asym else jnp.zeros_like(qt.scale)
    out = _dequant_matmul_impl(
        x,
        qt.codes,
        qt.scale.astype(jnp.float32),
        zero.astype(jnp.float32),
        bits=qt.bits,
        group=qt.group,
        asym=asym,
        block_m=block_m,
        block_n=block_n,
        interpret=interpret,
    )
    return out.astype(x.dtype)
