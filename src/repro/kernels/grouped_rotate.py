"""Block-diagonal (local) rotation Pallas kernel: the GSR/LH online path.

Computes ``y[:, nG:(n+1)G] = x[:, nG:(n+1)G] @ R_n`` for every group n.
With G = 128 each grid step is exactly one 128x128 MXU tile contraction -
the TPU-native answer to the paper's A.2 concern that local online
rotation "disables the fast-hadamard-transform": on a systolic-array
machine the G x G dense block *is* the fast path.

Blocks: x ``(block_m, G)`` at (i, n); rotation ``(1, G, G)`` at block n
(or the single shared Walsh block for GSR, index 0).  FLOPs per element:
G MACs vs log2(D) adds for global FWHT - but at G=128 on the MXU this is
~1 tile-op, while FWHT's log-depth shuffle is VPU-bound, so GSR rotation
is *faster* per byte than the global transform it replaces.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rot_kernel(x_ref, r_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (bm, G)
    r = r_ref[0].astype(jnp.float32)  # (G, G)
    o_ref[...] = jax.lax.dot(x, r, precision=jax.lax.Precision.HIGHEST).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret", "inverse"))
def grouped_rotate_pallas(
    x: jax.Array,
    blocks: jax.Array,
    *,
    inverse: bool = False,
    block_m: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, C); blocks: (N, G, G) per-group rotations (N=1 = shared/GSR).

    C must equal num_groups * G where num_groups = C // G.
    """
    m, c = x.shape
    nb, g, g2 = blocks.shape
    assert g == g2, "rotation blocks must be square"
    if c % g != 0:
        raise ValueError(f"C={c} not divisible by G={g}")
    n = c // g
    if nb not in (1, n):
        raise ValueError(f"blocks leading dim {nb} must be 1 or {n}")
    if inverse:
        blocks = jnp.swapaxes(blocks, -1, -2)
    bm = block_m or min(256, m)
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    mp = x.shape[0]
    rot_idx = (lambda i, j: (0, 0, 0)) if nb == 1 else (lambda i, j: (j, 0, 0))
    out = pl.pallas_call(
        _rot_kernel,
        grid=(mp // bm, n),
        in_specs=[
            pl.BlockSpec((bm, g), lambda i, j: (i, j)),
            pl.BlockSpec((1, g, g), rot_idx),
        ],
        out_specs=pl.BlockSpec((bm, g), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, c), x.dtype),
        interpret=interpret,
    )(x, blocks)
    return out[:m] if pad else out
