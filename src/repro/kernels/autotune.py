"""Measure-and-cache block-size autotuning for the Pallas kernels.

The kernels ship with conservative default block sizes that are correct
everywhere but tuned nowhere.  This harness closes the loop: on a real
backend it times each candidate block configuration for the exact
(shape, dtype) it is asked about, picks the fastest, and persists the
choice in a JSON table so every later process (and every later PR) gets
the tuned value for free.

Key structure: ``op -> "shape|dtype|backend" -> {param: value}``, e.g.

    {"dequant_matmul": {"(512, 4096, 1024)|f32|tpu":
        {"block_m": 512, "block_n": 256, "us": 113.2}}}

Lookup order (``best``):

1. table hit -> use the cached choice: exact shape|dtype|backend first,
   else the same shape|dtype measured on another backend (tpu preferred)
   — which is how a table measured on TPU rides into CPU CI unchanged,
   and how tests inject known values;
2. no hit, measurable backend (``tpu``/``gpu``) -> time every candidate,
   cache + persist the winner;
3. no hit, interpret-mode backend (CPU) -> the caller's defaults —
   interpret wall time reflects the emulator, not the hardware, so
   measuring would poison the table.

The cache file lives at ``$REPRO_AUTOTUNE_CACHE`` (default
``~/.cache/repro/autotune.json``).  Regenerate on hardware with::

    python -m repro.kernels.autotune            # tune all registered ops
    python -m repro.kernels.autotune --op fwht  # one op
"""
from __future__ import annotations

import itertools
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

_TABLE: Optional[Dict] = None  # lazy-loaded in-memory cache


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"),
    )


def _backend() -> str:
    return jax.default_backend()


def measurable() -> bool:
    """Interpret-mode backends must not write measurements (see module doc)."""
    return _backend() in ("tpu", "gpu")


def load_table(path: Optional[str] = None) -> Dict:
    global _TABLE
    if _TABLE is None or path is not None:
        p = path or cache_path()
        try:
            with open(p) as f:
                _TABLE = json.load(f)
        except (OSError, ValueError):
            _TABLE = {}
    return _TABLE


def save_table(path: Optional[str] = None) -> str:
    p = path or cache_path()
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(load_table(), f, indent=1, sort_keys=True)
    os.replace(tmp, p)
    return p


def reset_cache() -> None:
    """Drop the in-memory table (tests; env-var repoints the file)."""
    global _TABLE
    _TABLE = None


def key_for(shapes: Sequence[int], dtype) -> str:
    dt = jax.numpy.dtype(dtype).name if dtype is not None else "-"
    return f"{tuple(int(s) for s in shapes)}|{dt}|{_backend()}"


def _time_call(fn: Callable, iters: int = 5) -> float:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def record(op: str, key: str, choice: Dict) -> None:
    load_table().setdefault(op, {})[key] = dict(choice)


def lookup(op: str, key: str) -> Optional[Dict]:
    """Exact ``shape|dtype|backend`` hit, else the same shape|dtype entry
    measured on another backend (tpu preferred) — this is what lets a
    table regenerated on TPU ride into CPU CI unchanged."""
    entries = load_table().get(op, {})
    hit = entries.get(key)
    if hit:
        return dict(hit)
    prefix = key.rsplit("|", 1)[0]
    for backend in ("tpu", "gpu", "cpu"):
        hit = entries.get(f"{prefix}|{backend}")
        if hit:
            return dict(hit)
    return None


def best(
    op: str,
    shapes: Sequence[int],
    dtype,
    defaults: Dict,
    candidates: Optional[Sequence[Dict]] = None,
    measure: Optional[Callable[[Dict], Callable]] = None,
) -> Dict:
    """The tuned block config for ``op`` at this shape/dtype/backend.

    ``measure(params) -> thunk`` builds a zero-arg callable running the
    kernel with candidate ``params``; it is only invoked on measurable
    backends with no cached entry.  The returned dict always contains at
    least the keys of ``defaults``.
    """
    # observability: every resolution reports its source (table hit /
    # measured sweep / static default) to any subscribed profiler
    from repro.obs.profile import notify_autotune

    key = key_for(shapes, dtype)
    hit = lookup(op, key)
    if hit is not None:
        notify_autotune(op, "table", key=key, best_us=hit.get("us"))
        return {**defaults, **{k: v for k, v in hit.items() if k in defaults}}
    if not measurable() or not candidates or measure is None:
        notify_autotune(op, "default", key=key)
        return dict(defaults)
    best_params, best_us = dict(defaults), float("inf")
    for params in candidates:
        try:
            us = _time_call(measure(params))
        except Exception:  # candidate doesn't fit (VMEM, divisibility): skip
            continue
        if us < best_us:
            best_params, best_us = dict(params), us
    choice = dict(best_params)
    if best_us < float("inf"):
        choice["us"] = round(best_us, 2)
    record(op, key, choice)
    save_table()
    notify_autotune(op, "measured", key=key,
                    best_us=None if best_us == float("inf") else best_us)
    return {**defaults, **best_params}


def grid(**axes: Sequence) -> List[Dict]:
    """Cartesian candidate grid: ``grid(block_m=(128, 256), ...)``."""
    names = list(axes)
    return [dict(zip(names, vals))
            for vals in itertools.product(*(axes[n] for n in names))]


# ---------------------------------------------------------------------------
# Registered tuning entry points (the CLI sweeps these on hardware)
# ---------------------------------------------------------------------------


def tune_fwht(shapes: Tuple[int, int] = (4096, 4096)) -> Dict:
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.fwht import default_block_m, fwht_pallas

    m, d = shapes
    x = jnp.asarray(np.random.default_rng(0).normal(size=(m, d)), jnp.float32)
    return best(
        "fwht", (m, d), x.dtype, {"block_m": default_block_m(d)},
        candidates=grid(block_m=(64, 128, 256, 512)),
        measure=lambda p: lambda: fwht_pallas(
            x, block_m=p["block_m"], interpret=not measurable()),
    )


def tune_dequant_matmul(shapes: Tuple[int, int, int] = (512, 4096, 4096),
                        bits: int = 4, group: int = 128) -> Dict:
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.dequant_matmul import dequant_matmul_pallas
    from repro.quant import pack, rtn
    from repro.quant.qtypes import QuantConfig

    m, c, h = shapes
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, c)), jnp.float32)
    qt = pack.pack(rtn.quantize_weight_grouped(
        jnp.asarray(rng.normal(size=(c, h)), jnp.float32),
        QuantConfig(bits=bits, group=group, symmetric=False)))
    return best(
        "dequant_matmul", (m, c, h), x.dtype,
        {"block_m": 256, "block_n": 256},
        candidates=grid(block_m=(128, 256, 512), block_n=(128, 256, 512)),
        measure=lambda p: lambda: dequant_matmul_pallas(
            x, qt, block_m=p["block_m"], block_n=p["block_n"],
            interpret=not measurable()),
    )


def tune_paged_attention(n_slots: int = 8, pages: int = 32,
                         page_tokens: int = 16, kv: int = 4, rep: int = 4,
                         hd: int = 64) -> Dict:
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.paged_attention import paged_attention_pallas

    rng = np.random.default_rng(0)
    nb = n_slots * pages + 1
    q = jnp.asarray(rng.normal(size=(n_slots, kv, rep, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(1, nb, page_tokens, kv, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(1, nb, page_tokens, kv, hd)), jnp.float32)
    knew = jnp.asarray(rng.normal(size=(n_slots, kv, hd)), jnp.float32)
    tables = jnp.asarray(
        1 + np.arange(n_slots * pages).reshape(n_slots, pages), jnp.int32)
    lengths = jnp.full((n_slots,), pages * page_tokens - 1, jnp.int32)

    def run(p):
        def thunk():
            out, _ = paged_attention_pallas(
                q, tables, lengths, 0, (kp,), (vp,), None, (knew,), (knew,),
                None, block_pages=p["block_pages"],
                interpret=not measurable())
            return out
        return thunk

    return best(
        "paged_attention", (n_slots, pages, page_tokens, kv, rep, hd),
        q.dtype, {"block_pages": 1},
        candidates=grid(block_pages=(1, 2, 4, 8)),
        measure=run,
    )


TUNERS = {
    "fwht": tune_fwht,
    "dequant_matmul": tune_dequant_matmul,
    "paged_attention": tune_paged_attention,
}


def main(argv: Optional[List[str]] = None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--op", choices=sorted(TUNERS), default=None,
                    help="tune one op (default: all)")
    args = ap.parse_args(argv)
    if not measurable():
        print(f"[autotune] backend {_backend()!r} is interpret-mode; "
              "defaults apply and nothing is measured. Run on TPU/GPU.")
    for name in ([args.op] if args.op else sorted(TUNERS)):
        choice = TUNERS[name]()
        print(f"[autotune] {name}: {choice}")
    if measurable():
        print(f"[autotune] table written to {save_table()}")


if __name__ == "__main__":
    main()
