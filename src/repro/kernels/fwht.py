"""Fast Walsh-Hadamard transform Pallas kernel.

Tiling: the transform mixes the full channel axis D, so each VMEM block is
``(block_m, D)`` - a row stripe.  All log2(D) butterfly stages run on the
block while it is resident in VMEM (one HBM read + one write per element,
the memory-roofline optimum for this op; a matmul-based Hadamard would
read D*D matrix bytes and burn D x more MXU flops).

VMEM budget: in/out blocks are f32, so ``2 * block_m * D * 4`` bytes must
fit in ~16 MiB; ``default_block_m`` picks the largest power of two that
keeps a <=8 MiB working set.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.hadamard import is_pow2


def default_block_m(d: int, bytes_budget: int = 4 * 1024 * 1024) -> int:
    bm = max(1, bytes_budget // (d * 4))
    # round down to a power of two, cap at 512 rows
    bm = 1 << (bm.bit_length() - 1)
    return int(min(bm, 512))


def _fwht_kernel(x_ref, o_ref, *, normalize: bool):
    x = x_ref[...].astype(jnp.float32)
    m, d = x.shape
    h = 1
    while h < d:  # static python loop: d is a compile-time block dim
        x = x.reshape(m, d // (2 * h), 2, h)
        a = x[:, :, 0, :]
        b = x[:, :, 1, :]
        x = jnp.concatenate([(a + b)[:, :, None, :], (a - b)[:, :, None, :]], axis=2)
        h *= 2
    x = x.reshape(m, d)
    if normalize:
        x = x * np.float32(1.0 / np.sqrt(d))
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("normalize", "block_m", "interpret"))
def fwht_pallas(
    x: jax.Array,
    *,
    normalize: bool = True,
    block_m: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """x: (M, D) -> Hadamard transform along D (natural order)."""
    m, d = x.shape
    if not is_pow2(d):
        raise ValueError(f"D must be a power of two, got {d}")
    if block_m is None:
        from repro.kernels import autotune

        # measured-on-hardware row-stripe height; VMEM-budget heuristic
        # default everywhere the table has no entry (trace-time lookup).
        block_m = autotune.best("fwht", (m, d), x.dtype,
                                {"block_m": default_block_m(d)})["block_m"]
    bm = min(block_m, m)
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    mp = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_fwht_kernel, normalize=normalize),
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, d), x.dtype),
        interpret=interpret,
    )(x)
    return out[:m] if pad else out
