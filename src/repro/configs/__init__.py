"""Exact published configs for the assigned architectures (one per file)."""
