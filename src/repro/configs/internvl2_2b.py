"""internvl2-2b [arXiv:2404.16821; hf] - InternViT + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT frontend
is a STUB: input_specs supplies precomputed patch embeddings (B, 256, D)
prepended to the text sequence; an identity patch_proj weight exists so
R1 rotation fuses into the vision path too.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="dense",
    modality="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92553,
    n_patches=256,
)
