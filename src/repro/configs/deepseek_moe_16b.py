"""deepseek-moe-16b [arXiv:2401.06066; hf] - fine-grained MoE.

28L d_model=2048 16H (MHA kv=16) per-expert d_ff=1408 vocab=102400,
64 routed experts top-6 + 2 shared experts.  (The HF model's first layer
is dense; we use the assigned uniform MoE stack - DESIGN.md §Fidelity.)
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    d_expert=1408,
)
