"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf] - small llama arch.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.  Also the
~100M-class model used by the end-to-end training example.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
)
