"""zamba2-1.2b [arXiv:2411.15242; hf] - Mamba2 backbone + shared attn.

38 Mamba2 layers, d_model=2048, ssm_state=64, 32 SSD heads (head dim
128 with expand=2); one shared attention block (32H, d_ff=8192) applied
every 6 layers.  Runs the long_500k cell (O(1) backbone state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_heads=32,
    ssm_expand=2,
    conv_width=4,
    attn_every=6,
)
