"""llama2-7b [arXiv:2307.09288] - the paper's evaluation model.

32L d_model=4096 32H (MHA) d_ff=11008 vocab=32000.  Used by the
Table 1/2 reproduction benchmarks (at reduced scale on CPU) and
available as a full dry-run config.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=32000,
)
