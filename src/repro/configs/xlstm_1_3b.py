"""xlstm-1.3b [arXiv:2405.04517; unverified] - sLSTM + mLSTM blocks.

48L d_model=2048 4H d_ff=0 (blocks carry their own projections)
vocab=50304; one sLSTM block per 8 layers (7 mLSTM + 1 sLSTM groups).
Runs the long_500k cell: decode state is O(1) in context.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    ssm_expand=1,
    slstm_every=8,
)
