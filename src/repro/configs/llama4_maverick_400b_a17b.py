"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128 routed
experts top-1 + 1 shared expert (sigmoid gate), MoE every other layer
(interleave step 2, as in the released Maverick; this also reconciles
the 400B-total / 17B-active numbers in the model name - DESIGN.md).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    n_experts=128,
    top_k=1,
    n_shared_experts=1,
    d_expert=8192,
    moe_every=2,
    rope_theta=500000.0,
)
