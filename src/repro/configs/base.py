"""Model configuration system.

One frozen dataclass covers every assigned architecture family; each
``src/repro/configs/<arch>.py`` instantiates it with the exact published
numbers.  ``reduced()`` produces a structure-preserving shrunken config for
CPU smoke tests (same family/block pattern, tiny widths).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

FAMILIES = ("dense", "moe", "mla", "ssm", "hybrid")
MODALITIES = ("text", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    modality: str = "text"
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0  # per-expert FFN width (fine-grained MoE)
    capacity_factor: float = 1.25
    moe_every: int = 1  # MoE layer every N layers (others dense), llama4=2
    # --- MLA (multi-head latent attention) ---
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # --- SSM / recurrent ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_expand: int = 2
    conv_width: int = 4
    slstm_every: int = 0  # xLSTM: one sLSTM block per this many layers
    attn_every: int = 0  # Zamba: shared attention block every N ssm layers
    # --- modality stubs ---
    n_patches: int = 0  # vlm: precomputed patch embeddings prepended
    n_codebooks: int = 0  # audio: EnCodec codebooks (summed embeddings)
    # --- attention behaviour ---
    sliding_window: int = 0  # 0 = full causal attention

    def __post_init__(self):
        assert self.family in FAMILIES, self.family
        assert self.modality in MODALITIES, self.modality

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_recurrent(self) -> bool:
        """True if decode state is O(1) in sequence length (SSM family)."""
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """long_500k cells run only for sub-quadratic archs (SSM/hybrid).

        A hybrid still carries attention KV, but its shared-block KV at
        seq 500k (batch 1) is small; pure full-attention archs skip the
        cell (DESIGN.md 'Arch-applicability')."""
        return self.family in ("ssm", "hybrid")

    # ------------------------------------------------------------------
    def param_count(self) -> Tuple[int, int]:
        """(total, active-per-token) parameter counts, embeddings included.

        Used for MODEL_FLOPS = 6 * N_active * D in the roofline analysis.
        """
        d, v = self.d_model, self.vocab
        embed = v * d * (self.n_codebooks or 1)
        head = 0 if self.tie_embeddings else v * d * (self.n_codebooks or 1)
        total = active = embed + head + d  # + final norm

        if self.family in ("dense", "moe"):
            hd = self.hd
            attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
            if self.qkv_bias:
                attn += (self.n_heads + 2 * self.n_kv_heads) * hd
            if self.family == "dense":
                mlp_total = mlp_active = 3 * d * self.d_ff
                n_moe_layers = 0
            else:
                de = self.d_expert or self.d_ff
                router = d * self.n_experts
                mlp_total = router + 3 * d * de * (self.n_experts + self.n_shared_experts)
                mlp_active = router + 3 * d * de * (self.top_k + self.n_shared_experts)
                n_moe_layers = self.n_layers // self.moe_every
            n_dense_layers = self.n_layers - n_moe_layers
            dense_mlp = 3 * d * self.d_ff if self.family == "moe" else mlp_total
            total += self.n_layers * (attn + 2 * d)
            active += self.n_layers * (attn + 2 * d)
            total += n_moe_layers * mlp_total + (
                n_dense_layers * dense_mlp if self.family == "moe" else n_dense_layers * mlp_total
            )
            active += n_moe_layers * mlp_active + (
                n_dense_layers * dense_mlp if self.family == "moe" else n_dense_layers * mlp_active
            )
        elif self.family == "mla":
            qk_head = self.qk_nope_dim + self.qk_rope_dim
            attn = (
                d * self.q_lora_rank
                + self.q_lora_rank * self.n_heads * qk_head
                + d * (self.kv_lora_rank + self.qk_rope_dim)
                + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.n_heads * self.v_head_dim * d
            )
            per_layer = attn + 3 * d * self.d_ff + 2 * d
            total += self.n_layers * per_layer
            active += self.n_layers * per_layer
        elif self.family == "ssm":  # xLSTM
            n_slstm = self.n_layers // self.slstm_every if self.slstm_every else 0
            n_mlstm = self.n_layers - n_slstm
            di = self.ssm_expand * d
            mlstm = 4 * d * di + di * d  # q,k,v,gates in_proj + out_proj
            hds = d // max(self.n_heads, 1)
            slstm = 4 * d * d + 4 * self.n_heads * hds * hds + d * d
            total += n_mlstm * mlstm + n_slstm * slstm + self.n_layers * d
            active = total
        elif self.family == "hybrid":  # Zamba2: Mamba2 + one shared attn blk
            di = self.ssm_expand * d
            nh = self.ssm_heads
            mamba = (
                d * (2 * di + 2 * self.ssm_state + nh)  # in_proj (x,z,B,C,dt)
                + self.conv_width * (di + 2 * self.ssm_state)
                + nh  # A_log
                + di  # D skip
                + di * d  # out_proj
                + d
            )
            hd = self.hd
            shared = (
                d * self.n_heads * hd
                + 2 * d * self.n_kv_heads * hd
                + self.n_heads * hd * d
                + 3 * d * self.d_ff
                + 2 * d
            )
            total += self.n_layers * mamba + shared
            active = total
        return int(total), int(active)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny structure-preserving config for CPU smoke tests."""

        def shrink_heads(h, kv):
            if h == 0:
                return 0, 0
            ratio = max(h // max(kv, 1), 1)
            h2 = min(h, 4)
            kv2 = max(h2 // ratio, 1)
            return h2, kv2

        h2, kv2 = shrink_heads(self.n_heads, self.n_kv_heads)
        d2 = 64
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, max(2, self.slstm_every or 0, self.attn_every or 0) * 2)
            if (self.slstm_every or self.attn_every)
            else min(self.n_layers, 2),
            d_model=d2,
            n_heads=h2,
            n_kv_heads=kv2,
            head_dim=d2 // h2 if h2 else 0,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
        )
        if self.family == "moe":
            # capacity_factor = E makes reduced routing dropless, so the
            # prefill/decode teacher-forcing equivalence tests are exact.
            kw.update(
                n_experts=min(self.n_experts, 8),
                top_k=min(self.top_k, 2),
                d_expert=32,
                capacity_factor=8.0,
            )
        if self.family == "mla":
            kw.update(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8, qk_rope_dim=8, v_head_dim=8)
        if self.family in ("ssm", "hybrid"):
            kw.update(ssm_state=16, ssm_heads=max(h2, 2), ssm_head_dim=0)
        if self.n_patches:
            kw.update(n_patches=8)
        return dataclasses.replace(self, **kw)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shape sets (assigned): every LM cell is seq_len x global_batch.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg: ModelConfig):
    """The (arch x shape) dry-run cells this arch participates in."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.supports_long_context:
        out.append("long_500k")
    return out
