"""musicgen-medium [arXiv:2306.05284; hf] - decoder over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048, K=4 codebooks
(delay pattern handled by the frontend STUB: inputs are 4 token ids per
step, embeddings summed, 4 output heads).  Cross-attention conditioning
is out of scope for the backbone spec (DESIGN.md §Fidelity).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    modality="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    n_codebooks=4,
)
