"""Checkpointing: atomic, resumable, retention-managed.

Pytrees are flattened to path-keyed arrays in one ``.npz`` per (step,
host-shard); a JSON manifest carries step/metadata and is written LAST via
atomic rename, so a checkpoint is visible only when complete - a crash
mid-write can never produce a corrupt "latest".  ``CheckpointManager``
adds retention (keep_last) and restart-resume; on a real cluster each host
writes its own process-local shard file (``shard`` arg) to its own path,
which is exactly the layout distributed restore needs.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


_NATIVE = {np.dtype(t) for t in
           ("float64", "float32", "float16", "int64", "int32", "int16", "int8",
            "uint8", "uint16", "uint32", "uint64", "bool")}


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype not in _NATIVE:  # bf16 etc: store as f32 (lossless up)
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save_checkpoint(directory: str, step: int, tree: Any, *, shard: int = 0,
                    n_shards: Optional[int] = None,
                    write_manifest: bool = True,
                    metadata: Optional[Dict] = None) -> str:
    """Write {directory}/step_{step}/shard_{shard}.npz atomically.

    Multi-shard writers (one shard per host, or ``QuantizedModel.save``'s
    single-process splitting) call this once per shard with
    ``write_manifest=False`` for all but the final call, so the manifest —
    and with it checkpoint visibility — still lands last; ``n_shards``
    records the total in the manifest for the reader.
    """
    stepdir = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(stepdir, exist_ok=True)
    flat = _flatten_with_paths(tree)
    tmp = tempfile.NamedTemporaryFile(dir=stepdir, suffix=".tmp", delete=False)
    try:
        np.savez(tmp, **flat)
        tmp.close()
        os.replace(tmp.name, os.path.join(stepdir, f"shard_{shard}.npz"))
    finally:
        if os.path.exists(tmp.name):
            os.unlink(tmp.name)
    if write_manifest:
        # manifest last -> checkpoint becomes visible atomically
        man = {"step": step, "time": time.time(),
               "shards": n_shards or shard + 1, **(metadata or {})}
        mtmp = os.path.join(stepdir, ".manifest.tmp")
        with open(mtmp, "w") as f:
            json.dump(man, f)
        os.replace(mtmp, os.path.join(stepdir, "manifest.json"))
    return stepdir


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    best = None
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            s = int(m.group(1))
            best = s if best is None else max(best, s)
    return best


def restore_checkpoint(directory: str, template: Any, *, step: Optional[int] = None,
                       shard: int = 0) -> Tuple[Any, int]:
    """Restore into the structure of ``template`` (dtypes preserved)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}", f"shard_{shard}.npz")
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"checkpoint shard missing: {path}  (the manifest exists, so "
            f"the step was saved — copy the full step directory, or pass "
            f"the right shard index)")
    try:
        data = np.load(path)
        files = set(data.files)
    except Exception as e:  # BadZipFile / EOFError / OSError
        raise ValueError(
            f"checkpoint shard unreadable: {path} ({e!r})  (the npz is "
            f"truncated or corrupt; restore from another step or re-save)"
        ) from e
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    out = []
    for p, leaf in leaves_paths:
        key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
        if key not in files:
            raise ValueError(
                f"checkpoint shard {path} has no entry {key!r}  (the "
                f"template's structure does not match what was saved — "
                f"wrong model config, or a multi-shard save read "
                f"single-shard)")
        arr = data[key]
        out.append(jnp.asarray(arr, dtype=leaf.dtype if hasattr(leaf, "dtype") else None))
    return jax.tree_util.tree_unflatten(treedef, out), step


class CheckpointManager:
    """Retention + resume wrapper used by the Trainer."""

    def __init__(self, directory: str, keep_last: int = 3):
        self.directory = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)

    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None) -> str:
        path = save_checkpoint(self.directory, step, tree, metadata=metadata)
        self._gc()
        return path

    def restore_latest(self, template: Any) -> Optional[Tuple[Any, int]]:
        step = latest_step(self.directory)
        if step is None:
            return None
        return restore_checkpoint(self.directory, template, step=step)

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for m in (re.fullmatch(r"step_(\d+)", n) for n in os.listdir(self.directory))
            if m
        )
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)
