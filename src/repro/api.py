"""The front door for quantized models: quantize -> save/load -> serve.

The paper's pitch is "quantization for free"; this module makes it one
call each way:

    from repro import api

    arch = get_arch("smollm-135m", reduced=True)
    qm = api.quantize(arch, params, api.PTQConfig(r1_kind="GSR", wakv="W4A8"))
    qm.save("artifacts/smollm-w4a8")            # packed ints + manifest
    ...
    qm = api.load_quantized("artifacts/smollm-w4a8")   # no re-quantization
    engine = qm.serve(api.ServeConfig(), backend="pallas")
    engine.generate(prompts, max_new_tokens=32)

``quantize`` also takes a declarative per-site
:class:`~repro.quant.policy.QuantPolicy` (or a preset name such as
``"w2-sensitive-fp4"``): ordered ``site glob x layer range`` rules give
every matmul site its own (bits, group, method, online rotation) and the
rotation plan its R1 source (constructed / SpinQuant-learned / loaded,
optionally composed with a GSR post-rotation).  The flat ``PTQConfig``
lowers to a single-rule policy, and the resolved policy is serialized
into the artifact manifest, so mixed-precision models round-trip
bit-exactly.

A :class:`QuantizedModel` is a first-class pytree artifact: *packed*
integer weights (``quant.packed.PackedWeight`` leaves: uint8 codes +
grouped scale/zero) for every quantized matrix of all five model
families, float leaves for everything else, plus the fused rotation
metadata (R1 kind/seed/group, R4 spec) and the full model config - so a
saved directory is self-describing and re-servable anywhere.

Persistence rides :mod:`repro.checkpoint.ckpt` (atomic manifest-last
writes); execution rides the pluggable weight backend of
:class:`repro.serve.engine.ServeEngine` (``"reference"`` dequant-on-use
vs ``"pallas"`` fused dequant-matmul), selectable per launch.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ModelConfig
from repro.models.common import QuantizeSpec
from repro.quant import packed as packedmod
from repro.quant.packed import PackedWeight
from repro.quant.pipeline import PTQConfig, normalize_policy, quantize_packed
from repro.quant.policy import (
    PRESETS, QuantPolicy, RotationPlan, RotationSpec, SiteRule, get_policy,
)
from repro.obs import ObsConfig
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.faults import FaultPlan

__all__ = [
    "FaultPlan", "ObsConfig", "PRESETS", "PTQConfig", "QuantPolicy",
    "QuantizeSpec", "QuantizedModel", "RotationPlan", "RotationSpec",
    "ServeConfig", "SiteRule", "derive_draft", "get_policy",
    "load_quantized", "quantize",
]

# 2: manifest carries the resolved QuantPolicy
# 3: + the resolved per-site activation table ("act_sites": the
#    pattern -> (bits, group, clip) entries QuantizeSpec.act_for serves);
#    format-2 artifacts (no act rules by construction) still load.
_FORMAT_VERSION = 3


def _artifact_err(path: str, msg: str, *, hint: str = "") -> ValueError:
    """Actionable artifact errors: always name the offending path and say
    what to do about it (mirrors ``quant.policy._err``)."""
    return ValueError(f"quantized-model artifact {path}: {msg}"
                      + (f"  ({hint})" if hint else ""))


# ---------------------------------------------------------------------------
# Artifact container
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantizedModel:
    """Packed quantized model + everything needed to re-serve it.

    ``policy`` is the canonical provenance (the resolved
    :class:`~repro.quant.policy.QuantPolicy` every quantization now runs
    through); ``ptq`` is kept when the model was quantized via the flat
    :class:`PTQConfig` front door, so old call sites and old artifacts
    keep their exact shape.
    """

    arch: Any  # repro.models.registry.Arch
    params: Dict  # pytree: PackedWeight leaves for quantized weights
    ptq: Optional[PTQConfig]
    spec: QuantizeSpec
    policy: Optional[QuantPolicy] = None

    def __post_init__(self):
        if self.policy is None and self.ptq is not None:
            self.policy = self.ptq.to_policy()

    # -- views -----------------------------------------------------------
    @property
    def config(self) -> ModelConfig:
        return self.arch.config

    @property
    def rotation(self) -> Dict:
        """Fused-rotation provenance (R1 is already folded into weights;
        R4/R3 remain online via ``spec``)."""
        r1 = self.policy.rotation.r1
        kind = {"construct": r1.kind, "identity": "I", "learn": r1.kind,
                "load": "loaded"}[r1.source]
        if r1.compose:
            kind = f"{kind}+{r1.compose}"
        return {
            "r1_kind": kind, "r1_seed": r1.seed, "r1_group": r1.group,
            "r1_source": r1.source, "r4_kind": self.spec.r4_kind,
            "r4_group": self.spec.r4_group, "r4_seed": self.spec.r4_seed,
            "learned": (r1.learn if r1.source == "learn" else "none"),
        }

    def dequantize(self, dtype: Any = None) -> Dict:
        """Back to the fake-quant float param tree (bit-identical to what
        the legacy ``quantize_model`` pipeline returned)."""
        return packedmod.dequantize_tree(self.params, dtype)

    def packed_bytes(self) -> int:
        return packedmod.packed_bytes(self.params)

    # -- serving ---------------------------------------------------------
    def serve(self, scfg: Optional[ServeConfig] = None, *, mesh=None,
              backend: str = "reference", dtype=jnp.float32,
              draft: Optional["QuantizedModel"] = None) -> ServeEngine:
        """Build a ServeEngine executing the packed weights through the
        chosen backend ("reference" dequant-on-use | "pallas" fused
        dequant-matmul).  ``ServeConfig(prefix_cache=True)`` shares cached
        prompt-prefix KV blocks across requests (system-prompt traffic)
        with bit-identical output — see ``repro.serve.prefixcache``.

        ``draft`` (with ``ServeConfig(spec_decode=True)``) plugs in a
        self-draft derived from this same artifact via
        :func:`derive_draft`: the scheduler drafts ``draft_k`` tokens per
        slot with the draft weights over the *same* block-paged pool and
        verifies them in one chunked call — greedy output stays
        token-identical to non-spec decode."""
        draft_params = None
        if draft is not None:
            if draft.config != self.config:
                raise ValueError(
                    "draft model config differs from the target's "
                    f"({draft.config.name!r} vs {self.config.name!r}); "
                    "derive the draft from this artifact with "
                    "api.derive_draft")
            if draft.spec != self.spec:
                raise ValueError(
                    "draft serving spec differs from the target's — the "
                    "shared KV pool needs one cache codec; derive the "
                    "draft with api.derive_draft (weight-only overlay)")
            draft_params = draft.params
        return ServeEngine(self.arch, self.params, scfg or ServeConfig(),
                           self.spec, dtype=dtype, mesh=mesh,
                           backend=backend, draft_params=draft_params)

    # -- persistence -----------------------------------------------------
    def save(self, directory: str, *, shards: int = 1) -> str:
        """Write the artifact: packed arrays in ``shards`` npz files + a
        JSON manifest carrying config / PTQ / per-leaf quantization
        metadata.  Uses the checkpoint layer's atomic manifest-last
        protocol (the manifest is written only after the *last* shard), so
        a partially written artifact is never visible.

        ``shards > 1`` is the multi-host layout: leaves are split into
        byte-balanced groups, one ``shard_<i>.npz`` each — on a cluster
        each host writes its own shard via the checkpoint layer's
        ``shard`` argument; here all shards are written by this process so
        a single-host artifact and a cluster artifact restore identically.
        """
        packed_meta: Dict[str, Dict] = {}
        dtypes: Dict[str, str] = {}

        def plain(tree, prefix=""):
            if packedmod.is_packed(tree):
                packed_meta[prefix] = {
                    "bits": tree.bits, "group": tree.group, "c": tree.c,
                    "dtype": tree.dtype, "packed": tree.packed,
                }
                return {"codes": tree.codes, "scale": tree.scale,
                        "zero": tree.zero}
            if isinstance(tree, dict):
                return {k: plain(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in tree.items()}
            dtypes[prefix] = str(jnp.asarray(tree).dtype)
            return tree

        meta = {
            "kind": "quantized-model",
            "format": _FORMAT_VERSION,
            "config": dataclasses.asdict(self.config),
            "policy": self.policy.to_json_dict(),
            # resolved activation table (provenance; the policy above is
            # canonical and re-derives it on load)
            "act_sites": [list(entry) for entry in self.spec.act_sites],
            "packed": packed_meta,
            "dtypes": dtypes,
        }
        if self.ptq is not None:
            meta["ptq"] = dataclasses.asdict(self.ptq)
        tree = plain(self.params)
        if shards <= 1:
            return ckpt.save_checkpoint(directory, 0, tree, metadata=meta)
        parts = _partition_leaves(tree, shards)
        out = None
        for i, part in enumerate(parts):
            out = ckpt.save_checkpoint(
                directory, 0, part, shard=i, n_shards=len(parts),
                write_manifest=(i == len(parts) - 1), metadata=meta)
        return out

    @classmethod
    def load(cls, directory: str, *, backend: str = "reference"
             ) -> "QuantizedModel":
        """Reconstruct a saved artifact; no re-quantization, packed ints
        are loaded bit-exact."""
        from repro.models.registry import build_arch

        step = ckpt.latest_step(directory)
        if step is None:
            # shard files without a manifest mean the atomic manifest-last
            # save never completed — say so instead of "not found"
            orphans = []
            if os.path.isdir(directory):
                orphans = [n for n in sorted(os.listdir(directory))
                           if n.startswith("step_")]
            if orphans:
                raise _artifact_err(
                    directory,
                    f"step dir(s) {orphans} present but no manifest.json",
                    hint="the save was interrupted before the manifest-last "
                         "write; delete the partial step dir and re-save")
            raise FileNotFoundError(f"no quantized-model artifact in {directory}")
        stepdir = os.path.join(directory, f"step_{step:08d}")
        man_path = os.path.join(stepdir, "manifest.json")
        try:
            with open(man_path) as f:
                man = json.load(f)
        except json.JSONDecodeError as e:
            raise _artifact_err(
                man_path, f"manifest is not valid JSON ({e})",
                hint="the file was modified after the save; re-save the "
                     "artifact") from e
        if man.get("kind") != "quantized-model":
            raise _artifact_err(
                directory,
                f"manifest kind is {man.get('kind')!r}, expected "
                f"'quantized-model'",
                hint="this directory holds a different checkpoint type "
                     "(e.g. a trainer checkpoint); point load_quantized at "
                     "a QuantizedModel.save output")
        fmt = int(man.get("format", 1))
        if fmt > _FORMAT_VERSION:
            raise _artifact_err(
                directory,
                f"manifest format {fmt} is newer than this build's "
                f"{_FORMAT_VERSION}",
                hint="the artifact was written by a newer version; upgrade, "
                     "or re-save the model with this one")
        for key in ("config", "packed"):
            if key not in man:
                raise _artifact_err(
                    man_path, f"manifest is missing the {key!r} entry",
                    hint="the manifest was truncated or hand-edited; "
                         "re-save the artifact")

        tree: Dict = {}
        for shard in range(int(man.get("shards", 1))):
            shard_path = os.path.join(stepdir, f"shard_{shard}.npz")
            if not os.path.exists(shard_path):
                raise _artifact_err(
                    shard_path,
                    f"missing shard {shard} of {int(man.get('shards', 1))}",
                    hint="the manifest records more shards than are on "
                         "disk; copy the full artifact directory")
            try:
                data = np.load(shard_path)
                arrays = {key: data[key] for key in data.files}
            except Exception as e:  # BadZipFile / EOFError / OSError
                raise _artifact_err(
                    shard_path, f"unreadable shard npz ({e!r})",
                    hint="the shard is truncated or corrupt; re-copy or "
                         "re-save the artifact") from e
            for key in arrays:
                node = tree
                *parents, leaf = key.split("/")
                for p in parents:
                    node = node.setdefault(p, {})
                node[leaf] = arrays[key]

        dtypes = man.get("dtypes", {})

        def rebuild(node, prefix=""):
            meta = man["packed"].get(prefix)
            if meta is not None:
                return PackedWeight(
                    codes=jnp.asarray(node["codes"]),
                    scale=jnp.asarray(node["scale"], jnp.float32),
                    zero=jnp.asarray(node["zero"], jnp.float32),
                    bits=int(meta["bits"]), group=int(meta["group"]),
                    c=int(meta["c"]), dtype=meta["dtype"],
                    packed=bool(meta["packed"]), backend=backend,
                )
            if isinstance(node, dict):
                return {k: rebuild(v, f"{prefix}/{k}" if prefix else k)
                        for k, v in node.items()}
            return jnp.asarray(node, dtype=dtypes.get(prefix) or None)

        params = rebuild(tree)
        cfg = ModelConfig(**man["config"])
        ptq = PTQConfig(**man["ptq"]) if "ptq" in man else None
        if "policy" in man:  # format >= 2: the policy is canonical
            policy = QuantPolicy.from_json_dict(man["policy"])
        else:  # format-1 artifact: reconstruct from the flat config
            policy = ptq.to_policy()
        return cls(arch=build_arch(cfg), params=params, ptq=ptq,
                   spec=policy.spec(), policy=policy)


def _partition_leaves(tree: Dict, shards: int) -> list:
    """Split a nested array tree into ``shards`` flat {path: array} dicts,
    greedily byte-balanced (largest leaves first, deterministic
    tie-breaking by path) — the per-host shard layout."""
    flat: Dict[str, Any] = {}

    def walk(node, prefix=""):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{prefix}/{k}" if prefix else k)
        else:
            flat[prefix] = node

    walk(tree)
    order = sorted(flat, key=lambda k: (-np.asarray(flat[k]).nbytes, k))
    parts = [{} for _ in range(max(1, shards))]
    loads = [0] * len(parts)
    for key in order:
        i = loads.index(min(loads))
        parts[i][key] = flat[key]
        loads[i] += np.asarray(flat[key]).nbytes
    return parts


# ---------------------------------------------------------------------------
# Front-door entry points
# ---------------------------------------------------------------------------


def quantize(arch, params: Dict, ptq,
             calib_batches: Optional[Iterator] = None) -> QuantizedModel:
    """Rotate + quantize ``params`` into a packed :class:`QuantizedModel`.

    ``ptq`` is a flat :class:`PTQConfig`, a declarative
    :class:`QuantPolicy`, or a policy name/JSON accepted by
    :func:`repro.quant.policy.get_policy` (e.g. ``"w2-sensitive-fp4"``).
    The single entry covering all five families: R1/R2 fusion from the
    rotation plan, per-site GPTQ (dense) or RTN weights at per-site
    bits/groups, grouped packing - kept as packed integers.
    """
    policy = normalize_policy(ptq)
    qparams, spec = quantize_packed(arch, params, policy, calib_batches)
    return QuantizedModel(arch=arch, params=qparams,
                          ptq=ptq if isinstance(ptq, PTQConfig) else None,
                          spec=spec, policy=policy)


def load_quantized(directory: str, *, backend: str = "reference"
                   ) -> QuantizedModel:
    """Load a saved artifact (see :meth:`QuantizedModel.save`)."""
    return QuantizedModel.load(directory, backend=backend)


def derive_draft(qm: QuantizedModel,
                 draft_policy="draft-w2-rtn") -> QuantizedModel:
    """Derive a cheap self-draft from an already-packed artifact.

    Re-quantizes every packed leaf of ``qm`` under ``draft_policy`` (a
    :class:`QuantPolicy`, or a preset name such as ``"draft-w2-rtn"``) —
    calibration-free RTN over the *already rotated* weights, so the draft
    shares the target's rotations, activation rules, KV cache codec and
    block tables.  Float leaves (norms, embeddings) are shared by
    reference; no second checkpoint exists.  The returned model carries a
    combined policy whose ``spec()`` equals the target's, so it saves and
    reloads as a normal artifact.

    The overlay must be layer-uniform, weight-only, and strictly cheaper
    than the target — validated up front with actionable errors (see
    :func:`repro.serve.specdecode.validate_draft_policy`).

    Use with ``qm.serve(ServeConfig(spec_decode=True, draft_k=k),
    draft=derived)`` for draft-k/verify-1 speculative decoding whose
    greedy output is token-identical to non-spec decode.
    """
    from repro.serve import specdecode

    if isinstance(draft_policy, str):
        draft_policy = get_policy(draft_policy)
    specdecode.validate_draft_policy(draft_policy)
    draft_params = specdecode.derive_draft_params(qm.params, draft_policy)
    policy = specdecode.combined_policy(qm.policy, draft_policy)
    return QuantizedModel(arch=qm.arch, params=draft_params, ptq=None,
                          spec=policy.spec(), policy=policy)
