"""Training step: loss, microbatched grad accumulation, NaN-safe update.

The returned step function is pure (params, opt_state, err_state, batch) ->
(params, opt_state, err_state, metrics) and jit/pjit-compatible; the
launcher binds shardings.  Fault-tolerance hooks live here:

  * non-finite gradient norms skip the update (the step still returns, so
    a poisoned batch or a flaky host cannot corrupt the weights);
  * optional int8 error-feedback gradient compression before the DP
    all-reduce (``repro.train.grad_compress``).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import NOQUANT, QuantizeSpec, cross_entropy
from repro.train import grad_compress
from repro.train.optimizer import OptConfig, OptState, adamw_update, global_norm


def chunked_lm_loss(h: jax.Array, lm_head: jax.Array, labels: jax.Array,
                    *, chunk: int = 1024) -> jax.Array:
    """Mean token NLL without materialising full f32 logits.

    h: (B, S, D) final hidden; lm_head (D, V) or (K, D, V) (audio, with
    labels (B, S, K)).  Sequence chunks are processed under
    ``jax.checkpoint``: forward keeps one chunk of logits live; backward
    recomputes per chunk and accumulates the lm_head gradient through the
    scan - the memory saving that lets 150k-vocab 4k-seq training fit.
    """
    b, s, d = h.shape
    c = min(chunk, s)
    nc = -(-s // c)
    pad = nc * c - s
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)) + ((0, 0),) * (labels.ndim - 2))
    valid = (jnp.arange(nc * c) < s).astype(jnp.float32)  # (S',)
    hs = h.reshape(b, nc, c, d).swapaxes(0, 1)  # (nc, B, c, D)
    ls = labels.reshape(b, nc, c, *labels.shape[2:]).swapaxes(0, 1)
    ms = valid.reshape(nc, c)
    audio = lm_head.ndim == 3

    @jax.checkpoint
    def body(carry, xs):
        hc, lc, mc = xs
        hc = hc.astype(jnp.float32)
        if audio:
            logits = jnp.einsum("bcd,kdv->bckv", hc, lm_head.astype(jnp.float32))
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            logz = jax.nn.logsumexp(logits, axis=-1)
            nll = (logz - gold).mean(-1)  # mean over codebooks
        else:
            logits = hc @ lm_head.astype(jnp.float32)
            gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
            logz = jax.nn.logsumexp(logits, axis=-1)
            nll = logz - gold
        w = mc[None, :]
        tot, cnt = carry
        return (tot + (nll * w).sum(), cnt + mc.sum() * b), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def make_loss_fn(arch, spec: QuantizeSpec = NOQUANT, *, remat: bool = True,
                 chunked: bool = True) -> Callable:
    cfg = arch.config

    def loss_fn(params, batch):
        toks = batch["tokens"]
        if chunked:
            h = arch.forward(params, batch, spec, remat=remat, return_hidden=True)
            if cfg.modality == "vlm":
                h = h[:, cfg.n_patches :]
            return chunked_lm_loss(h[:, :-1], params["lm_head"], toks[:, 1:])
        logits = arch.forward(params, batch, spec, remat=remat)
        if cfg.modality == "vlm":
            logits = logits[:, cfg.n_patches :]
        return cross_entropy(logits[:, :-1], toks[:, 1:])

    return loss_fn


def make_train_step(
    arch,
    opt_cfg: OptConfig,
    spec: QuantizeSpec = NOQUANT,
    *,
    microbatches: int = 1,
    compress_grads: bool = False,
    remat: bool = True,
) -> Callable:
    loss_fn = make_loss_fn(arch, spec, remat=remat)

    def train_step(params, opt_state: OptState, err_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # Grad accumulation via scan over a reshaped leading microbatch
            # axis: scan's static slicing keeps the batch-axis sharding
            # intact (a dynamic_slice on a sharded axis would force an
            # all-gather and replicated compute).
            def mb(carry, sub):
                acc_loss, acc_g = carry
                l, g = jax.value_and_grad(loss_fn)(params, sub)
                return (acc_loss + l, jax.tree.map(jnp.add, acc_g, g)), None

            # strided split: (B,) -> (B/mb, mb) -> (mb, B/mb) keeps the
            # sharded batch axis inner, so every microbatch slice is fully
            # local to its data shard (no cross-device resharding).
            sub_batches = jax.tree.map(
                lambda x: x.reshape(x.shape[0] // microbatches, microbatches,
                                    *x.shape[1:]).swapaxes(0, 1),
                batch,
            )
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(mb, (0.0, zero), sub_batches)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        if compress_grads:
            grads, err_state = grad_compress.compress_for_allreduce(grads, err_state)

        new_params, new_opt, metrics = adamw_update(params, grads, opt_state, opt_cfg)
        # NaN/Inf guard: skip the update, keep the old state
        ok = jnp.isfinite(metrics["grad_norm"]) & jnp.isfinite(loss)
        pick = lambda new, old: jax.tree.map(
            lambda a, b: jnp.where(ok, a, b), new, old
        )
        params = pick(new_params, params)
        opt_state = OptState(
            step=jnp.where(ok, new_opt.step, opt_state.step),
            mu=pick(new_opt.mu, opt_state.mu),
            nu=pick(new_opt.nu, opt_state.nu),
        )
        metrics = dict(metrics, loss=loss, skipped=(~ok).astype(jnp.int32))
        return params, opt_state, err_state, metrics

    return train_step


def make_eval_step(arch, spec: QuantizeSpec = NOQUANT) -> Callable:
    """Returns mean token NLL (PPL = exp) and top-1 next-token accuracy."""
    cfg = arch.config

    def eval_step(params, batch):
        logits = arch.forward(params, batch, spec, remat=False)
        toks = batch["tokens"]
        if cfg.modality == "vlm":
            logits = logits[:, cfg.n_patches :]
        nll = cross_entropy(logits[:, :-1], toks[:, 1:])
        pred = jnp.argmax(logits[:, :-1], axis=-1)
        acc = jnp.mean((pred == toks[:, 1:]).astype(jnp.float32))
        return {"nll": nll, "top1": acc}

    return eval_step
