"""AdamW + schedules, hand-rolled (no optax in the container).

Supports the large-model memory mode used by the llama4 dry-run: moments
kept in bf16 (``moment_dtype``) - the classic 1000-node-scale trick that
halves optimizer HBM at negligible quality cost when paired with f32
master arithmetic at update time.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves optimizer memory


class OptState(NamedTuple):
    step: jax.Array
    mu: Dict
    nu: Dict


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params: Dict, cfg: OptConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    z = lambda p: jnp.zeros(p.shape, dt)
    return OptState(step=jnp.zeros((), jnp.int32), mu=jax.tree.map(z, params),
                    nu=jax.tree.map(z, params))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    params: Dict, grads: Dict, state: OptState, cfg: OptConfig
) -> Tuple[Dict, OptState, Dict]:
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) if cfg.grad_clip else 1.0
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # no decay on norms/biases
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
