"""Fault-tolerant training driver.

Production behaviours implemented (and exercised by tests/examples):

  * **checkpoint/restart**: periodic + final checkpoints through
    ``CheckpointManager``; construction auto-resumes from the latest
    complete checkpoint, so a killed process restarts where it left off.
  * **poisoned-step protection**: the jitted step skips non-finite
    updates (see train_step); the driver counts skips and aborts if a
    configurable streak is exceeded (a persistent NaN source is a bug,
    not noise).
  * **preemption hooks**: ``request_stop()`` (wired to SIGTERM by the
    launcher) finishes the current step, checkpoints, and exits clean -
    the behaviour TPU preemption notices require.
  * **failure injection**: ``fail_at_step`` simulates a hard crash for
    the restart tests.

Straggler mitigation and elastic re-mesh are properties of the launch
layer (synchronous SPMD makes per-step stragglers a collective-latency
matter): see repro.dist.elastic for the re-mesh/reshard path and
DESIGN.md §Fault tolerance for the deployment story.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.models.common import NOQUANT, QuantizeSpec
from repro.train import grad_compress
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_interval: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_interval: int = 10
    max_skip_streak: int = 10
    microbatches: int = 1
    compress_grads: bool = False
    fail_at_step: Optional[int] = None  # failure injection (tests)
    seed: int = 0


class Trainer:
    def __init__(self, arch, opt_cfg: OptConfig, tcfg: TrainerConfig,
                 spec: QuantizeSpec = NOQUANT, dtype=jnp.float32,
                 step_fn: Optional[Callable] = None, mesh=None):
        self.arch = arch
        self.tcfg = tcfg
        self.opt_cfg = opt_cfg
        self.mgr = CheckpointManager(tcfg.ckpt_dir, keep_last=tcfg.keep_last)
        self._stop = False
        self.metrics_log = []
        self.mesh = mesh
        self._batch_shardings = None

        params = arch.init(jax.random.PRNGKey(tcfg.seed), dtype)
        opt_state = init_opt_state(params, opt_cfg)
        err_state = (
            grad_compress.init_error_state(params) if tcfg.compress_grads else {}
        )
        self.state = {"params": params, "opt": opt_state, "err": err_state}
        self.step = 0
        restored = self.mgr.restore_latest(self.state)
        if restored is not None:
            self.state, self.step = restored
            print(f"[trainer] resumed from step {self.step}")
        if mesh is not None:
            self._shard_state(mesh)

        self._train_step = step_fn or jax.jit(
            make_train_step(
                arch, opt_cfg, spec,
                microbatches=tcfg.microbatches,
                compress_grads=tcfg.compress_grads,
            )
        )

    # ------------------------------------------------------------------
    def _shard_state(self, mesh):
        """Place params/opt/err with the dist.sharding rules.

        Moments and error-feedback state mirror the parameter tree, so
        they reuse the parameter specs leaf-for-leaf — the co-sharding
        that keeps the AdamW update collective-free.  Restored checkpoint
        state goes through the same path (the elastic re-mesh story: plan
        with ``dist.elastic.plan_remesh``, rebuild the mesh, re-enter
        here).
        """
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.dist.elastic import reshard
        from repro.dist.sharding import param_pspecs, sanitize_pspecs
        from repro.launch.mesh import dp_axes_of

        params = self.state["params"]
        params_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params
        )
        pspec = sanitize_pspecs(mesh, param_pspecs(self.arch.config, params_sds),
                                params_sds)
        from repro.train.optimizer import OptState

        ospec = OptState(step=P(), mu=pspec, nu=pspec)
        espec = pspec if self.tcfg.compress_grads else {}
        spec_tree = {"params": pspec, "opt": ospec, "err": espec}
        self.state = reshard(mesh, spec_tree, self.state)
        dp = dp_axes_of(mesh)

        def batch_sharding(x):
            spec = P(dp, *([None] * (x.ndim - 1))) if x.ndim else P()
            return NamedSharding(mesh, sanitize_pspecs(mesh, spec, x))

        self._batch_shardings = lambda batch: jax.tree.map(batch_sharding, batch)

    def _mesh_ctx(self):
        import contextlib

        return self.mesh if self.mesh is not None else contextlib.nullcontext()

    # ------------------------------------------------------------------
    def request_stop(self, *_args):
        """Preemption hook: finish the step, checkpoint, exit clean."""
        self._stop = True

    def run(self, batches: Iterator[Dict]) -> Dict:
        tcfg = self.tcfg
        skip_streak = 0
        t0 = time.time()
        while self.step < tcfg.total_steps and not self._stop:
            if tcfg.fail_at_step is not None and self.step == tcfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {self.step}")
            batch = next(batches)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            if self._batch_shardings is not None:
                batch = jax.device_put(batch, self._batch_shardings(batch))
            with self._mesh_ctx():
                p, o, e, m = self._train_step(
                    self.state["params"], self.state["opt"], self.state["err"], batch
                )
            self.state = {"params": p, "opt": o, "err": e}
            self.step += 1
            skipped = int(m["skipped"])
            skip_streak = skip_streak + 1 if skipped else 0
            if skip_streak > tcfg.max_skip_streak:
                raise RuntimeError(
                    f"{skip_streak} consecutive non-finite steps - aborting"
                )
            if self.step % tcfg.log_interval == 0 or self.step == tcfg.total_steps:
                rec = {
                    "step": self.step,
                    "loss": float(m["loss"]),
                    "grad_norm": float(m["grad_norm"]),
                    "lr": float(m["lr"]),
                    "sec": time.time() - t0,
                }
                self.metrics_log.append(rec)
                print(f"[trainer] {rec}")
            if self.step % tcfg.ckpt_interval == 0:
                self.mgr.save(self.step, self.state, metadata={"loss": float(m["loss"])})
        self.mgr.save(self.step, self.state)
        return {"step": self.step, "log": self.metrics_log, "state": self.state}
