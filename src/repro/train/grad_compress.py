"""Gradient compression with error feedback (distributed-optimization trick).

int8 per-tensor-group quantization of gradients before the data-parallel
all-reduce, with local error-feedback accumulators so the quantization
error is re-injected next step (EF-SGD); convergence is unaffected while
the DP all-reduce volume drops 4x vs f32 / 2x vs bf16.

Two execution modes:
  * ``compress_for_allreduce`` - pjit-friendly simulation: gradients are
    quantize-dequantized *before* the (XLA-inserted) all-reduce, so the
    reduction semantics and convergence behaviour match the explicit path
    while remaining fully auto-sharded.
  * ``shard_map`` explicit path (``int8_psum``) - the deployment schedule:
    codes are summed in int32 across the data axis (exact for <= 2^23
    participants) and rescaled; used by the optimized §Perf variant.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


GROUP = 1024  # quantization group along the flattened gradient


def _quant_ef(g: jax.Array, err: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (codes int8, scale per group, new_error)."""
    gf = g.astype(jnp.float32) + err
    flat = gf.reshape(-1)
    pad = (-flat.size) % GROUP
    flat = jnp.pad(flat, (0, pad))
    grp = flat.reshape(-1, GROUP)
    amax = jnp.max(jnp.abs(grp), axis=1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(grp / scale), -127, 127)
    dq = (codes * scale).reshape(-1)[: gf.size].reshape(g.shape)
    new_err = gf - dq
    return codes.astype(jnp.int8), scale[:, 0], new_err


def _dequant(codes: jax.Array, scale: jax.Array, shape) -> jax.Array:
    dq = codes.astype(jnp.float32) * scale[:, None]
    size = 1
    for s in shape:
        size *= s
    return dq.reshape(-1)[:size].reshape(shape)


def init_error_state(grads: Dict) -> Dict:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compress_for_allreduce(grads: Dict, err_state: Dict) -> Tuple[Dict, Dict]:
    """Quantize-dequantize each gradient leaf with error feedback.

    Under pjit the subsequent (automatic) all-reduce then carries values
    with int8 information content; the explicit int8 collective lives in
    :func:`int8_psum`.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        codes, scale, new_e = _quant_ef(g, e)
        out_g.append(_dequant(codes, scale, g.shape).astype(g.dtype))
        out_e.append(new_e)
    return treedef.unflatten(out_g), treedef.unflatten(out_e)


def int8_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Explicit compressed all-reduce for use inside shard_map.

    All shards agree on a per-group scale (pmax of local maxima) so the
    int32 code sum is an exact reduction of the quantized values; error
    feedback captures each shard's local quantization residual.
    """
    gf = g.astype(jnp.float32) + err
    flat = gf.reshape(-1)
    pad = (-flat.size) % GROUP
    flat = jnp.pad(flat, (0, pad))
    grp = flat.reshape(-1, GROUP)
    amax = jnp.max(jnp.abs(grp), axis=1, keepdims=True)
    amax = jax.lax.pmax(amax, axis_name)  # shared scale across the axis
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(grp / scale), -127, 127)
    local_dq = (codes * scale).reshape(-1)[: gf.size].reshape(g.shape)
    new_err = gf - local_dq
    total = jax.lax.psum(codes.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = (total.astype(jnp.float32) * scale / n).reshape(-1)[: gf.size].reshape(g.shape)
    return mean.astype(g.dtype), new_err
