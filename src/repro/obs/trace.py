"""Structured tracing: nestable spans over a pluggable monotonic clock.

The tracer records the per-request serving lifecycle —

    request ⊃ queue (submit → admit)
            ⊃ prefill (admit → first logits)
            ⊃ decode (first token → finish, with per-token instants)

— plus engine-side spans (``decode_tick`` / ``decode_window`` /
``spec_window``) into a bounded ring buffer.  Completed records export as
JSON-lines (one record per line, for grep/jq) or as a Chrome-trace file
(``chrome://tracing`` / Perfetto ``traceEvents`` schema, "X" complete
events with microsecond timestamps).

``validate_chrome_trace`` is the schema contract used by tests and the CI
cell: every request tid must carry exactly one complete
``request`` root span, properly nested ``queue``/``prefill``/``decode``
children, and monotonic phase timestamps.  ``python -m repro.obs.trace
FILE`` runs the validator from the command line.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

__all__ = [
    "ENGINE_PID",
    "REQUEST_PID",
    "Span",
    "Tracer",
    "validate_chrome_trace",
]

# Chrome-trace "process" ids: one lane for engine-wide spans (ticks,
# windows, jit compiles), one where each request gets its own tid row.
ENGINE_PID = 1
REQUEST_PID = 2


@dataclasses.dataclass
class Span:
    """One span-in-flight; becomes a ring record when ended."""

    name: str
    pid: int
    tid: int
    t0: float
    cat: str = "serve"
    args: Dict[str, object] = dataclasses.field(default_factory=dict)
    t1: Optional[float] = None

    @property
    def duration(self) -> Optional[float]:
        return None if self.t1 is None else self.t1 - self.t0


class Tracer:
    """Bounded ring of completed spans and instant events.

    All timestamps come from the injected ``clock`` (monotonic seconds);
    tests drive a fake clock for deterministic traces.  ``begin``/``end``
    accept explicit ``t=`` overrides so callers can reuse timestamps they
    already took (e.g. ``Request.submit_t``) instead of sampling twice.
    """

    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 capacity: int = 65536):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.clock = clock or time.perf_counter
        self.capacity = capacity
        self._ring: deque = deque(maxlen=capacity)
        self.dropped = 0
        self._names: Dict[Tuple[int, int], str] = {}

    # -- recording ----------------------------------------------------------

    def label(self, pid: int, tid: int, name: str) -> None:
        """Name a (pid, tid) lane; exported as Chrome thread metadata."""
        self._names[(pid, tid)] = name

    def begin(self, name: str, *, pid: int = ENGINE_PID, tid: int = 0,
              t: Optional[float] = None, cat: str = "serve",
              **args) -> Span:
        return Span(name=name, pid=pid, tid=tid, cat=cat,
                    t0=self.clock() if t is None else t, args=dict(args))

    def end(self, span: Span, *, t: Optional[float] = None, **args) -> Span:
        span.t1 = self.clock() if t is None else t
        if args:
            span.args.update(args)
        self._push({"ph": "X", "name": span.name, "cat": span.cat,
                    "pid": span.pid, "tid": span.tid,
                    "t0": span.t0, "t1": span.t1, "args": span.args})
        return span

    def span(self, name: str, **kw):
        """Context manager: ``with tracer.span("prefill", tid=rid): ...``"""
        return _SpanCtx(self, name, kw)

    def event(self, name: str, *, pid: int = ENGINE_PID, tid: int = 0,
              t: Optional[float] = None, cat: str = "serve", **args) -> None:
        self._push({"ph": "i", "name": name, "cat": cat, "pid": pid,
                    "tid": tid, "t0": self.clock() if t is None else t,
                    "args": dict(args)})

    def _push(self, rec: Dict[str, object]) -> None:
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(rec)

    # -- introspection ------------------------------------------------------

    def __len__(self) -> int:
        return len(self._ring)

    def records(self) -> List[Dict[str, object]]:
        return list(self._ring)

    def last_record(self, pid: int, tid: int) -> Optional[Dict[str, object]]:
        """Most recent completed record on a lane (stall diagnostics)."""
        for rec in reversed(self._ring):
            if rec["pid"] == pid and rec["tid"] == tid:
                return rec
        return None

    # -- export -------------------------------------------------------------

    def to_chrome(self) -> Dict[str, object]:
        """Chrome-trace document: "X" complete events in microseconds
        relative to the earliest record, plus lane-name metadata."""
        recs = self.records()
        t_base = min((r["t0"] for r in recs), default=0.0)
        events: List[Dict[str, object]] = []
        for (pid, tid), name in sorted(self._names.items()):
            events.append({"ph": "M", "name": "thread_name", "pid": pid,
                           "tid": tid, "args": {"name": name}})
        events.append({"ph": "M", "name": "process_name", "pid": ENGINE_PID,
                       "tid": 0, "args": {"name": "engine"}})
        events.append({"ph": "M", "name": "process_name", "pid": REQUEST_PID,
                       "tid": 0, "args": {"name": "requests"}})
        for r in recs:
            ev: Dict[str, object] = {
                "name": r["name"], "cat": r["cat"], "ph": r["ph"],
                "pid": r["pid"], "tid": r["tid"],
                "ts": (r["t0"] - t_base) * 1e6, "args": r["args"],
            }
            if r["ph"] == "X":
                ev["dur"] = max(0.0, (r["t1"] - r["t0"]) * 1e6)
            else:
                ev["s"] = "t"  # instant scope: thread
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_records": self.dropped}}

    def to_jsonl(self) -> str:
        lines = [json.dumps(r, sort_keys=True) for r in self.records()]
        return "\n".join(lines) + ("\n" if lines else "")

    def export(self, path: str) -> str:
        """Write the trace to ``path``: ``.jsonl`` -> JSON-lines,
        anything else -> Chrome-trace JSON."""
        if str(path).endswith(".jsonl"):
            payload = self.to_jsonl()
        else:
            payload = json.dumps(self.to_chrome()) + "\n"
        with open(path, "w") as f:
            f.write(payload)
        return str(path)


class _SpanCtx:
    def __init__(self, tracer: Tracer, name: str, kw: Dict[str, object]):
        self.tracer, self.name, self.kw = tracer, name, kw
        self.span: Optional[Span] = None

    def __enter__(self) -> Span:
        self.span = self.tracer.begin(self.name, **self.kw)
        return self.span

    def __exit__(self, *exc):
        self.tracer.end(self.span)
        return False


# ---------------------------------------------------------------------------
# Schema validation (tests + CI)
# ---------------------------------------------------------------------------

_REQUIRED_CHILDREN = ("queue", "prefill", "decode")


def validate_chrome_trace(doc: Dict[str, object]) -> Dict[str, int]:
    """Validate a Chrome-trace document against the serving schema.

    Checks: well-formed ``traceEvents``; non-negative, finite timestamps
    and durations; per-lane proper span nesting; and — on the request pid —
    exactly one complete ``request`` root per tid spanning ``queue`` /
    ``prefill`` / ``decode`` children with monotonic phase starts.

    Returns ``{"events": ..., "spans": ..., "requests": ...}`` on success;
    raises ``ValueError`` describing the first violation.
    """
    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        raise ValueError("not a Chrome-trace document (missing traceEvents)")
    events = doc["traceEvents"]
    if not events:
        raise ValueError("empty traceEvents")
    spans_by_lane: Dict[Tuple[int, int], List[Dict[str, object]]] = {}
    instants_by_lane: Dict[Tuple[int, int], List[Dict[str, object]]] = {}
    n_spans = 0
    for i, ev in enumerate(events):
        for k in ("name", "ph", "pid", "tid"):
            if k not in ev:
                raise ValueError(f"event {i} missing {k!r}")
        if ev["ph"] == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i} ({ev['name']!r}) bad ts: {ts!r}")
        lane = (ev["pid"], ev["tid"])
        if ev["ph"] == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"event {i} ({ev['name']!r}) bad dur: {dur!r}")
            spans_by_lane.setdefault(lane, []).append(ev)
            n_spans += 1
        elif ev["ph"] == "i":
            instants_by_lane.setdefault(lane, []).append(ev)
        else:
            raise ValueError(f"event {i} unexpected ph {ev['ph']!r}")

    eps = 1e-3  # µs tolerance for float rounding
    for lane, spans in spans_by_lane.items():
        ordered = sorted(spans, key=lambda e: (e["ts"], -e["dur"]))
        stack: List[Dict[str, object]] = []
        for ev in ordered:
            end = ev["ts"] + ev["dur"]
            while stack and ev["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack and end > stack[-1]["ts"] + stack[-1]["dur"] + eps:
                raise ValueError(
                    f"span {ev['name']!r} on pid={lane[0]} tid={lane[1]} "
                    f"overlaps parent {stack[-1]['name']!r} without nesting")
            stack.append(ev)

    req_pid = REQUEST_PID
    req_lanes = {lane: spans for lane, spans in spans_by_lane.items()
                 if lane[0] == req_pid}
    if not req_lanes:
        raise ValueError("no request spans recorded (pid=%d)" % req_pid)
    for lane, spans in req_lanes.items():
        roots = [s for s in spans if s["name"] == "request"]
        if len(roots) != 1:
            raise ValueError(
                f"request tid={lane[1]}: expected exactly one 'request' "
                f"root span, found {len(roots)}")
        root = roots[0]
        root_end = root["ts"] + root["dur"]
        named = {s["name"]: s for s in spans}
        for child in _REQUIRED_CHILDREN:
            if child not in named:
                raise ValueError(
                    f"request tid={lane[1]} missing {child!r} span")
            c = named[child]
            if c["ts"] < root["ts"] - eps or c["ts"] + c["dur"] > root_end + eps:
                raise ValueError(
                    f"request tid={lane[1]}: {child!r} escapes its "
                    f"'request' root")
        if not (named["queue"]["ts"] <= named["prefill"]["ts"] + eps
                <= named["decode"]["ts"] + 2 * eps):
            raise ValueError(
                f"request tid={lane[1]}: phases out of order "
                f"(queue -> prefill -> decode)")
        toks = [e for e in instants_by_lane.get(lane, ())
                if e["name"] == "token"]
        last_ts = None
        for e in toks:
            if e["ts"] < root["ts"] - eps or e["ts"] > root_end + eps:
                raise ValueError(
                    f"request tid={lane[1]}: token instant outside the "
                    f"request span")
            if last_ts is not None and e["ts"] < last_ts - eps:
                raise ValueError(
                    f"request tid={lane[1]}: token timestamps not monotonic")
            last_ts = e["ts"]
    return {"events": len(events), "spans": n_spans,
            "requests": len(req_lanes)}


def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trace",
        description="Validate a Chrome-trace export against the serving "
                    "span schema.")
    ap.add_argument("file", help="trace JSON file (as written by --trace-out)")
    args = ap.parse_args(argv)
    with open(args.file) as f:
        doc = json.load(f)
    try:
        summary = validate_chrome_trace(doc)
    except ValueError as e:
        print(f"[trace] INVALID: {e}")
        return 1
    print(f"[trace] ok: {summary['events']} events, {summary['spans']} "
          f"spans, {summary['requests']} request lanes")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(_main())
