"""Profiling hooks: jit-dispatch timing, compile counting, autotune events.

``Profiler.wrap(site, fn)`` decorates the engine's jitted entry points
(paged-attention decode tick, in-graph decode/spec windows,
``prefill_shared``, sampling).  Each call records host-side dispatch wall
time into ``profile_dispatch_seconds{site=...}`` and watches the
underlying jit cache (``fn._cache_size()``) for growth — every new cache
entry is a (re)compile, surfaced as ``jit_compiles_total{site=...}`` and,
when tracing is on, a ``jit_compile`` instant on the engine lane.

Autotune measurements report through a module-level subscriber list so
``kernels.autotune.best`` needs no engine reference: enabled profilers
subscribe (weakly — a dropped engine unsubscribes itself) and count
lookups per (op, source) plus measured wall time.
"""
from __future__ import annotations

import time
import weakref
from typing import Callable, List, Optional

from repro.obs.trace import ENGINE_PID, Tracer
from repro.obs.metrics import MetricsRegistry

__all__ = ["Profiler", "notify_autotune", "register_profile_metrics"]

_AUTOTUNE_SUBS: List["weakref.ref[Profiler]"] = []


def notify_autotune(op: str, source: str, key: object = None,
                    best_us: Optional[float] = None) -> None:
    """Called by ``kernels.autotune.best`` on every lookup.

    ``source`` is one of ``table`` (exact or cross-backend hit),
    ``measured`` (fresh timing sweep), or ``default`` (static fallback).
    No-op unless a live profiler has subscribed.
    """
    if not _AUTOTUNE_SUBS:
        return
    dead = []
    for ref in _AUTOTUNE_SUBS:
        prof = ref()
        if prof is None:
            dead.append(ref)
        else:
            prof.on_autotune(op, source, key, best_us)
    for ref in dead:
        _AUTOTUNE_SUBS.remove(ref)


def register_profile_metrics(reg: MetricsRegistry) -> None:
    """Declare the profiling metric schema (kept feature-independent so the
    exported key set is identical whether or not profiling ran)."""
    reg.histogram("profile_dispatch_seconds",
                  "Host-side wall time of one jitted dispatch",
                  labels=("site",))
    reg.counter("jit_compiles_total",
                "New jit-cache entries observed per site (compiles and "
                "shape-driven recompiles)", labels=("site",))
    reg.counter("autotune_lookups_total",
                "Autotune table lookups by resolution source",
                labels=("op", "source"))
    reg.histogram("autotune_measure_seconds",
                  "Best measured kernel time per autotune sweep",
                  labels=("op",))


class Profiler:
    def __init__(self, registry: MetricsRegistry,
                 tracer: Optional[Tracer] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.registry = registry
        self.tracer = tracer
        self.clock = clock or time.perf_counter
        self._cache_sizes: dict = {}
        register_profile_metrics(registry)
        _AUTOTUNE_SUBS.append(weakref.ref(self))

    # -- jit dispatch -------------------------------------------------------

    def wrap(self, site: str, fn: Callable) -> Callable:
        """Return ``fn`` timed under ``site``.

        The jit cache is found on ``fn`` itself or on ``fn._jitted`` (the
        KV pool's bound step closure exposes its inner jit that way).
        """
        target = getattr(fn, "_jitted", fn)
        hist = self.registry.histogram("profile_dispatch_seconds")
        self._cache_sizes[site] = self._cache_size(target)

        def timed(*args, **kwargs):
            t0 = self.clock()
            out = fn(*args, **kwargs)
            dt = self.clock() - t0
            hist.observe(dt, site=site)
            self._note_compiles(site, target, dt)
            return out

        timed.__name__ = getattr(fn, "__name__", site)
        timed._profiled_site = site
        timed._wrapped = fn
        return timed

    @staticmethod
    def _cache_size(target) -> Optional[int]:
        try:
            return int(target._cache_size())
        except Exception:
            return None

    def _note_compiles(self, site: str, target, dispatch_s: float) -> None:
        cs = self._cache_size(target)
        if cs is None:
            return
        last = self._cache_sizes.get(site) or 0
        if cs > last:
            self.registry.counter("jit_compiles_total").inc(cs - last,
                                                            site=site)
            if self.tracer is not None:
                self.tracer.event("jit_compile", pid=ENGINE_PID, tid=0,
                                  cat="profile", site=site, new=cs - last,
                                  cache_size=cs, dispatch_s=dispatch_s)
        self._cache_sizes[site] = cs

    # -- autotune -----------------------------------------------------------

    def on_autotune(self, op: str, source: str, key: object,
                    best_us: Optional[float]) -> None:
        self.registry.counter("autotune_lookups_total").inc(
            1, op=op, source=source)
        if best_us is not None:
            self.registry.histogram("autotune_measure_seconds").observe(
                best_us * 1e-6, op=op)
        if self.tracer is not None:
            self.tracer.event("autotune", pid=ENGINE_PID, tid=0,
                              cat="profile", op=op, source=source,
                              key=str(key), best_us=best_us)
