"""Serving observability: tracing, metrics registry, profiling hooks.

One instrument for the whole serving stack (scheduler → prefix cache →
spec-decode windows → paged kernel):

  * :mod:`repro.obs.metrics` — typed counters/gauges/histograms with label
    sets; Prometheus-text and JSON exporters.  Always on: the legacy
    ``scheduler.metrics()`` dict is a compatibility view over it.
  * :mod:`repro.obs.trace` — nestable spans over a pluggable monotonic
    clock; per-request lifecycle (enqueue → admit → prefill → decode →
    finish) in a bounded ring; JSON-lines and Chrome-trace export.
  * :mod:`repro.obs.profile` — jit-dispatch timing, compile/recompile
    counting, autotune lookup events.

``ObsConfig(enabled=False)`` (the default, carried on ``ServeConfig.obs``)
keeps tracing and profiling entirely out of the hot loop: no spans, no
wrappers around the jitted entry points — emitted tokens and the legacy
metrics dict are bit-identical to an unobserved engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import Profiler, register_profile_metrics
from repro.obs.trace import (ENGINE_PID, REQUEST_PID, Tracer,
                             validate_chrome_trace)

__all__ = [
    "ObsConfig",
    "Observability",
    "MetricsRegistry",
    "Tracer",
    "Profiler",
    "ENGINE_PID",
    "REQUEST_PID",
    "validate_chrome_trace",
]


@dataclasses.dataclass
class ObsConfig:
    """Observability switches, carried on ``ServeConfig.obs``.

    ``enabled`` gates tracing + profiling (the expensive, per-tick parts);
    the metrics registry itself is always live because the scheduler's
    legacy counters are backed by it.  ``clock`` injects a monotonic time
    source (seconds) shared by the tracer, the profiler, and the
    scheduler; ``None`` means ``time.perf_counter``.
    """

    enabled: bool = False
    profile: bool = True
    ring_capacity: int = 65536
    clock: Optional[Callable[[], float]] = None

    def __post_init__(self):
        if self.ring_capacity < 1:
            raise ValueError("ring_capacity must be >= 1")


class Observability:
    """Per-engine bundle: registry (always), tracer + profiler (opt-in)."""

    def __init__(self, cfg: Optional[ObsConfig] = None):
        self.cfg = cfg or ObsConfig()
        self.clock: Callable[[], float] = self.cfg.clock or time.perf_counter
        self.registry = MetricsRegistry()
        register_profile_metrics(self.registry)
        self.tracer: Optional[Tracer] = None
        self.profiler: Optional[Profiler] = None
        if self.cfg.enabled:
            self.tracer = Tracer(clock=self.clock,
                                 capacity=self.cfg.ring_capacity)
            if self.cfg.profile:
                self.profiler = Profiler(self.registry, self.tracer,
                                         self.clock)

    @property
    def enabled(self) -> bool:
        return self.tracer is not None

    def wrap(self, site: str, fn):
        """Profile ``fn`` under ``site`` — identity when profiling is off,
        so the disabled path adds zero indirection to the hot loop."""
        if self.profiler is None:
            return fn
        return self.profiler.wrap(site, fn)

    def export_trace(self, path: str) -> str:
        if self.tracer is None:
            raise RuntimeError(
                "tracing is disabled; construct the engine with "
                "ServeConfig(obs=ObsConfig(enabled=True)) to record spans")
        return self.tracer.export(path)

    def export_metrics(self, path: str) -> str:
        return self.registry.export(path)
