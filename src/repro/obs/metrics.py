"""Typed metrics registry: counters, gauges, histograms with label sets.

The registry replaces the hand-rolled aggregate ints that used to live on
``ContinuousScheduler`` — every serving-side count flows through one
instrument with a stable schema, so benches, launchers, and CI all export
the same names.  Two exporters are provided:

  * ``to_prometheus()`` — the Prometheus text exposition format
    (``# HELP`` / ``# TYPE`` headers, ``name{label="v"} value`` samples,
    histogram ``_bucket{le=...}`` / ``_sum`` / ``_count`` series);
  * ``to_json()`` — a deterministic JSON document (sorted metric names,
    sorted label tuples) suitable for committing as a curated snapshot
    (``BENCH_*.json``) and diffing across PRs.

Metrics are host-side Python objects: incrementing a counter is a dict
update outside any jit graph, so the registry can stay always-on (the
legacy ``scheduler.metrics()`` view is built from it) while tracing and
profiling remain opt-in.
"""
from __future__ import annotations

import json
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "DEFAULT_TIME_BUCKETS",
]

# Prometheus-style latency buckets (seconds); generous low end because the
# reference backend on CPU dispatches in the ~100us-10ms range.
DEFAULT_TIME_BUCKETS = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025,
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)

LabelKey = Tuple[str, ...]


def _fmt(v: float) -> str:
    """Prometheus sample formatting: integers render bare, floats as repr."""
    if v == math.inf:
        return "+Inf"
    if v == -math.inf:
        return "-Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def _label_str(names: Sequence[str], values: LabelKey) -> str:
    if not names:
        return ""
    inner = ",".join(
        '%s="%s"' % (n, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for n, v in zip(names, values))
    return "{" + inner + "}"


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._series: Dict[LabelKey, object] = {}

    def _key(self, labels: Mapping[str, str]) -> LabelKey:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"metric {self.name!r} takes labels {self.labelnames}, "
                f"got {tuple(sorted(labels))}")
        return tuple(str(labels[n]) for n in self.labelnames)

    def series(self) -> List[Tuple[LabelKey, object]]:
        return sorted(self._series.items())

    def reset(self) -> None:
        self._series.clear()


class Counter(_Metric):
    """Monotonically increasing count (resettable only via the registry)."""

    kind = "counter"

    def inc(self, v: float = 1.0, **labels: str) -> None:
        if v < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0.0) + v

    def value(self, **labels: str) -> float:
        return float(self._series.get(self._key(labels), 0.0))

    def _set(self, v: float, **labels: str) -> None:
        # Back door for the legacy scheduler attributes (``decode_steps = 0``
        # style resets done by benches); not part of the public counter API.
        self._series[self._key(labels)] = float(v)


class Gauge(_Metric):
    """A value that can go up and down (free blocks, queue depth, ...)."""

    kind = "gauge"

    def set(self, v: float, **labels: str) -> None:
        self._series[self._key(labels)] = float(v)

    def inc(self, v: float = 1.0, **labels: str) -> None:
        k = self._key(labels)
        self._series[k] = self._series.get(k, 0.0) + v

    def dec(self, v: float = 1.0, **labels: str) -> None:
        self.inc(-v, **labels)

    def value(self, **labels: str) -> float:
        return float(self._series.get(self._key(labels), 0.0))


class Histogram(_Metric):
    """Cumulative-bucket histogram (Prometheus semantics).

    Each series holds per-bucket counts for the configured upper bounds
    plus ``+Inf``, a running sum, and a total count.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, labelnames: Sequence[str] = (),
                 buckets: Sequence[float] = DEFAULT_TIME_BUCKETS):
        super().__init__(name, help, labelnames)
        bs = tuple(sorted(float(b) for b in buckets))
        if not bs:
            raise ValueError("histogram needs at least one bucket")
        if bs[-1] != math.inf:
            bs = bs + (math.inf,)
        self.buckets = bs

    def observe(self, v: float, **labels: str) -> None:
        k = self._key(labels)
        st = self._series.get(k)
        if st is None:
            st = {"counts": [0] * len(self.buckets), "sum": 0.0, "count": 0}
            self._series[k] = st
        for i, b in enumerate(self.buckets):
            if v <= b:
                st["counts"][i] += 1
                break
        st["sum"] += float(v)
        st["count"] += 1

    def count(self, **labels: str) -> int:
        st = self._series.get(self._key(labels))
        return 0 if st is None else int(st["count"])

    def sum(self, **labels: str) -> float:
        st = self._series.get(self._key(labels))
        return 0.0 if st is None else float(st["sum"])


class MetricsRegistry:
    """Name -> metric map with get-or-create registration.

    Re-registering an existing name is idempotent when the kind and label
    names match (so independent modules can each declare the metrics they
    touch) and an error otherwise.
    """

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}

    # -- registration -------------------------------------------------------

    def _get_or_create(self, cls, name: str, help: str,
                       labels: Sequence[str], **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or (help and m.labelnames != tuple(labels)):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind} "
                    f"with labels {m.labelnames}")
            return m
        m = cls(name, help, labels, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels,
                                   buckets=buckets)

    # -- introspection ------------------------------------------------------

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str) -> Optional[_Metric]:
        return self._metrics.get(name)

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def schema(self) -> Dict[str, Dict[str, object]]:
        """Stable {name: {kind, labels}} map — what the schema test freezes."""
        return {n: {"kind": m.kind, "labels": list(m.labelnames)}
                for n, m in sorted(self._metrics.items())}

    def reset(self) -> None:
        """Zero every series; registrations (the schema) survive."""
        for m in self._metrics.values():
            m.reset()

    # -- exporters ----------------------------------------------------------

    def to_json(self) -> Dict[str, object]:
        out: Dict[str, object] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            entry: Dict[str, object] = {
                "type": m.kind, "help": m.help,
                "labels": list(m.labelnames), "series": [],
            }
            for key, val in m.series():
                row: Dict[str, object] = {
                    "labels": dict(zip(m.labelnames, key))}
                if m.kind == "histogram":
                    cum = 0
                    buckets = {}
                    for b, c in zip(m.buckets, val["counts"]):
                        cum += c
                        buckets[_fmt(b)] = cum
                    row.update(count=val["count"], sum=val["sum"],
                               buckets=buckets)
                else:
                    row["value"] = val
                entry["series"].append(row)
            out[name] = entry
        return out

    def to_prometheus(self) -> str:
        lines: List[str] = []
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, val in m.series():
                if m.kind == "histogram":
                    cum = 0
                    for b, c in zip(m.buckets, val["counts"]):
                        cum += c
                        le = _label_str(m.labelnames + ("le",),
                                        key + (_fmt(b),))
                        lines.append(f"{name}_bucket{le} {cum}")
                    ls = _label_str(m.labelnames, key)
                    lines.append(f"{name}_sum{ls} {_fmt(val['sum'])}")
                    lines.append(f"{name}_count{ls} {val['count']}")
                else:
                    ls = _label_str(m.labelnames, key)
                    lines.append(f"{name}{ls} {_fmt(val)}")
        return "\n".join(lines) + "\n"

    def export(self, path: str) -> str:
        """Write metrics to ``path``; format picked by extension
        (``.json`` -> JSON document, anything else -> Prometheus text)."""
        if str(path).endswith(".json"):
            doc = json.dumps(self.to_json(), indent=1, sort_keys=False)
            payload = doc + "\n"
        else:
            payload = self.to_prometheus()
        with open(path, "w") as f:
            f.write(payload)
        return str(path)
