"""Explicit shard_map collectives: the MoE expert-dispatch schedule.

Under plain pjit, ``models.moe.moe_apply`` pins the dispatch buffer to
``P(dp, "model", None, None)`` and lets GSPMD infer the resharding
collectives around the expert einsums.  That is correct but leaves the
schedule to the partitioner: the (B, E, cap, D) buffer is replicated
across the model axis before the slice, so every model rank materialises
the full dispatch volume.

The optimized variant here makes the schedule explicit with ``shard_map``:

1. the dispatch buffer enters *fully batch-sharded* — batch over the data
   axes **and** the model axis, experts unsharded — so no rank ever holds
   a replicated copy;
2. :func:`all_to_all_dispatch` rotates it over the model axis (split the
   expert axis, concatenate the batch axis): afterwards each model rank
   holds **all** tokens for its ``E / ep`` local experts;
3. the expert FFN runs as purely local einsums (no inferred collectives
   possible — shard_map guarantees it);
4. :func:`all_to_all_combine` rotates the outputs back to the
   batch-sharded layout for the token-side combine in ``moe_apply``.

Wire volume is one activation-sized all-to-all each way — the minimum any
EP schedule can do — versus GSPMD's replicate+slice on dispatch and
expert-axis all-gather on combine.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def all_to_all_dispatch(xe: jax.Array, axis_name: str = "model") -> jax.Array:
    """(B_loc, E, cap, D) batch-sharded -> (B_loc*ep, E/ep, cap, D) expert-sharded.

    Must run inside ``shard_map`` (or any SPMD context binding
    ``axis_name``).  With ep == 1 this is the identity.
    """
    if jax.lax.psum(1, axis_name) == 1:
        return xe
    return jax.lax.all_to_all(xe, axis_name, split_axis=1, concat_axis=0,
                              tiled=True)


def all_to_all_combine(ye: jax.Array, axis_name: str = "model") -> jax.Array:
    """Inverse of :func:`all_to_all_dispatch` for the expert outputs."""
    if jax.lax.psum(1, axis_name) == 1:
        return ye
    return jax.lax.all_to_all(ye, axis_name, split_axis=0, concat_axis=1,
                              tiled=True)


def _batch_entry(data_axes: Sequence[str], expert_axis: str):
    return tuple(data_axes) + (expert_axis,)


def expert_ffn_ep(
    xe: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    mesh,
    *,
    data_axes: Sequence[str] = ("data",),
    expert_axis: str = "model",
    spec=None,
) -> jax.Array:
    """Explicit-EP expert FFN over a (B, E, cap, D) dispatch buffer.

    ``xe`` is consumed batch-sharded over ``data_axes + (expert_axis,)``
    and returned in the same layout; expert weights ``(E, D, de)`` /
    ``(E, de, D)`` are sharded over ``expert_axis``.  The batch axis must
    divide the full mesh size and E must divide the ``expert_axis`` size
    (use ``dist.sharding.sanitize_pspecs`` upstream to guarantee it).

    ``spec`` is the model QuantizeSpec: the W4A4 activation hooks (act
    quant + R4 online rotation before the down projection) are applied
    inside the local compute, exactly mirroring ``moe_apply``.
    """
    from repro.models.common import NOQUANT, act_q, apply_r4

    spec = spec or NOQUANT
    batch = _batch_entry(data_axes, expert_axis)
    xe_spec = P(batch, None, None, None)
    w_spec = P(expert_axis, None, None)

    def local(xl, wg, wu, wd):
        xl = all_to_all_dispatch(xl, expert_axis)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xl, wg)) * jnp.einsum(
            "becd,edf->becf", xl, wu
        )
        h = apply_r4(h, spec, "w_down")
        h = act_q(h, spec, site="w_down")
        yl = jnp.einsum("becf,efd->becd", h, wd)
        return all_to_all_combine(yl, expert_axis)

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(xe_spec, w_spec, w_spec, w_spec),
        out_specs=xe_spec,
        check_rep=False,
    )
    return fn(xe, w_gate, w_up, w_down)


def psum_partial_combine(y_partials: jax.Array, mesh,
                         expert_axis: str = "model") -> jax.Array:
    """Sum stacked per-rank partials ``(ep, ...)`` over the expert axis.

    The row-parallel alternative to all-gathering expert outputs: each
    rank combines only its local experts into a (B, S, D) partial, the
    partials are stacked on a leading axis sharded over ``expert_axis``
    (so slice ``i`` lives on rank ``i`` — never replicated), and the
    activation-sized psum finishes the reduction.  Returns the summed
    ``(...)`` array (leading axis removed).
    """
    if y_partials.shape[0] != ep_degree(mesh, expert_axis):
        raise ValueError(
            f"need one partial per {expert_axis!r} rank: "
            f"{y_partials.shape[0]} != {ep_degree(mesh, expert_axis)}"
        )
    in_spec = P(expert_axis, *([None] * (y_partials.ndim - 1)))
    out_spec = P(*([None] * (y_partials.ndim - 1)))

    def local(y):
        return jax.lax.psum(y[0], expert_axis)

    fn = shard_map(local, mesh=mesh, in_specs=(in_spec,), out_specs=out_spec,
                   check_rep=False)
    return fn(y_partials)


def ep_degree(mesh, expert_axis: str = "model") -> int:
    """Expert-parallel degree of a mesh (1 when the axis is absent)."""
    try:
        sizes = dict(mesh.shape.items()) if hasattr(mesh.shape, "items") else {
            name: size for name, size in mesh.shape_tuple
        }
    except AttributeError:
        return 1
    return int(sizes.get(expert_axis, 1))


def dispatch_layout(n_tokens_local: int, n_experts: int, ep: int
                    ) -> Tuple[int, int]:
    """(tokens_after_dispatch, local_experts) for capacity planning."""
    assert n_experts % ep == 0, (n_experts, ep)
    return n_tokens_local * ep, n_experts // ep
