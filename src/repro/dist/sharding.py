"""PartitionSpec derivation for every registered architecture.

Rules are *intent* specs computed from ``ShapeDtypeStruct`` trees (never
concrete arrays) and are keyed on leaf name + rank, so the same rule set
covers a stacked ``(L, C, H)`` transformer weight, an interleaved-MoE
``(G, every, C, H)`` stack, and an unstacked Zamba shared-block ``(C, H)``
matrix.  Layout conventions (trailing-axis relative, mesh axes
``data``/``pod`` = data parallel, ``model`` = tensor/expert parallel):

* column-parallel (up-projections, qkv, router, lm_head): ``model`` on
  the output (last) axis, FSDP axes on the contraction axis.
* row-parallel (down/out-projections): ``model`` on the contraction
  (second-to-last) axis, FSDP axes on the output axis.
* expert-parallel (MoE expert stacks): ``model`` on the expert axis
  (third-from-last), FSDP on the ``d_model`` axis.
* embeddings: ``model`` on the vocab axis; norms/gates/small recurrences
  replicated.

Quantized (packed) leaves — ``repro.quant.packed.PackedWeight`` nodes —
inherit their source weight's spec verbatim: :func:`param_pspecs` derives
the rule from the *logical* ``(..., C, H)`` shape and mirrors it onto the
codes ``(..., C/pb, H)`` and grouped scale/zero ``(..., C/g, H)``
children, so ``model`` stays on the output axis H and codes and scales
always co-shard with the weight they dequantize into.  Because the rule
is keyed on the logical shape alone, *heterogeneous* packed trees — a
per-site ``QuantPolicy`` mixing bits and group sizes across leaves (or
across layers inside one leaf) — co-shard exactly like uniform ones;
per-child divisibility (C/pb vs C/g, whatever g each leaf ended up with)
is settled by :func:`sanitize_pspecs` like any other leaf.

Every intent spec must pass :func:`sanitize_pspecs` against a concrete
mesh before use — that is the single place axis divisibility is decided
(a placement whose mesh-axis product does not divide the dimension is
dropped, i.e. replicated).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# Leaf-name role sets (union over all families; rank rules disambiguate).
_COL = {
    "wq", "wk", "wv", "w_gate", "w_up", "shared_gate", "shared_up",
    "wq_a", "wq_b", "wkv_a", "wx", "wo_gate", "wi", "wf", "in_proj",
    "router", "lm_head", "conv_w", "patch_proj",
}
_ROW = {"wo", "w_down", "shared_down", "out_proj"}
_BIAS = {"bq", "bk", "bv", "A_log", "D_skip", "dt_bias"}
_REPLICATED = {
    "attn_norm", "mlp_norm", "norm", "q_norm", "kv_norm", "final_norm",
    "rh",
}


def _dp_entry(axes: Sequence[str]):
    axes = tuple(axes)
    return axes[0] if len(axes) == 1 else axes


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        key = getattr(p, "key", None)
        if key is not None:
            out.append(str(key))
    return tuple(out)


def _axis_sizes(mesh) -> Dict[str, int]:
    """Axis-name -> size for concrete Mesh, AbstractMesh, or test doubles."""
    shape = getattr(mesh, "shape", None)
    if shape is not None and hasattr(shape, "items"):
        return dict(shape.items())
    if hasattr(mesh, "shape_tuple"):
        return {name: size for name, size in mesh.shape_tuple}
    return dict(zip(mesh.axis_names, np.shape(mesh.devices)))


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def param_pspecs(cfg, params_sds, *, fsdp_axes: Optional[Sequence[str]] = None,
                 fsdp_size: int = 16):
    """One PartitionSpec per leaf of ``params_sds`` (rank-matched).

    ``fsdp_axes`` (e.g. ``("data",)`` or ``("pod", "data")``) shard the
    designated storage axis of each large matrix; placement is skipped up
    front when the axis is not divisible by ``fsdp_size`` (the product of
    the FSDP mesh axes) so intent specs stay close to what survives
    :func:`sanitize_pspecs`.

    Rules key off each leaf's *logical* weight shape (packed leaves
    contribute ``PackedWeight.logical_shape``), which is what makes a
    spec-decode draft tree (``api.derive_draft`` — same logical shapes,
    harsher bits/group) land on exactly the target's placement: the serve
    engine runs this same function over the draft tree and draft/target
    shards align axis-for-axis on the mesh.
    """
    from repro.quant.packed import is_packed

    fsdp = _dp_entry(fsdp_axes) if fsdp_axes else None

    def fsdp_ok(dim: int) -> bool:
        return fsdp is not None and fsdp_size > 0 and dim % fsdp_size == 0

    def visit(path, leaf):
        if is_packed(leaf):
            # Derive the rule from the logical (..., C, H) weight shape and
            # mirror it onto codes/scale/zero (packed-quant co-sharding).
            base = visit(path, jax.ShapeDtypeStruct(leaf.logical_shape,
                                                    np.float32))
            return leaf.replace(codes=base, scale=base, zero=base)
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        nd = len(shape)
        parts = [None] * nd

        if nd == 0 or name in _REPLICATED or name.endswith("norm"):
            return P()
        if name in _BIAS:
            parts[-1] = "model"
            return P(*parts)
        is_expert = (
            "moe_mlp" in names
            or (
                cfg.family == "moe"
                and cfg.moe_every == 1
                and name in ("w_gate", "w_up", "w_down")
                and nd >= 4
            )
        ) and name != "router"
        if is_expert and nd >= 3:
            # (..., E, C, H): expert-parallel over model; FSDP on d_model.
            parts[-3] = "model"
            ax = -1 if name == "w_down" else -2
            if fsdp_ok(shape[ax]):
                parts[ax] = fsdp
            return P(*parts)
        if name == "embed":
            # (..., V, D): vocab on model, FSDP on d_model.
            if nd >= 2:
                parts[-2] = "model"
                if fsdp_ok(shape[-1]):
                    parts[-1] = fsdp
            return P(*parts)
        if name == "wkv_b" and nd >= 3:
            # (..., rank, H, nope+v): shard the head axis.
            parts[-2] = "model"
            if fsdp_ok(shape[-3]):
                parts[-3] = fsdp
            return P(*parts)
        if name in _ROW and nd >= 2:
            parts[-2] = "model"
            if fsdp_ok(shape[-1]):
                parts[-1] = fsdp
            return P(*parts)
        if name in _COL and nd >= 2:
            parts[-1] = "model"
            if fsdp_ok(shape[-2]):
                parts[-2] = fsdp
            return P(*parts)
        # Unknown leaf: replicate (correct for any shape; costs memory only).
        return P()

    return jax.tree_util.tree_map_with_path(visit, params_sds, is_leaf=is_packed)


# ---------------------------------------------------------------------------
# Caches (KV / SSM / conv state)
# ---------------------------------------------------------------------------


def cache_pspecs(cfg, cache_sds, dp_axes: Sequence[str], *,
                 shard_batch: bool = True, model_size: int = 16):
    """Specs for decode/prefill cache trees of every family.

    The batch axis shards over the data axes (unless ``shard_batch=False``,
    the long-context regime where batch=1); the head-like axis shards over
    ``model`` only when divisible by ``model_size`` — KV-head counts are
    small, so the fallback tries the head_dim axis before replicating.
    """
    dp = _dp_entry(dp_axes) if shard_batch else None

    def maybe_model(dim: int) -> Optional[str]:
        return "model" if model_size > 0 and dim % model_size == 0 else None

    def visit(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = leaf.shape
        nd = len(shape)
        if nd == 0 or name == "length":
            return P()
        parts = [None] * nd

        if cfg.family == "ssm":
            # xlstm: m leaves (G, m_per, B, H, ...) / s leaves (G, B, H, dh)
            batch_ax = 2 if name == "m" else 1
            head_ax = batch_ax + 1
        else:
            batch_ax = 1
            head_ax = {
                "ssm_s": 2, "ssm_n": 2, "conv": 3,
                "k": 3, "v": 3, "k_scale": 3, "k_zero": 3,
                "v_scale": 3, "v_zero": 3, "ckv": 3,
            }.get(name)
        if batch_ax < nd:
            parts[batch_ax] = dp
        if head_ax is not None and head_ax < nd:
            placed = maybe_model(shape[head_ax])
            if placed is None and head_ax + 1 < nd:
                # e.g. few KV heads but wide head_dim: shard head_dim.
                placed = maybe_model(shape[head_ax + 1])
                if placed:
                    parts[head_ax + 1] = placed
            else:
                parts[head_ax] = placed
        return P(*parts)

    return jax.tree_util.tree_map_with_path(visit, cache_sds)


def pool_pspecs(cfg, pool_sds, dp_axes: Sequence[str], *,
                shard_blocks: bool = True, model_size: int = 16):
    """Specs for the paged KV-pool storage of ``repro.serve.kvpool``.

    The pool allocates block storage through the model's own
    ``init_cache(batch=n_blocks, max_seq=block_tokens)``, so every leaf
    keeps the static cache's layout with the *block* axis sitting exactly
    where the batch axis sits (and the per-slot state fragment keeps the
    batch axis as the slot axis).  KV blocks therefore shard on the same
    mesh axes as the static cache: blocks/slots over the data axes,
    head-like axes over ``model`` — :func:`cache_pspecs` applies verbatim.
    Pass the pool-geometry ShapeDtypeStruct tree (``cache_specs(n_blocks,
    block_tokens)`` or ``cache_specs(n_slots, block_tokens)``) and gate
    the result through :func:`sanitize_pspecs` as usual.

    Prefix sharing (``repro.serve.prefixcache``) changes nothing here:
    placement is keyed by *block id*, and sharing only multiplies how many
    slot tables reference an id — refcounts, the radix index, and the
    pin set are host-side bookkeeping.  A shared block lives on exactly
    the shards its id maps to regardless of reference count, and
    copy-on-write allocates a fresh id that shards by the same rule.
    """
    return cache_pspecs(cfg, pool_sds, dp_axes, shard_batch=shard_blocks,
                        model_size=model_size)


def step_input_pspecs(tree_sds):
    """Replicated specs for the decode-tick control inputs.

    The fused no-gather layout keeps KV *blocks* sharded in place
    (:func:`pool_pspecs`) while the per-tick control state — tokens,
    per-slot lengths, the block table, and the in-graph window's
    stop/count/alive vectors — is tiny and consulted by every shard (the
    paged kernel walks the table against its local block shard; the
    sampler masks every slot).  Replicating it explicitly keeps the
    fused step's placement deterministic instead of letting jit infer a
    sharding from whatever device the host arrays landed on.
    """
    return jax.tree.map(lambda _: P(), tree_sds)


# ---------------------------------------------------------------------------
# Token batches
# ---------------------------------------------------------------------------


def batch_pspecs(cfg, batch_sds, dp_axes: Sequence[str], *,
                 shard_seq: bool = False):
    """Specs for step-input trees (tokens / patch_embeds).

    Default: batch axis over the data axes.  ``shard_seq=True`` is the
    long-context layout: the *sequence* axis (axis 1) takes the data axes
    instead (batch is 1 there, and a mesh axis may appear only once per
    spec).
    """
    dp = _dp_entry(dp_axes)

    def visit(path, leaf):
        nd = leaf.ndim
        if nd == 0:
            return P()
        parts = [None] * nd
        if shard_seq and nd >= 2:
            parts[1] = dp
        else:
            parts[0] = dp
        return P(*parts)

    return jax.tree_util.tree_map_with_path(visit, batch_sds)


# ---------------------------------------------------------------------------
# Divisibility sanitizer
# ---------------------------------------------------------------------------


def sanitize_pspecs(mesh, specs, sds):
    """Drop axis placements that do not divide the dimension on ``mesh``.

    The single divisibility gate between intent specs and a concrete mesh:
    for every spec entry, the product of the named mesh-axis sizes must
    divide the corresponding array dimension, and every named axis must
    exist on the mesh — otherwise the entry is replaced by ``None``
    (replicated).  Entry form (bare name vs. axis tuple) is preserved.

    ``specs`` and ``sds`` must be matching pytrees with PartitionSpec /
    ShapeDtypeStruct (or array) leaves respectively.
    """
    sizes = _axis_sizes(mesh)

    def fix(spec, leaf):
        if not isinstance(spec, P):
            return spec
        shape = leaf.shape
        out = []
        for i, entry in enumerate(spec):
            if entry is None or i >= len(shape):
                out.append(None)
                continue
            axis_names = entry if isinstance(entry, tuple) else (entry,)
            if not all(a in sizes for a in axis_names):
                out.append(None)
                continue
            total = int(np.prod([sizes[a] for a in axis_names]))
            out.append(entry if total > 0 and shape[i] % total == 0 else None)
        return P(*out)

    return jax.tree.map(fix, specs, sds, is_leaf=lambda x: isinstance(x, P))
