"""Distribution layer: sharding rules, elastic re-mesh, explicit collectives.

Design note
-----------
Everything the mesh-scale launchers need to place a model lives here, in
three deliberately separate concerns:

* :mod:`repro.dist.sharding` derives ``PartitionSpec`` pytrees *from
  shapes, not arrays* — every rule consumes the ``ShapeDtypeStruct``
  trees produced by ``Arch.param_specs`` / ``cache_specs`` /
  ``input_specs``, so specs for a 400B model are computed without
  allocating a byte.  Rules are name+rank keyed per model family
  (dense / MoE / MLA / xLSTM / Zamba hybrid): column-parallel
  up-projections, row-parallel down-projections, expert-parallel MoE
  stacks, and packed quantized leaves (codes + per-group scales) that
  co-shard with their source weight's output axis.  A single
  ``sanitize_pspecs`` pass reconciles the *intent* specs against a
  concrete mesh by dropping any axis placement that does not divide the
  dimension — the one place divisibility is decided, shared by the
  launchers and by ``models.moe``'s in-graph sharding hints.
* :mod:`repro.dist.elastic` plans mesh shape + per-device batch +
  gradient accumulation for an arbitrary surviving device count, so an
  elastic resize preserves the global batch (and therefore the training
  trajectory) instead of silently changing it.
* :mod:`repro.dist.collectives` holds the explicit ``shard_map``
  all-to-all expert dispatch schedule — the optimized alternative to
  letting GSPMD infer collectives from the MoE einsums.

The dry-run (``launch/dryrun.py``) lowers every (arch x shape x mesh)
cell against 512 placeholder host devices using exactly these specs; the
serving engine and trainer accept an optional mesh and reuse the same
rules, so the tested single-device path and the production path diverge
only in placement, never in math.
"""
from repro.dist import collectives, elastic, sharding  # noqa: F401
