"""Elastic re-mesh planning: survive device-count changes without changing
the training trajectory.

Synchronous SPMD has no per-step straggler story — a slow or lost host is
a collective-latency event — so elasticity happens *between* steps: the
launcher observes the surviving device count, asks :func:`plan_remesh`
for a new (data, model) mesh plus a per-device batch / gradient-
accumulation split that preserves the global batch, rebuilds the mesh,
and reshards the checkpointed state with :func:`reshard`.  Keeping the
global batch fixed keeps the optimizer schedule and loss curve
comparable across resizes; the model axis shrinks only when the new
device count stops dividing by the preferred tensor-parallel degree.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

PREFERRED_MODEL_PARALLEL = 16  # one v5e ICI torus row
MAX_PER_DEVICE_BATCH = 16


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    mesh_shape: Tuple[int, int]  # (data, model)
    per_device_batch: int
    grad_accum: int
    global_batch: int
    axis_names: Tuple[str, str] = ("data", "model")

    @property
    def n_devices(self) -> int:
        return self.mesh_shape[0] * self.mesh_shape[1]

    @property
    def effective_batch(self) -> int:
        """Tokens-batch actually stepped; >= global_batch, == when exact."""
        return self.per_device_batch * self.mesh_shape[0] * self.grad_accum


def plan_remesh(n_devices: int, global_batch: int, *,
                model_parallel: int = PREFERRED_MODEL_PARALLEL,
                max_per_device_batch: int = MAX_PER_DEVICE_BATCH) -> RemeshPlan:
    """Plan a (data, model) mesh for ``n_devices`` preserving ``global_batch``.

    The model axis keeps the preferred tensor-parallel degree whenever it
    divides the device count, and otherwise halves until it does (1 always
    divides).  When the data degree divides the global batch the split is
    exact — ``per_device_batch * data * grad_accum == global_batch`` —
    with grad-accum absorbing an exact divisor so the live microbatch
    stays under ``max_per_device_batch``; otherwise the per-device batch
    rounds up, never down (a too-large batch changes the trajectory less
    than a silently shrunken one), split the same way under the cap.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    if global_batch < 1:
        raise ValueError(f"global_batch must be >= 1, got {global_batch}")
    model = max(1, model_parallel)
    while model > 1 and n_devices % model:
        model //= 2
    data = n_devices // model

    if global_batch % data == 0:
        per = global_batch // data
        # smallest accum that keeps the split exact AND under the live
        # microbatch cap (accum == per, i.e. microbatch 1, always works)
        accum = next(
            a for a in range(1, per + 1)
            if per % a == 0 and per // a <= max_per_device_batch
        )
        per //= accum
    else:
        per = -(-global_batch // data)  # ceil: round up, never shrink
        accum = -(-per // max_per_device_batch)
        per = -(-per // accum)
    return RemeshPlan((data, model), per, accum, global_batch)


def make_mesh(plan: RemeshPlan):
    """Concrete mesh for a plan (uses all planned devices)."""
    return jax.make_mesh(plan.mesh_shape, plan.axis_names)


def reshard(mesh, specs, tree):
    """Place ``tree`` (restored checkpoint state) onto ``mesh`` per ``specs``.

    Used after an elastic resize: the sanitized spec tree from
    ``dist.sharding`` is valid for any mesh it was sanitized against, so
    re-placement is one ``device_put`` per leaf.
    """
    return jax.tree.map(
        lambda spec, x: jax.device_put(x, NamedSharding(mesh, spec)),
        specs, tree, is_leaf=lambda x: isinstance(x, P),
    )
