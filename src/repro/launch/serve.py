"""Quantized serving launcher: quantize once -> save -> re-serve forever.

The end-to-end deployment path of the paper through the front-door API
(``repro.api``): load (or init) weights, PTQ them into a packed
:class:`~repro.api.QuantizedModel` artifact, optionally persist it, and
serve greedy generations through the selected weight backend.

  # quantize + serve (and keep the artifact for later)
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --r1 GSR --wakv W4A8 --save-artifact /tmp/smollm-w4a8

  # re-serve the saved artifact: no re-quantization, packed ints loaded
  PYTHONPATH=src python -m repro.launch.serve --artifact /tmp/smollm-w4a8 \
      --backend pallas
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.checkpoint import restore_checkpoint
from repro.models.registry import ARCH_IDS, get_arch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None, help="restore trained weights")
    ap.add_argument("--artifact", default=None,
                    help="serve a saved QuantizedModel dir (skips PTQ)")
    ap.add_argument("--save-artifact", default=None,
                    help="persist the quantized model to this dir")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"))
    ap.add_argument("--policy", default=None,
                    help="quantize under a declarative QuantPolicy: a "
                         "preset name (paper-table1 | w2-sensitive-fp4 | "
                         "gsr-over-spinquant), a JSON object, or a path "
                         "to a policy JSON; overrides --r1/--wakv/"
                         "--method/--group")
    ap.add_argument("--r1", default="GSR", choices=("I", "GH", "GW", "LH", "GSR"))
    ap.add_argument("--wakv", default="W4A16")
    ap.add_argument("--method", default="rtn", choices=("rtn", "gptq"))
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--continuous", action="store_true",
                    help="replay a synthetic mixed-length request trace "
                         "through the continuous-batching scheduler")
    ap.add_argument("--trace-requests", type=int, default=8)
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="KV pool block size (continuous mode)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share cached prompt-prefix KV blocks across "
                         "requests (continuous mode; token-identical)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common N-token prefix to every trace "
                         "prompt (continuous mode; exercises the prefix "
                         "cache)")
    ap.add_argument("--prefix-groups", type=int, default=1,
                    help="number of distinct shared prefixes, assigned "
                         "round-robin")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-drafted speculative decoding: derive a "
                         "harsher draft from the same artifact "
                         "(api.derive_draft) and run draft-k/verify-1 "
                         "over the shared paged pool (greedy output is "
                         "token-identical)")
    ap.add_argument("--draft-policy", default="draft-w2-rtn",
                    help="draft overlay policy for --spec-decode (preset "
                         "name / JSON / path; weight-only, layer-uniform)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens per verify step (--spec-decode)")
    ap.add_argument("--trace-out", default=None,
                    help="enable observability and write the request trace "
                         "here (.jsonl = JSON-lines, else a Chrome-trace "
                         "file for chrome://tracing / Perfetto)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable observability and write the metrics "
                         "registry here (.json = JSON document, else "
                         "Prometheus text format)")
    ap.add_argument("--inject-faults", default=None,
                    help="deterministic fault plan (JSON object, or @path "
                         "to one): nan_logits/callback_raise/draft_fail/"
                         "leak_block/corrupt_prefix/clock_stall; surviving "
                         "requests stay bit-identical to the clean run")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission backpressure: reject submissions once "
                         "this many requests are waiting")
    args = ap.parse_args()

    if args.artifact:
        qm = api.load_quantized(args.artifact, backend=args.backend)
        print(f"[serve] loaded artifact {args.artifact}: {qm.config.name} "
              f"({qm.policy.describe()}, "
              f"{qm.packed_bytes()/2**20:.2f} MiB packed)")
        if args.save_artifact:  # re-export the loaded copy
            path = qm.save(args.save_artifact)
            print(f"[serve] artifact re-saved to {path}")
    else:
        arch = get_arch(args.arch, reduced=args.reduced)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        if args.ckpt_dir:
            restored, step = restore_checkpoint(
                args.ckpt_dir, {"params": params, "opt": None, "err": {}})
            params = restored["params"]
            print(f"[serve] restored weights from step {step}")

        if args.policy:
            ptq = api.get_policy(args.policy)
        else:
            ptq = api.PTQConfig(r1_kind=args.r1, wakv=args.wakv,
                                method=args.method, group=args.group)
        qm = api.quantize(arch, params, ptq)
        print(f"[serve] PTQ done: {qm.policy.describe()} "
              f"({qm.packed_bytes()/2**20:.2f} MiB packed)")
        if args.save_artifact:
            path = qm.save(args.save_artifact)
            print(f"[serve] artifact saved to {path}")

    cfg = qm.config
    draft = None
    if args.spec_decode:
        draft = api.derive_draft(qm, args.draft_policy)
        print(f"[serve] spec decode: draft {draft.policy.name} "
              f"({draft.packed_bytes()/2**20:.2f} MiB packed), "
              f"k={args.draft_k}")
    obs_cfg = api.ObsConfig(
        enabled=bool(args.trace_out or args.metrics_out))
    faults = (api.FaultPlan.from_json(args.inject_faults)
              if args.inject_faults else None)
    if faults is not None:
        print(f"[serve] fault plan armed: {faults.to_json()}")
    eng = qm.serve(api.ServeConfig(
        max_seq=args.max_seq, batch_slots=args.prompts,
        temperature=args.temperature, block_tokens=args.block_tokens,
        prefix_cache=args.prefix_cache, spec_decode=args.spec_decode,
        draft_k=args.draft_k, obs=obs_cfg, faults=faults,
        max_queue=args.max_queue, health_every_syncs=8),
        backend=args.backend, draft=draft)
    if args.continuous:
        from repro.serve.scheduler import run_continuous_trace

        run_continuous_trace(eng, n_requests=args.trace_requests,
                             prompt_len=args.prompt_len,
                             max_new=args.max_new,
                             shared_prefix_tokens=args.shared_prefix,
                             n_prefix_groups=args.prefix_groups)
        _export_obs(eng, args)
        return
    rng = np.random.default_rng(0)
    if cfg.modality == "audio":
        prompts = rng.integers(0, cfg.vocab,
                               size=(args.prompts, args.prompt_len, cfg.n_codebooks))
    else:
        prompts = rng.integers(0, cfg.vocab, size=(args.prompts, args.prompt_len))
    pe = None
    if cfg.modality == "vlm":
        pe = rng.normal(size=(args.prompts, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
    out = eng.generate(prompts.astype(np.int32), args.max_new, patch_embeds=pe)
    print(f"[serve] backend={args.backend}: generated {out['tokens'].shape} "
          f"tokens; final cache length {out['final_length']}")
    print(out["tokens"][:2])
    _export_obs(eng, args)


def _export_obs(eng, args) -> None:
    if args.trace_out:
        print(f"[serve] trace written to "
              f"{eng.obs.export_trace(args.trace_out)}")
    if args.metrics_out:
        print(f"[serve] metrics written to "
              f"{eng.obs.export_metrics(args.metrics_out)}")


if __name__ == "__main__":
    main()
