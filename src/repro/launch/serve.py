"""Quantized serving launcher: PTQ a model, then serve batched requests.

The end-to-end deployment path of the paper: load (or train) weights,
run the GSR + GPTQ/RTN PTQ pipeline, and serve greedy generations from
the quantized model.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --r1 GSR --wakv W4A8 --prompts 4 --max-new 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore_checkpoint
from repro.models.registry import ARCH_IDS, get_arch
from repro.quant.pipeline import PTQConfig, quantize_model
from repro.serve.engine import ServeConfig, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None, help="restore trained weights")
    ap.add_argument("--r1", default="GSR", choices=("I", "GH", "GW", "LH", "GSR"))
    ap.add_argument("--wakv", default="W4A16")
    ap.add_argument("--method", default="rtn", choices=("rtn", "gptq"))
    ap.add_argument("--group", type=int, default=32)
    ap.add_argument("--prompts", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    arch = get_arch(args.arch, reduced=args.reduced)
    cfg = arch.config
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    if args.ckpt_dir:
        state_tpl = {"params": params}
        restored, step = restore_checkpoint(args.ckpt_dir, {"params": params, "opt": None, "err": {}})
        params = restored["params"]
        print(f"[serve] restored weights from step {step}")

    ptq = PTQConfig(r1_kind=args.r1, wakv=args.wakv, method=args.method,
                    group=args.group)
    qparams, spec = quantize_model(arch, params, ptq)
    print(f"[serve] PTQ done: R1={args.r1} {args.wakv} via {args.method}")

    eng = ServeEngine(arch, qparams, ServeConfig(
        max_seq=args.max_seq, batch_slots=args.prompts,
        temperature=args.temperature), spec)
    rng = np.random.default_rng(0)
    if cfg.modality == "audio":
        prompts = rng.integers(0, cfg.vocab,
                               size=(args.prompts, args.prompt_len, cfg.n_codebooks))
    else:
        prompts = rng.integers(0, cfg.vocab, size=(args.prompts, args.prompt_len))
    pe = None
    if cfg.modality == "vlm":
        pe = rng.normal(size=(args.prompts, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
    out = eng.generate(prompts.astype(np.int32), args.max_new, patch_embeds=pe)
    print(f"[serve] generated {out['tokens'].shape} tokens; "
          f"final cache length {out['final_length']}")
    print(out["tokens"][:2])


if __name__ == "__main__":
    main()
