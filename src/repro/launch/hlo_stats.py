"""Post-SPMD HLO statistics: collective bytes per op class.

``cost_analysis`` has no collective accounting, so the roofline's third
term is derived here by parsing the compiled (per-device SPMD) HLO text
and summing result-shape bytes of every collective.  Wire-cost factors
follow the standard ring models: all-reduce moves ~2x its payload,
all-gather / reduce-scatter / all-to-all / permute ~1x.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_WIRE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "ragged-all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|ragged-all-to-all|"
    r"collective-permute)(?:-start)?\("
)


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str, *, body_multiplier: int = 1) -> Dict[str, Dict[str, float]]:
    """{op: {count, bytes, wire_bytes}} from per-device optimized HLO.

    Collectives inside non-ENTRY computations (scan/while bodies - in this
    framework, the layer scan) execute once per layer: their bytes are
    multiplied by ``body_multiplier`` (pass the scan length).  This is the
    accounting used consistently across all roofline comparisons.
    """
    out = {op: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0} for op in _COLLECTIVES}
    in_entry = False
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.startswith("ENTRY"):
            in_entry = True
        elif stripped.endswith("{") and ("(" in stripped) and not line.startswith(" "):
            in_entry = False
        m = _LINE_RE.search(line)
        if not m:
            continue
        shape_str, op = m.group(1), m.group(2)
        mult = 1 if in_entry else body_multiplier
        b = _shape_bytes(shape_str) * mult
        out[op]["count"] += mult
        out[op]["bytes"] += b
        out[op]["wire_bytes"] += b * _WIRE_FACTOR[op]
    return out


def total_wire_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["wire_bytes"] for v in stats.values())
