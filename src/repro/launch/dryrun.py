import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the
production meshes are built from 512 placeholder host devices (the
XLA_FLAGS line above MUST precede any jax import), every step function is
jit-lowered with explicit in_shardings, compiled, and its
``memory_analysis`` / ``cost_analysis`` / per-device HLO collective bytes
are recorded to JSON for the roofline analysis (EXPERIMENTS.md §Dry-run /
§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k [--multi-pod] [--out results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
Optimized-variant flags (§Perf hillclimbing):
  --wbits {16,8,4,2}   packed weight storage for serve cells
  --kvbits {16,8,4}    quantized KV cache for decode cells
  --moment-dtype bf16  optimizer moments in bf16 (train cells)
  --no-fsdp / --fsdp   override the parameter-sharding heuristic
  --seq-shard          shard long-context activations over data axes
"""
import argparse
import json
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import SHAPES, cells_for
from repro.dist.sharding import batch_pspecs, cache_pspecs, param_pspecs, sanitize_pspecs
from repro.launch.hlo_stats import collective_stats, total_wire_bytes
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.common import QuantizeSpec
from repro.models.registry import ARCH_IDS, get_arch
from repro.train.optimizer import OptConfig, OptState, init_opt_state
from repro.train.train_step import make_train_step

DRYRUN_ARCHS = [a for a in ARCH_IDS if a != "llama2-7b"]  # 10 assigned archs


def _ns(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


def _fsdp_axes_for(total_params: int, dp, override: Optional[bool], kind: str,
                   scope: str = "auto"):
    if scope == "intra":
        # FSDP only within a pod (ICI); cross-pod (DCN) holds replicas and
        # sees one gradient all-reduce per step instead of per-microbatch
        # parameter gathers.
        dp = ("data",)
    if override is False:
        return None
    if override is True:
        return dp
    if kind != "train":
        # serving has no optimizer state: only llama4-class weights need
        # data-axis sharding (everything else fits via tensor parallelism)
        return dp if total_params > 50e9 else None
    if total_params > 100e9:
        return dp  # must shard over every data axis (llama4-class)
    if total_params > 3e9:
        return ("data",)
    return None


def _auto_microbatches(cfg, shape, dp_total: int, budget: int = 2 << 30) -> int:
    """Split the batch so the per-device layer-boundary residuals
    (saved by scan-over-layers remat) stay under ~2 GiB."""
    per_dev = max(shape.global_batch // dp_total, 1)
    carry = cfg.n_layers * per_dev * shape.seq_len * cfg.d_model * 2
    mb = 1
    while (
        carry // mb > budget
        and shape.global_batch % (mb * 2) == 0
        and (shape.global_batch // (mb * 2)) % dp_total == 0
    ):
        mb *= 2
    return mb


def pick_moe_ep_default(moe_ep: Dict) -> str:
    """Data-driven default for the MoE expert-FFN schedule in one cell.

    The explicit shard_map EP path becomes the default exactly where the
    recorded per-layer HLO collective bytes show it beating the GSPMD
    einsum schedule; cells where it is infeasible (recorded as an error)
    or not cheaper keep the gspmd path (closes the ROADMAP open item —
    the measurement half landed with the ``moe_ep`` records).
    """
    exp = moe_ep.get("explicit_ep", {})
    gsp = moe_ep.get("gspmd_einsum", {})
    if "wire_bytes_per_layer" not in exp or "wire_bytes_per_layer" not in gsp:
        return "gspmd"
    return ("explicit"
            if exp["wire_bytes_per_layer"] < gsp["wire_bytes_per_layer"]
            else "gspmd")


def lower_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    **kw,
) -> Dict:
    """Lower + compile one cell; returns the record dict.

    MoE cells first record the explicit-EP vs GSPMD collective-byte
    comparison (``moe_ep``) and then lower with whichever expert-FFN
    schedule the measurement favours (``moe_ep.default_path``)."""
    from repro.models import moe as moe_mod

    cfg = get_arch(arch_name).config
    moe_ep = None
    impl = "gspmd"
    if cfg.family == "moe":
        mesh = make_production_mesh(multi_pod=multi_pod)
        shape = SHAPES[shape_name]
        try:
            moe_ep = moe_ep_collectives(cfg, mesh, shape)
        except Exception as e:  # noqa: BLE001 - keep the cell record alive
            moe_ep = {"error": repr(e)}
        impl = pick_moe_ep_default(moe_ep)
        moe_ep["default_path"] = impl
    with moe_mod.moe_ep_impl(impl):
        return _lower_cell(arch_name, shape_name, multi_pod=multi_pod,
                           moe_ep=moe_ep, **kw)


def _lower_cell(
    arch_name: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    wbits: int = 16,
    kvbits: int = 16,
    moment_dtype: Optional[str] = None,
    fsdp: Optional[bool] = None,
    fsdp_scope: str = "auto",
    seq_shard: bool = False,
    moe_ep: Optional[Dict] = None,
) -> Dict:
    arch = get_arch(arch_name)
    cfg = arch.config
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    dp = dp_axes_of(mesh)
    total, active = cfg.param_count()
    spec = QuantizeSpec(kv_bits=kvbits)

    rec = {
        "arch": arch_name,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "params_total": total,
        "params_active": active,
        "wbits": wbits,
        "kvbits": kvbits,
    }
    if moe_ep is not None:
        rec["moe_ep"] = moe_ep

    t0 = time.time()
    params_sds = arch.param_specs(dtype=jnp.bfloat16)
    fsdp_axes = _fsdp_axes_for(total, dp, fsdp, shape.kind, scope=fsdp_scope)
    fsdp_size = int(np.prod([mesh.shape[a] for a in fsdp_axes])) if fsdp_axes else 16
    pspec = sanitize_pspecs(
        mesh,
        param_pspecs(cfg, params_sds, fsdp_axes=fsdp_axes, fsdp_size=fsdp_size),
        params_sds,
    )
    rec["fsdp_axes"] = list(fsdp_axes) if fsdp_axes else None

    if shape.kind == "train":
        mdt = moment_dtype or ("bfloat16" if total > 100e9 else "float32")
        opt_cfg = OptConfig(moment_dtype=mdt)
        rec["moment_dtype"] = mdt
        opt_sds = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_sds)
        batch_sds = arch.input_specs(shape)
        bspec = sanitize_pspecs(mesh, batch_pspecs(cfg, batch_sds, dp), batch_sds)
        ospec = OptState(step=P(), mu=pspec, nu=pspec)
        dp_total = int(np.prod([mesh.shape[a] for a in dp]))
        mb = _auto_microbatches(cfg, shape, dp_total)
        rec["microbatches"] = mb
        step = make_train_step(arch, opt_cfg, QuantizeSpec(), microbatches=mb)
        fn = jax.jit(
            step,
            in_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), {}, _ns(mesh, bspec)),
            out_shardings=(_ns(mesh, pspec), _ns(mesh, ospec), {},
                           jax.tree.map(lambda _: NamedSharding(mesh, P()), 
                                        {"grad_norm": 0, "lr": 0, "loss": 0, "skipped": 0})),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = fn.lower(params_sds, opt_sds, {}, batch_sds)
        n_tokens = shape.global_batch * shape.seq_len
        rec["model_flops"] = 6.0 * active * n_tokens
    else:
        if wbits < 16:
            # packed-weight serving: not lowered through the bf16 model; the
            # quantized-serve variant is handled by serve_quant step below.
            return lower_quant_serve_cell(arch, shape, mesh, rec, wbits, kvbits,
                                          seq_shard)
        long_ctx = shape.seq_len > 100_000
        shard_batch = not long_ctx
        # vlm caches also hold the vision prefix
        max_seq = shape.seq_len + (cfg.n_patches if cfg.modality == "vlm" else 0)
        cache_sds = arch.cache_specs(shape.global_batch, max_seq, spec)
        cspec = sanitize_pspecs(
            mesh, cache_pspecs(cfg, cache_sds, dp, shard_batch=shard_batch, model_size=mesh.shape['model']), cache_sds
        )
        if shape.kind == "prefill":
            batch_sds = arch.input_specs(shape)
            bspec = sanitize_pspecs(
                mesh, batch_pspecs(cfg, batch_sds, dp, shard_seq=long_ctx or seq_shard),
                batch_sds,
            )
            fn = jax.jit(
                lambda p, b, c: arch.prefill(p, b, c, spec),
                in_shardings=(_ns(mesh, pspec), _ns(mesh, bspec), _ns(mesh, cspec)),
                out_shardings=(NamedSharding(mesh, P()), _ns(mesh, cspec)),
                donate_argnums=(2,),
            )
            with mesh:
                lowered = fn.lower(params_sds, batch_sds, cache_sds)
            rec["model_flops"] = 2.0 * active * shape.global_batch * shape.seq_len
        else:  # decode
            tok_sds = arch.input_specs(shape)
            tspec = (
                jax.tree.map(lambda x: P(), tok_sds)
                if long_ctx
                else sanitize_pspecs(mesh, batch_pspecs(cfg, tok_sds, dp), tok_sds)
            )
            fn = jax.jit(
                lambda p, t, c: arch.decode(p, t["tokens"], c, spec),
                in_shardings=(_ns(mesh, pspec), _ns(mesh, tspec), _ns(mesh, cspec)),
                out_shardings=(NamedSharding(mesh, P()), _ns(mesh, cspec)),
                donate_argnums=(2,),
            )
            with mesh:
                lowered = fn.lower(params_sds, tok_sds, cache_sds)
            rec["model_flops"] = 2.0 * active * shape.global_batch
    rec["lower_s"] = round(time.time() - t0, 2)

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)

    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_device_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per program
        ca = ca[0] if ca else {}
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    hlo = compiled.as_text()
    colls = collective_stats(hlo, body_multiplier=cfg.n_layers)
    rec["collectives"] = colls
    rec["collective_wire_bytes"] = total_wire_bytes(colls)
    rec["hlo_bytes"] = len(hlo)
    return rec


def moe_ep_collectives(cfg, mesh, shape) -> Dict:
    """Collective-byte comparison for the MoE expert FFN: the explicit
    ``dist.collectives.expert_ffn_ep`` shard_map schedule vs the GSPMD
    einsum path ``moe_apply`` uses today (ROADMAP open item, measurement
    half: the default-path switch should be data-driven).

    Both variants consume and return the dispatch buffer in the token-side
    layout (batch over the data axes, experts unsharded), so each graph
    carries its *own* resharding cost: the explicit path's batch-spread
    over the model axis + two all-to-alls, vs whatever the partitioner
    infers around the pinned ``P(dp, "model", ...)`` einsums.  Bytes are
    per MoE-layer application; multiply by ``n_moe_layers`` (recorded) for
    the per-step total.
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist.collectives import expert_ffn_ep
    from repro.models.moe import capacity

    dp = dp_axes_of(mesh)
    dp_entry = dp if len(dp) > 1 else dp[0]
    e, d = cfg.n_experts, cfg.d_model
    de = cfg.d_expert or cfg.d_ff
    s = 1 if shape.kind == "decode" else min(shape.seq_len, 4096)
    cap = capacity(cfg, s)
    xe_sds = jax.ShapeDtypeStruct((shape.global_batch, e, cap, d), jnp.bfloat16)
    wcol_sds = jax.ShapeDtypeStruct((e, d, de), jnp.bfloat16)
    wrow_sds = jax.ShapeDtypeStruct((e, de, d), jnp.bfloat16)

    tok_spec = sanitize_pspecs(mesh, P(dp_entry, None, None, None), xe_sds)
    full_spec = sanitize_pspecs(mesh, P(tuple(dp) + ("model",), None, None, None),
                                xe_sds)
    pin_spec = sanitize_pspecs(mesh, P(dp_entry, "model", None, None), xe_sds)
    w_spec = sanitize_pspecs(mesh, P("model", None, None), wcol_sds)

    def explicit(xe, wg, wu, wd):
        xe = jax.lax.with_sharding_constraint(xe, full_spec)
        ye = expert_ffn_ep(xe, wg, wu, wd, mesh, data_axes=dp)
        return jax.lax.with_sharding_constraint(ye, tok_spec)

    def gspmd(xe, wg, wu, wd):
        xe = jax.lax.with_sharding_constraint(xe, pin_spec)
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, wg)) * jnp.einsum(
            "becd,edf->becf", xe, wu)
        ye = jnp.einsum("becf,efd->becd", h, wd)
        ye = jax.lax.with_sharding_constraint(ye, pin_spec)
        return jax.lax.with_sharding_constraint(ye, tok_spec)

    out = {
        "dispatch_shape": list(xe_sds.shape),
        "n_moe_layers": cfg.n_layers // max(1, cfg.moe_every),
    }
    for name, fn in (("explicit_ep", explicit), ("gspmd_einsum", gspmd)):
        # A variant can be infeasible for this cell's dispatch layout (e.g.
        # batch not divisible by data x model for the shard_map spread) —
        # that infeasibility is itself the record: the default path cannot
        # switch for this cell.
        try:
            jf = jax.jit(
                fn,
                in_shardings=(_ns(mesh, tok_spec), _ns(mesh, w_spec),
                              _ns(mesh, w_spec), _ns(mesh, w_spec)),
                out_shardings=_ns(mesh, tok_spec),
            )
            with mesh:
                hlo = jf.lower(xe_sds, wcol_sds, wcol_sds,
                               wrow_sds).compile().as_text()
            colls = collective_stats(hlo)
            out[name] = {
                "collectives": {k: v for k, v in colls.items() if v["count"]},
                "wire_bytes_per_layer": total_wire_bytes(colls),
            }
        except Exception as e:  # noqa: BLE001
            out[name] = {"error": repr(e)}
    return out


def lower_quant_serve_cell(arch, shape, mesh, rec, wbits, kvbits, seq_shard):
    """Optimized decode variant: packed int weights streamed by dequant.

    Weight tensors are stored packed (uint8 codes + grouped scales), cutting
    the dominant HBM term of memory-bound decode by 16/wbits.  Lowered via a
    quantized-param model wrapper (dequant-on-use; on TPU the fused Pallas
    dequant-matmul streams the packed bytes directly).
    """
    from repro.launch.quant_serve import lower_quant_decode

    return lower_quant_decode(arch, shape, mesh, rec, wbits, kvbits)


def run_cells(args) -> int:
    os.makedirs(args.out, exist_ok=True)
    if args.all:
        jobs = []
        for a in DRYRUN_ARCHS:
            for s in cells_for(get_arch(a).config):
                jobs.append((a, s))
    else:
        jobs = [(args.arch, args.shape)]
    meshes = [False, True] if args.all else ([True] if args.multi_pod else [False])

    failures = 0
    for a, s in jobs:
        for mp in meshes:
            tag = f"{a}__{s}__{'pod2' if mp else 'pod1'}"
            if args.wbits < 16:
                tag += f"__w{args.wbits}"
            if args.kvbits < 16:
                tag += f"__kv{args.kvbits}"
            if args.fsdp_scope != "auto":
                tag += f"__fsdp-{args.fsdp_scope}"
            out_path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(out_path):
                print(f"[dryrun] skip {tag} (exists)")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = lower_cell(
                    a, s, multi_pod=mp, wbits=args.wbits, kvbits=args.kvbits,
                    moment_dtype=args.moment_dtype, fsdp=args.fsdp,
                    fsdp_scope=args.fsdp_scope, seq_shard=args.seq_shard,
                )
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(
                    f"[dryrun] {tag}: compile={rec['compile_s']}s "
                    f"peak={rec['memory']['peak_device_bytes']/2**30:.2f}GiB "
                    f"flops={rec['cost']['flops']:.3e} "
                    f"coll={rec['collective_wire_bytes']/2**20:.1f}MiB",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 - record and continue
                failures += 1
                with open(out_path + ".err", "w") as f:
                    f.write(traceback.format_exc())
                print(f"[dryrun] {tag} FAILED: {e}", flush=True)
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="every (arch x shape x mesh)")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--wbits", type=int, default=16, choices=(2, 4, 8, 16))
    ap.add_argument("--kvbits", type=int, default=16, choices=(4, 8, 16))
    ap.add_argument("--moment-dtype", default=None, choices=(None, "float32", "bfloat16"))
    ap.add_argument("--fsdp", default=None, action=argparse.BooleanOptionalAction)
    ap.add_argument("--fsdp-scope", default="auto", choices=("auto", "intra"))
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()
    failures = run_cells(args)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")
    print("[dryrun] all requested cells compiled OK")


if __name__ == "__main__":
    main()
