"""Quantized-serving launcher: packed low-bit weights on the decode path.

The paper's deployment story: after GSR rotation + GPTQ, weights live in
HBM as packed uint8 codes (4x-8x fewer bytes than bf16) with per-group
scales/zeros.  Decode is memory-roofline-bound on weight streaming, so
this is the dominant-term lever for the decode cells (§Perf).

Both entry points consume the *artifact* representation — params trees
whose quantized leaves are :class:`repro.quant.packed.PackedWeight` —
never ad-hoc inline quantization:

  * :func:`lower_quant_decode` (called by ``launch.dryrun`` for the
    ``--wbits`` cells) builds the packed ShapeDtypeStruct tree for a
    production config and lowers ``arch.decode`` *directly on the packed
    params*: the PackedWeight dispatch dequantizes on use, proving
    sharding + compile of the packed tensors at mesh scale.  On real TPU
    the ``backend="pallas"`` dispatch streams the packed bytes through
    the fused ``dequant_matmul`` kernel instead of materialising bf16
    weights; the roofline memory term for quantized decode is computed
    from ``argument_bytes`` (weights + cache actually resident in HBM).

  * ``main()`` serves a *saved* :class:`repro.api.QuantizedModel`
    artifact — ``python -m repro.launch.quant_serve --artifact DIR`` —
    with the weight backend selectable per launch and no requantization
    anywhere on the path.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.dist.sharding import batch_pspecs, cache_pspecs, param_pspecs, sanitize_pspecs
from repro.launch.hlo_stats import collective_stats, total_wire_bytes
from repro.launch.mesh import dp_axes_of
from repro.models.common import QuantizeSpec
from repro.quant.packed import PackedWeight, dequantize_tree, is_packed
from repro.quant.pack import codes_per_byte, packable
from repro.quant.pipeline import _FAMILY_WEIGHTS, fit_group


def _quantizable(path_keys, leaf, names) -> bool:
    return path_keys[-1] in names and getattr(leaf, "ndim", 0) >= 2 and (
        not path_keys[-1].startswith("b")
    )


def quant_param_specs(cfg, params_sds, wbits: int, group: int = 128,
                      backend: str = "reference"):
    """Replace quantizable leaves with PackedWeight ShapeDtypeStruct nodes
    — the artifact layout ``repro.api.quantize`` produces for this config."""
    names = _FAMILY_WEIGHTS[cfg.family]
    pb = codes_per_byte(wbits)

    def visit(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if not _quantizable(keys, leaf, names):
            return leaf
        *lead, c, h = leaf.shape
        if not packable(wbits, c):
            return leaf  # unpackable channel count: keep bf16
        g = fit_group(c, group)
        return PackedWeight(
            codes=jax.ShapeDtypeStruct((*lead, c // pb, h), jnp.uint8),
            scale=jax.ShapeDtypeStruct((*lead, c // g, h), jnp.float32),
            zero=jax.ShapeDtypeStruct((*lead, c // g, h), jnp.float32),
            bits=wbits, group=g, c=c, dtype=str(np.dtype(leaf.dtype)),
            packed=True, backend=backend,
        )

    return jax.tree_util.tree_map_with_path(visit, params_sds)


def dequant_params(qparams, dtype=jnp.bfloat16):
    """Materialize every packed leaf (dequant-on-use reference path)."""
    return dequantize_tree(qparams, dtype)


def quant_param_pspecs(cfg, params_sds, qparams_sds, fsdp_axes=None):
    """Specs for the packed tree: ``dist.sharding.param_pspecs`` mirrors
    each logical weight's spec onto its codes/scale/zero children.
    (``params_sds`` is retained for signature compatibility.)"""
    del params_sds
    return param_pspecs(cfg, qparams_sds, fsdp_axes=fsdp_axes)


def lower_quant_decode(arch, shape: ShapeConfig, mesh, rec: Dict, wbits: int,
                       kvbits: int) -> Dict:
    cfg = arch.config
    dp = dp_axes_of(mesh)
    spec = QuantizeSpec(kv_bits=kvbits)
    long_ctx = shape.seq_len > 100_000

    t0 = time.time()
    params_sds = arch.param_specs(dtype=jnp.bfloat16)
    qsds = quant_param_specs(cfg, params_sds, wbits)

    max_seq = shape.seq_len + (cfg.n_patches if cfg.modality == "vlm" else 0)
    cache_sds = arch.cache_specs(shape.global_batch, max_seq, spec)
    cspec = sanitize_pspecs(
        mesh, cache_pspecs(cfg, cache_sds, dp, shard_batch=not long_ctx, model_size=mesh.shape['model']), cache_sds
    )
    pspec_q = sanitize_pspecs(mesh, param_pspecs(cfg, qsds), qsds)
    tok_sds = arch.input_specs(shape)
    tspec = (
        jax.tree.map(lambda x: P(), tok_sds)
        if long_ctx
        else sanitize_pspecs(mesh, batch_pspecs(cfg, tok_sds, dp), tok_sds)
    )

    def decode_fn(qp, toks, cache):
        # Packed params execute directly: the PackedWeight leaves
        # dequantize at their use sites inside the scanned layer body.
        return arch.decode(qp, toks["tokens"], cache, spec)

    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    fn = jax.jit(
        decode_fn,
        in_shardings=(ns(pspec_q), ns(tspec), ns(cspec)),
        out_shardings=(NamedSharding(mesh, P()), ns(cspec)),
        donate_argnums=(2,),
    )
    with mesh:
        lowered = fn.lower(qsds, tok_sds, cache_sds)
    rec["lower_s"] = round(time.time() - t0, 2)
    rec["model_flops"] = 2.0 * rec["params_active"] * shape.global_batch

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_device_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per program
        ca = ca[0] if ca else {}
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    hlo = compiled.as_text()
    colls = collective_stats(hlo, body_multiplier=cfg.n_layers)
    rec["collectives"] = colls
    rec["collective_wire_bytes"] = total_wire_bytes(colls)
    rec["hlo_bytes"] = len(hlo)
    return rec


# ---------------------------------------------------------------------------
# Artifact serving entry point
# ---------------------------------------------------------------------------


def main():
    import argparse

    from repro import api

    from repro.models.registry import ARCH_IDS, get_arch

    ap = argparse.ArgumentParser(
        description="Serve a saved QuantizedModel artifact (no requantization)."
    )
    ap.add_argument("--artifact", default=None, help="QuantizedModel.save dir")
    ap.add_argument("--policy", default=None,
                    help="no --artifact: quantize --arch under this "
                         "QuantPolicy (preset name / JSON / path — per-site "
                         "weight, rotation, and activation rules) and serve "
                         "the result")
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--save-artifact", default=None,
                    help="persist the (policy-)quantized model to this dir")
    ap.add_argument("--backend", default="reference",
                    choices=("reference", "pallas"))
    ap.add_argument("--prompts", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--continuous", action="store_true",
                    help="replay a synthetic mixed-length request trace "
                         "through the continuous-batching scheduler")
    ap.add_argument("--trace-requests", type=int, default=8)
    ap.add_argument("--block-tokens", type=int, default=16,
                    help="KV pool block size (continuous mode)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share cached prompt-prefix KV blocks across "
                         "requests (continuous mode; token-identical)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend a common N-token prefix to every trace "
                         "prompt (continuous mode; exercises the prefix "
                         "cache)")
    ap.add_argument("--prefix-groups", type=int, default=1,
                    help="number of distinct shared prefixes, assigned "
                         "round-robin")
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-drafted speculative decoding: derive a "
                         "harsher draft from the same artifact "
                         "(api.derive_draft) and run draft-k/verify-1 "
                         "over the shared paged pool (greedy output is "
                         "token-identical)")
    ap.add_argument("--draft-policy", default="draft-w2-rtn",
                    help="draft overlay policy for --spec-decode (preset "
                         "name / JSON / path; weight-only, layer-uniform)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="draft tokens per verify step (--spec-decode)")
    ap.add_argument("--trace-out", default=None,
                    help="enable observability and write the request trace "
                         "here (.jsonl = JSON-lines, else a Chrome-trace "
                         "file for chrome://tracing / Perfetto)")
    ap.add_argument("--metrics-out", default=None,
                    help="enable observability and write the metrics "
                         "registry here (.json = JSON document, else "
                         "Prometheus text format)")
    ap.add_argument("--inject-faults", default=None,
                    help="deterministic fault plan (JSON object, or @path "
                         "to one): nan_logits/callback_raise/draft_fail/"
                         "leak_block/corrupt_prefix/clock_stall; surviving "
                         "requests stay bit-identical to the clean run")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission backpressure: reject submissions once "
                         "this many requests are waiting")
    args = ap.parse_args()

    if args.artifact:
        qm = api.load_quantized(args.artifact, backend=args.backend)
        cfg = qm.config
        n_packed = sum(1 for l in jax.tree.leaves(qm.params, is_leaf=is_packed)
                       if is_packed(l))
        print(f"[quant_serve] loaded {cfg.name}: {n_packed} packed weight "
              f"stacks, {qm.packed_bytes()/2**20:.2f} MiB packed "
              f"({qm.policy.describe()})")
    elif args.policy:
        arch = get_arch(args.arch, reduced=args.reduced)
        params = arch.init(jax.random.PRNGKey(0), jnp.float32)
        qm = api.quantize(arch, params, api.get_policy(args.policy))
        cfg = qm.config
        print(f"[quant_serve] PTQ done: {qm.policy.describe()} "
              f"({qm.packed_bytes()/2**20:.2f} MiB packed)")
    else:
        ap.error("one of --artifact or --policy is required")
    if args.save_artifact:
        path = qm.save(args.save_artifact)
        print(f"[quant_serve] artifact saved to {path}")

    draft = None
    if args.spec_decode:
        draft = api.derive_draft(qm, args.draft_policy)
        print(f"[quant_serve] spec decode: draft {draft.policy.name} "
              f"({draft.packed_bytes()/2**20:.2f} MiB packed), "
              f"k={args.draft_k}")
    obs_cfg = api.ObsConfig(
        enabled=bool(args.trace_out or args.metrics_out))
    faults = (api.FaultPlan.from_json(args.inject_faults)
              if args.inject_faults else None)
    if faults is not None:
        print(f"[quant_serve] fault plan armed: {faults.to_json()}")
    eng = qm.serve(api.ServeConfig(max_seq=args.max_seq,
                                   batch_slots=args.prompts,
                                   block_tokens=args.block_tokens,
                                   prefix_cache=args.prefix_cache,
                                   spec_decode=args.spec_decode,
                                   draft_k=args.draft_k,
                                   obs=obs_cfg, faults=faults,
                                   max_queue=args.max_queue,
                                   health_every_syncs=8),
                   backend=args.backend, draft=draft)
    if args.continuous:
        from repro.serve.scheduler import run_continuous_trace

        run_continuous_trace(eng, n_requests=args.trace_requests,
                             prompt_len=args.prompt_len,
                             max_new=args.max_new,
                             shared_prefix_tokens=args.shared_prefix,
                             n_prefix_groups=args.prefix_groups)
        _export_obs(eng, args)
        return
    rng = np.random.default_rng(0)
    if cfg.modality == "audio":
        prompts = rng.integers(0, cfg.vocab,
                               size=(args.prompts, args.prompt_len, cfg.n_codebooks))
    else:
        prompts = rng.integers(0, cfg.vocab, size=(args.prompts, args.prompt_len))
    pe = None
    if cfg.modality == "vlm":
        pe = rng.normal(size=(args.prompts, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
    t0 = time.time()
    out = eng.generate(prompts.astype(np.int32), args.max_new, patch_embeds=pe)
    dt = time.time() - t0
    print(f"[quant_serve] backend={args.backend}: generated "
          f"{out['tokens'].shape} tokens in {dt:.2f}s "
          f"({args.prompts * args.max_new / dt:.1f} tok/s)")
    print(out["tokens"][:2])
    _export_obs(eng, args)


def _export_obs(eng, args) -> None:
    if args.trace_out:
        print(f"[quant_serve] trace written to "
              f"{eng.obs.export_trace(args.trace_out)}")
    if args.metrics_out:
        print(f"[quant_serve] metrics written to "
              f"{eng.obs.export_metrics(args.metrics_out)}")


if __name__ == "__main__":
    main()
