"""Quantized-serving dry-run: packed low-bit weights on the decode path.

The paper's deployment story: after GSR rotation + GPTQ, weights live in
HBM as packed uint8 codes (4x-8x fewer bytes than bf16) with per-group
scales/zeros.  Decode is memory-roofline-bound on weight streaming, so
this is the dominant-term lever for the decode cells (§Perf).

Here the packed representation is lowered through a dequant-on-use wrapper
(proving sharding + compile of the packed tensors at mesh scale); on real
TPU the fused Pallas ``dequant_matmul`` kernel streams the packed bytes
without materialising bf16 weights, so the roofline memory term for
quantized decode is computed from ``argument_bytes`` (weights + cache
actually resident in HBM), recorded alongside the HLO terms.
"""
from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.dist.sharding import batch_pspecs, cache_pspecs, param_pspecs, sanitize_pspecs
from repro.launch.hlo_stats import collective_stats, total_wire_bytes
from repro.launch.mesh import dp_axes_of
from repro.models.common import QuantizeSpec
from repro.quant.pipeline import _FAMILY_WEIGHTS, fit_group
from repro.quant.pack import codes_per_byte


def _quantizable(path_keys, leaf, names) -> bool:
    return path_keys[-1] in names and getattr(leaf, "ndim", 0) >= 2 and (
        not path_keys[-1].startswith("b")
    )


def quant_param_specs(cfg, params_sds, wbits: int, group: int = 128):
    """Replace quantizable leaves with {codes, scale, zero} SDS subtrees."""
    names = _FAMILY_WEIGHTS[cfg.family]
    pb = codes_per_byte(wbits)

    def visit(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        if not _quantizable(keys, leaf, names):
            return leaf
        *lead, c, h = leaf.shape
        g = fit_group(c, group)
        if c % pb:
            return leaf  # unpackable channel count: keep bf16
        return {
            "codes": jax.ShapeDtypeStruct((*lead, c // pb, h), jnp.uint8),
            "scale": jax.ShapeDtypeStruct((*lead, c // g, h), jnp.float32),
            "zero": jax.ShapeDtypeStruct((*lead, c // g, h), jnp.float32),
            "__meta__": (wbits, g, c),
        }

    return jax.tree_util.tree_map_with_path(visit, params_sds)


def dequant_leaf(q: Dict, dtype=jnp.bfloat16) -> jax.Array:
    """Unpack + dequantize a packed leaf (any leading stack dims)."""
    wbits, g, c = q["__meta__"]
    codes, scale, zero = q["codes"], q["scale"], q["zero"]
    pb = codes_per_byte(wbits)
    mask = (1 << wbits) - 1
    parts = [((codes >> (wbits * i)) & mask).astype(jnp.float32) for i in range(pb)]
    w = jnp.stack(parts, axis=-2)  # (..., C/pb, pb, H)
    w = w.reshape(*codes.shape[:-2], c, codes.shape[-1])
    ng = c // g
    wg = w.reshape(*codes.shape[:-2], ng, g, codes.shape[-1])
    wg = (wg - zero[..., :, None, :]) * scale[..., :, None, :]
    return wg.reshape(*codes.shape[:-2], c, codes.shape[-1]).astype(dtype)


def _is_qleaf(x) -> bool:
    return isinstance(x, dict) and "__meta__" in x


def dequant_params(qparams, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda x: dequant_leaf(x, dtype) if _is_qleaf(x) else x,
        qparams,
        is_leaf=lambda x: _is_qleaf(x) or not isinstance(x, dict),
    )


def quant_param_pspecs(cfg, params_sds, qparams_sds, fsdp_axes=None):
    """Mirror the bf16 param specs onto the packed representation."""
    base = param_pspecs(cfg, params_sds, fsdp_axes=fsdp_axes)

    def visit(spec, qleaf):
        if not _is_qleaf(qleaf):
            return spec
        nd = qleaf["codes"].ndim
        parts = list(spec) + [None] * (nd - len(spec))
        sub = P(*parts)
        return {"codes": sub, "scale": sub, "zero": sub, "__meta__": None}

    return jax.tree.map(
        visit, base, qparams_sds,
        is_leaf=lambda x: isinstance(x, P) or _is_qleaf(x),
    )


def lower_quant_decode(arch, shape: ShapeConfig, mesh, rec: Dict, wbits: int,
                       kvbits: int) -> Dict:
    cfg = arch.config
    dp = dp_axes_of(mesh)
    spec = QuantizeSpec(kv_bits=kvbits)
    long_ctx = shape.seq_len > 100_000

    t0 = time.time()
    params_sds = arch.param_specs(dtype=jnp.bfloat16)
    qparams_sds = quant_param_specs(cfg, params_sds, wbits)
    # strip __meta__ (static) from the SDS pytree passed to jit
    metas = {}

    def strip(path, x):
        if _is_qleaf(x):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            metas[key] = x["__meta__"]
            return {k: v for k, v in x.items() if k != "__meta__"}
        return x

    qsds = jax.tree_util.tree_map_with_path(
        strip, qparams_sds, is_leaf=lambda x: _is_qleaf(x) or not isinstance(x, dict)
    )

    max_seq = shape.seq_len + (cfg.n_patches if cfg.modality == "vlm" else 0)
    cache_sds = arch.cache_specs(shape.global_batch, max_seq, spec)
    cspec = sanitize_pspecs(
        mesh, cache_pspecs(cfg, cache_sds, dp, shard_batch=not long_ctx, model_size=mesh.shape['model']), cache_sds
    )
    pspec_q = quant_param_pspecs(cfg, params_sds, qparams_sds)
    pspec_q = jax.tree_util.tree_map_with_path(
        lambda path, x: {k: v for k, v in x.items() if k != "__meta__"}
        if isinstance(x, dict) and "__meta__" in x
        else x,
        pspec_q,
        is_leaf=lambda x: (isinstance(x, dict) and "__meta__" in x) or isinstance(x, P),
    )
    pspec_q = sanitize_pspecs(mesh, pspec_q, qsds)
    tok_sds = arch.input_specs(shape)
    tspec = (
        jax.tree.map(lambda x: P(), tok_sds)
        if long_ctx
        else sanitize_pspecs(mesh, batch_pspecs(cfg, tok_sds, dp), tok_sds)
    )

    def is_packed(x):
        return isinstance(x, dict) and set(x) >= {"codes", "scale", "zero"}

    def decode_fn(qp, toks, cache):
        def deq(path, x):
            if is_packed(x):
                key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
                return dequant_leaf({**x, "__meta__": metas[key]})
            return x

        params = jax.tree_util.tree_map_with_path(
            deq, qp, is_leaf=lambda x: is_packed(x) or not isinstance(x, dict)
        )
        return arch.decode(params, toks["tokens"], cache, spec)

    ns = lambda tree: jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    fn = jax.jit(
        decode_fn,
        in_shardings=(ns(pspec_q), ns(tspec), ns(cspec)),
        out_shardings=(NamedSharding(mesh, P()), ns(cspec)),
        donate_argnums=(2,),
    )
    with mesh:
        lowered = fn.lower(qsds, tok_sds, cache_sds)
    rec["lower_s"] = round(time.time() - t0, 2)
    rec["model_flops"] = 2.0 * rec["params_active"] * shape.global_batch

    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "peak_device_bytes": int(
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes + ma.temp_size_in_bytes
        ),
    }
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older jaxlib: one dict per program
        ca = ca[0] if ca else {}
    rec["cost"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
    }
    hlo = compiled.as_text()
    colls = collective_stats(hlo, body_multiplier=cfg.n_layers)
    rec["collectives"] = colls
    rec["collective_wire_bytes"] = total_wire_bytes(colls)
    rec["hlo_bytes"] = len(hlo)
    return rec
