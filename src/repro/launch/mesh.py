"""Production mesh construction.

Target hardware: TPU v5e pods, 256 chips each (16x16 ICI torus).  The
single-pod mesh is (data=16, model=16); the multi-pod mesh adds a leading
``pod`` axis over DCN: (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this
module touches no jax device state - the dry-run must set
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any
device query, and smoke tests must keep seeing 1 CPU device.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes_of(mesh) -> Tuple[str, ...]:
    """The data-parallel axes (everything except 'model')."""
    return tuple(n for n in mesh.axis_names if n != "model")


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh for elastic re-mesh / tests."""
    return jax.make_mesh(shape, axes)
