"""Distributed training launcher.

On real hardware each host runs this under its TPU runtime (jax.distributed
initializes from the cluster env); on this container it drives the same
code single-process.  Wires together: mesh + sharding rules, the
fault-tolerant Trainer (checkpoint/resume, NaN-skip, SIGTERM-clean-exit),
the sharded synthetic data pipeline, and optional gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck
"""
from __future__ import annotations

import argparse
import signal

import jax
import jax.numpy as jnp

from repro.data import SyntheticLM
from repro.data.synthetic import make_batch_for
from repro.models.common import QuantizeSpec
from repro.models.registry import ARCH_IDS, get_arch
from repro.train.optimizer import OptConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = get_arch(args.arch, reduced=args.reduced)
    cfg = arch.config
    opt = OptConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                    total_steps=args.steps)
    tcfg = TrainerConfig(
        total_steps=args.steps, ckpt_interval=args.ckpt_interval,
        ckpt_dir=args.ckpt_dir, microbatches=args.microbatches,
        compress_grads=args.compress_grads, seed=args.seed,
    )
    trainer = Trainer(arch, opt, tcfg, QuantizeSpec())
    # preemption-clean exit: finish step, checkpoint, stop
    signal.signal(signal.SIGTERM, trainer.request_stop)

    shard = jax.process_index()
    data = SyntheticLM(cfg.vocab, args.seq, seed=args.seed)

    def batches():
        step = trainer.step
        while True:
            yield make_batch_for(cfg, data, step, shard, args.batch)
            step += 1

    out = trainer.run(batches())
    print(f"[train] finished at step {out['step']}; "
          f"final loss {out['log'][-1]['loss'] if out['log'] else float('nan'):.4f}")


if __name__ == "__main__":
    main()
