from repro.quant.packed import (  # noqa: F401
    PackedWeight,
    dense_w,
    dequantize_tree,
    is_packed,
    set_backend,
)
from repro.quant.qtypes import QuantConfig, QuantizedTensor, WAKVConfig  # noqa: F401
from repro.quant.rtn import (  # noqa: F401
    compute_qparams,
    quantize,
    dequantize,
    fake_quant,
    quantize_weight_grouped,
    fake_quant_act_grouped,
)
