"""Bit-packing of low-bit integer codes.

Codes are packed along the *input-channel* axis (axis -2 of a ``(..., C, H)``
weight) so a dequant-matmul kernel can stream contiguous packed K-tiles from
HBM: 4-bit -> 2 codes/byte, 2-bit -> 4 codes/byte, 8-bit -> identity.  Any
leading stack axes (layer ``L``, expert ``E``, interleave group) ride along
untouched, so the same packer covers a 2-D Zamba shared-block weight, a
stacked ``(L, C, H)`` transformer weight, and a ``(L, E, C, H)`` MoE expert
stack.

The packed representation is what the serving path stores in HBM; the
roofline memory term of quantized decode is computed from these packed
byte counts.  Asymmetric codes are stored biased to unsigned (0..2^b-1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QuantizedTensor

_PER_BYTE = {2: 4, 4: 2, 8: 1}


def codes_per_byte(bits: int) -> int:
    if bits not in _PER_BYTE:
        raise ValueError(f"unsupported pack width {bits}")
    return _PER_BYTE[bits]


def packable(bits: int, c: int) -> bool:
    """True when a C-channel weight at this width can be byte-packed."""
    return bits in _PER_BYTE and c % _PER_BYTE[bits] == 0


def pack_codes(codes: jax.Array, bits: int) -> jax.Array:
    """Pack unsigned codes ``(..., C, H)`` -> uint8 ``(..., C/pb, H)``.

    Codes must already be biased to unsigned (0..2^bits-1); channel row
    ``byte*pb + i`` lands in bit-slot ``i`` of its byte.
    """
    n = codes_per_byte(bits)
    *lead, c, h = codes.shape
    if c % n != 0:
        raise ValueError(f"C={c} not divisible by codes/byte={n}")
    mask = (1 << bits) - 1
    u = (codes.astype(jnp.int32) & mask).astype(jnp.uint8)
    u = u.reshape(*lead, c // n, n, h)
    out = jnp.zeros((*lead, c // n, h), jnp.uint8)
    for i in range(n):
        out = out | (u[..., i, :] << (bits * i))
    return out


def unpack_codes(packed: jax.Array, bits: int, c: int) -> jax.Array:
    """Inverse of :func:`pack_codes`: uint8 ``(..., C/pb, H)`` -> int32 codes."""
    n = codes_per_byte(bits)
    mask = (1 << bits) - 1
    parts = [((packed >> (bits * i)) & mask).astype(jnp.int32) for i in range(n)]
    u = jnp.stack(parts, axis=-2)  # (..., C/pb, pb, H)
    out = u.reshape(*packed.shape[:-2], packed.shape[-2] * n, packed.shape[-1])
    if out.shape[-2] != c:
        raise ValueError(f"unpacked rows {out.shape[-2]} != C={c}")
    return out


def pack(qt: QuantizedTensor) -> QuantizedTensor:
    """Pack int codes ``(..., C, H)`` -> uint8 ``(..., C/pb, H)``."""
    if qt.packed:
        return qt
    # Bias symmetric codes to unsigned.
    offset = 0 if qt.zero is not None else (1 << (qt.bits - 1))
    u = jnp.clip(qt.codes.astype(jnp.int32) + offset, 0, (1 << qt.bits) - 1)
    return QuantizedTensor(
        codes=pack_codes(u, qt.bits), scale=qt.scale, zero=qt.zero,
        bits=qt.bits, group=qt.group, packed=True,
    )


def unpack(qt: QuantizedTensor) -> QuantizedTensor:
    """Inverse of :func:`pack`."""
    if not qt.packed:
        return qt
    c = qt.codes.shape[-2] * codes_per_byte(qt.bits)
    u = unpack_codes(qt.codes, qt.bits, c)
    offset = 0 if qt.zero is not None else (1 << (qt.bits - 1))
    return QuantizedTensor(
        codes=(u - offset).astype(jnp.int32), scale=qt.scale, zero=qt.zero,
        bits=qt.bits, group=qt.group, packed=False,
    )
