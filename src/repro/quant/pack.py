"""Bit-packing of low-bit integer codes.

Codes are packed along the *input-channel* axis (axis 0 of a (C, H) weight)
so a dequant-matmul kernel can stream contiguous packed K-tiles from HBM:
4-bit -> 2 codes/byte, 2-bit -> 4 codes/byte, 8-bit -> identity.

The packed representation is what the serving path stores in HBM; the
roofline memory term of quantized decode is computed from these packed
byte counts.  Asymmetric codes are stored biased to unsigned (0..2^b-1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QuantizedTensor

_PER_BYTE = {2: 4, 4: 2, 8: 1}


def codes_per_byte(bits: int) -> int:
    if bits not in _PER_BYTE:
        raise ValueError(f"unsupported pack width {bits}")
    return _PER_BYTE[bits]


def pack(qt: QuantizedTensor) -> QuantizedTensor:
    """Pack int8 codes (C, H) -> uint8 (C // per_byte, H)."""
    if qt.packed:
        return qt
    n = codes_per_byte(qt.bits)
    c, h = qt.codes.shape
    if c % n != 0:
        raise ValueError(f"C={c} not divisible by codes/byte={n}")
    # Bias symmetric codes to unsigned.
    offset = 0 if qt.zero is not None else (1 << (qt.bits - 1))
    u = jnp.clip(qt.codes.astype(jnp.int32) + offset, 0, (1 << qt.bits) - 1).astype(jnp.uint8)
    u = u.reshape(c // n, n, h)
    out = jnp.zeros((c // n, h), jnp.uint8)
    for i in range(n):
        out = out | (u[:, i, :] << (qt.bits * i))
    return QuantizedTensor(
        codes=out, scale=qt.scale, zero=qt.zero, bits=qt.bits, group=qt.group, packed=True
    )


def unpack(qt: QuantizedTensor) -> QuantizedTensor:
    """Inverse of :func:`pack`."""
    if not qt.packed:
        return qt
    n = codes_per_byte(qt.bits)
    cp, h = qt.codes.shape
    mask = (1 << qt.bits) - 1
    parts = [
        ((qt.codes >> (qt.bits * i)) & mask).astype(jnp.int32) for i in range(n)
    ]  # each (C//n, H)
    u = jnp.stack(parts, axis=1).reshape(cp * n, h)
    offset = 0 if qt.zero is not None else (1 << (qt.bits - 1))
    codes = (u - offset).astype(jnp.int32)
    return QuantizedTensor(
        codes=codes, scale=qt.scale, zero=qt.zero, bits=qt.bits, group=qt.group, packed=False
    )
