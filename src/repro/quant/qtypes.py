"""Quantization config + container types.

Bit-width notation follows the paper: WxAyKVz, e.g. W2A4KV16 = 2-bit
weights, 4-bit activations, bf16 KV cache.  Group quantization everywhere
("Since 2-bit per-channel quantization can easily fail to converge, we
assume group quantization in all cases" - paper footnote 2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """One tensor-class quantizer config (weights OR activations OR kv).

    Attributes:
      bits: bit width (2, 3, 4, 8); 16 means "not quantized".
      group: group size along the quantized (channel/reduction) axis.
      symmetric: symmetric (zero_point == 0) vs asymmetric.
      clip_ratio: static clip of the max (act quant; QuaRot uses 0.9).
      mse_clip: grid-search the clip ratio minimising quant MSE (weights).
      mse_grid: number of grid points for the MSE search.
    """

    bits: int = 16
    group: int = 128
    symmetric: bool = True
    clip_ratio: float = 1.0
    mse_clip: bool = False
    mse_grid: int = 20

    @property
    def enabled(self) -> bool:
        return self.bits < 16

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.symmetric else 2**self.bits - 1

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


# Paper settings (Appendix A.1): asymmetric W with MSE clip, group 128;
# symmetric RTN A with clip 0.9, group 128.
def paper_weight_cfg(bits: int = 2, group: int = 128) -> QuantConfig:
    return QuantConfig(bits=bits, group=group, symmetric=False, mse_clip=True)


def paper_act_cfg(bits: int = 4, group: int = 128) -> QuantConfig:
    return QuantConfig(bits=bits, group=group, symmetric=True, clip_ratio=0.9)


@dataclasses.dataclass(frozen=True)
class WAKVConfig:
    """Full WxAyKVz setting."""

    weight: QuantConfig = QuantConfig()
    act: QuantConfig = QuantConfig()
    kv: QuantConfig = QuantConfig()

    @classmethod
    def parse(cls, spec: str, group: int = 128) -> "WAKVConfig":
        """Parse 'W2A4KV16' / 'W2A16' / 'W16A16' into a config."""
        import re

        m = re.fullmatch(r"W(\d+)A(\d+)(?:KV(\d+))?", spec.upper())
        if not m:
            raise ValueError(f"bad quant spec {spec!r}")
        w, a = int(m.group(1)), int(m.group(2))
        kv = int(m.group(3)) if m.group(3) else 16
        return cls(
            weight=paper_weight_cfg(w, group) if w < 16 else QuantConfig(),
            act=paper_act_cfg(a, group) if a < 16 else QuantConfig(),
            kv=QuantConfig(bits=kv, group=group, symmetric=False) if kv < 16 else QuantConfig(),
        )

    def tag(self) -> str:
        return f"W{self.weight.bits}A{self.act.bits}KV{self.kv.bits}"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Grouped-quantized tensor: integer codes + per-group scale/zero.

    For a weight ``(C, H)`` with group G along C: codes ``(C, H)`` int8
    (or packed - see :mod:`repro.quant.pack`), scale/zero ``(C//G, H)``.
    Dequant: ``(codes - zero) * scale`` broadcast over groups.
    """

    codes: jax.Array  # int8 (unpacked) or packed uint8/int32
    scale: jax.Array
    zero: Optional[jax.Array]
    bits: int
    group: int
    packed: bool = False

    def tree_flatten(self):
        children = (self.codes, self.scale, self.zero)
        aux = (self.bits, self.group, self.packed)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale, zero = children
        bits, group, packed = aux
        return cls(codes=codes, scale=scale, zero=zero, bits=bits, group=group, packed=packed)

    @property
    def out_features(self) -> int:
        return self.codes.shape[-1]

    def nbytes_ideal(self) -> int:
        """Ideal storage (bits-true packing + fp16 scales)."""
        n_codes = 1
        for s in self.codes.shape:
            n_codes *= s
        if self.packed:
            code_bytes = n_codes * self.codes.dtype.itemsize
        else:
            code_bytes = n_codes * self.bits / 8
        meta = self.scale.size * 2 + (self.zero.size * 2 if self.zero is not None else 0)
        return int(code_bytes + meta)
