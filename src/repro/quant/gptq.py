"""GPTQ (OPTQ) solver in pure JAX.

Used for weight quantization exactly as in the paper's QuaRot setting
(Appendix A.1): asymmetric weights, MSE-based clipping, group size 128,
calibration Hessian from 128x2048-token WikiText-2 samples (here: the
framework's calibration pipeline).

Layout: weight ``(C, H)`` (in, out), Hessian ``(C, C)`` over input channels.
The algorithm walks input channels in order (no act-order permutation),
quantizing one channel at a time and propagating the quantization error to
the not-yet-quantized channels through the Cholesky factor of the inverse
Hessian - the standard blocked GPTQ recursion, expressed with
``lax.fori_loop`` + masked rank-G trailing updates so the whole solver jits.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant import rtn
from repro.quant.qtypes import QuantConfig, QuantizedTensor


def collect_hessian(xs: jax.Array) -> jax.Array:
    """H = 2 X^T X from calibration activations ``xs`` of shape (..., C)."""
    x = xs.reshape(-1, xs.shape[-1]).astype(jnp.float32)
    return 2.0 * (x.T @ x)


def _chol_inv_upper(h: jax.Array, percdamp: float) -> jax.Array:
    """U = cholesky(inv(H + damp I), upper) - the GPTQ propagation factor."""
    c = h.shape[0]
    diag_mean = jnp.mean(jnp.diag(h))
    damp = jnp.maximum(percdamp * diag_mean, 1e-8)
    h = h + damp * jnp.eye(c, dtype=h.dtype)
    # inv via Cholesky solve (stable for PSD).
    l = jnp.linalg.cholesky(h)
    hinv = jax.scipy.linalg.cho_solve((l, True), jnp.eye(c, dtype=h.dtype))
    return jnp.linalg.cholesky(hinv, upper=True)


@functools.partial(jax.jit, static_argnames=("cfg", "percdamp"))
def gptq_quantize(
    w: jax.Array,
    hessian: jax.Array,
    cfg: QuantConfig,
    percdamp: float = 0.01,
) -> Tuple[QuantizedTensor, jax.Array]:
    """Quantize (C, H) weight with GPTQ against the given input Hessian.

    Returns ``(QuantizedTensor, dequantized_weight)``.  Group boundaries
    coincide with the solver blocks so each group's scale/zero is computed
    from the *error-compensated* weights when the block is entered,
    matching the reference implementation's ``groupsize`` behaviour.
    """
    if not cfg.enabled:
        raise ValueError("GPTQ called with 16-bit config")
    c, h_out = w.shape
    g = cfg.group
    if c % g != 0:
        raise ValueError(f"C={c} not divisible by group={g}")
    nblocks = c // g
    w = w.astype(jnp.float32)

    # Dead channels (zero Hessian diagonal) contribute nothing; zero them.
    hdiag = jnp.diag(hessian)
    dead = hdiag <= 0
    hessian = hessian + jnp.diag(jnp.where(dead, 1.0, 0.0))
    w = jnp.where(dead[:, None], 0.0, w)

    u = _chol_inv_upper(hessian.astype(jnp.float32), percdamp)  # (C, C) upper

    def block_body(b, carry):
        wcur, codes, scales, zeros = carry
        start = b * g
        wb = jax.lax.dynamic_slice(wcur, (start, 0), (g, h_out))  # (G, H)
        ub = jax.lax.dynamic_slice(u, (start, start), (g, g))  # in-block factor
        # Group qparams from the error-compensated block.
        gcfg = cfg.replace(group=g)
        scale, zero = rtn.weight_qparams(wb, gcfg)  # (1, H)
        scale, zero = scale[0], zero[0]  # (H,)

        def col_body(i, inner):
            wb_i, q_i, e_i = inner
            col = wb_i[i]  # (H,)
            d = ub[i, i]
            q = rtn.quantize(col, scale, zero, cfg)
            dq = rtn.dequantize(q, scale, zero)
            err = (col - dq) / d
            # Propagate to later columns of this block only.
            rowmask = (jnp.arange(g) > i).astype(wb_i.dtype)
            wb_i = wb_i - (ub[i] * rowmask)[:, None] * err[None, :]
            q_i = q_i.at[i].set(q.astype(jnp.int32))
            e_i = e_i.at[i].set(err)
            return wb_i, q_i, e_i

        wb2, qb, eb = jax.lax.fori_loop(
            0,
            g,
            col_body,
            (wb, jnp.zeros((g, h_out), jnp.int32), jnp.zeros((g, h_out), jnp.float32)),
        )
        # Trailing update to all later blocks: W[start+g:] -= U[blk, start+g:]^T @ E
        urows = jax.lax.dynamic_slice(u, (start, 0), (g, c))  # (G, C)
        colmask = (jnp.arange(c) >= start + g).astype(wcur.dtype)
        update = (urows * colmask[None, :]).T @ eb  # (C, H)
        wcur = wcur - update
        codes = jax.lax.dynamic_update_slice(codes, qb, (start, 0))
        scales = jax.lax.dynamic_update_slice(scales, scale[None, :], (b, 0))
        zeros = jax.lax.dynamic_update_slice(zeros, zero[None, :], (b, 0))
        return wcur, codes, scales, zeros

    init = (
        w,
        jnp.zeros((c, h_out), jnp.int32),
        jnp.zeros((nblocks, h_out), jnp.float32),
        jnp.zeros((nblocks, h_out), jnp.float32),
    )
    _, codes, scales, zeros = jax.lax.fori_loop(0, nblocks, block_body, init)
    qt = QuantizedTensor(codes=codes, scale=scales, zero=zeros, bits=cfg.bits, group=g)
    return qt, rtn.dequantize_weight(qt)


def gptq_proxy_loss(w: jax.Array, wq: jax.Array, hessian: jax.Array) -> jax.Array:
    """tr((W-Wq)^T H (W-Wq)) - the objective GPTQ minimises (for tests)."""
    d = (w - wq).astype(jnp.float32)
    return jnp.einsum("ch,cd,dh->", d, hessian.astype(jnp.float32), d)
