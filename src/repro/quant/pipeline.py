"""End-to-end PTQ pipeline: rotate -> (GPTQ|RTN) weights -> serve spec.

This reproduces the paper's experimental harness (Appendix A.1):

  QuaRot row of Table 1   = ``PTQConfig(method="gptq", r1_kind=..., ...)``
  SpinQuant-lite (LR)     = ``learned="rotation"`` (Cayley-optimized R1
                            initialised from r1_kind)
  OSTQuant-lite (LR+LS)   = ``learned="rotation+scale"``

with r1_kind in {GH, GW, LH, GSR} as the paper's independent variable.
Weights: asymmetric, MSE-clipped, grouped (128 at full scale); acts:
symmetric RTN, clip 0.9; R4 online rotation ahead of down_proj.

The real API underneath is the declarative
:class:`repro.quant.policy.QuantPolicy`: an ordered list of per-site
pattern rules plus a rotation plan, quantizing every matmul site under
its own (bits, group, method, rotation) in one pass — heterogeneous
precision, per-site online rotations, learned/loaded/composed R1.
``PTQConfig`` lowers to a single-rule policy via :meth:`PTQConfig.
to_policy`, so every flat-config call site rides the same path and
produces byte-identical artifacts to what it always did.

Every family quantizer returns *packed integer* weights - a params tree
whose quantized leaves are :class:`repro.quant.packed.PackedWeight`
(codes + scale + zero) rather than fake-quant floats.  The packed tree is
the canonical artifact (``repro.api.QuantizedModel``); the legacy
float-valued view is one :func:`repro.quant.packed.dequantize_tree` away
and is what :func:`quantize_model` still returns for existing callers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fuse import fuse_rotations
from repro.core.rotation import Rotation, RotationKind, make_rotation
from repro.models import common as mcommon
from repro.models import transformer as tmod
from repro.models.common import QuantizeSpec, act_q, apply_r4, rmsnorm
from repro.quant import gptq as gptq_mod
from repro.quant import rtn
from repro.quant import policy as policy_mod
from repro.quant.packed import PackedWeight, dequantize_tree
from repro.quant.policy import (
    QuantPolicy, ResolvedPolicy, RotationPlan, RotationSpec, SiteRule,
    _site_layer_map, lower_wakv, resolve_policy,
)
from repro.quant.qtypes import QuantConfig, WAKVConfig

_R1_KINDS = ("I", "GH", "GW", "LH", "GSR")
_LEARNED = ("none", "rotation", "rotation+scale")


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    """Flat one-rule convenience constructor; lowers to a QuantPolicy.

    Validated at construction (bad ``wakv`` strings / groups / kinds used
    to surface as shape errors deep inside ``pack.py``).
    """

    r1_kind: str = "GSR"  # GH | GW | LH | GSR | I  (the paper's variable)
    r4_kind: str = "GH"  # QuaRot's default online rotation
    wakv: str = "W2A16"
    method: str = "gptq"  # gptq | rtn
    group: int = 128  # quant group size == GSR block size
    seed: int = 0
    learned: str = "none"  # none | rotation | rotation+scale
    learn_steps: int = 120
    n_calib: int = 8
    calib_seq: int = 256

    def __post_init__(self):
        if self.r1_kind not in _R1_KINDS:
            raise ValueError(
                f"PTQConfig.r1_kind {self.r1_kind!r} unknown  "
                f"(expected one of {_R1_KINDS})")
        if self.r4_kind not in _R1_KINDS:
            raise ValueError(
                f"PTQConfig.r4_kind {self.r4_kind!r} unknown  "
                f"(expected one of {_R1_KINDS})")
        if self.method not in ("rtn", "gptq"):
            raise ValueError(
                f"PTQConfig.method {self.method!r} unknown  "
                f"(expected 'rtn' or 'gptq')")
        if self.learned not in _LEARNED:
            raise ValueError(
                f"PTQConfig.learned {self.learned!r} unknown  "
                f"(expected one of {_LEARNED})")
        if self.group < 1:
            raise ValueError(
                f"PTQConfig.group must be >= 1, got {self.group}  "
                f"(it is both the quant group and the GSR block size)")
        lower_wakv(self.wakv, self.group)  # raises with the accepted format

    def spec(self) -> QuantizeSpec:
        w = WAKVConfig.parse(self.wakv, group=self.group)
        return QuantizeSpec(
            act_bits=w.act.bits,
            act_group=self.group,
            act_clip=w.act.clip_ratio,
            r4_kind=self.r4_kind,
            r4_group=self.group,
            kv_bits=w.kv.bits,
        )

    def weight_cfg(self) -> QuantConfig:
        return WAKVConfig.parse(self.wakv, group=self.group).weight

    def to_policy(self) -> QuantPolicy:
        """Lower to the equivalent single-rule policy (the real API).

        ``quantize_packed(arch, params, ptq)`` and ``quantize_packed(
        arch, params, ptq.to_policy())`` produce byte-identical artifacts.
        """
        wcfg, act_bits, act_clip, kv_bits = lower_wakv(self.wakv, self.group)
        if self.learned != "none":
            r1 = RotationSpec(source="learn", kind=self.r1_kind,
                              group=self.group, seed=self.seed,
                              learn=self.learned,
                              learn_steps=self.learn_steps)
        else:
            r1 = RotationSpec(source="construct", kind=self.r1_kind,
                              group=self.group, seed=self.seed)
        return QuantPolicy(
            rules=(SiteRule(pattern="*", bits=wcfg.bits, group=self.group,
                            method=self.method, symmetric=wcfg.symmetric,
                            mse_clip=wcfg.mse_clip,
                            clip_ratio=wcfg.clip_ratio),),
            rotation=RotationPlan(r1=r1, r4_kind=self.r4_kind,
                                  r4_group=self.group),
            act_bits=act_bits, act_group=self.group, act_clip=act_clip,
            kv_bits=kv_bits, seed=self.seed, n_calib=self.n_calib,
            calib_seq=self.calib_seq,
            name=f"ptq-{self.r1_kind}-{self.wakv}-{self.method}",
        )


def fit_group(c: int, group: int) -> int:
    g = min(group, c)
    while c % g:
        g //= 2
    return max(g, 1)


# ---------------------------------------------------------------------------
# Which leaves are quantized, per family (paper: "all transformer weights";
# embeddings / lm_head / norms / tiny recurrences stay high precision).
# ---------------------------------------------------------------------------

_FAMILY_WEIGHTS = {
    "dense": {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"},
    "moe": {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "router",
            "shared_gate", "shared_up", "shared_down"},
    "mla": {"wq_a", "wq_b", "wkv_a", "wkv_b", "wo", "w_gate", "w_up", "w_down"},
    "ssm": {"wq", "wk", "wv", "wi", "wf", "wo_gate", "out_proj", "wx"},
    "hybrid": {"in_proj", "out_proj", "wq", "wk", "wv", "wo",
               "w_gate", "w_up", "w_down"},
}


def _quantize_leaf_rtn(w: jax.Array, cfg: QuantConfig) -> PackedWeight:
    """Quantize a (stacked) weight (..., C, H) group-wise along C into the
    packed (codes, scale, zero) artifact form."""
    g = fit_group(w.shape[-2], cfg.group)
    return PackedWeight.from_float(w, cfg.replace(group=g))


def rtn_quantize_params(cfg: ModelConfig, params: Dict, wcfg: QuantConfig) -> Dict:
    """RTN-quantize every quantizable leaf to a :class:`PackedWeight`."""
    names = _FAMILY_WEIGHTS[cfg.family]

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k in names and getattr(v, "ndim", 0) >= 3:
                out[k] = _quantize_leaf_rtn(v, wcfg)
            elif k in names and getattr(v, "ndim", 0) == 2 and "b" != k[0]:
                # unstacked (zamba shared block) 2-D weights
                out[k] = _quantize_leaf_rtn(v, wcfg)
            else:
                out[k] = v
        return out

    return walk(params)


# ---------------------------------------------------------------------------
# GPTQ path (dense transformer family - the paper's Llama-2 setting)
# ---------------------------------------------------------------------------


def collect_dense_hessians(cfg: ModelConfig, params: Dict, batches,
                           spec: QuantizeSpec) -> Dict[str, jax.Array]:
    """Layer-wise calibration: Hessians for every quantized matmul input.

    Mirrors the dense transformer block exactly (tested by equivalence of
    the final logits with ``transformer.forward``).
    """
    assert cfg.family == "dense"
    l = cfg.n_layers
    hess = None

    for batch in batches:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        h = tmod.embed_inputs(cfg, params, batch)
        b, s, d = h.shape
        positions = jnp.arange(s)[None, :]
        acc = {"attn_in": [], "wo_in": [], "mlp_in": [], "down_in": []}
        for i in range(l):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
            acc["attn_in"].append(
                gptq_mod.collect_hessian(act_q(x, spec, site="wq")))
            q, k, v = tmod._qkv(cfg, lp, x, positions, spec)
            attn = mcommon.flash_attention(q, k, v, causal=True,
                                           window=cfg.sliding_window)
            ao = act_q(attn.reshape(b, s, cfg.n_heads * cfg.hd), spec,
                       site="wo")
            acc["wo_in"].append(gptq_mod.collect_hessian(ao))
            h = h + ao @ lp["wo"]
            x2 = rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
            xq = act_q(x2, spec, site="w_gate")
            acc["mlp_in"].append(gptq_mod.collect_hessian(xq))
            hidden = jax.nn.silu(xq @ lp["w_gate"]) * (xq @ lp["w_up"])
            hidden = act_q(apply_r4(hidden, spec), spec, site="w_down")
            acc["down_in"].append(gptq_mod.collect_hessian(hidden))
            h = h + hidden @ lp["w_down"]
        cur = {k: jnp.stack(v) for k, v in acc.items()}
        hess = cur if hess is None else jax.tree.map(jnp.add, hess, cur)
    return hess


_DENSE_HESS_FOR = {
    "wq": "attn_in", "wk": "attn_in", "wv": "attn_in",
    "wo": "wo_in",
    "w_gate": "mlp_in", "w_up": "mlp_in",
    "w_down": "down_in",
}


def gptq_quantize_dense(cfg: ModelConfig, params: Dict, hess: Dict,
                        wcfg: QuantConfig) -> Dict:
    """GPTQ every dense-family weight into a :class:`PackedWeight` stack."""
    layers = dict(params["layers"])
    for name, hkey in _DENSE_HESS_FOR.items():
        w = layers[name]  # (L, C, H)
        g = fit_group(w.shape[1], wcfg.group)
        lcfg = wcfg.replace(group=g)
        quant_one = lambda wi, hi: gptq_mod.gptq_quantize(wi, hi, lcfg)[0]
        qt = jax.vmap(quant_one)(
            w.astype(jnp.float32), hess[hkey].astype(jnp.float32)
        )  # stacked QuantizedTensor: codes (L, C, H), scale/zero (L, C/g, H)
        layers[name] = PackedWeight.from_codes(
            qt.codes, qt.scale, qt.zero, bits=lcfg.bits, group=g,
            symmetric=lcfg.symmetric, dtype=str(w.dtype),
        )
    return dict(params, layers=layers)


# ---------------------------------------------------------------------------
# Learned refinements (SpinQuant-lite / OSTQuant-lite)
# ---------------------------------------------------------------------------


def _learned_rotation(cfg: ModelConfig, params: Dict, r_init: Rotation,
                      proxy_cfg: QuantConfig, *, learn_scale: bool,
                      steps: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    from repro.quant import spinquant

    layers = params["layers"]
    # first + middle + last layers' front weights as the proxy set
    l = cfg.n_layers
    sel = sorted({0, l // 2, l - 1})
    front = []
    for i in sel:
        for k in ("wq", "wk", "wv", "w_gate", "w_up"):
            if k in layers:
                front.append(layers[k][i].astype(jnp.float32))
    res = spinquant.optimize_rotation(
        r_init.dense(),
        front,
        [],  # rear side is covered by orthogonal invariance; keep proxy light
        proxy_cfg.replace(mse_clip=False),
        learn_scale=learn_scale,
        steps=steps,
    )
    return res.rotation, res.scale


def build_plan_rotations(cfg: ModelConfig, params: Dict, policy: QuantPolicy
                         ) -> Tuple[Rotation, Optional[Rotation],
                                    Optional[np.ndarray]]:
    """Materialise the plan's fused slots: (R1, R2, learned smoothing).

    R1 sources: ``construct`` keeps the factored
    :class:`~repro.core.rotation.Rotation` (identical to the flat-config
    path); ``learn`` runs SpinQuant-lite from the ``kind`` init;
    ``load`` reads an orthogonal matrix from disk.  A ``compose`` kind
    post-multiplies a constructed rotation onto the base — activations
    see ``x @ R_base @ R_post`` — which is how GSR is layered over a
    learned/loaded SpinQuant rotation (paper Sec. 4).
    """
    plan = policy.rotation
    r1s = plan.r1
    dim = cfg.d_model

    if r1s.source == "construct":
        r1 = make_rotation(r1s.kind, dim, group=fit_group(dim, r1s.group),
                           seed=r1s.seed)
    elif r1s.source == "identity":
        r1 = make_rotation("I", dim)
    else:
        r1 = None  # learn / load build a dense matrix below

    scale = None
    base: Optional[np.ndarray] = None
    if r1s.source == "learn":
        r_init = make_rotation(r1s.kind, dim, group=fit_group(dim, r1s.group),
                               seed=r1s.seed)
        # Proxy quantizer = the first rule's config (for a lowered
        # PTQConfig this is exactly the flat config's weight_cfg(), with
        # the group fitted to d_model so reduced configs don't crash).
        rule = policy.rules[0]
        proxy = QuantConfig(bits=rule.bits, group=fit_group(dim, rule.group),
                            symmetric=rule.symmetric, mse_clip=rule.mse_clip,
                            clip_ratio=rule.clip_ratio)
        base, scale = _learned_rotation(
            cfg, params, r_init, proxy,
            learn_scale=(r1s.learn == "rotation+scale"),
            steps=r1s.learn_steps)
    elif r1s.source == "load":
        base = r1s.base_matrix(dim)

    post = r1s.compose_matrix(dim)
    if base is not None or post is not None:
        if base is None:
            base = r1.dense() if r1 is not None else np.eye(dim)
        m = base if post is None else base @ post
        # kind label irrelevant once the matrix is dense
        r1 = Rotation(kind=RotationKind.GLOBAL_HADAMARD, dim=dim, matrix=m)

    r2 = None
    if plan.r2 is not None and plan.r2 != "I":
        if cfg.family in ("mla", "ssm"):
            raise ValueError(
                f"RotationPlan.r2 is a per-head rotation for standard "
                f"attention; family {cfg.family!r} has none  (drop r2 or "
                f"use a dense/moe/hybrid arch)")
        hd = cfg.hd
        r2 = make_rotation(plan.r2, hd, group=fit_group(hd, r1s.group),
                           seed=r1s.seed + 7)
    return r1, r2, scale


# ---------------------------------------------------------------------------
# Policy-driven per-site quantization
# ---------------------------------------------------------------------------


def _tree_get(tree: Dict, path: Tuple[str, ...]):
    node = tree
    for p in path:
        node = node[p]
    return node


def _tree_set(tree: Dict, path: Tuple[str, ...], value) -> Dict:
    """Copy-on-write set along ``path`` (shares untouched siblings)."""
    if len(path) == 1:
        return dict(tree, **{path[0]: value})
    return dict(tree, **{path[0]: _tree_set(tree[path[0]], path[1:], value)})


def _gptq_site(w: jax.Array, hess: jax.Array, wcfg: QuantConfig
               ) -> PackedWeight:
    """GPTQ a stacked (L, C, H) dense-family site under one rule."""
    quant_one = lambda wi, hi: gptq_mod.gptq_quantize(wi, hi, wcfg)[0]
    qt = jax.vmap(quant_one)(
        w.astype(jnp.float32), hess.astype(jnp.float32))
    return PackedWeight.from_codes(
        qt.codes, qt.scale, qt.zero, bits=wcfg.bits, group=wcfg.group,
        symmetric=wcfg.symmetric, dtype=str(w.dtype),
    )


def _quantize_site_mixed(cfg: ModelConfig, w: jax.Array, site: str,
                         path: Tuple[str, ...], rules_for_lead, rules,
                         hess: Optional[Dict]) -> PackedWeight:
    """Quantize one stacked site whose layers carry *different* rules.

    Each layer slice quantizes on its own grid; the per-layer grids are
    merged into one uniform leaf so the stacked weight still rides
    ``lax.scan``: codes are stored at the widest rule's bit width (packed
    when the channel count allows), scales/zeros at the finest rule's
    group (coarser groups replicate their rows — numerically exact, the
    dequant rule ``(codes - zero) * scale`` never consults the rule).
    """
    from repro.quant import pack as packmod

    *lead, c, h = w.shape
    flat = w.astype(jnp.float32).reshape(-1, c, h)
    cfgs = {rid: rules[rid].weight_cfg(c) for rid in set(rules_for_lead)}
    gmin = min(qc.group for qc in cfgs.values())
    bits_max = max(qc.bits for qc in cfgs.values())
    bare = path[-1]
    hkey = _DENSE_HESS_FOR.get(bare) if cfg.family == "dense" else None

    us, scs, zs = [], [], []
    for i, rid in enumerate(rules_for_lead):
        qc = cfgs[rid]
        if rules[rid].method == "gptq" and hess is not None and hkey:
            qt = gptq_mod.gptq_quantize(
                flat[i], hess[hkey][i].astype(jnp.float32), qc)[0]
        else:
            qt = rtn.quantize_weight_grouped(flat[i], qc)
        offset = (1 << (qc.bits - 1)) if qc.symmetric else 0
        zero = jnp.zeros_like(qt.scale) if qt.zero is None else qt.zero
        rep = qc.group // gmin
        us.append(qt.codes.astype(jnp.int32) + offset)
        scs.append(jnp.repeat(qt.scale.astype(jnp.float32), rep, axis=0))
        zs.append(jnp.repeat(zero.astype(jnp.float32) + offset, rep, axis=0))
    u = jnp.stack(us).reshape(*lead, c, h)
    scale = jnp.stack(scs).reshape(*lead, c // gmin, h)
    zero = jnp.stack(zs).reshape(*lead, c // gmin, h)
    packed = packmod.packable(bits_max, c)
    codes = packmod.pack_codes(u, bits_max) if packed else u.astype(jnp.uint8)
    return PackedWeight(codes=codes, scale=scale, zero=zero, bits=bits_max,
                        group=gmin, c=c, dtype=str(w.dtype), packed=packed)


def quantize_by_policy(cfg: ModelConfig, fused: Dict,
                       resolved: ResolvedPolicy,
                       hess: Optional[Dict] = None) -> Dict:
    """Quantize every resolved site of ``fused`` under its own rule.

    Homogeneous sites (every layer on one rule — the flat-config case)
    take exactly the historical path: vmapped RTN packing or the stacked
    GPTQ loop, so lowered ``PTQConfig`` artifacts stay byte-identical.
    Heterogeneous sites merge per-layer grids via
    :func:`_quantize_site_mixed`.
    """
    rules = resolved.policy.rules
    out = fused
    for rs in resolved.sites:
        if not rs.quantized:
            continue
        w = _tree_get(fused, rs.path)
        lead = tuple(w.shape[:-2])
        layer_map = _site_layer_map(cfg, rs.path, lead)
        layer_ids = sorted(set(int(l) for l in layer_map))
        rid_of = dict(zip(layer_ids, rs.rule_ids))
        rules_for_lead = [rid_of[int(l)] for l in layer_map]
        bare = rs.path[-1]
        hkey = _DENSE_HESS_FOR.get(bare) if cfg.family == "dense" else None
        if rs.homogeneous:
            rule = rules[rs.rule_ids[0]]
            wcfg = rule.weight_cfg(rs.in_channels)
            if rule.method == "gptq" and hess is not None and hkey:
                new = _gptq_site(w, hess[hkey], wcfg)
            else:
                new = PackedWeight.from_float(w, wcfg)
        else:
            new = _quantize_site_mixed(cfg, w, rs.site, rs.path,
                                       rules_for_lead, rules, hess)
        out = _tree_set(out, rs.path, new)
    return out


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def normalize_policy(ptq: Union[PTQConfig, QuantPolicy, str]) -> QuantPolicy:
    """PTQConfig | QuantPolicy | preset-name/JSON -> QuantPolicy."""
    if isinstance(ptq, QuantPolicy):
        return ptq
    if isinstance(ptq, PTQConfig):
        return ptq.to_policy()
    if isinstance(ptq, str):
        return policy_mod.get_policy(ptq)
    raise TypeError(
        f"expected PTQConfig, QuantPolicy, or a policy name, got "
        f"{type(ptq).__name__}")


def quantize_packed(
    arch,
    params: Dict,
    ptq: Union[PTQConfig, QuantPolicy, str],
    calib_batches: Optional[Iterator] = None,
) -> Tuple[Dict, QuantizeSpec]:
    """Full PTQ to the packed artifact form.

    ``ptq`` may be the flat :class:`PTQConfig`, a declarative
    :class:`~repro.quant.policy.QuantPolicy` (or preset name / JSON), in
    which case every matmul site quantizes under its own rule.  Returns
    ``(fused params with PackedWeight leaves, serving spec)`` - the
    canonical representation; wrap it in ``repro.api.QuantizedModel`` (or
    call :func:`quantize_model` for the legacy fake-quant float view).
    """
    cfg = arch.config
    policy = normalize_policy(ptq)
    spec = policy.spec()
    resolved = _resolve_or_none(policy, cfg, params)

    r1, r2, scale = build_plan_rotations(cfg, params, policy)
    fused = fuse_rotations(cfg, params, r1, r2=r2, spec=spec)
    if scale is not None:
        # OSTQuant-lite smoothing in the rotated basis: norm gamma = 1/s,
        # front weights *= s - an exact equivalence (rms-normalize itself
        # is untouched), changing only what the quantizers see.
        fused = _apply_smoothing(cfg, fused, scale)

    if resolved is None or not any(s.quantized for s in resolved.sites):
        return fused, spec

    hess = None
    needs_gptq = cfg.family == "dense" and any(
        policy.rules[i].method == "gptq"
        for s in resolved.sites for i in s.rule_ids if i is not None)
    if needs_gptq:
        if calib_batches is None:
            from repro.data import calibration_batches

            calib_batches = calibration_batches(
                cfg, policy.n_calib, policy.calib_seq, seed=policy.seed + 99)
        hess = collect_dense_hessians(cfg, fused, calib_batches, spec)
    return quantize_by_policy(cfg, fused, resolved, hess), spec


def _resolve_or_none(policy: QuantPolicy, cfg, params):
    """Resolve, treating an all-float policy (W16) as 'quantize nothing'."""
    if all(r.bits >= 16 for r in policy.rules):
        return None
    return resolve_policy(policy, cfg, params)


def quantize_model(
    arch,
    params: Dict,
    ptq: PTQConfig,
    calib_batches: Optional[Iterator] = None,
) -> Tuple[Dict, QuantizeSpec]:
    """Legacy view: (fake-quant float params, serving QuantizeSpec).

    Exactly :func:`quantize_packed` followed by leaf dequantization; the
    float values are bit-identical to what the quantizers historically
    emitted.  New code should prefer ``repro.api.quantize``.
    """
    qparams, spec = quantize_packed(arch, params, ptq, calib_batches)
    return dequantize_tree(qparams), spec


def _apply_smoothing(cfg: ModelConfig, fused: Dict, s: np.ndarray) -> Dict:
    """Post-fusion smoothing fold: norm gammas 1/s, front weights diag(s).

    rms(h) * (1/s) @ (diag(s) W) == rms(h) @ W exactly, so the model is
    unchanged in fp; the quantizers see equalised channels.
    """
    sj = jnp.asarray(s, jnp.float32)
    inv = (1.0 / sj).astype(jnp.float32)
    p = dict(fused)
    layers = dict(p["layers"])
    for k in ("attn_norm", "mlp_norm"):
        if k in layers:
            layers[k] = (layers[k].astype(jnp.float32) * inv).astype(layers[k].dtype)
    for k in ("wq", "wk", "wv", "w_gate", "w_up", "router",
              "shared_gate", "shared_up", "wq_a", "wkv_a"):
        if k in layers:
            w = layers[k]
            layers[k] = (w.astype(jnp.float32) * sj[..., :, None]).astype(w.dtype)
    p["final_norm"] = (p["final_norm"].astype(jnp.float32) * inv).astype(
        p["final_norm"].dtype
    )
    lm = p["lm_head"]
    p["lm_head"] = (lm.astype(jnp.float32) * sj[..., :, None]).astype(lm.dtype)
    p["layers"] = layers
    return p
