"""End-to-end PTQ pipeline: rotate -> (GPTQ|RTN) weights -> serve spec.

This reproduces the paper's experimental harness (Appendix A.1):

  QuaRot row of Table 1   = ``PTQConfig(method="gptq", r1_kind=..., ...)``
  SpinQuant-lite (LR)     = ``learned="rotation"`` (Cayley-optimized R1
                            initialised from r1_kind)
  OSTQuant-lite (LR+LS)   = ``learned="rotation+scale"``

with r1_kind in {GH, GW, LH, GSR} as the paper's independent variable.
Weights: asymmetric, MSE-clipped, grouped (128 at full scale); acts:
symmetric RTN, clip 0.9; R4 online rotation ahead of down_proj.

Every family quantizer returns *packed integer* weights - a params tree
whose quantized leaves are :class:`repro.quant.packed.PackedWeight`
(codes + scale + zero) rather than fake-quant floats.  The packed tree is
the canonical artifact (``repro.api.QuantizedModel``); the legacy
float-valued view is one :func:`repro.quant.packed.dequantize_tree` away
and is what :func:`quantize_model` still returns for existing callers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.fuse import fuse_rotations
from repro.core.rotation import Rotation, RotationKind, make_rotation
from repro.models import common as mcommon
from repro.models import transformer as tmod
from repro.models.common import QuantizeSpec, act_q, apply_r4, rmsnorm
from repro.quant import gptq as gptq_mod
from repro.quant import rtn
from repro.quant.packed import PackedWeight, dequantize_tree
from repro.quant.qtypes import QuantConfig, WAKVConfig


@dataclasses.dataclass(frozen=True)
class PTQConfig:
    r1_kind: str = "GSR"  # GH | GW | LH | GSR | I  (the paper's variable)
    r4_kind: str = "GH"  # QuaRot's default online rotation
    wakv: str = "W2A16"
    method: str = "gptq"  # gptq | rtn
    group: int = 128  # quant group size == GSR block size
    seed: int = 0
    learned: str = "none"  # none | rotation | rotation+scale
    learn_steps: int = 120
    n_calib: int = 8
    calib_seq: int = 256

    def spec(self) -> QuantizeSpec:
        w = WAKVConfig.parse(self.wakv, group=self.group)
        return QuantizeSpec(
            act_bits=w.act.bits,
            act_group=self.group,
            act_clip=w.act.clip_ratio,
            r4_kind=self.r4_kind,
            r4_group=self.group,
            kv_bits=w.kv.bits,
        )

    def weight_cfg(self) -> QuantConfig:
        return WAKVConfig.parse(self.wakv, group=self.group).weight


def fit_group(c: int, group: int) -> int:
    g = min(group, c)
    while c % g:
        g //= 2
    return max(g, 1)


# ---------------------------------------------------------------------------
# Which leaves are quantized, per family (paper: "all transformer weights";
# embeddings / lm_head / norms / tiny recurrences stay high precision).
# ---------------------------------------------------------------------------

_FAMILY_WEIGHTS = {
    "dense": {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down"},
    "moe": {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "router",
            "shared_gate", "shared_up", "shared_down"},
    "mla": {"wq_a", "wq_b", "wkv_a", "wkv_b", "wo", "w_gate", "w_up", "w_down"},
    "ssm": {"wq", "wk", "wv", "wi", "wf", "wo_gate", "out_proj", "wx"},
    "hybrid": {"in_proj", "out_proj", "wq", "wk", "wv", "wo",
               "w_gate", "w_up", "w_down"},
}


def _quantize_leaf_rtn(w: jax.Array, cfg: QuantConfig) -> PackedWeight:
    """Quantize a (stacked) weight (..., C, H) group-wise along C into the
    packed (codes, scale, zero) artifact form."""
    g = fit_group(w.shape[-2], cfg.group)
    return PackedWeight.from_float(w, cfg.replace(group=g))


def rtn_quantize_params(cfg: ModelConfig, params: Dict, wcfg: QuantConfig) -> Dict:
    """RTN-quantize every quantizable leaf to a :class:`PackedWeight`."""
    names = _FAMILY_WEIGHTS[cfg.family]

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            elif k in names and getattr(v, "ndim", 0) >= 3:
                out[k] = _quantize_leaf_rtn(v, wcfg)
            elif k in names and getattr(v, "ndim", 0) == 2 and "b" != k[0]:
                # unstacked (zamba shared block) 2-D weights
                out[k] = _quantize_leaf_rtn(v, wcfg)
            else:
                out[k] = v
        return out

    return walk(params)


# ---------------------------------------------------------------------------
# GPTQ path (dense transformer family - the paper's Llama-2 setting)
# ---------------------------------------------------------------------------


def collect_dense_hessians(cfg: ModelConfig, params: Dict, batches,
                           spec: QuantizeSpec) -> Dict[str, jax.Array]:
    """Layer-wise calibration: Hessians for every quantized matmul input.

    Mirrors the dense transformer block exactly (tested by equivalence of
    the final logits with ``transformer.forward``).
    """
    assert cfg.family == "dense"
    l = cfg.n_layers
    hess = None

    for batch in batches:
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        h = tmod.embed_inputs(cfg, params, batch)
        b, s, d = h.shape
        positions = jnp.arange(s)[None, :]
        acc = {"attn_in": [], "wo_in": [], "mlp_in": [], "down_in": []}
        for i in range(l):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            x = rmsnorm(h, lp["attn_norm"], cfg.norm_eps)
            acc["attn_in"].append(gptq_mod.collect_hessian(act_q(x, spec)))
            q, k, v = tmod._qkv(cfg, lp, x, positions, spec)
            attn = mcommon.flash_attention(q, k, v, causal=True,
                                           window=cfg.sliding_window)
            ao = act_q(attn.reshape(b, s, cfg.n_heads * cfg.hd), spec)
            acc["wo_in"].append(gptq_mod.collect_hessian(ao))
            h = h + ao @ lp["wo"]
            x2 = rmsnorm(h, lp["mlp_norm"], cfg.norm_eps)
            xq = act_q(x2, spec)
            acc["mlp_in"].append(gptq_mod.collect_hessian(xq))
            hidden = jax.nn.silu(xq @ lp["w_gate"]) * (xq @ lp["w_up"])
            hidden = act_q(apply_r4(hidden, spec), spec)
            acc["down_in"].append(gptq_mod.collect_hessian(hidden))
            h = h + hidden @ lp["w_down"]
        cur = {k: jnp.stack(v) for k, v in acc.items()}
        hess = cur if hess is None else jax.tree.map(jnp.add, hess, cur)
    return hess


_DENSE_HESS_FOR = {
    "wq": "attn_in", "wk": "attn_in", "wv": "attn_in",
    "wo": "wo_in",
    "w_gate": "mlp_in", "w_up": "mlp_in",
    "w_down": "down_in",
}


def gptq_quantize_dense(cfg: ModelConfig, params: Dict, hess: Dict,
                        wcfg: QuantConfig) -> Dict:
    """GPTQ every dense-family weight into a :class:`PackedWeight` stack."""
    layers = dict(params["layers"])
    for name, hkey in _DENSE_HESS_FOR.items():
        w = layers[name]  # (L, C, H)
        g = fit_group(w.shape[1], wcfg.group)
        lcfg = wcfg.replace(group=g)
        quant_one = lambda wi, hi: gptq_mod.gptq_quantize(wi, hi, lcfg)[0]
        qt = jax.vmap(quant_one)(
            w.astype(jnp.float32), hess[hkey].astype(jnp.float32)
        )  # stacked QuantizedTensor: codes (L, C, H), scale/zero (L, C/g, H)
        layers[name] = PackedWeight.from_codes(
            qt.codes, qt.scale, qt.zero, bits=lcfg.bits, group=g,
            symmetric=lcfg.symmetric, dtype=str(w.dtype),
        )
    return dict(params, layers=layers)


# ---------------------------------------------------------------------------
# Learned refinements (SpinQuant-lite / OSTQuant-lite)
# ---------------------------------------------------------------------------


def _learned_rotation(cfg: ModelConfig, params: Dict, r_init: Rotation,
                      ptq: PTQConfig) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    from repro.quant import spinquant

    layers = params["layers"]
    # first + middle + last layers' front weights as the proxy set
    l = cfg.n_layers
    sel = sorted({0, l // 2, l - 1})
    front = []
    for i in sel:
        for k in ("wq", "wk", "wv", "w_gate", "w_up"):
            if k in layers:
                front.append(layers[k][i].astype(jnp.float32))
    res = spinquant.optimize_rotation(
        r_init.dense(),
        front,
        [],  # rear side is covered by orthogonal invariance; keep proxy light
        ptq.weight_cfg().replace(mse_clip=False),
        learn_scale=(ptq.learned == "rotation+scale"),
        steps=ptq.learn_steps,
    )
    return res.rotation, res.scale


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def quantize_packed(
    arch,
    params: Dict,
    ptq: PTQConfig,
    calib_batches: Optional[Iterator] = None,
) -> Tuple[Dict, QuantizeSpec]:
    """Full PTQ to the packed artifact form.

    Returns ``(fused params with PackedWeight leaves, serving spec)`` -
    the canonical representation; wrap it in ``repro.api.QuantizedModel``
    (or call :func:`quantize_model` for the legacy fake-quant float view).
    """
    cfg = arch.config
    spec = ptq.spec()
    wcfg = ptq.weight_cfg()

    r1_group = fit_group(cfg.d_model, ptq.group)
    r1 = make_rotation(ptq.r1_kind, cfg.d_model, group=r1_group, seed=ptq.seed)

    scale = None
    if ptq.learned != "none":
        r_learn, scale = _learned_rotation(cfg, params, r1, ptq)
        r1 = Rotation(kind=RotationKind.GLOBAL_HADAMARD, dim=cfg.d_model,
                      matrix=r_learn)  # kind label irrelevant post-learning

    fused = fuse_rotations(cfg, params, r1, spec=spec)
    if scale is not None:
        # OSTQuant-lite smoothing in the rotated basis: norm gamma = 1/s,
        # front weights *= s - an exact equivalence (rms-normalize itself
        # is untouched), changing only what the quantizers see.
        fused = _apply_smoothing(cfg, fused, scale)

    if not wcfg.enabled:
        return fused, spec
    if ptq.method == "gptq" and cfg.family == "dense":
        if calib_batches is None:
            from repro.data import calibration_batches

            calib_batches = calibration_batches(cfg, ptq.n_calib, ptq.calib_seq,
                                                seed=ptq.seed + 99)
        hess = collect_dense_hessians(cfg, fused, calib_batches, spec)
        qparams = gptq_quantize_dense(cfg, fused, hess, wcfg)
    else:
        qparams = rtn_quantize_params(cfg, fused, wcfg)
    return qparams, spec


def quantize_model(
    arch,
    params: Dict,
    ptq: PTQConfig,
    calib_batches: Optional[Iterator] = None,
) -> Tuple[Dict, QuantizeSpec]:
    """Legacy view: (fake-quant float params, serving QuantizeSpec).

    Exactly :func:`quantize_packed` followed by leaf dequantization; the
    float values are bit-identical to what the quantizers historically
    emitted.  New code should prefer ``repro.api.quantize``.
    """
    qparams, spec = quantize_packed(arch, params, ptq, calib_batches)
    return dequantize_tree(qparams), spec


def _apply_smoothing(cfg: ModelConfig, fused: Dict, s: np.ndarray) -> Dict:
    """Post-fusion smoothing fold: norm gammas 1/s, front weights diag(s).

    rms(h) * (1/s) @ (diag(s) W) == rms(h) @ W exactly, so the model is
    unchanged in fp; the quantizers see equalised channels.
    """
    sj = jnp.asarray(s, jnp.float32)
    inv = (1.0 / sj).astype(jnp.float32)
    p = dict(fused)
    layers = dict(p["layers"])
    for k in ("attn_norm", "mlp_norm"):
        if k in layers:
            layers[k] = (layers[k].astype(jnp.float32) * inv).astype(layers[k].dtype)
    for k in ("wq", "wk", "wv", "w_gate", "w_up", "router",
              "shared_gate", "shared_up", "wq_a", "wkv_a"):
        if k in layers:
            w = layers[k]
            layers[k] = (w.astype(jnp.float32) * sj[..., :, None]).astype(w.dtype)
    p["final_norm"] = (p["final_norm"].astype(jnp.float32) * inv).astype(
        p["final_norm"].dtype
    )
    lm = p["lm_head"]
    p["lm_head"] = (lm.astype(jnp.float32) * sj[..., :, None]).astype(lm.dtype)
    p["layers"] = layers
    return p
