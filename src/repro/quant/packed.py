"""PackedWeight: the quantized-artifact leaf every layer of the stack shares.

A ``PackedWeight`` holds one quantized ``(..., C, H)`` weight as packed
integer codes plus per-group scale/zero, registered as a pytree so it flows
through jit / scan / vmap / ``device_put`` / checkpointing like any bundle
of arrays, while the static quantization metadata (bit width, group size,
original channel count, dequantized dtype, execution backend) lives in the
treedef.  It is what ``repro.api.QuantizedModel`` stores, what
``dist.sharding`` co-shards, and what the launchers stream.

Storage convention: codes are biased to unsigned ``0..2^bits-1`` with the
bias folded into ``zero`` (for symmetric quantizers ``zero == 2^(bits-1)``
exactly), so one dequant rule covers both: ``(codes - zero) * scale``.
This makes dequantization bit-identical to the fake-quant float path the
``quant.pipeline`` quantizers always produced.

Execution dispatch: jax defers binary ops on unrecognised operand types,
so a plain ``x @ w`` inside any model forward routes to
:meth:`PackedWeight.__rmatmul__`:

  * ``backend="reference"`` - dequantize-on-use in pure jnp; XLA fuses the
    dequant into the matmul producer.  The oracle path, bit-identical to
    evaluating the fake-quant float model.
  * ``backend="pallas"`` - the fused ``kernels.dequant_matmul`` kernel
    streams the packed bytes from HBM (interpret mode off-TPU).

Consumers that contract through ``jnp.einsum`` (MoE expert stacks, MLA's
``wkv_b``) cannot dispatch on a custom operand type; those call sites
materialize explicitly via :func:`dense_w`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.quant import pack as packmod
from repro.quant import rtn
from repro.quant.qtypes import QuantConfig, QuantizedTensor

BACKENDS = ("reference", "pallas")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class PackedWeight:
    """Grouped-quantized ``(..., C, H)`` weight; groups of ``group`` along C.

    codes: uint8 - packed ``(..., C/pb, H)`` when ``packed`` else unpacked
      ``(..., C, H)`` (non-byte-divisible channel counts, 3-bit codes).
    scale/zero: float32 ``(..., C/g, H)``.
    c: the original input-channel count (static; the packed axis hides it).
    dtype: numpy dtype name the weight dequantizes back to.
    backend: execution path for ``x @ w`` (see module docstring).
    """

    codes: jax.Array
    scale: jax.Array
    zero: jax.Array
    bits: int
    group: int
    c: int
    dtype: str = "float32"
    packed: bool = True
    backend: str = "reference"

    # -- pytree protocol -------------------------------------------------
    def tree_flatten(self):
        return (self.codes, self.scale, self.zero), (
            self.bits, self.group, self.c, self.dtype, self.packed, self.backend,
        )

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale, zero = children
        bits, group, c, dtype, packed, backend = aux
        return cls(codes=codes, scale=scale, zero=zero, bits=bits, group=group,
                   c=c, dtype=dtype, packed=packed, backend=backend)

    def replace(self, **kw) -> "PackedWeight":
        return dataclasses.replace(self, **kw)

    # -- construction ----------------------------------------------------
    @classmethod
    def from_codes(cls, codes: jax.Array, scale: jax.Array,
                   zero: Optional[jax.Array], *, bits: int, group: int,
                   symmetric: bool = False, dtype: str = "float32",
                   backend: str = "reference") -> "PackedWeight":
        """Wrap quantizer output ``(..., C, H)`` codes + ``(..., C/g, H)``
        scale/zero, biasing symmetric codes to the unsigned storage form
        and byte-packing when the width/channel count allow."""
        c = codes.shape[-2]
        offset = (1 << (bits - 1)) if symmetric else 0
        u = codes.astype(jnp.int32) + offset
        scale = scale.astype(jnp.float32)
        zf = jnp.zeros_like(scale) if zero is None else zero.astype(jnp.float32)
        zf = zf + float(offset)
        packed = packmod.packable(bits, c)
        stored = packmod.pack_codes(u, bits) if packed else u.astype(jnp.uint8)
        return cls(codes=stored, scale=scale, zero=zf, bits=bits, group=group,
                   c=c, dtype=dtype, packed=packed, backend=backend)

    @classmethod
    def from_float(cls, w: jax.Array, cfg: QuantConfig, *,
                   backend: str = "reference") -> "PackedWeight":
        """RTN-quantize a float ``(..., C, H)`` weight (any leading stack
        axes) into the packed artifact form."""
        *lead, c, h = w.shape
        flat = w.astype(jnp.float32).reshape(-1, c, h)
        qt = jax.vmap(lambda m: rtn.quantize_weight_grouped(m, cfg))(flat)
        rs = lambda a: a.reshape(*lead, *a.shape[1:])
        return cls.from_codes(
            rs(qt.codes), rs(qt.scale),
            rs(qt.zero) if qt.zero is not None else None,
            bits=cfg.bits, group=cfg.group, symmetric=cfg.symmetric,
            dtype=str(w.dtype), backend=backend,
        )

    # -- shape metadata --------------------------------------------------
    @property
    def logical_shape(self):
        """Shape of the float weight this dequantizes into."""
        return (*self.codes.shape[:-2], self.c, self.codes.shape[-1])

    @property
    def out_features(self) -> int:
        return self.codes.shape[-1]

    def nbytes_packed(self) -> int:
        n = 1
        for s in self.codes.shape:
            n *= s
        return int(n + 2 * self.scale.size + 2 * self.zero.size)

    # -- execution -------------------------------------------------------
    def int_codes(self) -> jax.Array:
        """Unpacked unsigned integer codes ``(..., C, H)`` (int32)."""
        if self.packed:
            return packmod.unpack_codes(self.codes, self.bits, self.c)
        return self.codes.astype(jnp.int32)

    def dequantize(self, dtype: Any = None) -> jax.Array:
        """Back to the fake-quant float weight: ``(codes - zero) * scale``."""
        dt = dtype if dtype is not None else self.dtype
        codes = self.int_codes()
        *lead, c, h = codes.shape
        ng = c // self.group
        wg = codes.astype(jnp.float32).reshape(*lead, ng, self.group, h)
        wg = (wg - self.zero[..., :, None, :]) * self.scale[..., :, None, :]
        return wg.reshape(*lead, c, h).astype(dt)

    def to_qt(self) -> QuantizedTensor:
        """View as the kernel-facing container (packed, asymmetric form)."""
        if not self.packed:
            raise ValueError("to_qt requires packed codes")
        return QuantizedTensor(codes=self.codes, scale=self.scale,
                               zero=self.zero, bits=self.bits,
                               group=self.group, packed=True)

    def __rmatmul__(self, x):
        """``x @ w`` - the pluggable weight-backend dispatch point."""
        if self.backend == "pallas" and self.packed and self.codes.ndim == 2:
            from repro.kernels import ops  # local: kernels are optional

            return ops.dequant_matmul(x, self.to_qt())
        return x @ self.dequantize()

    def astype(self, dtype) -> jax.Array:
        return self.dequantize(dtype)

    def __getitem__(self, idx) -> "PackedWeight":
        """Index *leading stack axes only* (layer / expert / interleave
        group — e.g. the per-group slicing in transformer._group_slices).
        The trailing (C, H) axes cannot be indexed: the packed-C length
        differs between codes (C/pb) and scale/zero (C/g), so one index
        cannot mean the same rows in all three children."""
        items = idx if isinstance(idx, tuple) else (idx,)
        if any(e is Ellipsis for e in items) or len(items) > self.codes.ndim - 2:
            raise IndexError(
                "PackedWeight indexing is limited to leading stack axes; "
                "dequantize() first to index the (C, H) plane"
            )
        return self.replace(codes=self.codes[idx], scale=self.scale[idx],
                            zero=self.zero[idx])


# ---------------------------------------------------------------------------
# Tree helpers
# ---------------------------------------------------------------------------


def is_packed(x) -> bool:
    return isinstance(x, PackedWeight)


def _map_packed(fn, tree):
    return jax.tree_util.tree_map(
        lambda x: fn(x) if is_packed(x) else x, tree, is_leaf=is_packed
    )


def dense_w(w, dtype: Any = None):
    """Materialize a PackedWeight (einsum consumers); pass arrays through."""
    if is_packed(w):
        return w.dequantize(dtype)
    return w


def dequantize_tree(tree, dtype: Any = None):
    """Replace every PackedWeight leaf with its fake-quant float weight."""
    return _map_packed(lambda w: w.dequantize(dtype), tree)


def set_backend(tree, backend: str):
    """Return ``tree`` with every PackedWeight switched to ``backend``."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown weight backend {backend!r}; want {BACKENDS}")
    return _map_packed(lambda w: w.replace(backend=backend), tree)


def packed_bytes(tree) -> int:
    """Total packed bytes (codes + fp16-equivalent scales/zeros)."""
    total = 0
    for leaf in jax.tree.leaves(tree, is_leaf=is_packed):
        if is_packed(leaf):
            total += leaf.nbytes_packed()
    return total
