"""Quantized linear application.

Two execution paths:
  * ``dequant_matmul_ref``: pure-jnp (dequantize then matmul) - the oracle
    and the path used inside jit for simulated-quant evaluation.
  * ``dequant_matmul``: routes to the Pallas fused dequant-matmul kernel
    (``repro.kernels``) when available/appropriate; on TPU this streams the
    *packed* codes from HBM, which is what makes W2/W4 decode ~4-8x less
    memory-bound (the roofline hillclimb lever).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import pack as packmod
from repro.quant import rtn
from repro.quant.qtypes import QuantConfig, QuantizedTensor


def quantize_for_serving(w: jax.Array, cfg: QuantConfig) -> QuantizedTensor:
    """RTN-quantize + pack a weight for the serving path."""
    qt = rtn.quantize_weight_grouped(w, cfg)
    return packmod.pack(qt)


def dequant_weight(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jax.Array:
    if qt.packed:
        qt = packmod.unpack(qt)
    return rtn.dequantize_weight(qt).astype(dtype)


def dequant_matmul_ref(x: jax.Array, qt: QuantizedTensor) -> jax.Array:
    """y = x @ dequant(W); grouped dequant fused at the jnp level.

    XLA fuses the dequant into the matmul producer on TPU; the Pallas
    kernel variant makes the packed-byte streaming explicit.
    """
    w = dequant_weight(qt, dtype=jnp.float32)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def dequant_matmul(x: jax.Array, qt, *, use_kernel: bool = False) -> jax.Array:
    """Accepts a :class:`QuantizedTensor` or a ``quant.packed.PackedWeight``
    (the artifact leaf routes through its own backend dispatch)."""
    from repro.quant.packed import is_packed

    if is_packed(qt):
        return x @ qt.replace(backend="pallas" if use_kernel else "reference")
    if use_kernel:
        from repro.kernels import ops  # local import: kernels are optional

        return ops.dequant_matmul(x, qt)
    return dequant_matmul_ref(x, qt)
