"""Quantized KV cache (KVz in WxAyKVz).

Per-(token, head) asymmetric quantization over ``head_dim`` - one group per
head vector (head_dim <= 128 in all assigned archs), so scales/zeros are
``(B, S, n_kv)`` fp32 alongside int8 codes ``(B, S, n_kv, head_dim)``.

R3 (the post-RoPE query/key rotation) makes K quantization-friendly; the
cache quantizer here is rotation-agnostic and simply stores what it is
given.  Decode-path dequantization happens on the fly per KV block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant import rtn
from repro.quant.qtypes import QuantConfig


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantKVCache:
    """int8-coded KV cache with per-(token, head) scale/zero.

    When ``bits == 16`` the codes arrays hold the raw bf16 values and
    scale/zero are dummies (kept so the pytree structure is static).
    """

    k_codes: jax.Array  # (B, S, n_kv, hd) int8 or bf16
    v_codes: jax.Array
    k_scale: jax.Array  # (B, S, n_kv)
    k_zero: jax.Array
    v_scale: jax.Array
    v_zero: jax.Array
    length: jax.Array  # () int32 current fill
    bits: int = 16

    def tree_flatten(self):
        return (
            (self.k_codes, self.v_codes, self.k_scale, self.k_zero, self.v_scale, self.v_zero, self.length),
            (self.bits,),
        )

    @classmethod
    def tree_unflatten(cls, aux, ch):
        return cls(*ch, bits=aux[0])

    @classmethod
    def create(cls, batch: int, max_seq: int, n_kv: int, head_dim: int, cfg: QuantConfig,
               dtype=jnp.bfloat16) -> "QuantKVCache":
        code_dtype = jnp.uint8 if cfg.enabled else dtype
        z = lambda: jnp.zeros((batch, max_seq, n_kv, head_dim), code_dtype)
        s = lambda: jnp.zeros((batch, max_seq, n_kv), jnp.float32)
        return cls(z(), z(), s(), s(), s(), s(), jnp.zeros((), jnp.int32),
                   bits=cfg.bits if cfg.enabled else 16)

    @property
    def max_seq(self) -> int:
        return self.k_codes.shape[1]


def _quant_kv(x: jax.Array, cfg: QuantConfig) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, T, n_kv, hd) -> codes, scale, zero (one group per head vec)."""
    scale, zero = rtn.compute_qparams(x, cfg)  # reduce over hd
    # uint8 holds asymmetric codes up to 8 bits (kv quant is asymmetric).
    codes = rtn.quantize(x, scale[..., None], zero[..., None], cfg).astype(jnp.uint8)
    return codes, scale, zero


def cache_update(cache: QuantKVCache, k: jax.Array, v: jax.Array, cfg: QuantConfig,
                 start: jax.Array) -> QuantKVCache:
    """Write T new tokens of K/V at position ``start``."""
    if cfg.enabled:
        kc, ks, kz = _quant_kv(k.astype(jnp.float32), cfg)
        vc, vs, vz = _quant_kv(v.astype(jnp.float32), cfg)
    else:
        kc, vc = k.astype(cache.k_codes.dtype), v.astype(cache.v_codes.dtype)
        b, t, n = k.shape[:3]
        ks = kz = vs = vz = jnp.zeros((b, t, n), jnp.float32)
    upd = lambda buf, val: jax.lax.dynamic_update_slice(buf, val, (0, start, 0, 0))
    upd3 = lambda buf, val: jax.lax.dynamic_update_slice(buf, val, (0, start, 0))
    return QuantKVCache(
        k_codes=upd(cache.k_codes, kc), v_codes=upd(cache.v_codes, vc),
        k_scale=upd3(cache.k_scale, ks), k_zero=upd3(cache.k_zero, kz),
        v_scale=upd3(cache.v_scale, vs), v_zero=upd3(cache.v_zero, vz),
        length=start + k.shape[1], bits=cache.bits,
    )


def cache_kv(cache: QuantKVCache, dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Dequantize the whole cache (decode attention reads it blockwise)."""
    if cache.bits >= 16:
        return cache.k_codes.astype(dtype), cache.v_codes.astype(dtype)
    k = (cache.k_codes.astype(jnp.float32) - cache.k_zero[..., None]) * cache.k_scale[..., None]
    v = (cache.v_codes.astype(jnp.float32) - cache.v_zero[..., None]) * cache.v_scale[..., None]
    return k.astype(dtype), v.astype(dtype)
