"""Declarative per-site quantization policy: the PTQ front door.

The paper's central observation is that *which* rotation sits at *which*
site matters (GSR's block-diagonal Walsh isolates outliers per group, and
layering GSR over learned rotations helps further), and production
recipes need the same per-site freedom for precision: W2 everywhere
except the sensitive ``down_proj`` at W4, GPTQ on attention but cheap RTN
on experts, and so on.  A :class:`QuantPolicy` expresses all of that
declaratively:

* an ordered list of :class:`SiteRule` pattern rules — ``site glob x
  layer range -> (bits, group, method, rotation)`` — resolved first-match
  -wins against every quantizable matmul site of a registered arch;
* a :class:`RotationPlan` naming each rotation slot: R1 (residual
  stream, fused offline) from a pluggable :class:`RotationSpec` source —
  constructed (GH/GW/LH/GSR), learned (SpinQuant-lite), loaded from disk,
  optionally composed with a constructed post-rotation (the
  "GSR-over-SpinQuant" recipe) — plus R2 (per-head, fused), R3 (online
  q/k) and the online R4 slot ahead of each down projection, overridable
  per site through ``SiteRule.rotation``.

``PTQConfig`` (:mod:`repro.quant.pipeline`) remains the one-line
front door; it now *lowers* to a single-rule policy via
``PTQConfig.to_policy()``, so the policy is the real API and the flat
config is a convenience constructor.

Shipped presets (``get_policy``):

==================  ======================================================
``paper-table1``    the paper's main setting: GSR R1, W2 asymmetric MSE-
                    clipped GPTQ group-128 everywhere, A16.
``w2-sensitive-fp4``  W2 everywhere except the sensitive down projections
                    (``*down*``) kept at 4-bit with A8 activations on
                    those same sites — the mixed-precision recipe
                    unreachable from the flat config.
``gsr-over-spinquant``  SpinQuant-lite learned R1 composed with a GSR
                    post-rotation (paper Sec. 4: GSR layered over
                    optimization-based rotations), W4 RTN.
``draft-w2-rtn``    weight-only overlay for ``api.derive_draft``: W2 RTN
                    group-128 on every site, no rotation/act/kv changes —
                    re-quantizes an artifact's packed weights into a cheap
                    self-draft for speculative decoding.
``draft-w3-rtn``    same overlay at W3 (higher acceptance, less
                    compression).
==================  ======================================================
"""
from __future__ import annotations

import dataclasses
import fnmatch
import json
import os
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.models.common import QuantizeSpec
from repro.quant.qtypes import QuantConfig, WAKVConfig

_ROTATION_KINDS = ("I", "GH", "GW", "LH", "GSR")
_ROTATION_SOURCES = ("construct", "learn", "load", "identity")
_METHODS = ("rtn", "gptq")
_BITS = (2, 3, 4, 8, 16)


def _err(msg: str, *, hint: str = "") -> ValueError:
    return ValueError(msg + (f"  ({hint})" if hint else ""))


# ---------------------------------------------------------------------------
# Rotation slots
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RotationSpec:
    """One rotation slot's pluggable source (used for the fused R1 slot).

    ``source``:
      * ``construct`` — build ``kind`` (GH/GW/LH/GSR/I) at ``group``/``seed``;
      * ``learn``     — SpinQuant-lite Cayley optimization initialised from
        ``kind`` (``learn`` selects rotation vs rotation+scale);
      * ``load``      — read an orthogonal ``(dim, dim)`` matrix from
        ``path`` (``.npy``), e.g. a SpinQuant checkpoint;
      * ``identity``  — no rotation.

    ``compose`` post-composes a *constructed* rotation: the applied matrix
    is ``R_base @ R_compose`` (activations see ``x R_base R_compose``) —
    how "GSR over SpinQuant" is expressed.
    """

    source: str = "construct"
    kind: str = "GSR"
    group: int = 128
    seed: int = 0
    path: Optional[str] = None
    compose: Optional[str] = None  # constructed post-rotation kind
    compose_group: int = 128
    learn: str = "rotation"  # rotation | rotation+scale
    learn_steps: int = 120

    def __post_init__(self):
        if self.source not in _ROTATION_SOURCES:
            raise _err(f"RotationSpec.source {self.source!r} unknown",
                       hint=f"expected one of {_ROTATION_SOURCES}")
        if self.kind not in _ROTATION_KINDS:
            raise _err(f"RotationSpec.kind {self.kind!r} unknown",
                       hint=f"expected one of {_ROTATION_KINDS}")
        if self.compose is not None and self.compose not in _ROTATION_KINDS:
            raise _err(f"RotationSpec.compose {self.compose!r} unknown",
                       hint=f"expected one of {_ROTATION_KINDS}")
        if self.source == "load" and not self.path:
            raise _err("RotationSpec(source='load') requires a path",
                       hint="point it at a .npy orthogonal (dim, dim) matrix")
        if self.learn not in ("rotation", "rotation+scale"):
            raise _err(f"RotationSpec.learn {self.learn!r} unknown",
                       hint="expected 'rotation' or 'rotation+scale'")
        if self.group < 1:
            raise _err(f"RotationSpec.group must be >= 1, got {self.group}")

    def base_matrix(self, dim: int) -> Optional[np.ndarray]:
        """Dense base matrix for the non-learned sources (learned sources
        are optimized inside the pipeline, which has model access)."""
        from repro.core.rotation import make_rotation
        from repro.quant.pipeline import fit_group

        if self.source == "identity" or (self.source == "construct"
                                         and self.kind == "I"):
            return None
        if self.source == "construct":
            g = fit_group(dim, self.group)
            return make_rotation(self.kind, dim, group=g, seed=self.seed).dense()
        if self.source == "load":
            if not os.path.exists(self.path):
                raise _err(f"rotation matrix file not found: {self.path}")
            m = np.load(self.path)
            if m.ndim != 2 or m.shape[0] != m.shape[1]:
                raise _err(f"loaded rotation must be square, got {m.shape}")
            if m.shape[0] != dim:
                raise _err(f"loaded rotation is {m.shape[0]}-dim but the "
                           f"model residual stream is {dim}-dim")
            if not np.allclose(m @ m.T, np.eye(dim), atol=1e-4):
                raise _err(f"loaded matrix {self.path} is not orthogonal",
                           hint="R @ R.T must be I (tolerance 1e-4)")
            return m.astype(np.float64)
        return None  # learn: handled by the pipeline

    def compose_matrix(self, dim: int) -> Optional[np.ndarray]:
        from repro.core.rotation import make_rotation
        from repro.quant.pipeline import fit_group

        if self.compose is None or self.compose == "I":
            return None
        g = fit_group(dim, self.compose_group)
        return make_rotation(self.compose, dim, group=g, seed=self.seed).dense()


@dataclasses.dataclass(frozen=True)
class RotationPlan:
    """Names every rotation slot of the stack.

    R1 (residual stream) and R2 (per-head, standard attention) are fused
    offline; R3 (post-RoPE q/k Hadamard) and R4 (ahead of each down
    projection) run online and are carried by the serving
    :class:`~repro.models.common.QuantizeSpec`.  Per-site R4 overrides
    come from ``SiteRule.rotation``.
    """

    r1: RotationSpec = RotationSpec()
    r2: Optional[str] = None  # per-head fused rotation kind (GH/GW), or None
    r3: bool = False
    r4_kind: str = "GH"
    r4_group: int = 128
    r4_seed: int = 1234

    def __post_init__(self):
        if self.r2 is not None and self.r2 not in _ROTATION_KINDS:
            raise _err(f"RotationPlan.r2 {self.r2!r} unknown",
                       hint=f"expected one of {_ROTATION_KINDS} or None")
        if self.r4_kind not in _ROTATION_KINDS:
            raise _err(f"RotationPlan.r4_kind {self.r4_kind!r} unknown",
                       hint=f"expected one of {_ROTATION_KINDS}")


# ---------------------------------------------------------------------------
# Precision rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SiteRule:
    """One pattern rule: ``site glob x layer range -> quantizer config``.

    ``pattern`` globs (``fnmatch``) against both the bare leaf name
    (``w_down``) and the slash-qualified site path (``moe_mlp/w_down``);
    ``layers=(lo, hi)`` restricts the rule to stack layers lo..hi
    inclusive (``hi=None`` = to the end).  ``rotation`` overrides the
    plan's online R4 kind for down-projection sites this rule matches
    (layer-restricted rules cannot carry a rotation override: the online
    op inside the scanned layer body is layer-uniform).  Online rotation
    lookups happen by *bare* site name — the layer body cannot know its
    qualified tree path — so a slash-qualified pattern's last component
    is what a rotation override resolves by (see
    ``QuantizeSpec.r4_for``).

    ``act_bits``/``act_group``/``act_clip`` override the policy-global
    activation quantizer for the GEMM inputs this rule matches — the
    activation-side mirror of the weight fields, resolved by the same
    first-match-wins machinery (``QuantizeSpec.act_for``), with the same
    layer-uniformity constraint as ``rotation``: the ``act_q`` op runs
    inside the scanned layer body.  ``None`` inherits the policy global;
    a rule with no act override set contributes nothing to the resolved
    activation table.
    """

    pattern: str = "*"
    layers: Optional[Tuple[int, Optional[int]]] = None
    bits: int = 4
    group: int = 128
    method: str = "rtn"
    symmetric: bool = False
    mse_clip: bool = True
    clip_ratio: float = 1.0
    rotation: Optional[str] = None  # per-site online R4 override
    act_bits: Optional[int] = None  # per-site activation precision override
    act_group: Optional[int] = None
    act_clip: Optional[float] = None

    def __post_init__(self):
        if not self.pattern:
            raise _err("SiteRule.pattern must be a non-empty glob",
                       hint="e.g. '*', 'w_down', 'moe_mlp/*'")
        if self.bits not in _BITS:
            raise _err(f"SiteRule.bits {self.bits} unsupported",
                       hint=f"expected one of {_BITS}")
        if self.group < 1:
            raise _err(f"SiteRule.group must be >= 1, got {self.group}")
        if self.method not in _METHODS:
            raise _err(f"SiteRule.method {self.method!r} unknown",
                       hint=f"expected one of {_METHODS}")
        if self.rotation is not None and self.rotation not in _ROTATION_KINDS:
            raise _err(f"SiteRule.rotation {self.rotation!r} unknown",
                       hint=f"expected one of {_ROTATION_KINDS}")
        if self.act_bits is not None and self.act_bits not in _BITS:
            raise _err(f"SiteRule.act_bits {self.act_bits} unsupported",
                       hint=f"expected one of {_BITS} or None (inherit)")
        if self.act_group is not None and self.act_group < 1:
            raise _err(f"SiteRule.act_group must be >= 1, got "
                       f"{self.act_group}")
        if self.act_clip is not None and not (0.0 < self.act_clip <= 1.0):
            raise _err(f"SiteRule.act_clip must be in (0, 1], got "
                       f"{self.act_clip}")
        if self.layers is not None:
            lo, hi = self.layers
            if lo < 0 or (hi is not None and hi < lo):
                raise _err(f"SiteRule.layers {self.layers} invalid",
                           hint="want (lo, hi) with 0 <= lo <= hi "
                                "(hi=None = open-ended)")
            if self.rotation is not None:
                raise _err(
                    "a layer-restricted SiteRule cannot override the online "
                    "rotation", hint="online R4 runs inside the scanned "
                    "layer body, so it must be layer-uniform per site; use "
                    "an un-ranged rule for the rotation override")
            if self.has_act_override:
                raise _err(
                    "a layer-restricted SiteRule cannot override activation "
                    "quantization", hint="act_q runs inside the scanned "
                    "layer body, so it must be layer-uniform per site; use "
                    "an un-ranged rule for the act override")

    @property
    def has_act_override(self) -> bool:
        return (self.act_bits is not None or self.act_group is not None
                or self.act_clip is not None)

    # -- matching --------------------------------------------------------
    def matches(self, site: str, layer: Optional[int]) -> bool:
        name = site.rsplit("/", 1)[-1]
        if not (fnmatch.fnmatchcase(site, self.pattern)
                or fnmatch.fnmatchcase(name, self.pattern)):
            return False
        if self.layers is None or layer is None:
            return True
        lo, hi = self.layers
        return layer >= lo and (hi is None or layer <= hi)

    def weight_cfg(self, c: int) -> QuantConfig:
        """Concrete quantizer config for a C-input-channel site."""
        from repro.quant.pipeline import fit_group

        return QuantConfig(bits=self.bits, group=fit_group(c, self.group),
                           symmetric=self.symmetric, mse_clip=self.mse_clip,
                           clip_ratio=self.clip_ratio)


# ---------------------------------------------------------------------------
# The policy
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QuantPolicy:
    """Ordered per-site precision rules + the rotation plan + the online
    (activation / KV) settings — everything `repro.api.quantize` needs.

    Rules resolve first-match-wins per ``(site, layer)``; a site no rule
    matches stays unquantized (add a trailing ``SiteRule("*")`` for a
    default).  ``act_bits``/``act_group``/``act_clip`` are the
    *default* activation quantizer — a rule carrying
    ``act_bits``/``act_group``/``act_clip`` overrides them for the GEMM
    inputs it matches (the first act-carrying rule wins; see
    ``QuantizeSpec.act_for``).  ``kv_bits`` stays policy-global.
    """

    rules: Tuple[SiteRule, ...] = (SiteRule(),)
    rotation: RotationPlan = RotationPlan()
    act_bits: int = 16
    act_group: int = 128
    act_clip: float = 0.9
    kv_bits: int = 16
    seed: int = 0
    n_calib: int = 8
    calib_seq: int = 256
    name: str = ""

    def __post_init__(self):
        if not self.rules:
            raise _err("QuantPolicy needs at least one SiteRule")
        if not all(isinstance(r, SiteRule) for r in self.rules):
            raise _err("QuantPolicy.rules must be SiteRule instances")
        if self.act_bits not in _BITS:
            raise _err(f"QuantPolicy.act_bits {self.act_bits} unsupported",
                       hint=f"expected one of {_BITS}")
        if self.kv_bits not in _BITS:
            raise _err(f"QuantPolicy.kv_bits {self.kv_bits} unsupported",
                       hint=f"expected one of {_BITS}")
        if self.act_group < 1:
            raise _err(f"QuantPolicy.act_group must be >= 1")

    # -- resolution ------------------------------------------------------
    def rule_for(self, site: str, layer: Optional[int] = None
                 ) -> Optional[SiteRule]:
        """First rule matching ``(site, layer)``; None = leave in float."""
        for r in self.rules:
            if r.matches(site, layer):
                return r
        return None

    def resolve(self, cfg) -> "ResolvedPolicy":
        """Concrete per-site plan for a model config (validated)."""
        return resolve_policy(self, cfg)

    def spec(self) -> QuantizeSpec:
        """The serving/online spec this policy implies (R3/R4/acts/KV).

        Rules with activation overrides lower into the spec's resolved
        ``act_sites`` table (pattern -> (bits, group, clip), unset fields
        inheriting the policy globals) exactly as rotation overrides
        lower into ``r4_sites``; a policy with no act overrides lowers to
        an empty table, so every pre-existing config is untouched.
        """
        plan = self.rotation
        r4_sites = tuple(
            (r.pattern, r.rotation, r.group, plan.r4_seed)
            for r in self.rules if r.rotation is not None
        )
        act_sites = tuple(
            (r.pattern,
             self.act_bits if r.act_bits is None else r.act_bits,
             self.act_group if r.act_group is None else r.act_group,
             self.act_clip if r.act_clip is None else r.act_clip)
            for r in self.rules if r.has_act_override
        )
        return QuantizeSpec(
            act_bits=self.act_bits, act_group=self.act_group,
            act_clip=self.act_clip, r4_kind=plan.r4_kind,
            r4_group=plan.r4_group, r4_seed=plan.r4_seed, r3=plan.r3,
            kv_bits=self.kv_bits, r4_sites=r4_sites, act_sites=act_sites,
        )

    # -- serialization ---------------------------------------------------
    def to_json_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["rules"] = [dataclasses.asdict(r) for r in self.rules]
        d["rotation"] = dataclasses.asdict(self.rotation)
        d["rotation"]["r1"] = dataclasses.asdict(self.rotation.r1)
        return d

    @classmethod
    def from_json_dict(cls, d: Dict) -> "QuantPolicy":
        d = dict(d)
        rot = dict(d.pop("rotation", {}))
        r1 = RotationSpec(**rot.pop("r1", {}))
        rules = []
        for r in d.pop("rules", []):
            r = dict(r)
            if r.get("layers") is not None:
                r["layers"] = tuple(r["layers"])
            rules.append(SiteRule(**r))
        return cls(rules=tuple(rules), rotation=RotationPlan(r1=r1, **rot), **d)

    def describe(self) -> str:
        r1 = self.rotation.r1
        src = {"construct": r1.kind, "identity": "I",
               "learn": f"learned({r1.kind} init"
                        + (f", {r1.compose} post)" if r1.compose else ")"),
               "load": f"loaded({r1.path}"
                       + (f", {r1.compose} post)" if r1.compose else ")"),
               }[r1.source]
        rules = "; ".join(
            f"{r.pattern}"
            + (f"[{r.layers[0]}:{'' if r.layers[1] is None else r.layers[1]}]"
               if r.layers else "")
            + f"->W{r.bits}g{r.group}/{r.method}"
            + (f"/R4={r.rotation}" if r.rotation else "")
            + (f"/A{self.act_bits if r.act_bits is None else r.act_bits}"
               + (f"g{r.act_group}" if r.act_group is not None else "")
               if r.has_act_override else "")
            for r in self.rules)
        return (f"policy[{self.name or 'custom'}] R1={src} "
                f"A{self.act_bits}KV{self.kv_bits}: {rules}")


# ---------------------------------------------------------------------------
# Site enumeration + resolution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResolvedSite:
    """One quantizable site of a concrete model: where it lives in the
    params tree, its per-layer rule assignment, and its merged layout."""

    site: str  # slash-qualified site path (e.g. "moe_mlp/w_down")
    path: Tuple[str, ...]  # tree path under params
    n_layers: int
    rule_ids: Tuple[Optional[int], ...]  # per layer; None = float
    in_channels: int

    @property
    def quantized(self) -> bool:
        return any(i is not None for i in self.rule_ids)

    @property
    def homogeneous(self) -> bool:
        return len(set(self.rule_ids)) == 1


@dataclasses.dataclass(frozen=True)
class ResolvedPolicy:
    policy: QuantPolicy
    sites: Tuple[ResolvedSite, ...]

    def site(self, name: str) -> ResolvedSite:
        for s in self.sites:
            if s.site == name or s.site.rsplit("/", 1)[-1] == name:
                return s
        raise KeyError(name)

    def table(self) -> List[Dict]:
        out = []
        for s in self.sites:
            for rid in sorted({i for i in s.rule_ids if i is not None}):
                rule = self.policy.rules[rid]
                layers = [l for l, i in enumerate(s.rule_ids) if i == rid]
                out.append({
                    "site": s.site, "layers": layers, "bits": rule.bits,
                    "group": rule.group, "method": rule.method,
                    "rotation": rule.rotation,
                })
        return out


def _site_layer_map(cfg, path: Tuple[str, ...], lead: Tuple[int, ...]
                    ) -> np.ndarray:
    """Flat layer index for every entry of a leaf's leading stack axes.

    Stacked leaves carry the layer on axis 0 (experts ride an extra E axis
    that is *not* a layer axis); interleaved-MoE groups map ``(g, j)`` to
    ``g * moe_every + j`` (``moe_mlp`` leaves sit in the group's last
    slot); unstacked 2-D leaves (Zamba shared block) are layer 0.
    """
    interleaved = cfg.family == "moe" and cfg.moe_every > 1
    if not lead:
        return np.zeros((1,), np.int64)
    if interleaved and ("dense_mlp" in path or "moe_mlp" in path or
                        len(lead) >= 2):
        every = cfg.moe_every
        g = lead[0]
        if "moe_mlp" in path:
            # (G,) or (G, E): one MoE layer per group, experts ride along.
            layers = np.arange(g) * every + (every - 1)
            reps = int(np.prod(lead[1:], dtype=np.int64)) if len(lead) > 1 else 1
            return np.repeat(layers, reps)
        # attn (G, every, ...) / dense_mlp (G, every-1, ...)
        j = lead[1] if len(lead) > 1 else 1
        layers = (np.arange(g)[:, None] * every + np.arange(j)[None, :])
        reps = int(np.prod(lead[2:], dtype=np.int64)) if len(lead) > 2 else 1
        return np.repeat(layers.reshape(-1), reps)
    # flat stack: axis 0 is the layer; extra axes (E) replicate the layer.
    reps = int(np.prod(lead[1:], dtype=np.int64)) if len(lead) > 1 else 1
    return np.repeat(np.arange(lead[0]), reps)


def act_site_names() -> frozenset:
    """Every site tag an ``act_q`` call may carry: the union of all
    families' quantizable leaf names plus ``lm_head`` (activation-only —
    the final-norm hidden ahead of the output projection; the projection
    weight itself stays float).  The AST lint test
    (``tests/test_act_sites_lint.py``) checks every literal tag in the
    model code against this vocabulary, so policy act rules written
    against ``resolve_policy``'s site names always have a matching tag.
    """
    from repro.quant.pipeline import _FAMILY_WEIGHTS

    names = frozenset().union(*_FAMILY_WEIGHTS.values())
    return names | {"lm_head"}


def enumerate_sites(cfg, params) -> List[Tuple[str, Tuple[str, ...], object]]:
    """All quantizable matmul sites of a params tree:
    ``(qualified site name, tree path, leaf)`` triples.

    Site names drop the uninformative ``layers`` tree level, so a dense
    down projection is ``w_down`` while the interleaved-MoE expert stack
    is ``moe_mlp/w_down`` and the xLSTM matrix block is ``mlstm/wq``.
    """
    from repro.quant.pipeline import _FAMILY_WEIGHTS

    names = _FAMILY_WEIGHTS[cfg.family]
    out = []

    def walk(tree, path):
        for k in sorted(tree):
            v = tree[k]
            if isinstance(v, dict):
                walk(v, path + (k,))
            elif k in names and getattr(v, "ndim", 0) >= 2 and k[0] != "b":
                site = "/".join(p for p in path + (k,) if p != "layers")
                out.append((site, path + (k,), v))

    walk(params, ())
    return out


def resolve_policy(policy: QuantPolicy, cfg, params=None) -> ResolvedPolicy:
    """Resolve rules against a model config (+ optional params tree).

    Validates the resolution with actionable errors:
      * a site must be quantized at every layer or at none (packed and
        float layers cannot share one stacked leaf);
      * heterogeneous per-layer groups must share a common refinement
        (every group a multiple of the finest one);
      * GPTQ rules outside the dense family fall back to RTN (recorded,
        not an error — mirrors the flat-config behaviour).
    """
    if params is None:
        import jax.numpy as jnp

        from repro.models.registry import build_arch

        params = build_arch(cfg).param_specs(dtype=jnp.bfloat16)
    sites = []
    for site, path, leaf in enumerate_sites(cfg, params):
        lead = tuple(leaf.shape[:-2])
        c = leaf.shape[-2]
        layer_map = _site_layer_map(cfg, path, lead)
        layer_ids = sorted(set(int(l) for l in layer_map))
        per_layer: Dict[int, Optional[int]] = {}
        for l in layer_ids:
            rule = policy.rule_for(site, l)
            per_layer[l] = None if rule is None or rule.bits >= 16 else (
                policy.rules.index(rule))
        rule_ids = tuple(per_layer[l] for l in layer_ids)
        quant_layers = [l for l in layer_ids if per_layer[l] is not None]
        if quant_layers and len(quant_layers) != len(layer_ids):
            missing = [l for l in layer_ids if per_layer[l] is None]
            raise _err(
                f"site {site!r} is quantized at layers {quant_layers} but "
                f"left in float at layers {missing}",
                hint="a stacked leaf must be quantized everywhere or "
                     "nowhere; add a rule covering the remaining layers "
                     "(bits<16) or widen the float rule to the whole site")
        if quant_layers:
            groups = sorted({policy.rules[per_layer[l]].weight_cfg(c).group
                             for l in quant_layers})
            gmin = groups[0]
            bad = [g for g in groups if g % gmin]
            if bad:
                raise _err(
                    f"site {site!r}: per-layer groups {groups} have no "
                    f"common refinement (finest is {gmin})",
                    hint="pick group sizes that are multiples of the "
                         "smallest one so scales can share a layout")
        sites.append(ResolvedSite(site=site, path=path,
                                  n_layers=len(layer_ids),
                                  rule_ids=rule_ids, in_channels=c))
    resolved = ResolvedPolicy(policy=policy, sites=tuple(sites))
    if not any(s.quantized for s in resolved.sites) and any(
            r.bits < 16 for r in policy.rules):
        raise _err(
            f"policy quantizes nothing on {cfg.name}: no rule pattern "
            f"matched any site",
            hint=f"sites are {[s.site for s in resolved.sites]}")
    return resolved


# ---------------------------------------------------------------------------
# Presets + lookup
# ---------------------------------------------------------------------------


def _paper_table1() -> QuantPolicy:
    return QuantPolicy(
        name="paper-table1",
        rules=(SiteRule(pattern="*", bits=2, group=128, method="gptq"),),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=128)),
        act_bits=16, kv_bits=16,
    )


def _w2_sensitive_fp4() -> QuantPolicy:
    return QuantPolicy(
        name="w2-sensitive-fp4",
        rules=(
            # the sensitive down projections also carry the only low-bit
            # activations: A8 where the R4 rotation has tamed the
            # outliers, A16 (the policy default) everywhere else
            SiteRule(pattern="*down*", bits=4, group=128, method="rtn",
                     rotation="GSR", act_bits=8),
            SiteRule(pattern="*", bits=2, group=128, method="rtn"),
        ),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=128)),
        act_bits=16, kv_bits=16,
    )


def _gsr_over_spinquant() -> QuantPolicy:
    return QuantPolicy(
        name="gsr-over-spinquant",
        rules=(SiteRule(pattern="*", bits=4, group=128, method="rtn"),),
        rotation=RotationPlan(
            r1=RotationSpec(source="learn", kind="GH", compose="GSR",
                            compose_group=128, learn_steps=60)),
        act_bits=16, kv_bits=16,
    )


def _draft_w2_rtn() -> QuantPolicy:
    # weight-only draft overlay for api.derive_draft: one layer-uniform
    # calibration-free rule, no rotation/act/kv overrides, so the derived
    # draft shares the target's resolved spec (rotations, act rules, KV
    # layout) exactly — only the packed weights get cheaper
    return QuantPolicy(
        name="draft-w2-rtn",
        rules=(SiteRule(pattern="*", bits=2, group=128, method="rtn"),),
        act_bits=16, kv_bits=16,
    )


def _draft_w3_rtn() -> QuantPolicy:
    return QuantPolicy(
        name="draft-w3-rtn",
        rules=(SiteRule(pattern="*", bits=3, group=128, method="rtn"),),
        act_bits=16, kv_bits=16,
    )


PRESETS = {
    "paper-table1": _paper_table1,
    "w2-sensitive-fp4": _w2_sensitive_fp4,
    "gsr-over-spinquant": _gsr_over_spinquant,
    "draft-w2-rtn": _draft_w2_rtn,
    "draft-w3-rtn": _draft_w3_rtn,
}


def get_policy(name_or_json: str) -> QuantPolicy:
    """Resolve a ``--policy`` argument: preset name, JSON string, or path
    to a JSON file (e.g. one produced by ``policy.to_json_dict()``)."""
    if name_or_json in PRESETS:
        return PRESETS[name_or_json]()
    if name_or_json.strip().startswith("{"):
        return QuantPolicy.from_json_dict(json.loads(name_or_json))
    if os.path.exists(name_or_json):
        with open(name_or_json) as f:
            return QuantPolicy.from_json_dict(json.load(f))
    raise _err(f"unknown policy {name_or_json!r}",
               hint=f"expected a preset ({sorted(PRESETS)}), a JSON "
                    f"object, or a path to a JSON file")


def lower_wakv(wakv: str, group: int) -> Tuple[QuantConfig, int, float, int]:
    """Parse a WxAyKVz string into (weight cfg, act bits, act clip, kv bits)
    with a construction-time error (the satellite: bad strings used to
    fail deep inside pack.py with shape errors)."""
    try:
        w = WAKVConfig.parse(wakv, group=group)
    except ValueError as e:
        raise _err(
            f"bad wakv spec {wakv!r}: {e}",
            hint="expected 'W<bits>A<bits>[KV<bits>]', e.g. 'W4A8' or "
                 "'W2A4KV16'") from None
    for label, bits in (("weight", w.weight.bits), ("act", w.act.bits),
                        ("kv", w.kv.bits)):
        if bits not in _BITS:
            raise _err(f"{label} bits {bits} unsupported in {wakv!r}",
                       hint=f"supported widths: {_BITS}")
    return w.weight, w.act.bits, w.act.clip_ratio, w.kv.bits
