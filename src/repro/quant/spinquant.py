"""Learned-rotation / learned-scale baselines (SpinQuant-lite, OSTQuant-lite).

The paper's Table 1 compares its training-free GSR against *optimization-
based* methods.  To reproduce that comparison end-to-end inside this
framework we implement compact versions of both:

  * SpinQuant-lite ("LR"): optimizes the residual-stream rotation R1 on the
    orthogonal manifold via the Cayley transform, minimising a calibration
    Hessian-weighted weight-quantization proxy loss (SpinQuant optimises
    a network loss with Cayley SGD; the proxy keeps this laptop-scale while
    preserving the method's structure: learned orthogonal R, STE through
    the quantizer).
  * OSTQuant-lite ("LR+LS"): additionally learns a per-channel positive
    scaling (smoothing) vector, applied as the equivalence transform
    x -> x diag(1/s) R,  W -> R^T diag(s) W.

Both accept an arbitrary initialisation rotation, which is how the paper's
"GSR as enhanced initialisation for training-based methods" experiment is
run (Sec. 4): init with GH vs GSR and compare the optimised result.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.quant import rtn
from repro.quant.qtypes import QuantConfig


class RotLearnResult(NamedTuple):
    rotation: np.ndarray  # learned (C, C) orthogonal matrix
    scale: Optional[np.ndarray]  # learned per-channel smoothing (C,) or None
    losses: np.ndarray  # proxy loss trajectory


def cayley(a_raw: jax.Array) -> jax.Array:
    """Orthogonal matrix from an unconstrained square parameter.

    A = U - U^T (skew);  R = (I - A) (I + A)^{-1}.  R is exactly orthogonal
    for any A, so plain Adam on ``a_raw`` walks the manifold.
    """
    a = a_raw - a_raw.T
    n = a.shape[0]
    eye = jnp.eye(n, dtype=a.dtype)
    return jnp.linalg.solve((eye + a).T, (eye - a).T).T


def _proxy_loss(
    r: jax.Array,
    log_s: Optional[jax.Array],
    weights_front: List[jax.Array],
    hdiags_front: List[jax.Array],
    weights_rear: List[jax.Array],
    cfg: QuantConfig,
    acts: Optional[jax.Array],
    act_cfg: Optional[QuantConfig],
) -> jax.Array:
    """Hessian-diag-weighted quantization MSE of all rotated weights."""
    loss = 0.0
    s = jnp.exp(log_s) if log_s is not None else None
    for w, hd in zip(weights_front, hdiags_front):
        wr = r.T @ w.astype(jnp.float32)  # front side: W' = R^T W
        if s is not None:
            # smoothing acts in the rotated basis (folded into norm gamma
            # at deployment, see quant.pipeline._apply_smoothing)
            wr = s[:, None] * wr
        dq = rtn.fake_quant_weight(wr, cfg)
        loss = loss + jnp.mean(hd[:, None] * (dq - wr) ** 2)
    for w in weights_rear:
        wr = w.astype(jnp.float32) @ r  # rear side: W' = W R
        dq = rtn.fake_quant_weight(wr, cfg)
        loss = loss + jnp.mean((dq - wr) ** 2)
    if acts is not None and act_cfg is not None and act_cfg.enabled:
        xr = acts.astype(jnp.float32) @ r
        if s is not None:
            xr = xr / s[None, :]
        dqa = rtn.fake_quant_act_grouped(xr, act_cfg)
        loss = loss + jnp.mean((dqa - xr) ** 2)
    return loss


def optimize_rotation(
    r_init: np.ndarray,
    weights_front: List[jax.Array],
    weights_rear: List[jax.Array],
    cfg: QuantConfig,
    *,
    hdiags_front: Optional[List[jax.Array]] = None,
    acts: Optional[jax.Array] = None,
    act_cfg: Optional[QuantConfig] = None,
    learn_scale: bool = False,
    steps: int = 150,
    lr: float = 1e-3,
) -> RotLearnResult:
    """Adam on (Cayley param, optional log-scale) starting at ``r_init``.

    The optimised rotation is ``cayley(A) @ r_init`` with A init 0, so step
    0 reproduces the initialisation exactly - the learned method is a
    strict refinement of whichever rotation (GH/GW/LH/GSR) seeds it.
    """
    c = r_init.shape[0]
    r0 = jnp.asarray(r_init, jnp.float32)
    # Proxy quantizer without the MSE grid search (cheap inner loop).
    prox_cfg = cfg.replace(mse_clip=False)
    if hdiags_front is None:
        hdiags_front = [jnp.ones((w.shape[0],), jnp.float32) for w in weights_front]

    def loss_fn(params):
        a_raw, log_s = params
        r = cayley(a_raw) @ r0
        return _proxy_loss(
            r, log_s if learn_scale else None, weights_front, hdiags_front,
            weights_rear, prox_cfg, acts, act_cfg,
        )

    params = (
        jnp.zeros((c, c), jnp.float32),
        jnp.zeros((c,), jnp.float32) if learn_scale else jnp.zeros((0,), jnp.float32),
    )
    # Hand-rolled Adam (no external deps).
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def step(i, params, m, v):
        loss, g = jax.value_and_grad(loss_fn)(params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        m = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m, g)
        v = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v, g)
        t = i + 1.0
        mhat = jax.tree.map(lambda a: a / (1 - b1**t), m)
        vhat = jax.tree.map(lambda a: a / (1 - b2**t), v)
        params = jax.tree.map(lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mhat, vhat)
        return loss, params, m, v

    losses = []
    for i in range(steps):
        loss, params, m, v = step(jnp.float32(i), params, m, v)
        losses.append(float(loss))
    r_final = np.asarray(cayley(params[0]) @ r0, dtype=np.float64)
    s_final = np.asarray(jnp.exp(params[1]), dtype=np.float64) if learn_scale else None
    return RotLearnResult(rotation=r_final, scale=s_final, losses=np.asarray(losses))
