"""Round-to-nearest group quantization (weights + activations).

Layout conventions (used across the whole framework):
  * weights are ``(in_features C, out_features H)`` so ``y = x @ W``;
    quantization groups run along the *input* (reduction) axis C, i.e.
    scale/zero have shape ``(C // G, H)`` - matching GPTQ / QuaRot.
  * activations are ``(..., C)``; groups along the channel axis, scales
    ``(..., C // G)``.

All quantizers are implemented as pure jax functions so they can sit inside
jit / grad (straight-through estimator for fake-quant) and inside the GPTQ
solver loop.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quant.qtypes import QuantConfig, QuantizedTensor


def _grouped(x: jax.Array, group: int, axis: int = -1) -> jax.Array:
    """Reshape axis into (num_groups, group)."""
    axis = axis % x.ndim
    if x.shape[axis] % group != 0:
        raise ValueError(f"axis size {x.shape[axis]} not divisible by group {group}")
    new_shape = x.shape[:axis] + (x.shape[axis] // group, group) + x.shape[axis + 1 :]
    return x.reshape(new_shape)


def compute_qparams(
    xg: jax.Array, cfg: QuantConfig, *, clip: Optional[jax.Array] = None
) -> Tuple[jax.Array, jax.Array]:
    """Scale/zero from a grouped view; reduction over the group axis.

    Args:
      xg: (..., num_groups, group, ...) with the group axis explicit - the
        caller reduces over `axis`; here we assume the group axis is the one
        directly after the num_groups axis, so we reduce over it via the
        convention that xg is (..., G) i.e. LAST axis is the group.
      clip: optional per-group multiplicative clip ratio in (0, 1].
    Returns: (scale, zero) with the group axis reduced.
    """
    if cfg.symmetric:
        amax = jnp.max(jnp.abs(xg), axis=-1)
        if clip is not None:
            amax = amax * clip
        amax = amax * cfg.clip_ratio
        scale = amax / cfg.qmax
        scale = jnp.where(scale <= 0, 1.0, scale)
        zero = jnp.zeros_like(scale)
    else:
        xmax = jnp.max(xg, axis=-1)
        xmin = jnp.min(xg, axis=-1)
        if clip is not None:
            xmax = xmax * clip
            xmin = xmin * clip
        xmax = jnp.maximum(xmax, 0.0) * cfg.clip_ratio
        xmin = jnp.minimum(xmin, 0.0) * cfg.clip_ratio
        scale = (xmax - xmin) / (cfg.qmax - cfg.qmin)
        scale = jnp.where(scale <= 0, 1.0, scale)
        zero = jnp.round(-xmin / scale)
    return scale, zero


def quantize(x: jax.Array, scale: jax.Array, zero: jax.Array, cfg: QuantConfig) -> jax.Array:
    """x -> integer codes, given broadcastable scale/zero."""
    q = jnp.round(x / scale + zero)
    return jnp.clip(q, cfg.qmin, cfg.qmax)


def dequantize(q: jax.Array, scale: jax.Array, zero: jax.Array) -> jax.Array:
    return (q - zero) * scale


def fake_quant(x: jax.Array, scale: jax.Array, zero: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Quantize-dequantize with a straight-through estimator gradient."""
    dq = dequantize(quantize(x, scale, zero, cfg), scale, zero)
    return x + jax.lax.stop_gradient(dq - x)


# ---------------------------------------------------------------------------
# Weights: (C, H), groups along C
# ---------------------------------------------------------------------------


def _mse_clip_search(
    wg: jax.Array, cfg: QuantConfig
) -> Tuple[jax.Array, jax.Array]:
    """Grid-search a per-group clip ratio minimising quant MSE.

    wg: (num_groups, G, H) grouped weight view (group axis = 1). The scale
    reduction in compute_qparams is over the LAST axis, so we transpose to
    (num_groups, H, G).
    Returns per-(group, H) scale/zero of shape (num_groups, H).
    """
    wt = jnp.swapaxes(wg, -1, -2)  # (N, H, G)
    ratios = jnp.linspace(1.0, 0.3, cfg.mse_grid, dtype=wt.dtype)

    def eval_ratio(r):
        cfgr = cfg.replace(clip_ratio=float(1.0))  # ratio folded via clip arg
        scale, zero = compute_qparams(wt, cfgr, clip=jnp.full(wt.shape[:-1], r, wt.dtype))
        dq = dequantize(
            quantize(wt, scale[..., None], zero[..., None], cfg), scale[..., None], zero[..., None]
        )
        err = jnp.sum((dq - wt) ** 2, axis=-1)  # (N, H)
        return err, scale, zero

    errs, scales, zeros = jax.vmap(eval_ratio)(ratios)  # (R, N, H)
    best = jnp.argmin(errs, axis=0)  # (N, H)
    scale = jnp.take_along_axis(scales, best[None], axis=0)[0]
    zero = jnp.take_along_axis(zeros, best[None], axis=0)[0]
    return scale, zero


def weight_qparams(w: jax.Array, cfg: QuantConfig) -> Tuple[jax.Array, jax.Array]:
    """Per-group scale/zero for a (C, H) weight; shapes (C//G, H)."""
    wg = _grouped(w, cfg.group, axis=0)  # (N, G, H)
    if cfg.mse_clip:
        return _mse_clip_search(wg, cfg)
    wt = jnp.swapaxes(wg, -1, -2)  # (N, H, G)
    scale, zero = compute_qparams(wt, cfg)  # (N, H)
    return scale, zero


def quantize_weight_grouped(w: jax.Array, cfg: QuantConfig) -> QuantizedTensor:
    """RTN-quantize a (C, H) weight into codes + grouped scales."""
    scale, zero = weight_qparams(w, cfg)
    wg = _grouped(w, cfg.group, axis=0)  # (N, G, H)
    codes = quantize(wg, scale[:, None, :], zero[:, None, :], cfg)
    codes = codes.reshape(w.shape).astype(jnp.int32)
    return QuantizedTensor(codes=codes, scale=scale, zero=zero, bits=cfg.bits, group=cfg.group)


def dequantize_weight(qt: QuantizedTensor) -> jax.Array:
    assert not qt.packed, "unpack first (repro.quant.pack.unpack)"
    c, h = qt.codes.shape
    g = qt.group
    codes = qt.codes.reshape(c // g, g, h).astype(qt.scale.dtype)
    zero = qt.zero if qt.zero is not None else 0.0
    w = (codes - (zero[:, None, :] if qt.zero is not None else 0.0)) * qt.scale[:, None, :]
    return w.reshape(c, h)


def fake_quant_weight(w: jax.Array, cfg: QuantConfig) -> jax.Array:
    if not cfg.enabled:
        return w
    return dequantize_weight(quantize_weight_grouped(w, cfg)).astype(w.dtype)


# ---------------------------------------------------------------------------
# Activations: (..., C), groups along last axis
# ---------------------------------------------------------------------------


def fake_quant_act_grouped(x: jax.Array, cfg: QuantConfig) -> jax.Array:
    """Symmetric RTN act fake-quant (paper: sym, clip 0.9, group 128).

    Quant math runs in f32 regardless of input dtype (matches the TPU VPU
    and the Pallas kernel numerics), result cast back to x.dtype.
    """
    if not cfg.enabled:
        return x
    xg = _grouped(x.astype(jnp.float32), cfg.group, axis=-1)  # (..., N, G)
    scale, zero = compute_qparams(xg, cfg)
    out = fake_quant(xg, scale[..., None], zero[..., None], cfg)
    return out.reshape(x.shape).astype(x.dtype)


def quantize_act_grouped(x: jax.Array, cfg: QuantConfig) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Real act quantization for the serving path: codes + scale + zero."""
    xg = _grouped(x, cfg.group, axis=-1)
    scale, zero = compute_qparams(xg, cfg)
    codes = quantize(xg, scale[..., None], zero[..., None], cfg).astype(jnp.int32)
    return codes.reshape(x.shape), scale, zero
