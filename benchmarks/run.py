# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: every paper table/figure + framework microbenches.

  PYTHONPATH=src python -m benchmarks.run [--fast]

Order: cheap theory checks first, then kernel microbench, then the
end-to-end PTQ tables on the trained bench model (slowest).  Each suite
also writes results/<suite>.json.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    fast = "--fast" in sys.argv
    t0 = time.time()

    print("# === sequency_analysis (paper Sec 2.1/3.2) ===")
    from benchmarks import sequency_analysis

    for r in sequency_analysis.run(quiet=True):
        print(f"sequency/dim{r['dim']}/g{r['group']},0,"
              f"varH={r['var_hadamard']:.1f};varRHT={r['var_rht']:.1f};"
              f"varW={r['var_walsh']:.1f}")

    print("# === quant_error (paper Sec 3.2 / Obs #1) ===")
    from benchmarks import quant_error

    for r in quant_error.run(quiet=True):
        vals = ";".join(f"{k}={r[k]:.5f}" for k in ("I", "GH", "GW", "LH", "GSR"))
        print(f"quant_error/{r['weights']}/W{r['bits']},0,{vals}")

    print("# === kernels (deployment hot spots) ===")
    from benchmarks import kernels_bench

    for r in kernels_bench.run(quiet=True):
        print(f"kernel/{r['name']},{r['us']:.1f},bytes={r['hbm_bytes']:.3e}")

    print("# === serve (continuous vs static batching) ===")
    from benchmarks import serve_bench

    for r in serve_bench.run(quiet=True, fast=fast):
        print(f"serve/{r['name']},0,tok_s={r['tokens_per_s']:.1f};"
              f"util={r['utilisation']:.3f};steps={r['decode_steps']}")

    print("# === eval_ppl (policy presets on the trained bench model) ===")
    from benchmarks import eval_ppl

    for r in eval_ppl.run(quiet=True, fast=fast):
        print(f"eval_ppl/{r['policy']},0,ppl={r['ppl']:.3f};"
              f"top1={r['top1']:.2f};mib={r['packed_mib']:.3f}")

    if not fast:
        print("# === table1 (paper Table 1) ===")
        from benchmarks import table1

        rows1 = table1.run(quiet=True)
        for r in rows1:
            print(f"table1/{r['method']}/{r['bits']}/{r['r1']},"
                  f"{r.get('quant_s', 0)},ppl={r['ppl']:.3f};top1={r['top1']:.2f}")
        ok, n = table1._verdict(rows1, quiet=True)
        print(f"table1/ordering_checks,0,{ok}/{n} hold")

        print("# === table2 (paper Table 2 / A.2) ===")
        from benchmarks import table2

        for r in table2.run(quiet=True):
            print(f"table2/R1={r['r1']}/R4={r['r4']},0,"
                  f"ppl_w2={r['ppl_w2']:.3f};ppl_w2a4={r['ppl_w2a4']:.3f}")

    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
