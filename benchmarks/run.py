# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark driver: every paper table/figure + framework microbenches.

  PYTHONPATH=src python -m benchmarks.run [--fast]
  PYTHONPATH=src python -m benchmarks.run --snapshot

Order: cheap theory checks first, then kernel microbench, then the
end-to-end PTQ tables on the trained bench model (slowest).  Each suite
also writes results/<suite>.json.

``--snapshot`` instead refreshes the curated in-repo trend files —
``BENCH_serve.json``, ``BENCH_quant.json``, ``BENCH_ppl.json`` — from a
deterministic fast run, stripping every wall-clock-derived field so the
committed snapshots diff cleanly across machines.  The quant/ppl
snapshots are :class:`repro.obs.metrics.MetricsRegistry` JSON exports:
the same schema the serving engine emits under ``--metrics-out``.
"""
from __future__ import annotations

import json
import sys
import time

# wall-clock-derived row fields: machine-dependent, stripped from the
# committed BENCH_serve.json snapshot (results/serve_bench.json keeps them)
_VOLATILE = ("wall_s", "tokens_per_s", "mean_ttft_s", "overhead_frac")


def snapshot() -> None:
    from benchmarks import eval_ppl, quant_error, serve_bench
    from repro.obs.metrics import MetricsRegistry

    print("# refreshing BENCH_serve.json (serve_bench --fast)")
    rows = [{k: v for k, v in r.items() if k not in _VOLATILE}
            for r in serve_bench.run(quiet=True, fast=True)]
    with open("BENCH_serve.json", "w") as f:
        json.dump({
            "_comment": "Curated serve_bench --fast snapshot (reference "
            "backend): the repo's diffable serving-perf trajectory. "
            "Refresh: PYTHONPATH=src python -m benchmarks.run --snapshot. "
            "Wall-clock-derived fields (wall_s, tokens_per_s, mean_ttft_s, "
            "overhead_frac) are stripped; utilisation, decode_steps, "
            "host_syncs, prefill_tokens_computed/saved, prefix_hit_rate, "
            "blocks_shared, acceptance_rate, decode_steps_saved, "
            "tokens_match, and tokens_sha1 are the stable signals (the two "
            "prefix rows must share tokens_sha1, the three spec rows "
            "likewise, and the faults_off row must report "
            "tokens_match=true - prefix sharing, greedy spec decode, and "
            "an armed-but-empty fault plan are all bit-exact).",
            "arch": serve_bench.ARCH, "slots": serve_bench.SLOTS,
            "trace_seed": serve_bench.TRACE_SEED, "n_requests": 24,
            "rows": rows}, f, indent=1)

    print("# refreshing BENCH_quant.json (quant_error)")
    reg = MetricsRegistry()
    g = reg.gauge("quant_error_rel_mse",
                  "relative weight-quantization MSE per rotation kind",
                  labels=("weights", "bits", "rotation"))
    for r in quant_error.run(quiet=True):
        for kind in ("I", "GH", "GW", "LH", "GSR"):
            g.set(round(r[kind], 6), weights=r["weights"],
                  bits=str(r["bits"]), rotation=kind)
    with open("BENCH_quant.json", "w") as f:
        json.dump({
            "_comment": "Curated quant_error snapshot as a MetricsRegistry "
            "JSON export (fixed seeds - fully deterministic). Refresh: "
            "PYTHONPATH=src python -m benchmarks.run --snapshot. The paper "
            "orderings must hold per (weights, bits) series: GW<=GH and "
            "GSR<=LH everywhere (sequency), GSR<=GH and LH<=GH on the "
            "outlier suite (local confinement, Fig. 2).",
            "metrics": reg.to_json()}, f, indent=1)

    print("# refreshing BENCH_ppl.json (eval_ppl --fast)")
    reg = MetricsRegistry()
    ppl = reg.gauge("eval_ppl", "held-out perplexity on the synthetic "
                    "stream (trained bench model)", labels=("policy",))
    top1 = reg.gauge("eval_top1", "top-1 next-token accuracy",
                     labels=("policy",))
    mib = reg.gauge("eval_packed_mib", "packed artifact size (MiB)",
                    labels=("policy",))
    for r in eval_ppl.run(quiet=True, fast=True):
        ppl.set(round(r["ppl"], 3), policy=r["policy"])
        top1.set(round(r["top1"], 4), policy=r["policy"])
        mib.set(round(r["packed_mib"], 3), policy=r["policy"])
    with open("BENCH_ppl.json", "w") as f:
        json.dump({
            "_comment": "Curated eval_ppl --fast snapshot as a "
            "MetricsRegistry JSON export (cached bench model at "
            "results/bench_model.npz; trained deterministically on first "
            "run). Refresh: PYTHONPATH=src python -m benchmarks.run "
            "--snapshot. float16 is the quality ceiling; every quantized "
            "policy should stay within a few percent of it and the GSR "
            "presets must not regress across PRs.",
            "metrics": reg.to_json()}, f, indent=1)
    print("# snapshot done: BENCH_serve.json BENCH_quant.json BENCH_ppl.json")


def main() -> None:
    if "--snapshot" in sys.argv:
        snapshot()
        return
    fast = "--fast" in sys.argv
    t0 = time.time()

    print("# === sequency_analysis (paper Sec 2.1/3.2) ===")
    from benchmarks import sequency_analysis

    for r in sequency_analysis.run(quiet=True):
        print(f"sequency/dim{r['dim']}/g{r['group']},0,"
              f"varH={r['var_hadamard']:.1f};varRHT={r['var_rht']:.1f};"
              f"varW={r['var_walsh']:.1f}")

    print("# === quant_error (paper Sec 3.2 / Obs #1) ===")
    from benchmarks import quant_error

    for r in quant_error.run(quiet=True):
        vals = ";".join(f"{k}={r[k]:.5f}" for k in ("I", "GH", "GW", "LH", "GSR"))
        print(f"quant_error/{r['weights']}/W{r['bits']},0,{vals}")

    print("# === kernels (deployment hot spots) ===")
    from benchmarks import kernels_bench

    for r in kernels_bench.run(quiet=True):
        print(f"kernel/{r['name']},{r['us']:.1f},bytes={r['hbm_bytes']:.3e}")

    print("# === serve (continuous vs static batching) ===")
    from benchmarks import serve_bench

    for r in serve_bench.run(quiet=True, fast=fast):
        # prefix/spec/obs rows carry their own signal set; print what's there
        tok_s = r.get("tokens_per_s")
        util = r.get("utilisation")
        parts = [f"tok_s={tok_s:.1f}" if tok_s is not None else "tok_s=-",
                 f"util={util:.3f}" if util is not None else "util=-",
                 f"steps={r.get('decode_steps', '-')}"]
        print(f"serve/{r['name']},0,{';'.join(parts)}")

    print("# === eval_ppl (policy presets on the trained bench model) ===")
    from benchmarks import eval_ppl

    for r in eval_ppl.run(quiet=True, fast=fast):
        print(f"eval_ppl/{r['policy']},0,ppl={r['ppl']:.3f};"
              f"top1={r['top1']:.2f};mib={r['packed_mib']:.3f}")

    if not fast:
        print("# === table1 (paper Table 1) ===")
        from benchmarks import table1

        rows1 = table1.run(quiet=True)
        for r in rows1:
            print(f"table1/{r['method']}/{r['bits']}/{r['r1']},"
                  f"{r.get('quant_s', 0)},ppl={r['ppl']:.3f};top1={r['top1']:.2f}")
        ok, n = table1._verdict(rows1, quiet=True)
        print(f"table1/ordering_checks,0,{ok}/{n} hold")

        print("# === table2 (paper Table 2 / A.2) ===")
        from benchmarks import table2

        for r in table2.run(quiet=True):
            print(f"table2/R1={r['r1']}/R4={r['r4']},0,"
                  f"ppl_w2={r['ppl_w2']:.3f};ppl_w2a4={r['ppl_w2a4']:.3f}")

    print(f"# total {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
