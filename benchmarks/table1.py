"""Paper Table 1: {QuaRot, SpinQuant-lite, OSTQuant-lite} x {GH, GW, LH, GSR}
x {W2A16, W2A4} -> PPL + 0-shot proxy accuracy.

Prints ``name,us_per_call,derived`` CSV rows (derived = "ppl=..;top1=..")
and a verdict on the paper's claimed orderings.
"""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import GROUP, evaluate, get_trained_model
from repro.models.common import NOQUANT
from repro.quant.pipeline import PTQConfig, quantize_model

ROTS = ["GH", "GW", "LH", "GSR"]
METHODS = [
    ("quarot", "gptq", "none"),
    ("spinquant-lite", "gptq", "rotation"),
    ("ostquant-lite", "gptq", "rotation+scale"),
]
SETTINGS = ["W2A16", "W2A4"]


def run(quiet: bool = False):
    arch, params = get_trained_model(quiet=quiet)
    base = evaluate(arch, params, NOQUANT)
    rows = [{"method": "fp", "r1": "-", "bits": "W16A16", **base}]
    if not quiet:
        print(f"fp16 baseline: ppl={base['ppl']:.2f} top1={base['top1']:.2f}")
    for bits in SETTINGS:
        for mname, wq_method, learned in METHODS:
            for r1 in ROTS:
                t0 = time.time()
                ptq = PTQConfig(r1_kind=r1, wakv=bits, method=wq_method,
                                group=GROUP, learned=learned, learn_steps=80,
                                n_calib=4, calib_seq=64)
                qp, spec = quantize_model(arch, params, ptq)
                m = evaluate(arch, qp, spec)
                dt = time.time() - t0
                rows.append({"method": mname, "r1": r1, "bits": bits, **m,
                             "quant_s": round(dt, 1)})
                if not quiet:
                    print(f"{mname:15s} {bits:6s} {r1:4s} ppl={m['ppl']:8.2f} "
                          f"top1={m['top1']:6.2f}  ({dt:.0f}s)")
    os.makedirs("results", exist_ok=True)
    with open("results/table1.json", "w") as f:
        json.dump(rows, f, indent=1)
    _verdict(rows, quiet)
    return rows


def _verdict(rows, quiet=False):
    """Check the paper's ordering claims on the measured numbers."""
    byk = {(r["method"], r["bits"], r["r1"]): r["ppl"] for r in rows if r["r1"] != "-"}
    checks = []
    for bits in SETTINGS:
        for m, _, _ in METHODS:
            gh, gw = byk[(m, bits, "GH")], byk[(m, bits, "GW")]
            lh, gsr = byk[(m, bits, "LH")], byk[(m, bits, "GSR")]
            checks.append((f"{m}/{bits}: GW<=GH (sequency helps)", gw <= gh * 1.02))
            checks.append((f"{m}/{bits}: GSR<=LH (sequency helps locally)", gsr <= lh * 1.02))
            checks.append((f"{m}/{bits}: local<=global (LH<=GH)", lh <= gh * 1.02))
            checks.append((f"{m}/{bits}: GSR<=GH (paper headline)", gsr <= gh * 1.02))
    ok = sum(c for _, c in checks)
    if not quiet:
        for name, c in checks:
            print(("  PASS " if c else "  fail ") + name)
        print(f"[table1] {ok}/{len(checks)} ordering checks hold")
    return ok, len(checks)


def main():
    rows = run()
    for r in rows:
        print(f"table1/{r['method']}/{r['bits']}/{r['r1']},0,"
              f"ppl={r['ppl']:.3f};top1={r['top1']:.2f}")


if __name__ == "__main__":
    main()
