"""Roofline analysis from the dry-run records (EXPERIMENTS.md §Roofline).

Sources and their validity:
  * ``memory_analysis`` (per-device peak buffers)  - exact, trip-count
    independent -> the HBM-fit column and memory-iteration deltas.
  * HLO collective parse (x layer-count for scan-body collectives,
    x microbatches for train) -> the collective term.
  * ``cost_analysis``                              - XLA counts while
    bodies ONCE (verified empirically), so raw FLOPs/bytes undercount by
    the enclosing trip counts.  The compute and memory *terms* therefore
    come from an auditable analytic model over the exact configs (matmul
    + attention/SSD terms, weight/cache/activation traffic), with the
    raw HLO numbers retained in the JSON for cross-checking.

Terms (seconds, per device, TPU v5e):
  compute    = FLOPs_dev / 197e12
  memory     = bytes_dev / 819e9
  collective = wire_bytes_dev / 50e9
roofline fraction = ideal_time / dominant_term,
ideal_time = MODEL_FLOPS / (197e12 x chips).
"""
from __future__ import annotations

import glob
import json
import os
import sys
from typing import Dict, List

from repro.configs.base import SHAPES
from repro.models.registry import get_config

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9
HBM_BYTES = 16 * 2**30


def load_records(path: str = "results/dryrun") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


# ---------------------------------------------------------------------------
# Analytic cost model (per device)
# ---------------------------------------------------------------------------


def _attn_flops_fwd(cfg, b, s) -> float:
    """Quadratic attention MACs*2 (causal halved), per full forward."""
    if cfg.family == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        per_tok_pair = cfg.n_heads * (qk + cfg.v_head_dim)
        return 2.0 * b * s * s * 0.5 * per_tok_pair * cfg.n_layers
    if cfg.family == "ssm":  # linear attention: state-sized, not quadratic
        dh = cfg.d_model // cfg.n_heads
        return 4.0 * b * s * cfg.n_heads * dh * dh * cfg.n_layers
    if cfg.family == "hybrid":
        di = cfg.ssm_expand * cfg.d_model
        dh = di // cfg.ssm_heads
        ssd = 4.0 * b * s * cfg.ssm_heads * cfg.ssm_state * dh * cfg.n_layers
        n_apps = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        attn = 2.0 * b * s * s * 0.5 * cfg.n_heads * cfg.hd * 2 * n_apps
        return ssd + attn
    return 2.0 * b * s * s * 0.5 * cfg.n_heads * cfg.hd * 2 * cfg.n_layers


def analytic_flops(rec: Dict, chips: int) -> float:
    """Per-device FLOPs for the lowered step."""
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    b, s = shape.global_batch, shape.seq_len
    total, active = cfg.param_count()
    if rec["kind"] == "train":
        fwd = 2.0 * active * b * s + _attn_flops_fwd(cfg, b, s)
        # bwd = 2x fwd, remat recompute = +1x fwd -> 4x
        return 4.0 * fwd / chips
    if rec["kind"] == "prefill":
        return (2.0 * active * b * s + _attn_flops_fwd(cfg, b, s)) / chips
    # decode: one token; attention reads the whole cache
    per_tok = 2.0 * active * b
    if cfg.family in ("dense", "moe"):
        per_tok += 4.0 * b * s * cfg.n_heads * cfg.hd * cfg.n_layers
    elif cfg.family == "mla":
        qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        per_tok += 2.0 * b * s * cfg.n_heads * (cfg.kv_lora_rank + qk) * cfg.n_layers
    elif cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        per_tok += 4.0 * b * s * cfg.n_heads * cfg.hd * n_apps
    return per_tok / chips


def analytic_bytes(rec: Dict, chips: int) -> float:
    """Per-device HBM traffic for the lowered step.

    Sharding-aware denominators: weights are tensor-parallel over the
    model axis (16) and additionally over the data axes only under FSDP;
    activations are data-parallel; caches shard over both.
    """
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    b, s = shape.global_batch, shape.seq_len
    total, active = cfg.param_count()
    model_size = 16
    data_size = max(chips // model_size, 1)
    wbytes = 2.0 if rec.get("wbits", 16) >= 16 else rec["wbits"] / 8.0
    param_shards = chips if rec.get("fsdp_axes") else model_size
    params_dev = total * wbytes / param_shards
    act_dev = b * s * cfg.d_model * 2.0 / data_size
    if rec["kind"] == "train":
        mb = rec.get("microbatches", 1)
        mdt = 2.0 if rec.get("moment_dtype") == "bfloat16" else 4.0
        # weights: fwd+remat+bwd reads per microbatch; grads + adam traffic
        # (optimizer state is sharded like the params)
        w_traffic = params_dev * (3.0 * mb + 2.0) + (
            total / param_shards
        ) * (3 * mdt + 4)
        a_traffic = act_dev * 2.0 * cfg.n_layers * 3.0  # layer in/out, fwd+remat+bwd
        return w_traffic + a_traffic
    if rec["kind"] == "prefill":
        kvb = 2.0 if rec.get("kvbits", 16) >= 16 else rec["kvbits"] / 8.0
        cache_write = _cache_bytes(cfg, b, s, kvb) / chips
        return params_dev + act_dev * 2.0 * cfg.n_layers + cache_write
    # decode: stream weights + read cache + write one token
    kvb = 2.0 if rec.get("kvbits", 16) >= 16 else rec["kvbits"] / 8.0
    cache_read = _cache_bytes(cfg, b, s, kvb) / chips
    return params_dev + cache_read


def _cache_bytes(cfg, b, s, kvb) -> float:
    if cfg.family in ("dense", "moe"):
        return 2.0 * b * s * cfg.n_kv_heads * cfg.hd * kvb * cfg.n_layers
    if cfg.family == "mla":
        return b * s * (cfg.kv_lora_rank * kvb + cfg.qk_rope_dim * 2.0) * cfg.n_layers
    if cfg.family == "hybrid":
        n_apps = cfg.n_layers // cfg.attn_every if cfg.attn_every else 0
        di = cfg.ssm_expand * cfg.d_model
        dh = di // cfg.ssm_heads
        state = cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_state * dh * 4.0
        return 2.0 * b * s * cfg.n_kv_heads * cfg.hd * kvb * n_apps + state
    if cfg.family == "ssm":
        dh = cfg.d_model // cfg.n_heads
        return cfg.n_layers * b * cfg.n_heads * dh * dh * 4.0
    return 0.0


# ---------------------------------------------------------------------------


def analyze(rec: Dict) -> Dict:
    chips = 1
    for s in rec["mesh"].split("x"):
        chips *= int(s)
    flops_dev = analytic_flops(rec, chips)
    bytes_dev = analytic_bytes(rec, chips)
    mb = rec.get("microbatches", 1) if rec["kind"] == "train" else 1
    wire_dev = rec["collective_wire_bytes"] * mb

    t = {
        "compute": flops_dev / PEAK_FLOPS,
        "memory": bytes_dev / HBM_BW,
        "collective": wire_dev / LINK_BW,
    }
    dominant = max(t, key=t.get)
    model_flops = rec.get("model_flops", 0.0)
    t_ideal = model_flops / (PEAK_FLOPS * chips)
    frac = t_ideal / max(t.values()) if max(t.values()) > 0 else 0.0
    return {
        "cell": f"{rec['arch']}/{rec['shape']}/{rec['mesh']}",
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t["compute"],
        "t_memory_s": t["memory"],
        "t_collective_s": t["collective"],
        "dominant": dominant,
        "model_flops": model_flops,
        "useful_ratio": model_flops / (flops_dev * chips) if flops_dev else 0.0,
        "roofline_frac": frac,
        "peak_gib": rec["memory"]["peak_device_bytes"] / 2**30,
        "fits_hbm": rec["memory"]["peak_device_bytes"] <= HBM_BYTES,
        "wbits": rec.get("wbits", 16),
        "kvbits": rec.get("kvbits", 16),
        "hlo_flops_dev": rec["cost"]["flops"],  # body-once caveat
        "hlo_bytes_dev": rec["cost"]["bytes_accessed"],
    }


def suggestion(a: Dict) -> str:
    if not a["fits_hbm"]:
        return "over HBM: quantize weights/KV, reshard, or deepen microbatching"
    d = a["dominant"]
    if d == "collective":
        return "cut gathered bytes: resharding/EP schedule, compressed or overlapped collectives"
    if d == "memory":
        if a["kvbits"] == 16 and "decode" in a["cell"]:
            return "W4/W2 packed weights + KV4 cache (the paper's deployment)"
        return "fuse/remat to cut HBM traffic"
    if a["useful_ratio"] < 0.5:
        return "recompute/capacity overhead: trim remat or MoE capacity"
    return "compute-bound near peak"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    rows = [analyze(r) for r in load_records(path)]
    rows.sort(key=lambda r: r["cell"])
    hdr = (f"{'cell':58s} {'comp(s)':>9s} {'mem(s)':>9s} {'coll(s)':>9s} "
           f"{'dom':>6s} {'roofl':>6s} {'peakGiB':>8s} fit")
    print(hdr)
    print("-" * len(hdr))
    for a in rows:
        print(
            f"{a['cell']:58s} {a['t_compute_s']:9.4f} {a['t_memory_s']:9.4f} "
            f"{a['t_collective_s']:9.4f} {a['dominant'][:6]:>6s} "
            f"{a['roofline_frac']:6.3f} {a['peak_gib']:8.2f} "
            f"{'Y' if a['fits_hbm'] else 'N'}"
        )
    os.makedirs("results", exist_ok=True)
    with open("results/roofline_summary.json", "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n{len(rows)} cells; {sum(not a['fits_hbm'] for a in rows)} over HBM")
    worst = sorted((a for a in rows if a["fits_hbm"]), key=lambda a: a["roofline_frac"])[:6]
    print("\nworst roofline fractions (fitting cells):")
    for a in worst:
        print(f"  {a['cell']:58s} {a['roofline_frac']:.4f}  <- {suggestion(a)}")
    collb = [a for a in rows if a["dominant"] == "collective"]
    collb.sort(key=lambda a: a["t_collective_s"] / max(a["t_compute_s"], 1e-12),
               reverse=True)
    print("\nmost collective-bound:")
    for a in collb[:6]:
        ratio = a["t_collective_s"] / max(a["t_compute_s"], 1e-12)
        print(f"  {a['cell']:58s} coll/comp={ratio:8.1f}  <- {suggestion(a)}")


if __name__ == "__main__":
    main()
