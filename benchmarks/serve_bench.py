"""Serving bench: static fixed-batch vs continuous batching (§Serving).

Replays one synthetic mixed-length FCFS trace (fixed prompt length,
decode lengths drawn uniformly — the straggler regime) through both
serving modes of the quantized artifact engine, on the reference and
pallas weight backends, and reports decode-slot utilisation and
tokens/s.  Static batching processes the trace in fixed groups of
``SLOTS`` requests and decodes each group for its *longest* member;
continuous batching refills each slot the tick it frees.

  PYTHONPATH=src python -m benchmarks.serve_bench [--fast]

Reading the numbers: ``utilisation`` and ``decode_steps`` are the
hardware-independent signals — every decode step costs one full-batch
model invocation, so fewer steps for the same tokens is the TPU win.
At this reduced CPU scale the continuous path's *wall clock* is dominated
by the per-tick host sync (sample + stop check), which on real hardware
overlaps the next step's dispatch; trend it, don't read it as speedup.

Writes ``results/serve_bench.json`` (nightly CI uploads it next to the
dry-run records).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.models.registry import get_arch
from repro.serve.scheduler import static_baseline_utilisation, synthetic_trace

ARCH = "smollm-135m"
SLOTS = 4
PROMPT_LEN = 10
MAX_NEW = 16
BLOCK_TOKENS = 8
MAX_SEQ = 48
TRACE_SEED = 7


def _trace(cfg, n):
    return synthetic_trace(cfg, n, seed=TRACE_SEED, prompt_len=PROMPT_LEN,
                           max_new_low=max(1, MAX_NEW // 4),
                           max_new_high=MAX_NEW)


def _bench_continuous(qm, backend, n_requests, *, steps_per_sync=1,
                      name=None):
    eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS,
                                   block_tokens=BLOCK_TOKENS,
                                   steps_per_sync=steps_per_sync),
                   backend=backend)
    trace = _trace(qm.config, n_requests)
    # warm the compile caches outside the timed window, then reset counters
    eng.scheduler.submit(_trace(qm.config, 1)[0])
    eng.drain()
    eng.scheduler.decode_steps = 0
    eng.scheduler.busy_slot_steps = 0
    eng.scheduler.tokens_generated = 0
    eng.scheduler.host_syncs = 0
    t0 = time.perf_counter()
    for r in trace:
        eng.scheduler.submit(r)
    eng.drain()
    wall = time.perf_counter() - t0
    agg = eng.scheduler.metrics()["aggregate"]
    tokens = sum(len(r.tokens) for r in trace)
    return {
        "name": name or f"{backend}/continuous",
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "utilisation": agg["slot_utilisation"],
        "decode_steps": agg["decode_steps"],
        "host_syncs": agg["host_syncs"],
        "steps_per_sync": steps_per_sync,
    }


def sync_sweep(qm, backend="reference", n_requests=24,
               intervals=(1, 2, 4, 8), quiet=False):
    """tokens/s and host-sync count vs ``ServeConfig.steps_per_sync``.

    ``steps_per_sync=1`` is the classic one-sync-per-token scheduler; the
    in-graph window divides the decode-path host syncs by ~N at identical
    tokens and decode steps.  On CPU the wall-clock delta understates the
    TPU win (interpret-mode kernels dominate); ``host_syncs`` is the
    hardware-independent signal."""
    rows = []
    for w in intervals:
        r = _bench_continuous(qm, backend, n_requests, steps_per_sync=w,
                              name=f"{backend}/sync{w}")
        rows.append(r)
        if not quiet:
            print(f"  [serve_bench] steps_per_sync={w}: "
                  f"{r['tokens_per_s']:.1f} tok/s, {r['host_syncs']} host "
                  f"syncs / {r['decode_steps']} decode steps "
                  f"({r['tokens']} tokens)")
    return rows


def _bench_static(qm, backend, n_requests):
    eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS),
                   backend=backend)
    trace = _trace(qm.config, n_requests)
    prompts = np.stack([r.prompt for r in trace])
    # warm-up: one group at the worst-case step count
    eng.generate_static(prompts[:SLOTS], MAX_NEW)
    total_steps = 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), SLOTS):
        steps = max(r.max_new_tokens for r in trace[i:i + SLOTS])
        eng.generate_static(prompts[i:i + SLOTS], steps)
        total_steps += steps
    wall = time.perf_counter() - t0
    useful = sum(r.max_new_tokens for r in trace)
    return {
        "name": f"{backend}/static",
        "tokens": useful,
        "wall_s": wall,
        "tokens_per_s": useful / wall,
        "utilisation": static_baseline_utilisation(trace, SLOTS),
        "decode_steps": total_steps,
    }


def run(quiet: bool = False, fast: bool = False):
    arch = get_arch(ARCH, reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    qm = api.quantize(arch, params,
                      api.PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn",
                                    group=32))
    n_requests = 24 if fast else 40
    backends = ("reference",) if fast else ("reference", "pallas")
    rows = []
    for backend in backends:
        for bench in (_bench_static, _bench_continuous):
            r = bench(qm, backend, n_requests)
            rows.append(r)
            if not quiet:
                print(f"  [serve_bench] {r['name']}: "
                      f"{r['tokens_per_s']:.1f} tok/s, "
                      f"utilisation {r['utilisation']:.2f} "
                      f"({r['decode_steps']} decode steps)")
    rows.extend(sync_sweep(qm, "reference", n_requests,
                           intervals=(1, 4) if fast else (1, 2, 4, 8),
                           quiet=quiet))
    os.makedirs("results", exist_ok=True)
    with open("results/serve_bench.json", "w") as f:
        json.dump({"arch": ARCH, "slots": SLOTS, "trace_seed": TRACE_SEED,
                   "n_requests": n_requests, "rows": rows}, f, indent=1)
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--sync-interval", type=str, default=None, metavar="LIST",
                    help="run only the steps_per_sync sweep over this "
                    "comma-separated list (e.g. 1,2,4,8)")
    args = ap.parse_args(argv)
    if args.sync_interval is None:
        run(fast=args.fast)
        return
    arch = get_arch(ARCH, reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    qm = api.quantize(arch, params,
                      api.PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn",
                                    group=32))
    intervals = tuple(int(x) for x in args.sync_interval.split(","))
    rows = sync_sweep(qm, "reference", 24 if args.fast else 40,
                      intervals=intervals)
    os.makedirs("results", exist_ok=True)
    with open("results/serve_bench_sync.json", "w") as f:
        json.dump({"arch": ARCH, "slots": SLOTS, "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()
