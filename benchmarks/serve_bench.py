"""Serving bench: static fixed-batch vs continuous batching (§Serving).

Replays one synthetic mixed-length FCFS trace (fixed prompt length,
decode lengths drawn uniformly — the straggler regime) through both
serving modes of the quantized artifact engine, on the reference and
pallas weight backends, and reports decode-slot utilisation and
tokens/s.  Static batching processes the trace in fixed groups of
``SLOTS`` requests and decodes each group for its *longest* member;
continuous batching refills each slot the tick it frees.

  PYTHONPATH=src python -m benchmarks.serve_bench [--fast]

Reading the numbers: ``utilisation`` and ``decode_steps`` are the
hardware-independent signals — every decode step costs one full-batch
model invocation, so fewer steps for the same tokens is the TPU win.
At this reduced CPU scale the continuous path's *wall clock* is dominated
by the per-tick host sync (sample + stop check), which on real hardware
overlaps the next step's dispatch; trend it, don't read it as speedup.

Writes ``results/serve_bench.json`` (nightly CI uploads it next to the
dry-run records).
"""
from __future__ import annotations

import hashlib
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import api
from repro.models.registry import get_arch
from repro.serve.scheduler import static_baseline_utilisation, synthetic_trace

ARCH = "smollm-135m"
SLOTS = 4
PROMPT_LEN = 10
MAX_NEW = 16
BLOCK_TOKENS = 8
MAX_SEQ = 48
TRACE_SEED = 7
# shared-prefix cell: every prompt carries one of PREFIX_GROUPS common
# 192-token prefixes (24 full blocks) ahead of its private 10-token tail —
# long enough that the saved prefix prefill dominates admission cost
SHARED_PREFIX_TOKENS = 192
PREFIX_GROUPS = 2
PREFIX_MAX_SEQ = 224


def _trace(cfg, n):
    return synthetic_trace(cfg, n, seed=TRACE_SEED, prompt_len=PROMPT_LEN,
                           max_new_low=max(1, MAX_NEW // 4),
                           max_new_high=MAX_NEW)


def _bench_continuous(qm, backend, n_requests, *, steps_per_sync=1,
                      name=None):
    eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS,
                                   block_tokens=BLOCK_TOKENS,
                                   steps_per_sync=steps_per_sync),
                   backend=backend)
    trace = _trace(qm.config, n_requests)
    # warm the compile caches outside the timed window, then reset counters
    eng.scheduler.submit(_trace(qm.config, 1)[0])
    eng.drain()
    eng.scheduler.decode_steps = 0
    eng.scheduler.busy_slot_steps = 0
    eng.scheduler.tokens_generated = 0
    eng.scheduler.host_syncs = 0
    t0 = time.perf_counter()
    for r in trace:
        eng.scheduler.submit(r)
    eng.drain()
    wall = time.perf_counter() - t0
    agg = eng.scheduler.metrics()["aggregate"]
    tokens = sum(len(r.tokens) for r in trace)
    return {
        "name": name or f"{backend}/continuous",
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "utilisation": agg["slot_utilisation"],
        "decode_steps": agg["decode_steps"],
        "host_syncs": agg["host_syncs"],
        "steps_per_sync": steps_per_sync,
    }


def sync_sweep(qm, backend="reference", n_requests=24,
               intervals=(1, 2, 4, 8), quiet=False):
    """tokens/s and host-sync count vs ``ServeConfig.steps_per_sync``.

    ``steps_per_sync=1`` is the classic one-sync-per-token scheduler; the
    in-graph window divides the decode-path host syncs by ~N at identical
    tokens and decode steps.  On CPU the wall-clock delta understates the
    TPU win (interpret-mode kernels dominate); ``host_syncs`` is the
    hardware-independent signal."""
    rows = []
    for w in intervals:
        r = _bench_continuous(qm, backend, n_requests, steps_per_sync=w,
                              name=f"{backend}/sync{w}")
        rows.append(r)
        if not quiet:
            print(f"  [serve_bench] steps_per_sync={w}: "
                  f"{r['tokens_per_s']:.1f} tok/s, {r['host_syncs']} host "
                  f"syncs / {r['decode_steps']} decode steps "
                  f"({r['tokens']} tokens)")
    return rows


def _bench_prefix(qm, backend, n_requests, *, prefix_cache, name=None):
    eng = qm.serve(api.ServeConfig(max_seq=PREFIX_MAX_SEQ, batch_slots=SLOTS,
                                   block_tokens=BLOCK_TOKENS,
                                   prefix_cache=prefix_cache),
                   backend=backend)
    trace = synthetic_trace(qm.config, n_requests, seed=TRACE_SEED,
                            prompt_len=PROMPT_LEN,
                            max_new_low=max(1, MAX_NEW // 4),
                            max_new_high=MAX_NEW,
                            shared_prefix_tokens=SHARED_PREFIX_TOKENS,
                            n_prefix_groups=PREFIX_GROUPS)
    # warm the compiles (full prefill, decode, and the continuation
    # prefill the shared tail takes) outside the timed window, then flush
    # the cache and counters so the measured run starts cold
    for r in synthetic_trace(qm.config, 2, seed=TRACE_SEED + 1,
                             prompt_len=PROMPT_LEN,
                             shared_prefix_tokens=SHARED_PREFIX_TOKENS,
                             n_prefix_groups=1):
        eng.scheduler.submit(r)
    eng.drain()
    if eng.prefix_cache is not None:
        eng.prefix_cache.flush()
    eng.scheduler.reset_metrics()
    t0 = time.perf_counter()
    for r in trace:
        eng.scheduler.submit(r)
    eng.drain()
    wall = time.perf_counter() - t0
    agg = eng.scheduler.metrics()["aggregate"]
    eng.pool.check_invariants()
    tokens = sum(len(r.tokens) for r in trace)
    digest = hashlib.sha1(b"".join(
        np.ascontiguousarray(r.token_array()).tobytes()
        for r in trace)).hexdigest()[:16]
    return {
        "name": name or f"{backend}/prefix_{'on' if prefix_cache else 'off'}",
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "prefill_tokens_computed": agg["prefill_tokens_computed"],
        "prefill_tokens_saved": agg["prefill_tokens_saved"],
        "prefix_hit_rate": agg["prefix_hit_rate"],
        "blocks_shared": agg["blocks_shared"],
        "cow_copies": agg["cow_copies"],
        "mean_ttft_s": agg["mean_ttft_s"],
        "tokens_sha1": digest,
        "shared_prefix_tokens": SHARED_PREFIX_TOKENS,
        "n_prefix_groups": PREFIX_GROUPS,
    }


def prefix_sweep(qm, backend="reference", n_requests=24, quiet=False):
    """Prefix cache off vs on over the same shared-prefix trace.

    ``prefill_tokens_computed`` is the hardware-independent signal: with
    the cache on, only the first request of each prefix group prefills
    its prefix — everyone after continuation-prefills the private tail.
    The rows must agree on ``tokens_sha1`` (sharing is bit-exact)."""
    rows = []
    for on in (False, True):
        r = _bench_prefix(qm, backend, n_requests, prefix_cache=on)
        rows.append(r)
        if not quiet:
            hr = r["prefix_hit_rate"]
            print(f"  [serve_bench] {r['name']}: "
                  f"{r['prefill_tokens_computed']} prefill tokens computed "
                  f"({r['prefill_tokens_saved']} saved, hit rate "
                  f"{'n/a' if hr is None else f'{hr:.2f}'}), mean TTFT "
                  f"{r['mean_ttft_s'] * 1e3:.2f} ms, "
                  f"tokens sha1 {r['tokens_sha1']}")
    assert rows[0]["tokens_sha1"] == rows[1]["tokens_sha1"], \
        "prefix cache changed the emitted tokens"
    return rows


def _bench_spec(qm, backend, n_requests, *, draft=None, draft_k=0,
                name=None):
    spec = draft is not None
    eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS,
                                   block_tokens=BLOCK_TOKENS,
                                   spec_decode=spec,
                                   draft_k=draft_k if spec else 4),
                   backend=backend, draft=draft)
    trace = _trace(qm.config, n_requests)
    # warm the compile caches (prefill + decode/spec window) outside the
    # timed run, then reset the counters
    eng.scheduler.submit(_trace(qm.config, 1)[0])
    eng.drain()
    eng.scheduler.reset_metrics()
    t0 = time.perf_counter()
    for r in trace:
        eng.scheduler.submit(r)
    eng.drain()
    wall = time.perf_counter() - t0
    agg = eng.scheduler.metrics()["aggregate"]
    eng.pool.check_invariants()
    tokens = sum(len(r.tokens) for r in trace)
    digest = hashlib.sha1(b"".join(
        np.ascontiguousarray(r.token_array()).tobytes()
        for r in trace)).hexdigest()[:16]
    return {
        "name": name or (f"{backend}/spec_k{draft_k}" if spec
                         else f"{backend}/spec_off"),
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / wall,
        "decode_steps": agg["decode_steps"],
        "host_syncs": agg["host_syncs"],
        "draft_k": draft_k if spec else 0,
        "spec_windows": agg["spec_windows"],
        "spec_draft_tokens": agg["spec_draft_tokens"],
        "spec_accepted_tokens": agg["spec_accepted_tokens"],
        "acceptance_rate": agg["spec_acceptance_rate"],
        "tokens_sha1": digest,
    }


def spec_sweep(qm, backend="reference", n_requests=24, ks=(2, 4),
               draft_policy="draft-w3-rtn", quiet=False):
    """Self-drafted spec decode (draft-k/verify-1) vs plain decode.

    One artifact, zero extra checkpoints: ``api.derive_draft`` re-rounds
    the packed weights under a harsher weight-only overlay and the
    scheduler drafts k tokens with it per verify call over the *same*
    paged pool.  ``decode_steps`` is the hardware-independent signal —
    with spec decode on every decode step is one verify invocation that
    can land up to k+1 tokens per slot, so the same trace finishes in
    fewer full-batch target-model calls.  Greedy spec decode is
    token-identical: all rows must agree on ``tokens_sha1``."""
    base = _bench_spec(qm, backend, n_requests)
    rows = [base]
    draft = api.derive_draft(qm, draft_policy)
    if not quiet:
        print(f"  [serve_bench] {base['name']}: "
              f"{base['decode_steps']} decode steps "
              f"({base['tokens']} tokens); draft {draft_policy} "
              f"({draft.packed_bytes()/2**20:.2f} MiB packed)")
    for k in ks:
        r = _bench_spec(qm, backend, n_requests, draft=draft, draft_k=k)
        r["decode_steps_saved"] = base["decode_steps"] - r["decode_steps"]
        rows.append(r)
        if not quiet:
            ar = r["acceptance_rate"]
            print(f"  [serve_bench] {r['name']}: {r['decode_steps']} verify "
                  f"steps ({r['decode_steps_saved']} saved), acceptance "
                  f"{'n/a' if ar is None else f'{ar:.2f}'} "
                  f"({r['spec_accepted_tokens']}/{r['spec_draft_tokens']} "
                  f"draft tokens), tokens sha1 {r['tokens_sha1']}")
        assert r["tokens_sha1"] == base["tokens_sha1"], \
            "spec decode changed the emitted tokens"
        assert r["decode_steps"] < base["decode_steps"], \
            f"spec k={k} took {r['decode_steps']} verify steps, baseline " \
            f"{base['decode_steps']} decode steps"
    return rows


def obs_replay(qm, backend="reference", n_requests=8, quiet=False,
               trace_path="results/serve_trace.json",
               metrics_path="results/serve_metrics.prom"):
    """Observability cell: replay a small trace with tracing enabled,
    validate the Chrome trace (one complete span tree per request), and
    write the trace + Prometheus metrics next to the bench record so
    nightly CI uploads them with ``results/``."""
    from repro.obs.trace import validate_chrome_trace

    eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS,
                                   block_tokens=BLOCK_TOKENS,
                                   obs=api.ObsConfig(enabled=True)),
                   backend=backend)
    for r in _trace(qm.config, n_requests):
        eng.scheduler.submit(r)
    eng.drain()
    stats = validate_chrome_trace(eng.obs.tracer.to_chrome())
    assert stats["requests"] == n_requests, \
        f"trace has {stats['requests']} request lanes, expected {n_requests}"
    eng.obs.export_trace(trace_path)
    eng.obs.export_metrics(metrics_path)
    agg = eng.scheduler.metrics()["aggregate"]
    row = {
        "name": f"{backend}/obs",
        "trace_events": stats["events"],
        "trace_spans": stats["spans"],
        "trace_requests": stats["requests"],
        "decode_steps": agg["decode_steps"],
        "trace_path": trace_path,
        "metrics_path": metrics_path,
    }
    if not quiet:
        print(f"  [serve_bench] {row['name']}: trace valid "
              f"({stats['events']} events, {stats['spans']} spans, "
              f"{stats['requests']} request lanes) -> {trace_path}, "
              f"metrics -> {metrics_path}")
    return row


def _bench_faults(qm, backend="reference", n_requests=16, quiet=False):
    """Robustness-layer overhead cell: the same trace with ``faults=None``
    (injection branched out) vs an armed-but-empty ``FaultPlan()``.

    The rows must agree on ``tokens_sha1`` — an armed injector that never
    fires is bit-identical — and ``overhead_frac`` trends the cost of the
    per-token predicate checks (volatile on CPU; the contract is the
    token match, not the timing)."""
    walls, digests = [], []
    for faults in (None, api.FaultPlan()):
        eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS,
                                       block_tokens=BLOCK_TOKENS,
                                       faults=faults),
                       backend=backend)
        trace = _trace(qm.config, n_requests)
        eng.scheduler.submit(_trace(qm.config, 1)[0])
        eng.drain()
        eng.scheduler.reset_metrics()
        t0 = time.perf_counter()
        for r in trace:
            eng.scheduler.submit(r)
        eng.drain()
        walls.append(time.perf_counter() - t0)
        eng.pool.check_invariants()
        digests.append(hashlib.sha1(b"".join(
            np.ascontiguousarray(r.token_array()).tobytes()
            for r in trace)).hexdigest()[:16])
    assert digests[0] == digests[1], \
        "an armed (empty) fault plan changed the emitted tokens"
    row = {
        "name": f"{backend}/faults_off",
        "tokens_match": True,
        "tokens_sha1": digests[0],
        "wall_s": walls[1],
        "overhead_frac": walls[1] / walls[0] - 1.0,
    }
    if not quiet:
        print(f"  [serve_bench] {row['name']}: armed-plan tokens match "
              f"(sha1 {digests[0]}), overhead "
              f"{row['overhead_frac'] * 100:+.1f}% wall")
    return row


def _bench_static(qm, backend, n_requests):
    eng = qm.serve(api.ServeConfig(max_seq=MAX_SEQ, batch_slots=SLOTS),
                   backend=backend)
    trace = _trace(qm.config, n_requests)
    prompts = np.stack([r.prompt for r in trace])
    # warm-up: one group at the worst-case step count
    eng.generate_static(prompts[:SLOTS], MAX_NEW)
    total_steps = 0
    t0 = time.perf_counter()
    for i in range(0, len(trace), SLOTS):
        steps = max(r.max_new_tokens for r in trace[i:i + SLOTS])
        eng.generate_static(prompts[i:i + SLOTS], steps)
        total_steps += steps
    wall = time.perf_counter() - t0
    useful = sum(r.max_new_tokens for r in trace)
    return {
        "name": f"{backend}/static",
        "tokens": useful,
        "wall_s": wall,
        "tokens_per_s": useful / wall,
        "utilisation": static_baseline_utilisation(trace, SLOTS),
        "decode_steps": total_steps,
    }


def run(quiet: bool = False, fast: bool = False):
    arch = get_arch(ARCH, reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    qm = api.quantize(arch, params,
                      api.PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn",
                                    group=32))
    n_requests = 24 if fast else 40
    backends = ("reference",) if fast else ("reference", "pallas")
    rows = []
    for backend in backends:
        for bench in (_bench_static, _bench_continuous):
            r = bench(qm, backend, n_requests)
            rows.append(r)
            if not quiet:
                print(f"  [serve_bench] {r['name']}: "
                      f"{r['tokens_per_s']:.1f} tok/s, "
                      f"utilisation {r['utilisation']:.2f} "
                      f"({r['decode_steps']} decode steps)")
    rows.extend(sync_sweep(qm, "reference", n_requests,
                           intervals=(1, 4) if fast else (1, 2, 4, 8),
                           quiet=quiet))
    rows.extend(prefix_sweep(qm, "reference", n_requests, quiet=quiet))
    rows.extend(spec_sweep(qm, "reference", n_requests, quiet=quiet))
    rows.append(_bench_faults(qm, "reference", quiet=quiet))
    os.makedirs("results", exist_ok=True)
    rows.append(obs_replay(qm, "reference", quiet=quiet))
    with open("results/serve_bench.json", "w") as f:
        json.dump({"arch": ARCH, "slots": SLOTS, "trace_seed": TRACE_SEED,
                   "n_requests": n_requests, "rows": rows}, f, indent=1)
    return rows


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--sync-interval", type=str, default=None, metavar="LIST",
                    help="run only the steps_per_sync sweep over this "
                    "comma-separated list (e.g. 1,2,4,8)")
    ap.add_argument("--shared-prefix", action="store_true",
                    help="run only the prefix-cache off/on cell over the "
                    "shared-prefix trace")
    ap.add_argument("--spec-sweep", action="store_true",
                    help="run only the self-drafted speculative-decoding "
                    "cell (baseline + k sweep off one artifact)")
    args = ap.parse_args(argv)
    if (args.sync_interval is None and not args.shared_prefix
            and not args.spec_sweep):
        run(fast=args.fast)
        return
    arch = get_arch(ARCH, reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    qm = api.quantize(arch, params,
                      api.PTQConfig(r1_kind="GSR", wakv="W4A8", method="rtn",
                                    group=32))
    n_requests = 24 if args.fast else 40
    os.makedirs("results", exist_ok=True)
    if args.spec_sweep:
        rows = spec_sweep(qm, "reference", n_requests)
        with open("results/serve_bench_spec.json", "w") as f:
            json.dump({"arch": ARCH, "slots": SLOTS,
                       "trace_seed": TRACE_SEED, "rows": rows}, f, indent=1)
        return
    if args.shared_prefix:
        rows = prefix_sweep(qm, "reference", n_requests)
        with open("results/serve_bench_prefix.json", "w") as f:
            json.dump({"arch": ARCH, "slots": SLOTS,
                       "trace_seed": TRACE_SEED, "rows": rows}, f, indent=1)
        return
    intervals = tuple(int(x) for x in args.sync_interval.split(","))
    rows = sync_sweep(qm, "reference", n_requests, intervals=intervals)
    with open("results/serve_bench_sync.json", "w") as f:
        json.dump({"arch": ARCH, "slots": SLOTS, "rows": rows}, f, indent=1)


if __name__ == "__main__":
    main()
