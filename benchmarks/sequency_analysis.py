"""Sequency theory checks (paper Sec. 2.1 + 3.2).

1. The H8 sequency example from the paper (0,7,3,4,1,6,2,5).
2. Intra-column-group sequency variance: Hadamard vs RHT vs Walsh, across
   dims/groups - the quantity the paper's argument says Walsh minimises.
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import hadamard as hd


def run(quiet: bool = False):
    h8 = hd.hadamard(8)
    seq8 = hd.sequency_of_rows(h8).tolist()
    assert seq8 == [0, 7, 3, 4, 1, 6, 2, 5], seq8
    if not quiet:
        print(f"H8 sequencies (paper Sec 2.1): {seq8}")

    rows = []
    for dim in (256, 1024, 4096):
        for group in (64, 128):
            seq_h = hd.natural_sequency(dim).astype(np.float64)
            seq_rht = hd.sequency_of_rows(hd.randomized_hadamard(dim, seed=0)).astype(np.float64)
            seq_w = np.arange(dim, dtype=np.float64)

            def gvar(s):
                return float(s.reshape(dim // group, group).var(axis=1).mean())

            r = {
                "dim": dim, "group": group,
                "var_hadamard": gvar(seq_h),
                "var_rht": gvar(seq_rht),
                "var_walsh": gvar(seq_w),
            }
            rows.append(r)
            if not quiet:
                print(f"dim={dim:5d} G={group:4d}  "
                      f"var(H)={r['var_hadamard']:12.1f}  "
                      f"var(RHT)={r['var_rht']:12.1f}  "
                      f"var(Walsh)={r['var_walsh']:10.1f}  "
                      f"ratio={r['var_hadamard']/r['var_walsh']:8.1f}x")
    os.makedirs("results", exist_ok=True)
    with open("results/sequency_analysis.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    for r in run():
        print(f"sequency/dim{r['dim']}/g{r['group']},0,"
              f"varH={r['var_hadamard']:.1f};varW={r['var_walsh']:.1f}")


if __name__ == "__main__":
    main()
