"""Teacher-forced PPL per policy preset: the end-metric for policy work.

``quant_error`` measures per-rule tensor error; this closes the loop with
the quality metric the paper actually reports — held-out perplexity (+
top-1 next-token accuracy) on the synthetic data layer — for the float
baseline, every shipped :mod:`repro.quant.policy` preset, and a per-site
activation pair that isolates the tentpole question: global A8 versus A8
spent only on the R4-rotated down projections.

  PYTHONPATH=src python -m benchmarks.eval_ppl [--fast]

Writes one JSON record per policy to ``results/eval_ppl.json``; wired
into ``benchmarks.run`` and the nightly workflow.
"""
from __future__ import annotations

import json
import os

import numpy as np

from benchmarks.common import GROUP, evaluate, get_trained_model

# (name, policy factory): factories defer imports so --fast stays light.


def _policies(fast: bool):
    from repro.quant.pipeline import PTQConfig
    from repro.quant.policy import (
        PRESETS, QuantPolicy, RotationPlan, RotationSpec, SiteRule,
        get_policy,
    )

    def fit(policy):
        """Presets assume full-scale groups; refit to bench width."""
        return QuantPolicy(
            rules=tuple(
                SiteRule(**{**{f.name: getattr(r, f.name)
                               for f in r.__dataclass_fields__.values()},
                            "group": GROUP})
                for r in policy.rules),
            rotation=RotationPlan(
                r1=RotationSpec(
                    source=policy.rotation.r1.source,
                    kind=policy.rotation.r1.kind, group=GROUP,
                    seed=policy.rotation.r1.seed,
                    compose=policy.rotation.r1.compose,
                    compose_group=GROUP,
                    learn=policy.rotation.r1.learn,
                    learn_steps=min(policy.rotation.r1.learn_steps, 30)),
                r2=policy.rotation.r2, r3=policy.rotation.r3,
                r4_kind=policy.rotation.r4_kind, r4_group=GROUP,
                r4_seed=policy.rotation.r4_seed),
            act_bits=policy.act_bits, act_group=GROUP,
            act_clip=policy.act_clip, kv_bits=policy.kv_bits,
            seed=policy.seed, n_calib=policy.n_calib,
            calib_seq=policy.calib_seq, name=policy.name,
        )

    out = [("float16", None)]
    for name in sorted(PRESETS):
        if fast and name == "gsr-over-spinquant":
            continue  # Cayley optimization: the one slow preset
        out.append((name, lambda n=name: fit(get_policy(n))))
    # the tentpole pair: same W4 everywhere, A8 global vs A8 only where
    # the online R4 rotation has tamed the activation outliers
    out.append(("w4-global-a8", lambda: PTQConfig(
        r1_kind="GSR", wakv="W4A8", method="rtn", group=GROUP).to_policy()))
    out.append(("w4-a8-down-only", lambda: QuantPolicy(
        rules=(SiteRule(pattern="*down*", bits=4, group=GROUP, method="rtn",
                        act_bits=8, act_group=GROUP),
               SiteRule(pattern="*", bits=4, group=GROUP, method="rtn")),
        rotation=RotationPlan(r1=RotationSpec(kind="GSR", group=GROUP),
                              r4_kind="GH", r4_group=GROUP),
        act_bits=16, act_group=GROUP, name="w4-a8-down-only")))
    return out


def run(quiet: bool = False, fast: bool = False):
    from repro import api
    from repro.models.common import NOQUANT

    arch, params = get_trained_model(quiet=quiet)
    rows = []
    for name, factory in _policies(fast):
        if factory is None:
            rec = dict(evaluate(arch, params, NOQUANT), policy="float16",
                       packed_mib=0.0)
        else:
            qm = api.quantize(arch, params, factory())
            rec = dict(evaluate(arch, qm.params, qm.spec), policy=name,
                       packed_mib=round(qm.packed_bytes() / 2**20, 3))
        rows.append(rec)
        if not quiet:
            print(f"  {name:22s} ppl={rec['ppl']:.3f} "
                  f"top1={rec['top1']:.2f} ({rec['packed_mib']:.3f} MiB)")
    os.makedirs("results", exist_ok=True)
    with open("results/eval_ppl.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    import sys

    rows = run(quiet=False, fast="--fast" in sys.argv)
    base = rows[0]["ppl"]
    worst = max(r["ppl"] for r in rows)
    print(f"eval_ppl: float16 {base:.3f}, worst policy {worst:.3f}")


if __name__ == "__main__":
    main()
