"""Shared benchmark harness: train a small model once, evaluate PTQ variants.

The paper evaluates on Llama-2-7B + WikiText-2; this container is CPU-only
and offline, so the reproduction target is a small dense llama-family
model trained to convergence on the structured synthetic stream, PPL
measured on held-out batches, and top-1 next-token accuracy as the
zero-shot-task proxy.  What must reproduce is the paper's *orderings*
(GSR < LH < GW < GH in PPL; learned methods improved by GSR init), not
the absolute Llama-2 numbers.
"""
from __future__ import annotations

import os
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.data.synthetic import make_batch_for
from repro.models.registry import build_arch
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import make_eval_step, make_train_step

BENCH_CONFIG = ModelConfig(
    name="bench-llama",
    family="dense",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab=512,
)
SEQ = 64
GROUP = 32  # quantization group == GSR block size at bench scale
CKPT = "results/bench_model.npz"


def get_trained_model(steps: int = 400, seed: int = 0, quiet: bool = False):
    """Train (or load cached) the benchmark model. Returns (arch, params)."""
    arch = build_arch(BENCH_CONFIG)
    params = arch.init(jax.random.PRNGKey(seed), jnp.float32)
    if os.path.exists(CKPT):
        data = np.load(CKPT)
        leaves, treedef = jax.tree.flatten(params)
        loaded = [jnp.asarray(data[str(i)]) for i in range(len(leaves))]
        if all(a.shape == b.shape for a, b in zip(loaded, leaves)):
            return arch, jax.tree.unflatten(treedef, loaded)
    opt = OptConfig(lr=1e-2, warmup_steps=20, total_steps=steps)
    step = jax.jit(make_train_step(arch, opt))
    state = init_opt_state(params, opt)
    stream = SyntheticLM(BENCH_CONFIG.vocab, SEQ, seed=1)
    for i in range(steps):
        batch = {"tokens": jnp.asarray(stream.batch(i, 0, 16))}
        params, state, _, m = step(params, state, {}, batch)
        if not quiet and i % 100 == 0:
            print(f"  [bench-train] step {i} loss {float(m['loss']):.3f}")
    os.makedirs("results", exist_ok=True)
    leaves, _ = jax.tree.flatten(params)
    np.savez(CKPT, **{str(i): np.asarray(x) for i, x in enumerate(leaves)})
    return arch, params


def evaluate(arch, params, spec, n_batches: int = 8) -> Dict[str, float]:
    """Held-out PPL + top-1 next-token accuracy (the 0-shot proxy).

    Same generative process (seed=1 transition structure) as training,
    evaluated on batch indices the training loop never reaches - i.e. a
    held-out *sample*, not a different language.
    """
    ev = jax.jit(make_eval_step(arch, spec))
    stream = SyntheticLM(arch.config.vocab, SEQ, seed=1)  # same process
    nll, acc = 0.0, 0.0
    for i in range(n_batches):
        batch = {"tokens": jnp.asarray(stream.batch(100_000 + i, 0, 16))}
        m = ev(params, batch)
        nll += float(m["nll"])
        acc += float(m["top1"])
    nll /= n_batches
    acc /= n_batches
    return {"ppl": float(np.exp(nll)), "nll": nll, "top1": 100 * acc}
