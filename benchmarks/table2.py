"""Paper Table 2 (A.2 ablation): global vs local rotation for R4.

R1 in {LH, GSR} x R4 in {GH, LH, GSR} under W2A16 and W2A4.  The paper
finds local R4 helps only when activations are quantized (W2A4), and
notes local online rotation is impractical on GPU - on this TPU target it
is the MXU-shaped fast path (see kernels/grouped_rotate.py), so the
framework treats it as a first-class deployment option.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import GROUP, evaluate, get_trained_model
from repro.quant.pipeline import PTQConfig, quantize_model


def run(quiet: bool = False):
    arch, params = get_trained_model(quiet=True)
    rows = []
    for r1 in ("LH", "GSR"):
        for r4 in ("GH", "LH", "GSR"):
            row = {"r1": r1, "r4": r4}
            for bits in ("W2A16", "W2A4"):
                ptq = PTQConfig(r1_kind=r1, r4_kind=r4, wakv=bits, method="gptq",
                                group=GROUP, n_calib=4, calib_seq=64)
                qp, spec = quantize_model(arch, params, ptq)
                m = evaluate(arch, qp, spec)
                row["ppl_w2" if bits == "W2A16" else "ppl_w2a4"] = m["ppl"]
            rows.append(row)
            if not quiet:
                print(f"R1={r1:4s} R4={r4:4s} PPL(W2)={row['ppl_w2']:8.2f} "
                      f"PPL(W2A4)={row['ppl_w2a4']:8.2f}")
    os.makedirs("results", exist_ok=True)
    with open("results/table2.json", "w") as f:
        json.dump(rows, f, indent=1)
    # paper claim: local R4 helps under activation quantization
    g = {(r["r1"], r["r4"]): r for r in rows}
    for r1 in ("LH", "GSR"):
        glob = g[(r1, "GH")]["ppl_w2a4"]
        loc = min(g[(r1, "LH")]["ppl_w2a4"], g[(r1, "GSR")]["ppl_w2a4"])
        tag = "PASS" if loc <= glob * 1.02 else "fail"
        if not quiet:
            print(f"  {tag} R1={r1}: local R4 <= global R4 under W2A4 "
                  f"({loc:.2f} vs {glob:.2f})")
    return rows


def main():
    for r in run():
        print(f"table2/R1={r['r1']}/R4={r['r4']},0,"
              f"ppl_w2={r['ppl_w2']:.3f};ppl_w2a4={r['ppl_w2a4']:.3f}")


if __name__ == "__main__":
    main()
