"""Direct weight-quantization error vs rotation kind (paper Sec. 3.2).

Controlled validation of Observation #1 and the sequency argument,
independent of any trained model: rotate weight matrices with realistic
channel structure (smooth cross-channel correlation + heavy-tailed
outlier channels - the regime rotation-based PTQ exists for), quantize
at W2/W3/W4 grouped, and measure relative MSE per rotation kind.

Expected (paper): err(GSR) <= err(LH) <= err(GW) <= err(GH) on
structured/outlier weights; all rotations >> identity on outliers.

``--policy <name|all>`` sweeps shipped :mod:`repro.quant.policy`
presets instead: each preset's R1 plan (constructed, or SpinQuant-lite
learned + composed) is materialised and every distinct precision rule is
measured against the same weight suite — the nightly record that keeps
the preset recipes honest as they evolve.
"""
from __future__ import annotations

import json
import os

import numpy as np

import jax.numpy as jnp

from repro.core.rotation import Rotation, RotationKind, make_rotation
from repro.quant.qtypes import QuantConfig
from repro.quant.rtn import fake_quant_weight

DIM, OUT, GROUP = 1024, 512, 128
KINDS = ["I", "GH", "GW", "LH", "GSR"]


def make_weights(kind: str, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(DIM, OUT)).astype(np.float32)
    if kind == "gaussian":
        return w
    if kind == "outlier":
        # a few massive input channels (the LLM.int8 phenomenon)
        idx = rng.choice(DIM, size=8, replace=False)
        w[idx] *= 20.0
        return w
    if kind == "structured":
        # smooth low-frequency channel profile + outliers + noise
        t = np.linspace(0, 6 * np.pi, DIM)[:, None]
        prof = 3.0 * np.sin(t) * rng.normal(size=(1, OUT)).astype(np.float32)
        idx = rng.choice(DIM, size=8, replace=False)
        w[idx] *= 12.0
        return (w + prof).astype(np.float32)
    raise ValueError(kind)


def rel_mse(w: np.ndarray, kind: str, bits: int, seed: int) -> float:
    rot = make_rotation(kind, DIM, group=GROUP, seed=seed)
    wr = rot.inverse_dense().astype(np.float32) @ w  # front side: R^T W
    cfg = QuantConfig(bits=bits, group=GROUP, symmetric=False, mse_clip=True)
    dq = np.asarray(fake_quant_weight(jnp.asarray(wr), cfg))
    return float(((dq - wr) ** 2).sum() / (wr**2).sum())


def run(quiet: bool = False):
    rows = []
    for wkind in ("gaussian", "outlier", "structured"):
        for bits in (2, 3, 4):
            errs = {}
            for rk in KINDS:
                e = np.mean([rel_mse(make_weights(wkind, s), rk, bits, s)
                             for s in range(3)])
                errs[rk] = float(e)
            rows.append({"weights": wkind, "bits": bits, **errs})
            if not quiet:
                order = " ".join(f"{k}={errs[k]:.4f}" for k in KINDS)
                print(f"{wkind:10s} W{bits}: {order}")
    os.makedirs("results", exist_ok=True)
    with open("results/quant_error.json", "w") as f:
        json.dump(rows, f, indent=1)
    if not quiet:
        for r in rows:
            # sequency claim (GW<=GH, GSR<=LH): holds in every regime.
            ok_seq = r["GW"] <= r["GH"] * 1.02 and r["GSR"] <= r["LH"] * 1.02
            print(f"  {'PASS' if ok_seq else 'fail'} "
                  f"{r['weights']}/W{r['bits']}: sequency ordering (GW<=GH, GSR<=LH)")
            if r["weights"] == "outlier":
                # local-confinement claim: the outlier regime the paper targets.
                ok_loc = r["GSR"] <= r["GH"] * 1.02 and r["LH"] <= r["GH"] * 1.02
                print(f"  {'PASS' if ok_loc else 'fail'} "
                      f"{r['weights']}/W{r['bits']}: local<=global (paper Fig. 2)")
    return rows


def _policy_r1(policy, dim: int) -> np.ndarray:
    """Materialise a policy's R1 as a dense (dim, dim) matrix.

    Learned sources optimize SpinQuant-lite directly on the synthetic
    weight suite (few steps — this is a benchmark, not a deployment) and
    compose the constructed post-rotation exactly like the pipeline.
    """
    from repro.quant.pipeline import fit_group
    from repro.quant.spinquant import optimize_rotation

    r1s = policy.rotation.r1
    if r1s.source == "learn":
        init = make_rotation(r1s.kind, dim, group=fit_group(dim, r1s.group),
                             seed=r1s.seed).dense()
        front = [jnp.asarray(make_weights("structured", s)) for s in range(2)]
        rule = policy.rules[0]
        proxy = QuantConfig(bits=rule.bits, group=fit_group(dim, rule.group),
                            symmetric=rule.symmetric)
        base = optimize_rotation(init, front, [], proxy,
                                 steps=min(r1s.learn_steps, 30)).rotation
    else:
        base = r1s.base_matrix(dim)
        base = np.eye(dim) if base is None else base
    post = r1s.compose_matrix(dim)
    return base if post is None else base @ post


def make_activations(seed: int = 0) -> np.ndarray:
    """Synthetic GEMM-input activations with massive-outlier channels —
    the regime per-site A8 rules exist for (LLM.int8 / SpinQuant)."""
    rng = np.random.default_rng(seed + 17)
    x = rng.normal(size=(256, DIM)).astype(np.float32)
    idx = rng.choice(DIM, size=6, replace=False)
    x[:, idx] *= 25.0
    return x


def _act_rel_mse(bits: int, group: int, clip: float, seed: int) -> float:
    from repro.quant.rtn import fake_quant_act_grouped

    if bits >= 16:
        return 0.0
    x = make_activations(seed)
    cfg = QuantConfig(bits=bits, group=min(group, DIM), symmetric=True,
                      clip_ratio=clip)
    dq = np.asarray(fake_quant_act_grouped(jnp.asarray(x), cfg))
    return float(((dq - x) ** 2).sum() / (x ** 2).sum())


def run_policies(names, quiet: bool = False):
    """Weight- and activation-quant error of every distinct rule of each
    policy preset.  Each row carries the rule's *resolved* activation
    quantizer (rule override or policy default — exactly what
    ``QuantizeSpec.act_for`` serves at that site), so a per-site A8 rule
    (``*down*`` act_bits=8) produces a strictly different row than a
    policy-global A8."""
    from repro.quant.policy import PRESETS, get_policy

    rows = []
    for name in (sorted(PRESETS) if names == "all" else names.split(",")):
        policy = get_policy(name)
        r1 = _policy_r1(policy, DIM)
        rot = Rotation(kind=RotationKind.GLOBAL_HADAMARD, dim=DIM, matrix=r1)
        for ri, rule in enumerate(policy.rules):
            cfg = rule.weight_cfg(DIM)
            act_bits = (policy.act_bits if rule.act_bits is None
                        else rule.act_bits)
            act_group = (policy.act_group if rule.act_group is None
                         else rule.act_group)
            act_clip = (policy.act_clip if rule.act_clip is None
                        else rule.act_clip)
            act_err = float(np.mean([
                _act_rel_mse(act_bits, act_group, act_clip, s)
                for s in range(3)]))
            for wkind in ("gaussian", "outlier", "structured"):
                errs = []
                errs_id = []
                for s in range(3):
                    w = make_weights(wkind, s)
                    wr = rot.inverse_dense().astype(np.float32) @ w
                    dq = np.asarray(fake_quant_weight(jnp.asarray(wr), cfg))
                    errs.append(((dq - wr) ** 2).sum() / (wr ** 2).sum())
                    dqi = np.asarray(fake_quant_weight(jnp.asarray(w), cfg))
                    errs_id.append(((dqi - w) ** 2).sum() / (w ** 2).sum())
                rows.append({
                    "policy": name, "rule": ri, "pattern": rule.pattern,
                    "bits": rule.bits, "group": cfg.group,
                    "act_bits": act_bits, "act_group": act_group,
                    "weights": wkind,
                    "rel_mse": float(np.mean(errs)),
                    "rel_mse_identity": float(np.mean(errs_id)),
                    "act_rel_mse": act_err,
                })
                if not quiet:
                    r = rows[-1]
                    print(f"{name:20s} rule{ri} ({rule.pattern:8s} "
                          f"W{rule.bits}A{act_bits}) "
                          f"{wkind:10s}: {r['rel_mse']:.5f} "
                          f"(identity {r['rel_mse_identity']:.5f}, "
                          f"act {r['act_rel_mse']:.5f})")
    os.makedirs("results", exist_ok=True)
    with open("results/quant_error_policy.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--policy", default=None,
                    help="sweep policy presets ('all' or comma-separated "
                         "names) instead of the rotation-kind grid")
    args = ap.parse_args()
    if args.policy:
        for r in run_policies(args.policy, quiet=True):
            print(f"quant_error_policy/{r['policy']}/rule{r['rule']}/"
                  f"{r['weights']},0,W{r['bits']}={r['rel_mse']:.5f};"
                  f"I={r['rel_mse_identity']:.5f};"
                  f"A{r['act_bits']}={r['act_rel_mse']:.5f}")
        return
    for r in run():
        vals = ";".join(f"{k}={r[k]:.5f}" for k in KINDS)
        print(f"quant_error/{r['weights']}/W{r['bits']},0,{vals}")


if __name__ == "__main__":
    main()
