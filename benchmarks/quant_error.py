"""Direct weight-quantization error vs rotation kind (paper Sec. 3.2).

Controlled validation of Observation #1 and the sequency argument,
independent of any trained model: rotate weight matrices with realistic
channel structure (smooth cross-channel correlation + heavy-tailed
outlier channels - the regime rotation-based PTQ exists for), quantize
at W2/W3/W4 grouped, and measure relative MSE per rotation kind.

Expected (paper): err(GSR) <= err(LH) <= err(GW) <= err(GH) on
structured/outlier weights; all rotations >> identity on outliers.
"""
from __future__ import annotations

import json
import os

import numpy as np

import jax.numpy as jnp

from repro.core.rotation import make_rotation
from repro.quant.qtypes import QuantConfig
from repro.quant.rtn import fake_quant_weight

DIM, OUT, GROUP = 1024, 512, 128
KINDS = ["I", "GH", "GW", "LH", "GSR"]


def make_weights(kind: str, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(DIM, OUT)).astype(np.float32)
    if kind == "gaussian":
        return w
    if kind == "outlier":
        # a few massive input channels (the LLM.int8 phenomenon)
        idx = rng.choice(DIM, size=8, replace=False)
        w[idx] *= 20.0
        return w
    if kind == "structured":
        # smooth low-frequency channel profile + outliers + noise
        t = np.linspace(0, 6 * np.pi, DIM)[:, None]
        prof = 3.0 * np.sin(t) * rng.normal(size=(1, OUT)).astype(np.float32)
        idx = rng.choice(DIM, size=8, replace=False)
        w[idx] *= 12.0
        return (w + prof).astype(np.float32)
    raise ValueError(kind)


def rel_mse(w: np.ndarray, kind: str, bits: int, seed: int) -> float:
    rot = make_rotation(kind, DIM, group=GROUP, seed=seed)
    wr = rot.inverse_dense().astype(np.float32) @ w  # front side: R^T W
    cfg = QuantConfig(bits=bits, group=GROUP, symmetric=False, mse_clip=True)
    dq = np.asarray(fake_quant_weight(jnp.asarray(wr), cfg))
    return float(((dq - wr) ** 2).sum() / (wr**2).sum())


def run(quiet: bool = False):
    rows = []
    for wkind in ("gaussian", "outlier", "structured"):
        for bits in (2, 3, 4):
            errs = {}
            for rk in KINDS:
                e = np.mean([rel_mse(make_weights(wkind, s), rk, bits, s)
                             for s in range(3)])
                errs[rk] = float(e)
            rows.append({"weights": wkind, "bits": bits, **errs})
            if not quiet:
                order = " ".join(f"{k}={errs[k]:.4f}" for k in KINDS)
                print(f"{wkind:10s} W{bits}: {order}")
    os.makedirs("results", exist_ok=True)
    with open("results/quant_error.json", "w") as f:
        json.dump(rows, f, indent=1)
    if not quiet:
        for r in rows:
            # sequency claim (GW<=GH, GSR<=LH): holds in every regime.
            ok_seq = r["GW"] <= r["GH"] * 1.02 and r["GSR"] <= r["LH"] * 1.02
            print(f"  {'PASS' if ok_seq else 'fail'} "
                  f"{r['weights']}/W{r['bits']}: sequency ordering (GW<=GH, GSR<=LH)")
            if r["weights"] == "outlier":
                # local-confinement claim: the outlier regime the paper targets.
                ok_loc = r["GSR"] <= r["GH"] * 1.02 and r["LH"] <= r["GH"] * 1.02
                print(f"  {'PASS' if ok_loc else 'fail'} "
                      f"{r['weights']}/W{r['bits']}: local<=global (paper Fig. 2)")
    return rows


def main():
    for r in run():
        vals = ";".join(f"{k}={r[k]:.5f}" for k in KINDS)
        print(f"quant_error/{r['weights']}/W{r['bits']},0,{vals}")


if __name__ == "__main__":
    main()
