"""Kernel microbenchmarks: wall time of the jit'd reference paths (this
container is CPU - Pallas interpret timings are not meaningful) plus the
derived per-call HBM bytes and FLOPs that set the TPU roofline for each
kernel.  The Pallas kernels themselves are correctness-validated in
tests/test_kernels.py against these references.

Also benchmarks the two *artifact weight backends* the serve path can
select per launch (``QuantizedModel.serve(backend=...)``): the
"reference" dequant-on-use dispatch vs the "pallas" fused dequant-matmul
(interpret mode here; the recorded bytes terms are what matter for the
TPU roofline, the interpret wall time is tracked for trend only).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hadamard import walsh
from repro.kernels import ref
from repro.quant import pack, rtn
from repro.quant.packed import PackedWeight
from repro.quant.qtypes import QuantConfig, paper_weight_cfg

M, D, G = 512, 4096, 128


def timeit(fn, *args, iters: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(quiet: bool = False):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))
    rows = []

    f_fwht = jax.jit(lambda a: ref.fwht_ref(a))
    us = timeit(f_fwht, x)
    rows.append({"name": "fwht_ref", "us": us,
                 "hbm_bytes": 2 * M * D * 4,
                 "flops": M * D * int(np.log2(D))})

    blocks = jnp.asarray(walsh(G), jnp.float32)[None]
    f_rot = jax.jit(lambda a: ref.grouped_rotate_ref(a, blocks))
    us = timeit(f_rot, x)
    rows.append({"name": "grouped_rotate_ref(GSR)", "us": us,
                 "hbm_bytes": 2 * M * D * 4 + G * G * 4,
                 "flops": 2 * M * D * G})

    cfg = QuantConfig(bits=4, group=G, symmetric=False)
    w = jnp.asarray(rng.normal(size=(D, 1024)).astype(np.float32))
    qt = pack.pack(rtn.quantize_weight_grouped(w, cfg))
    f_dq = jax.jit(lambda a: ref.dequant_matmul_ref(a, qt))
    us = timeit(f_dq, x)
    packed_bytes = D // 2 * 1024 + 2 * (D // G) * 1024 * 4
    rows.append({"name": "dequant_matmul_ref(W4)", "us": us,
                 "hbm_bytes": M * D * 4 + packed_bytes + M * 1024 * 4,
                 "flops": 2 * M * D * 1024,
                 "bf16_weight_bytes": D * 1024 * 2, "packed_weight_bytes": packed_bytes})

    f_q = jax.jit(lambda a: ref.rtn_fake_quant_ref(a, bits=4, group=G))
    us = timeit(f_q, x)
    rows.append({"name": "rtn_fake_quant_ref(A4)", "us": us,
                 "hbm_bytes": 2 * M * D * 4, "flops": 4 * M * D})

    # Artifact weight backends: x @ PackedWeight under each dispatch path.
    h_out = 1024
    wq = jnp.asarray(rng.normal(size=(D, h_out)).astype(np.float32))
    pw = PackedWeight.from_float(wq, paper_weight_cfg(4, group=G).replace(mse_clip=False))
    for backend in ("reference", "pallas"):
        pwb = pw.replace(backend=backend)
        f_b = jax.jit(lambda a: a @ pwb)
        us = timeit(f_b, x, iters=3 if backend == "pallas" else 10)
        rows.append({
            "name": f"artifact_matmul[{backend}](W4)", "us": us,
            "hbm_bytes": M * D * 4 + pw.nbytes_packed() + M * h_out * 4,
            "flops": 2 * M * D * h_out,
            "packed_weight_bytes": pw.nbytes_packed(),
            "bf16_weight_bytes": D * h_out * 2,
            "interpreted": backend == "pallas" and jax.default_backend() != "tpu",
        })

    if not quiet:
        for r in rows:
            ai = r["flops"] / r["hbm_bytes"]
            print(f"{r['name']:28s} {r['us']:10.1f} us/call  "
                  f"bytes/call={r['hbm_bytes']:.2e}  arith-intensity={ai:.2f}")
    os.makedirs("results", exist_ok=True)
    with open("results/kernels_bench.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main():
    for r in run():
        print(f"kernel/{r['name']},{r['us']:.1f},bytes={r['hbm_bytes']:.3e}")


if __name__ == "__main__":
    main()
