"""Kernel microbenchmarks: wall time of the jit'd reference paths (this
container is CPU - Pallas interpret timings are not meaningful) plus the
derived per-call HBM bytes and FLOPs that set the TPU roofline for each
kernel.  The Pallas kernels themselves are correctness-validated in
tests/test_kernels.py against these references.

Also benchmarks the two *artifact weight backends* the serve path can
select per launch (``QuantizedModel.serve(backend=...)``): the
"reference" dequant-on-use dispatch vs the "pallas" fused dequant-matmul
(interpret mode here; the recorded bytes terms are what matter for the
TPU roofline, the interpret wall time is tracked for trend only).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.hadamard import walsh
from repro.kernels import ref
from repro.quant import pack, rtn
from repro.quant.packed import PackedWeight
from repro.quant.qtypes import QuantConfig, paper_weight_cfg

M, D, G = 512, 4096, 128


def timeit(fn, *args, iters: int = 10) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def run(quiet: bool = False):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))
    rows = []

    f_fwht = jax.jit(lambda a: ref.fwht_ref(a))
    us = timeit(f_fwht, x)
    rows.append({"name": "fwht_ref", "us": us,
                 "hbm_bytes": 2 * M * D * 4,
                 "flops": M * D * int(np.log2(D))})

    blocks = jnp.asarray(walsh(G), jnp.float32)[None]
    f_rot = jax.jit(lambda a: ref.grouped_rotate_ref(a, blocks))
    us = timeit(f_rot, x)
    rows.append({"name": "grouped_rotate_ref(GSR)", "us": us,
                 "hbm_bytes": 2 * M * D * 4 + G * G * 4,
                 "flops": 2 * M * D * G})

    cfg = QuantConfig(bits=4, group=G, symmetric=False)
    w = jnp.asarray(rng.normal(size=(D, 1024)).astype(np.float32))
    qt = pack.pack(rtn.quantize_weight_grouped(w, cfg))
    f_dq = jax.jit(lambda a: ref.dequant_matmul_ref(a, qt))
    us = timeit(f_dq, x)
    packed_bytes = D // 2 * 1024 + 2 * (D // G) * 1024 * 4
    rows.append({"name": "dequant_matmul_ref(W4)", "us": us,
                 "hbm_bytes": M * D * 4 + packed_bytes + M * 1024 * 4,
                 "flops": 2 * M * D * 1024,
                 "bf16_weight_bytes": D * 1024 * 2, "packed_weight_bytes": packed_bytes})

    f_q = jax.jit(lambda a: ref.rtn_fake_quant_ref(a, bits=4, group=G))
    us = timeit(f_q, x)
    rows.append({"name": "rtn_fake_quant_ref(A4)", "us": us,
                 "hbm_bytes": 2 * M * D * 4, "flops": 4 * M * D})

    # Artifact weight backends: x @ PackedWeight under each dispatch path.
    h_out = 1024
    wq = jnp.asarray(rng.normal(size=(D, h_out)).astype(np.float32))
    pw = PackedWeight.from_float(wq, paper_weight_cfg(4, group=G).replace(mse_clip=False))
    for backend in ("reference", "pallas"):
        pwb = pw.replace(backend=backend)
        f_b = jax.jit(lambda a: a @ pwb)
        us = timeit(f_b, x, iters=3 if backend == "pallas" else 10)
        rows.append({
            "name": f"artifact_matmul[{backend}](W4)", "us": us,
            "hbm_bytes": M * D * 4 + pw.nbytes_packed() + M * h_out * 4,
            "flops": 2 * M * D * h_out,
            "packed_weight_bytes": pw.nbytes_packed(),
            "bf16_weight_bytes": D * h_out * 2,
            "interpreted": backend == "pallas" and jax.default_backend() != "tpu",
        })

    rows.extend(_paged_attention_rows())
    rows.extend(_decode_tick_rows())

    if not quiet:
        for r in rows:
            ai = r["flops"] / r["hbm_bytes"]
            print(f"{r['name']:28s} {r['us']:10.1f} us/call  "
                  f"bytes/call={r['hbm_bytes']:.2e}  arith-intensity={ai:.2f}")
    os.makedirs("results", exist_ok=True)
    with open("results/kernels_bench.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def _paged_attention_rows():
    """Paged decode attention: the fused block-table kernel vs the
    gather-into-view baseline, float and KV4 pages.

    The hardware-independent signal is ``copied_bytes`` — the per-tick
    contiguous view the gather path materializes (and scatters back)
    that the fused path never builds — plus ``kv_bytes_read``: the fused
    kernel touches only the blocks holding real tokens."""
    from repro.kernels import ops as kops
    from repro.models import common

    s, mb, t, kv, rep, hd = 8, 8, 16, 4, 4, 64
    nb = s * mb + 1
    h = kv * rep
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(s, kv, rep, hd)).astype(np.float32))
    tables = jnp.asarray(1 + np.arange(s * mb).reshape(s, mb), jnp.int32)
    lengths = jnp.asarray(
        rng.integers(t, mb * t - 1, size=(s,)).astype(np.int32))
    knew = jnp.asarray(rng.normal(size=(s, kv, hd)).astype(np.float32))
    vnew = jnp.asarray(rng.normal(size=(s, kv, hd)).astype(np.float32))

    def pages(dtype):
        return jnp.asarray(
            rng.normal(size=(1, nb, t, kv, hd)), jnp.float32).astype(dtype)

    def scales():
        return jnp.abs(jnp.asarray(
            rng.normal(size=(1, nb, t, kv)), jnp.float32))

    rows = []
    flops = 4 * s * h * mb * t * hd  # qk + pv over the full view
    for tag, kvq in (("float", False), ("kv4", True)):
        per_tok = kv * hd * (1 if kvq else 4) + (kv * 8 if kvq else 0)
        view_bytes = 2 * s * mb * t * per_tok  # k + v contiguous views
        if kvq:
            kp = ((pages(jnp.uint8), scales(), scales()),)
            vp = ((pages(jnp.uint8), scales(), scales()),)
            k_new = (knew.astype(jnp.uint8), jnp.ones((s, kv)),
                     jnp.zeros((s, kv)))
            v_new = (vnew.astype(jnp.uint8), jnp.ones((s, kv)),
                     jnp.zeros((s, kv)))
        else:
            kp, vp = ((pages(jnp.float32),),), ((pages(jnp.float32),),)
            k_new, v_new = (knew,), (vnew,)

        fused = jax.jit(lambda kp=kp[0], vp=vp[0]: kops.paged_attention(
            q, tables, lengths, 0, kp, vp, None, k_new, v_new, None)[0])
        us = timeit(fused, iters=3)
        valid_bytes = 2 * int(np.asarray(lengths).sum()) * per_tok
        rows.append({
            "name": f"paged_attention[fused]({tag})", "us": us,
            "hbm_bytes": s * h * hd * 4 + valid_bytes + s * h * hd * 4,
            "flops": flops, "copied_bytes": 0,
            "kv_bytes_read": valid_bytes,
            "interpreted": jax.default_backend() != "tpu"})

        def gather(kp=kp[0], vp=vp[0]):
            def view(pgs):
                g = jnp.take(pgs[0][0], tables, axis=0)
                g = g.reshape(s, mb * t, kv, hd)
                if not kvq:
                    return g.astype(jnp.float32)
                sc = jnp.take(pgs[1][0], tables, axis=0).reshape(s, mb * t, kv)
                zr = jnp.take(pgs[2][0], tables, axis=0).reshape(s, mb * t, kv)
                return (g.astype(jnp.float32) - zr[..., None]) * sc[..., None]
            return common.decode_attention(
                q.reshape(s, 1, h, hd), view(kp), view(vp),
                lengths[:, None, None, None])

        us = timeit(jax.jit(gather), iters=3)
        rows.append({
            "name": f"paged_attention[gather]({tag})", "us": us,
            "hbm_bytes": s * h * hd * 4 + 2 * view_bytes + s * h * hd * 4,
            "flops": flops, "copied_bytes": view_bytes,
            "kv_bytes_read": view_bytes})
    return rows


def _decode_tick_rows():
    """End-to-end serving decode tick (all pool slots, smollm reduced):
    fused paged path vs the gather/scatter baseline, float + KV4 pools.
    ``copied_bytes`` is the per-tick gather+scatter traffic the fused
    path removes (both directions, every paged leaf)."""
    from repro.models.common import QuantizeSpec
    from repro.models.registry import get_arch
    from repro.serve.engine import ServeConfig, ServeEngine

    arch = get_arch("smollm-135m", reduced=True)
    params = arch.init(jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    rows = []
    for tag, spec in (("float", None), ("kv4", QuantizeSpec(kv_bits=4))):
        for mode, pk in (("fused", True), ("gather", False)):
            scfg = ServeConfig(max_seq=64, batch_slots=4, block_tokens=8,
                               paged_kernel=pk)
            args = (spec,) if spec is not None else ()
            eng = ServeEngine(arch, params, scfg, *args)
            for _ in range(scfg.batch_slots):
                eng.submit(rng.integers(0, arch.config.vocab, size=(12,)
                                        ).astype(np.int32), 48)
            eng.step()  # admit + one decode: compiles the tick
            pool = eng.pool
            view_bytes = sum(
                2 * np.dtype(a.dtype).itemsize * pool.n_slots
                * int(np.prod(a.shape)) // pool.n_blocks * pool.blocks_per_slot
                for a in pool.paged.values())
            tokens = np.zeros((pool.n_slots,), np.int32)
            us = timeit(
                lambda: eng.pool_step(tokens, pool.lengths, pool.tables),
                iters=3)
            rows.append({
                "name": f"decode_tick[{mode}]({tag})", "us": us,
                "hbm_bytes": max(view_bytes, 1),
                "flops": 1,  # model flops dominated; bytes are the signal
                "copied_bytes": 0 if pk else view_bytes,
                "interpreted": pk and jax.default_backend() != "tpu"})
    return rows


def main():
    for r in run():
        print(f"kernel/{r['name']},{r['us']:.1f},bytes={r['hbm_bytes']:.3e}")


if __name__ == "__main__":
    main()
